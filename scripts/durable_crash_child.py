"""Subprocess body for SIGKILL crash fuzzing (tests/test_durability.py).

Builds the standard trace-harness fixture (same dataset/model/fleet the
in-process tests use), arms the durability layer's crash injector via
``REPRO_CRASH_AFTER_EVENTS`` / ``REPRO_CRASH_MODE=sigkill``, and runs —
the process dies with a real SIGKILL at the armed journal boundary. The
parent then resumes in-process and asserts bit-identity against an
uncrashed golden run.

Usage: python scripts/durable_crash_child.py <checkpoint_dir>
       (run with the env knobs above; unarmed it runs to completion and
       prints the final journal record count)
"""
import os
import sys

os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scheduler import build_engine          # noqa: E402
from repro.core.services import FLConfig               # noqa: E402
from repro.data.synthetic import make_federated_dataset  # noqa: E402
from repro.faas.hardware import paper_fleet            # noqa: E402
from repro.models.proxy_models import build_bench_model  # noqa: E402


def child_config(checkpoint_dir: str) -> FLConfig:
    """Must match tests/test_durability.py::_sigkill_cfg_kw exactly —
    the resume validates the child's journal against this config."""
    return FLConfig(
        n_clients=10, clients_per_round=4, rounds=2, local_epochs=1,
        batch_size=5, base_step_time=0.5, round_timeout=200.0, seed=0,
        strategy="apodotiko", durability="journal",
        checkpoint_dir=checkpoint_dir)


def main() -> int:
    root = sys.argv[1]
    data = make_federated_dataset("mnist", n_clients=10, scale=0.05, seed=0)
    model = build_bench_model("mnist")
    eng = build_engine(child_config(root), model, data, list(paper_fleet(10)))
    m = eng.run()
    print(m["journal_records"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
