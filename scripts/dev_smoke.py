"""Dev scratch: exercise every SMOKE config forward/loss/decode on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import build_model

rng = jax.random.PRNGKey(0)


def run(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, axes = model.init(rng)
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model))
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    # decode one step
    if cfg.family == "encdec":
        cache_struct, _ = model.cache_struct(B, S, S)
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_struct)
        logits, caches = model.decode_step(params, caches, batch["tokens"][:, :1], jnp.int32(0))
    else:
        cache_struct, _ = model.cache_struct(B, S)
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_struct)
        logits, caches = model.decode_step(params, caches, batch["tokens"][:, :1], jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    print(f"OK {arch:28s} params={n:,} loss={float(loss):.3f}")


for arch in (sys.argv[1:] or ARCH_IDS):
    run(arch)
