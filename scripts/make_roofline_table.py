"""Render EXPERIMENTS.md tables from results/roofline.jsonl + probe.jsonl."""
import json
import sys


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def load(path):
    rows = {}
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"], r.get("mesh", "16x16"))] = r
    return rows


def main():
    roof = load("results/roofline.jsonl")
    print("| arch | shape | kind | compute ms | memory ms | collective ms | "
          "bottleneck | peak GiB/dev | MODEL/HLO | roofline MFU | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = []
    for (a, s, m), r in roof.items():
        if a not in archs:
            archs.append(a)
    for a in archs:
        for s in order:
            r = roof.get((a, s, "16x16"))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | — | skipped | — | — | — | — |")
                continue
            if r["status"] == "error":
                print(f"| {a} | {s} | ERROR | {r['error'][:40]} |")
                continue
            print(f"| {a} | {s} | {r['kind']} | {fmt_ms(r['compute_s'])} | "
                  f"{fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} | "
                  f"{r['bottleneck']} | "
                  f"{r['peak_memory_per_device']/2**30:.1f} | "
                  f"{r['useful_ratio']:.2f} | {r['mfu']:.3f} | "
                  f"{r['compile_s']:.0f}+{r.get('unroll_compile_s',0):.0f} |")




def embed_into_experiments():
    """Replace the <!-- ROOFLINE_TABLE --> marker in EXPERIMENTS.md."""
    import io, contextlib
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        main()
    table = buf.getvalue()
    path = "EXPERIMENTS.md"
    src = open(path).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in src:
        src = src.replace(marker, table.rstrip())
        open(path, "w").write(src)
        print(f"embedded {table.count(chr(10))-2} rows into {path}")
    else:
        print("marker not found; printing only")
        print(table)


if __name__ == "__main__" and "--embed" in sys.argv:
    embed_into_experiments()
elif __name__ == "__main__":
    main()
