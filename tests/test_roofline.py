"""Unit tests for the roofline extraction (HLO collective parsing, terms)."""
import pytest

from repro.configs.base import SHAPES, get_config
from repro.launch.roofline import (
    Roofline,
    _shape_bytes,
    active_params,
    collective_bytes_per_device,
    model_flops,
    ssd_inner_scan_correction,
)

HLO = """
ENTRY %main {
  %ar = bf16[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4,256]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[2,256]{1,0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %cp = bf16[8]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,1024]") == 16 * 1024 * 2
    assert _shape_bytes("f32[4,256]") == 4 * 256 * 4
    assert _shape_bytes("(f32[8], bf16[4])") == 8 * 4 + 4 * 2


def test_collective_parse_kinds_and_factors():
    out = collective_bytes_per_device(HLO, n_devices=16)
    # all-reduce: group 4 -> 2*(3/4)*payload
    assert out["all-reduce"] == pytest.approx(16 * 1024 * 2 * 2 * 3 / 4)
    # all-gather iota groups [2,8] -> group size 8 -> (7/8)*payload
    assert out["all-gather"] == pytest.approx(4 * 256 * 4 * 7 / 8)
    # reduce-scatter group 2 -> (1/2)*payload
    assert out["reduce-scatter"] == pytest.approx(2 * 256 * 4 * 1 / 2)
    assert out["collective-permute"] == pytest.approx(8 * 2)
    assert out["total"] == pytest.approx(
        out["all-reduce"] + out["all-gather"] + out["reduce-scatter"]
        + out["all-to-all"] + out["collective-permute"])


def test_dot_ops_not_counted():
    out = collective_bytes_per_device("  %d = f32[8,8] dot(%a, %b)\n", 4)
    assert out["total"] == 0.0


def test_bottleneck_selection():
    r = Roofline("a", "s", "m", 256, flops_per_device=197e12,  # 1 s compute
                 bytes_per_device=819e9 * 0.5,                  # 0.5 s memory
                 coll_bytes_per_device=50e9 * 2,                # 2 s collective
                 coll_breakdown={}, peak_memory_per_device=0,
                 model_flops_global=197e12 * 256)
    assert r.bottleneck == "collective"
    assert r.step_time_s == pytest.approx(2.0)
    assert r.useful_ratio == pytest.approx(1.0)


def test_moe_active_params_smaller_than_total():
    cfg = get_config("arctic-480b")
    total = 477_000_000_000
    act = active_params(cfg, total)
    assert act < total / 10  # 2-of-128 experts active
    dense = get_config("granite-8b")
    assert active_params(dense, 8_000_000_000) == 8_000_000_000


def test_model_flops_monotone_in_tokens():
    cfg = get_config("granite-8b")
    t4k = model_flops(cfg, SHAPES["train_4k"], 8e9)
    pre = model_flops(cfg, SHAPES["prefill_32k"], 8e9)
    dec = model_flops(cfg, SHAPES["decode_32k"], 8e9)
    assert t4k > pre > dec > 0


def test_ssd_correction_only_for_ssm_families():
    mamba = get_config("mamba2-370m")
    dense = get_config("granite-8b")
    assert ssd_inner_scan_correction(mamba, SHAPES["train_4k"], "train") > 0
    assert ssd_inner_scan_correction(dense, SHAPES["train_4k"], "train") == 0
    assert ssd_inner_scan_correction(mamba, SHAPES["decode_32k"], "decode") == 0
