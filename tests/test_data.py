"""Data pipeline tests: non-IID partitioners + synthetic federated datasets."""
import numpy as np
import pytest

from repro.data.partition import (
    dirichlet_partition,
    label_shard_partition,
    lognormal_cardinalities,
)
from repro.data.synthetic import make_federated_dataset


def test_label_shards_give_label_skew(rng):
    labels = rng.integers(0, 10, 6000)
    parts = label_shard_partition(labels, n_clients=30, shards_per_client=2,
                                  rng=rng)
    assert len(parts) == 30
    # 2 shards of sorted labels -> at most ~4 distinct classes per client
    n_classes = [len(np.unique(labels[p])) for p in parts]
    assert np.median(n_classes) <= 4
    # full cover, no overlap
    allidx = np.concatenate(parts)
    assert len(allidx) == len(set(allidx.tolist()))


def test_dirichlet_partition_sizes(rng):
    labels = rng.integers(0, 5, 4000)
    card = np.full(20, 100)
    parts = dirichlet_partition(labels, 20, alpha=0.3, rng=rng,
                                cardinalities=card)
    sizes = np.array([len(p) for p in parts])
    np.testing.assert_array_equal(sizes, card)


def test_lognormal_cardinalities_bounds(rng):
    card = lognormal_cardinalities(500, mean=200, lo=20, rng=rng)
    assert card.min() >= 20 and card.max() <= 1200
    assert 100 < np.median(card) < 400


@pytest.mark.parametrize("name", ["mnist", "femnist", "speech", "shakespeare"])
def test_federated_dataset_shapes(name):
    data = make_federated_dataset(name, n_clients=12, scale=0.1, seed=0)
    assert data.n_clients == 12
    assert data.X.shape[0] == 12 and data.y.shape[0] == 12
    assert (data.n >= 1).all() and (data.n <= data.X.shape[1]).all()
    assert len(data.eval_x) > 100
    # labels within class range
    assert data.y.max() < {"mnist": 10, "femnist": 62, "speech": 35,
                           "shakespeare": 82}[name]


def test_mnist_shard_scheme_label_skew():
    data = make_federated_dataset("mnist", n_clients=20, scale=0.2, seed=1)
    distinct = []
    for c in range(20):
        labels = data.y[c, :data.n[c]]
        distinct.append(len(np.unique(labels)))
    assert np.median(distinct) <= 4  # shard-induced label skew


def test_dataset_deterministic_by_seed():
    a = make_federated_dataset("speech", n_clients=6, scale=0.1, seed=5)
    b = make_federated_dataset("speech", n_clients=6, scale=0.1, seed=5)
    np.testing.assert_array_equal(a.X, b.X)
    np.testing.assert_array_equal(a.n, b.n)
