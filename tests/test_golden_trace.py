"""Golden round-trace equivalence: every legacy strategy produces
bit-identical runs under the old poll loop (``Controller.run``) and the
adapter-on-scheduler path (``LegacyStrategyAdapter`` on ``Scheduler``),
on both update planes — the redesign's backwards-compatibility contract.

"Bit-identical" here is literal: selections (every invocation record),
round boundaries (t_start/t_end of every round), aggregation counts,
accuracies, final global parameters, and total simulated time.
"""
import jax
import numpy as np
import pytest

from repro.core.controller import Controller, FLConfig
from repro.core.scheduler import Scheduler
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
from repro.models.proxy_models import build_bench_model

N_CLIENTS = 10
ALL_STRATEGIES = ("fedavg", "fedprox", "scaffold", "fedlesscan", "fedbuff",
                  "apodotiko")


@pytest.fixture(scope="module")
def data():
    return make_federated_dataset("mnist", n_clients=N_CLIENTS, scale=0.05,
                                  seed=0)


@pytest.fixture(scope="module")
def model():
    return build_bench_model("mnist")


def _cfg(**kw):
    base = dict(n_clients=N_CLIENTS, clients_per_round=4, rounds=3,
                local_epochs=1, batch_size=5, base_step_time=0.5,
                round_timeout=200.0, seed=0)
    base.update(kw)
    return FLConfig(**base)


def _trace(engine):
    hist = [(l.round, l.t_start, l.t_end, l.accuracy, l.n_aggregated,
             l.n_stale) for l in engine.history]
    inv = [(r.client_id, r.round, r.t_invoked, r.cold, r.duration, r.failed)
           for r in engine.platform.invocations]
    return hist, inv


def _assert_equivalent(cfg, model, data, fleet):
    legacy = Controller(cfg, model, data, list(fleet))
    m_legacy = legacy.run()
    sched = Scheduler(cfg, model, data, list(fleet))
    m_sched = sched.run()

    h_legacy, i_legacy = _trace(legacy)
    h_sched, i_sched = _trace(sched)
    assert h_sched == h_legacy          # rounds, boundaries, accuracies
    assert i_sched == i_legacy          # every selection & invocation
    assert m_sched["total_time"] == m_legacy["total_time"]
    assert m_sched["total_cost_usd"] == m_legacy["total_cost_usd"]
    for a, b in zip(jax.tree.leaves(legacy.params),
                    jax.tree.leaves(sched.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the adapter must be invisible in the reported strategy name
    assert m_sched["strategy"] == m_legacy["strategy"]
    assert m_sched["engine"] == "scheduler"
    assert m_legacy["engine"] == "controller"


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_golden_trace_device_plane(strategy, data, model):
    _assert_equivalent(_cfg(strategy=strategy, update_plane="device"),
                       model, data, paper_fleet(N_CLIENTS))


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_golden_trace_blob_plane(strategy, data, model):
    _assert_equivalent(_cfg(strategy=strategy, update_plane="blob"),
                       model, data, paper_fleet(N_CLIENTS))


def test_golden_trace_with_failures(data, model):
    """Crashed invocations (no result ever lands) take the same paths."""
    _assert_equivalent(_cfg(strategy="apodotiko", failure_rate=0.3),
                       model, data, paper_fleet(N_CLIENTS))
    _assert_equivalent(_cfg(strategy="fedavg", failure_rate=0.4),
                       model, data, paper_fleet(N_CLIENTS))


def test_golden_trace_all_failures_sync(data, model):
    """Every invocation fails: the sync round must close by drain at the
    last failure time (NOT advance to its unreached deadline)."""
    _assert_equivalent(_cfg(strategy="fedavg", failure_rate=1.0),
                       model, data, paper_fleet(N_CLIENTS))


def test_golden_trace_round_timeout(data, model):
    """A straggler fleet forces the deadline barrier: the scheduler's
    timer must close the round at exactly t0 + round_timeout."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    _assert_equivalent(_cfg(strategy="fedavg", round_timeout=30.0,
                            base_step_time=5.0), model, data, fleet)


def test_golden_trace_sim_budget(data, model):
    """max_sim_time barrier: both engines stop at the same simulated
    instant mid-run (the async budget timer path)."""
    _assert_equivalent(_cfg(strategy="apodotiko", rounds=8,
                            max_sim_time=120.0),
                       model, data, paper_fleet(N_CLIENTS))
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    _assert_equivalent(_cfg(strategy="fedavg", rounds=8, max_sim_time=120.0,
                            round_timeout=600.0), model, data, fleet)


def test_golden_trace_eval_skip(data, model):
    """eval_every>1 carries the last accuracy across unevaluated rounds
    identically in both engines."""
    _assert_equivalent(_cfg(strategy="apodotiko", eval_every=2, rounds=5),
                       model, data, paper_fleet(N_CLIENTS))
