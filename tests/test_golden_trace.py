"""Golden round-trace equivalence: every legacy strategy produces
bit-identical runs under the old poll loop (``Controller.run``) and the
adapter-on-scheduler path (``LegacyStrategyAdapter`` on ``Scheduler``),
on both update planes — the redesign's backwards-compatibility contract.

"Bit-identical" here is literal: selections (every invocation record),
round boundaries (t_start/t_end of every round), aggregation counts,
accuracies, final global parameters, and total simulated time.
"""
import pytest

from repro.core.controller import FLConfig
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet

from trace_harness import (ALL_STRATEGIES, N_CLIENTS, base_cfg_kw,
                           assert_engines_equivalent as _assert_equivalent,
                           data, model)  # noqa: F401


def _cfg(**kw):
    return FLConfig(**base_cfg_kw(**{"rounds": 3, **kw}))


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_golden_trace_device_plane(strategy, data, model):
    _assert_equivalent(_cfg(strategy=strategy, update_plane="device"),
                       model, data, paper_fleet(N_CLIENTS))


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_golden_trace_blob_plane(strategy, data, model):
    _assert_equivalent(_cfg(strategy=strategy, update_plane="blob"),
                       model, data, paper_fleet(N_CLIENTS))


def test_golden_trace_with_failures(data, model):
    """Crashed invocations (no result ever lands) take the same paths."""
    _assert_equivalent(_cfg(strategy="apodotiko", failure_rate=0.3),
                       model, data, paper_fleet(N_CLIENTS))
    _assert_equivalent(_cfg(strategy="fedavg", failure_rate=0.4),
                       model, data, paper_fleet(N_CLIENTS))


def test_golden_trace_all_failures_sync(data, model):
    """Every invocation fails: the sync round must close by drain at the
    last failure time (NOT advance to its unreached deadline)."""
    _assert_equivalent(_cfg(strategy="fedavg", failure_rate=1.0),
                       model, data, paper_fleet(N_CLIENTS))


def test_golden_trace_round_timeout(data, model):
    """A straggler fleet forces the deadline barrier: the scheduler's
    timer must close the round at exactly t0 + round_timeout."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    _assert_equivalent(_cfg(strategy="fedavg", round_timeout=30.0,
                            base_step_time=5.0), model, data, fleet)


def test_golden_trace_sim_budget(data, model):
    """max_sim_time barrier: both engines stop at the same simulated
    instant mid-run (the async budget timer path)."""
    _assert_equivalent(_cfg(strategy="apodotiko", rounds=8,
                            max_sim_time=120.0),
                       model, data, paper_fleet(N_CLIENTS))
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    _assert_equivalent(_cfg(strategy="fedavg", rounds=8, max_sim_time=120.0,
                            round_timeout=600.0), model, data, fleet)


def test_golden_trace_eval_skip(data, model):
    """eval_every>1 carries the last accuracy across unevaluated rounds
    identically in both engines."""
    _assert_equivalent(_cfg(strategy="apodotiko", eval_every=2, rounds=5),
                       model, data, paper_fleet(N_CLIENTS))
