"""Database record semantics + crash-safe persistence."""
import numpy as np
import pytest

from repro.core.database import ClientRecord, Database, ResultRecord


def _mkdb():
    db = Database()
    for cid in range(4):
        db.register_client(ClientRecord(client_id=cid, hardware="cpu1",
                                        data_cardinality=50 + cid,
                                        batch_size=10, local_epochs=5))
    return db


def test_running_clients_marked_busy():
    db = _mkdb()
    db.mark_running(1, round_=0)
    assert db.clients[1].status == "running"
    db.mark_complete(1, duration=12.5)
    assert db.clients[1].status == "idle"
    assert db.clients[1].durations == [12.5]


def test_pending_results_staleness_window():
    db = _mkdb()
    for r in (1, 3, 5):
        db.put_update(ResultRecord(client_id=0, round=r, n_samples=10,
                                   train_duration=1.0, t_available=0.0),
                      {"w": np.ones(3, np.float32)})
    pend = db.pending_results(max_staleness=2, current_round=5)
    assert sorted(p.round for p in pend) == [3, 5]


def test_aggregated_results_freed():
    db = _mkdb()
    rec = ResultRecord(client_id=0, round=0, n_samples=10, train_duration=1.0,
                       t_available=0.0)
    db.put_update(rec, {"w": np.ones(3, np.float32)})
    assert rec.update_key in db.blobs
    db.mark_aggregated([rec])
    assert rec.update_key not in db.blobs
    assert not db.pending_results(5, 0)


def test_save_load_roundtrip(tmp_path):
    db = _mkdb()
    db.mark_running(2, 0)
    db.mark_complete(2, 7.0)
    db.clients[2].booster = 1.44
    rec = ResultRecord(client_id=2, round=0, n_samples=10, train_duration=7.0,
                       t_available=7.0)
    db.put_update(rec, {"w": np.arange(6, dtype=np.float32).reshape(2, 3)})
    db.put_global_model(0, {"w": np.full((2, 3), 2.0, np.float32)})
    db.round = 1
    db.save(str(tmp_path / "db"))

    db2 = Database.load(str(tmp_path / "db"))
    assert db2.round == 1
    assert db2.clients[2].booster == pytest.approx(1.44)
    assert db2.clients[2].durations == [7.0]
    np.testing.assert_array_equal(db2.blobs[rec.update_key]["w"],
                                  np.arange(6, dtype=np.float32).reshape(2, 3))
    np.testing.assert_array_equal(db2.latest_global()["w"],
                                  np.full((2, 3), 2.0, np.float32))


def test_global_model_retention():
    db = _mkdb()
    for r in range(6):
        db.put_global_model(r, {"w": np.full(2, float(r), np.float32)})
    assert len(db.global_models) == 3  # keeps only recent history
    assert db.latest_global()["w"][0] == 5.0


def test_blobs_save_is_atomic(tmp_path, monkeypatch):
    """A crash mid-``np.savez`` must not clobber the previous good
    blobs.npz: the write goes to a temp file and only a completed write
    is renamed into place."""
    import repro.core.database as dbmod

    db = _mkdb()
    rec = ResultRecord(client_id=1, round=0, n_samples=10, train_duration=1.0,
                       t_available=1.0)
    db.put_update(rec, {"w": np.arange(4, dtype=np.float32)})
    path = str(tmp_path / "db")
    db.save(path)

    real_savez = dbmod.np.savez

    def torn_savez(f, **arrays):
        f.write(b"PK\x03\x04 torn half-written archive")
        raise RuntimeError("simulated crash mid-savez")

    db.put_update(ResultRecord(client_id=2, round=0, n_samples=10,
                               train_duration=1.0, t_available=1.0),
                  {"w": np.full(4, 9.0, np.float32)})
    monkeypatch.setattr(dbmod.np, "savez", torn_savez)
    with pytest.raises(RuntimeError, match="mid-savez"):
        db.save(path)
    monkeypatch.setattr(dbmod.np, "savez", real_savez)

    # the old archive is intact and still loads the first update
    db2 = Database.load(path)
    np.testing.assert_array_equal(db2.blobs[rec.update_key]["w"],
                                  np.arange(4, dtype=np.float32))
