"""Shared chaos-testing harness (DESIGN.md §12), extending
``trace_harness`` with fault-schedule machinery:

* ``chaos_trace(engine)`` — the fault-aware observable trace: everything
  ``trace()`` sees plus per-invocation phase attribution, zombie/loss
  flags, timeout kills, and cancellations.
* ``run_chaos_pair`` — Controller-vs-Scheduler bit-identity under one
  seeded ``fault_profile`` (the cross-engine chaos contract: identical
  schedules must produce identical traces on both engines), plus the
  leak/consistency invariants on both.
* ``assert_no_leaks`` — after a crash storm, no leaked update-store
  rows, no stale blob entries, no dead in-flight registry entries.
* ``assert_fleet_consistent`` — FleetStore slot-map/free-list
  consistency (disjoint, exhaustive, id-coherent).

Imported by tests/test_chaos.py; the self-tests at the bottom keep the
harness itself honest.
"""
import numpy as np

from trace_harness import (N_CLIENTS, base_cfg_kw, data,  # noqa: F401
                           model, trace, assert_params_equal)

from repro.core.controller import Controller, FLConfig
from repro.core.scheduler import Scheduler
from repro.faas.hardware import paper_fleet


def chaos_trace(engine):
    """``trace()`` plus the fault-attribution fields — the bit-identity
    unit for chaos runs."""
    hist, inv = trace(engine)
    faults = [(r.client_id, r.round, r.failed_phase, r.lost, r.timed_out,
               r.cancelled) for r in engine.platform.invocations]
    return hist, inv, faults


def assert_no_leaks(engine):
    """Crash-storm hygiene: every in-flight registry entry is live, and
    every allocated update row / stored blob is reachable from either an
    un-aggregated result or a live un-landed payload."""
    live_rows, live_blobs = set(), set()
    for cid, invs in engine.inflight.items():
        assert invs, f"empty inflight bucket leaked for client {cid}"
        for inv in invs:
            assert not inv.done, \
                f"settled invocation leaked in registry for client {cid}"
            if not inv.payload.landed:
                if inv.payload.row >= 0:
                    live_rows.add(inv.payload.row)
                if inv.payload.blob is not None:
                    live_blobs.add(id(inv.payload.blob))

    db = engine.db
    pending_rows = {r.update_row for r in db.results
                    if not r.aggregated and r.update_row >= 0}
    store = getattr(engine, "store", None)
    if store is not None and engine.update_plane == "device":
        free = list(store._free)
        assert len(free) == len(set(free)), "duplicate free-list entries"
        allocated = set(range(store.capacity)) - set(free)
        assert allocated == pending_rows | live_rows, (
            f"leaked update rows: {sorted(allocated - pending_rows - live_rows)}"
            f" / lost rows: {sorted((pending_rows | live_rows) - allocated)}")
    # blob plane: the blob dict holds exactly the un-aggregated updates
    # plus the retained global models (in-flight payload blobs live only
    # on the Inflight entry until they land)
    expected = {r.update_key for r in db.results
                if not r.aggregated and r.update_key}
    expected |= set(db.global_models.values())
    assert set(db.blobs) == expected, (
        f"leaked blobs: {sorted(set(db.blobs) - expected)}"
        f" / lost blobs: {sorted(expected - set(db.blobs))}")


def assert_fleet_consistent(engine):
    """FleetStore invariants: the slot map and the free list partition
    the capacity, and every mapped slot carries its own id."""
    db = engine.db
    if not db.columnar:
        return
    fleet = db.fleet
    free = list(fleet._free)
    assert len(free) == len(set(free)), "duplicate fleet free-list entries"
    active = set(np.flatnonzero(fleet.active).tolist())
    assert active.isdisjoint(free), "slot both active and free"
    assert active | set(free) == set(range(fleet.capacity)), \
        "slots neither active nor free"
    assert set(fleet._slot.values()) == active, "slot map out of sync"
    for cid, slot in fleet._slot.items():
        assert int(fleet.ids[slot]) == int(cid), "slot id mismatch"


def assert_chaos_invariants(engine):
    assert_no_leaks(engine)
    assert_fleet_consistent(engine)


# ---------------------------------------------- crash-point fuzzing (§14)
def durable_cfg(root, **cfg_kw) -> FLConfig:
    """A journal-armed config writing to ``root``."""
    kw = dict(cfg_kw)
    kw.setdefault("durability", "journal")
    kw["checkpoint_dir"] = str(root)
    return FLConfig(**kw)


def golden_durable_run(cfg_kw, model, data, root, fleet=None):
    """The uncrashed reference: one durability-on run to completion.
    Returns (engine, metrics, journal bytes)."""
    import os
    n = cfg_kw.get("n_clients", N_CLIENTS)
    fl = list(fleet) if fleet is not None else list(paper_fleet(n))
    from repro.core.scheduler import build_engine
    eng = build_engine(durable_cfg(root, **cfg_kw), model, data, fl)
    m = eng.run()
    with open(os.path.join(str(root), "journal.wal"), "rb") as f:
        jbytes = f.read()
    return eng, m, jbytes


def crash_resume_trace(cfg_kw, model, data, root, crash_after, fleet=None):
    """Kill a durable run right after journal record ``crash_after`` is
    processed, then resume it from snapshot + journal and run to
    completion. Returns (resumed engine, metrics, journal bytes)."""
    import os
    from repro.durability import SimulatedCrash, resume_durable
    n = cfg_kw.get("n_clients", N_CLIENTS)
    fl = list(fleet) if fleet is not None else list(paper_fleet(n))
    from repro.core.scheduler import build_engine
    eng = build_engine(durable_cfg(root, **cfg_kw), model, data, list(fl))
    eng.durability.crash_after = crash_after
    try:
        eng.run()
        raise AssertionError(
            f"run finished before the armed crash point {crash_after}")
    except SimulatedCrash:
        pass
    resumed = resume_durable(durable_cfg(root, **cfg_kw), model, data,
                             list(fl))
    m = resumed.run()
    with open(os.path.join(str(root), "journal.wal"), "rb") as f:
        jbytes = f.read()
    return resumed, m, jbytes


def assert_resume_identical(gold_eng, gold_m, gold_bytes, eng, m, jbytes):
    """The tentpole contract: a crashed-and-resumed run is bit-identical
    to the uncrashed one — observable trace, params, simulated clock,
    and the journal itself — and leaks nothing."""
    assert chaos_trace(eng) == chaos_trace(gold_eng)
    assert m["history"] == gold_m["history"]
    assert m["total_time"] == gold_m["total_time"]
    assert jbytes == gold_bytes, "resumed journal differs from golden"
    assert_params_equal(eng.params, gold_eng.params)
    assert_chaos_invariants(eng)


def run_crash_sweep(cfg_kw, model, data, tmp_path, ks=None, fleet=None):
    """Crash-at-every-boundary fuzz: golden run once, then for each
    boundary ``k`` (default: all of them) kill-and-resume and assert
    bit-identity. Returns the number of boundaries exercised."""
    gold_eng, gold_m, gold_bytes = golden_durable_run(
        cfg_kw, model, data, tmp_path / "golden", fleet=fleet)
    n_records = gold_m["journal_records"]
    assert n_records > 0
    if ks is None:
        ks = range(1, n_records + 1)
    ks = [k for k in ks if 1 <= k <= n_records]
    for k in ks:
        eng, m, jbytes = crash_resume_trace(
            cfg_kw, model, data, tmp_path / f"crash_{k}", k, fleet=fleet)
        assert_resume_identical(gold_eng, gold_m, gold_bytes,
                                eng, m, jbytes)
    return len(ks)


def spot_ks(n_records, n_points=5):
    """A small spread of crash boundaries: the first records, the middle,
    and the tail (where round-close markers and run_end live)."""
    ks = {1, 2, n_records // 2, n_records - 1, n_records}
    step = max(1, n_records // n_points)
    ks.update(range(1, n_records + 1, step))
    return sorted(k for k in ks if 1 <= k <= n_records)


def run_chaos_pair(cfg_kw, model, data, fleet=None):
    """Run the same seeded fault schedule through both engines and assert
    bit-identical chaos traces + the post-run invariants. Recovery knobs
    must be off (they are scheduler-only). Returns (legacy, sched)."""
    n = cfg_kw.get("n_clients", N_CLIENTS)
    cfg = FLConfig(**cfg_kw)
    assert not (cfg.invocation_timeout or cfg.retry_budget
                or cfg.quarantine_threshold or cfg.quorum_fraction < 1.0), \
        "recovery is scheduler-only; cross-engine runs must disable it"
    fl = list(fleet) if fleet is not None else list(paper_fleet(n))
    legacy = Controller(cfg, model, data, list(fl))
    m_legacy = legacy.run()
    sched = Scheduler(FLConfig(**cfg_kw), model, data, list(fl))
    m_sched = sched.run()
    assert chaos_trace(sched) == chaos_trace(legacy)
    assert m_sched["total_time"] == m_legacy["total_time"]
    assert m_sched["n_failures"] == m_legacy["n_failures"]
    assert m_sched["failures_by_phase"] == m_legacy["failures_by_phase"]
    assert_params_equal(legacy.params, sched.params)
    for eng in (legacy, sched):
        assert_chaos_invariants(eng)
    return legacy, sched


# ----------------------------------------------------- harness self-tests
def test_chaos_trace_extends_trace(data, model):
    eng = Scheduler(FLConfig(**base_cfg_kw(strategy="fedavg")), model, data,
                    list(paper_fleet(N_CLIENTS)))
    hist, inv, faults = chaos_trace(eng)
    assert hist == [] and inv == [] and faults == []


def test_invariants_hold_on_clean_run(data, model):
    eng = Scheduler(FLConfig(**base_cfg_kw(strategy="fedavg")), model, data,
                    list(paper_fleet(N_CLIENTS)))
    eng.run()
    assert_chaos_invariants(eng)


def test_run_chaos_pair_rejects_recovery_configs(data, model):
    import pytest
    with pytest.raises(AssertionError, match="scheduler-only"):
        run_chaos_pair(base_cfg_kw(strategy="fedavg", retry_budget=2),
                       model, data)


def test_crash_resume_smoke(tmp_path, data, model):
    """Harness self-test: one crash point on a one-round run resumes
    bit-identically (the full sweeps live in tests/test_durability.py)."""
    kw = base_cfg_kw(strategy="fedavg", rounds=1)
    assert run_crash_sweep(kw, model, data, tmp_path, ks=[2]) == 1
