"""Shared chaos-testing harness (DESIGN.md §12), extending
``trace_harness`` with fault-schedule machinery:

* ``chaos_trace(engine)`` — the fault-aware observable trace: everything
  ``trace()`` sees plus per-invocation phase attribution, zombie/loss
  flags, timeout kills, and cancellations.
* ``run_chaos_pair`` — Controller-vs-Scheduler bit-identity under one
  seeded ``fault_profile`` (the cross-engine chaos contract: identical
  schedules must produce identical traces on both engines), plus the
  leak/consistency invariants on both.
* ``assert_no_leaks`` — after a crash storm, no leaked update-store
  rows, no stale blob entries, no dead in-flight registry entries.
* ``assert_fleet_consistent`` — FleetStore slot-map/free-list
  consistency (disjoint, exhaustive, id-coherent).

Imported by tests/test_chaos.py; the self-tests at the bottom keep the
harness itself honest.
"""
import numpy as np

from trace_harness import (N_CLIENTS, base_cfg_kw, data,  # noqa: F401
                           model, trace, assert_params_equal)

from repro.core.controller import Controller, FLConfig
from repro.core.scheduler import Scheduler
from repro.faas.hardware import paper_fleet


def chaos_trace(engine):
    """``trace()`` plus the fault-attribution fields — the bit-identity
    unit for chaos runs."""
    hist, inv = trace(engine)
    faults = [(r.client_id, r.round, r.failed_phase, r.lost, r.timed_out,
               r.cancelled) for r in engine.platform.invocations]
    return hist, inv, faults


def assert_no_leaks(engine):
    """Crash-storm hygiene: every in-flight registry entry is live, and
    every allocated update row / stored blob is reachable from either an
    un-aggregated result or a live un-landed payload."""
    live_rows, live_blobs = set(), set()
    for cid, invs in engine.inflight.items():
        assert invs, f"empty inflight bucket leaked for client {cid}"
        for inv in invs:
            assert not inv.done, \
                f"settled invocation leaked in registry for client {cid}"
            if not inv.payload.landed:
                if inv.payload.row >= 0:
                    live_rows.add(inv.payload.row)
                if inv.payload.blob is not None:
                    live_blobs.add(id(inv.payload.blob))

    db = engine.db
    pending_rows = {r.update_row for r in db.results
                    if not r.aggregated and r.update_row >= 0}
    store = getattr(engine, "store", None)
    if store is not None and engine.update_plane == "device":
        free = list(store._free)
        assert len(free) == len(set(free)), "duplicate free-list entries"
        allocated = set(range(store.capacity)) - set(free)
        assert allocated == pending_rows | live_rows, (
            f"leaked update rows: {sorted(allocated - pending_rows - live_rows)}"
            f" / lost rows: {sorted((pending_rows | live_rows) - allocated)}")
    # blob plane: the blob dict holds exactly the un-aggregated updates
    # plus the retained global models (in-flight payload blobs live only
    # on the Inflight entry until they land)
    expected = {r.update_key for r in db.results
                if not r.aggregated and r.update_key}
    expected |= set(db.global_models.values())
    assert set(db.blobs) == expected, (
        f"leaked blobs: {sorted(set(db.blobs) - expected)}"
        f" / lost blobs: {sorted(expected - set(db.blobs))}")


def assert_fleet_consistent(engine):
    """FleetStore invariants: the slot map and the free list partition
    the capacity, and every mapped slot carries its own id."""
    db = engine.db
    if not db.columnar:
        return
    fleet = db.fleet
    free = list(fleet._free)
    assert len(free) == len(set(free)), "duplicate fleet free-list entries"
    active = set(np.flatnonzero(fleet.active).tolist())
    assert active.isdisjoint(free), "slot both active and free"
    assert active | set(free) == set(range(fleet.capacity)), \
        "slots neither active nor free"
    assert set(fleet._slot.values()) == active, "slot map out of sync"
    for cid, slot in fleet._slot.items():
        assert int(fleet.ids[slot]) == int(cid), "slot id mismatch"


def assert_chaos_invariants(engine):
    assert_no_leaks(engine)
    assert_fleet_consistent(engine)


def run_chaos_pair(cfg_kw, model, data, fleet=None):
    """Run the same seeded fault schedule through both engines and assert
    bit-identical chaos traces + the post-run invariants. Recovery knobs
    must be off (they are scheduler-only). Returns (legacy, sched)."""
    n = cfg_kw.get("n_clients", N_CLIENTS)
    cfg = FLConfig(**cfg_kw)
    assert not (cfg.invocation_timeout or cfg.retry_budget
                or cfg.quarantine_threshold or cfg.quorum_fraction < 1.0), \
        "recovery is scheduler-only; cross-engine runs must disable it"
    fl = list(fleet) if fleet is not None else list(paper_fleet(n))
    legacy = Controller(cfg, model, data, list(fl))
    m_legacy = legacy.run()
    sched = Scheduler(FLConfig(**cfg_kw), model, data, list(fl))
    m_sched = sched.run()
    assert chaos_trace(sched) == chaos_trace(legacy)
    assert m_sched["total_time"] == m_legacy["total_time"]
    assert m_sched["n_failures"] == m_legacy["n_failures"]
    assert m_sched["failures_by_phase"] == m_legacy["failures_by_phase"]
    assert_params_equal(legacy.params, sched.params)
    for eng in (legacy, sched):
        assert_chaos_invariants(eng)
    return legacy, sched


# ----------------------------------------------------- harness self-tests
def test_chaos_trace_extends_trace(data, model):
    eng = Scheduler(FLConfig(**base_cfg_kw(strategy="fedavg")), model, data,
                    list(paper_fleet(N_CLIENTS)))
    hist, inv, faults = chaos_trace(eng)
    assert hist == [] and inv == [] and faults == []


def test_invariants_hold_on_clean_run(data, model):
    eng = Scheduler(FLConfig(**base_cfg_kw(strategy="fedavg")), model, data,
                    list(paper_fleet(N_CLIENTS)))
    eng.run()
    assert_chaos_invariants(eng)


def test_run_chaos_pair_rejects_recovery_configs(data, model):
    import pytest
    with pytest.raises(AssertionError, match="scheduler-only"):
        run_chaos_pair(base_cfg_kw(strategy="fedavg", retry_budget=2),
                       model, data)
