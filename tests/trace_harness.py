"""Shared differential round-trace harness (DESIGN.md §11).

Every golden-trace suite in this repo asserts the same contract — two
configurations of the runtime produce *bit-identical* runs: selections
(every invocation record), round boundaries, aggregation counts,
accuracies, final global parameters, and total simulated time. This
module is the single home for that machinery:

* ``data`` / ``model`` — the module-scoped MNIST fixtures every suite
  imports (``from trace_harness import data, model  # noqa: F401``).
* ``trace(engine)`` — the canonical observable trace.
* ``assert_engines_equivalent`` — Controller-vs-Scheduler equivalence
  (the reactive redesign's backwards-compatibility contract).
* ``run_flag_pair`` — generic "run once per flag value, assert the
  common observables bit-equal" helper backing the control-plane and
  data-plane suites (each adds its own plane-specific asserts on top).
* ``det_fleet`` / ``megastep_cfg`` / ``assert_fused_matches_stepwise``
  — the fused-megastep differential layer: a zero-variability fleet
  plus a deep end-state comparison (fleet columns, device score state,
  update-store free list, trainer RNG key) between ``megastep=fused``
  and the stepwise event-driven oracle.
"""
import numpy as np
import pytest

import jax

from repro.core.controller import Controller, FLConfig
from repro.core.scheduler import Scheduler
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HardwareProfile, paper_fleet
from repro.models.proxy_models import build_bench_model

N_CLIENTS = 10
ALL_STRATEGIES = ("fedavg", "fedprox", "scaffold", "fedlesscan", "fedbuff",
                  "apodotiko")
REACTIVE = ("apodotiko-hedge", "apodotiko-adaptive")


@pytest.fixture(scope="module")
def data():
    return make_federated_dataset("mnist", n_clients=N_CLIENTS, scale=0.05,
                                  seed=0)


@pytest.fixture(scope="module")
def model():
    return build_bench_model("mnist")


def base_cfg_kw(**kw):
    """The shared golden-trace config: small fleet, short rounds, fixed
    seed. Suites override per-test (rounds, strategy, planes, ...)."""
    base = dict(n_clients=N_CLIENTS, clients_per_round=4, rounds=2,
                local_epochs=1, batch_size=5, base_step_time=0.5,
                round_timeout=200.0, seed=0)
    base.update(kw)
    return base


def trace(engine):
    """Everything externally observable about a run, as plain tuples."""
    hist = [(l.round, l.t_start, l.t_end, l.accuracy, l.n_aggregated,
             l.n_stale) for l in engine.history]
    inv = [(r.client_id, r.round, r.t_invoked, r.cold, r.duration, r.failed)
           for r in engine.platform.invocations]
    return hist, inv


def assert_params_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def assert_engines_equivalent(cfg, model, data, fleet):
    """Legacy poll loop vs adapter-on-scheduler: bit-identical runs."""
    legacy = Controller(cfg, model, data, list(fleet))
    m_legacy = legacy.run()
    sched = Scheduler(cfg, model, data, list(fleet))
    m_sched = sched.run()

    h_legacy, i_legacy = trace(legacy)
    h_sched, i_sched = trace(sched)
    assert h_sched == h_legacy          # rounds, boundaries, accuracies
    assert i_sched == i_legacy          # every selection & invocation
    assert m_sched["total_time"] == m_legacy["total_time"]
    assert m_sched["total_cost_usd"] == m_legacy["total_cost_usd"]
    assert_params_equal(legacy.params, sched.params)
    # the adapter must be invisible in the reported strategy name
    assert m_sched["strategy"] == m_legacy["strategy"]
    assert m_sched["engine"] == "scheduler"
    assert m_legacy["engine"] == "controller"


def run_flag_pair(cfg_kw, flag, values, model, data, engine_cls=Scheduler,
                  fleet=None):
    """One run per ``flag`` value; assert the common observables (trace,
    total simulated time, final params) bit-equal, then hand the engines
    and metrics back for plane-specific asserts. Returns
    ``{value: (engine, metrics)}``."""
    n = cfg_kw.get("n_clients", N_CLIENTS)
    runs = {}
    for v in values:
        fl = list(fleet) if fleet is not None else list(paper_fleet(n))
        eng = engine_cls(FLConfig(**{**cfg_kw, flag: v}), model, data, fl)
        runs[v] = (eng, eng.run())
    first, m_first = runs[values[0]]
    for v in values[1:]:
        other, m_other = runs[v]
        assert trace(first) == trace(other)
        assert m_first["total_time"] == m_other["total_time"]
        assert_params_equal(first.params, other.params)
    return runs


# ------------------------------------------------------- megastep layer
def det_fleet(n, speeds=(1.0, 1.45, 1.9)):
    """Zero-variability hardware: invocation durations become pure
    functions of (profile, step count), the precondition for the fused
    megastep's eligibility proof."""
    return [HardwareProfile(f"det{i % len(speeds)}",
                            speed=speeds[i % len(speeds)], vcpus=1.0,
                            mem_gib=2.0, variability=0.0)
            for i in range(n)]


def megastep_cfg(**kw):
    """A config the fused path actually engages on: deterministic top-k
    selection, CR gate = full cohort, no eval/checkpoint barriers, and a
    keep-warm window long enough that no instance ever goes cold."""
    base = dict(n_clients=N_CLIENTS, clients_per_round=4, rounds=8,
                local_epochs=1, batch_size=5, base_step_time=0.5,
                strategy="apodotiko-topk", concurrency_ratio=1.0,
                eval_every=0, keep_warm=1e9, seed=0)
    base.update(kw)
    return base


def assert_fleet_state_equal(a, b):
    """Deep end-state equality between two engines: columnar fleet
    columns (f64 EMA + f32 mirrors, status, invocation counts, duration
    rings), the flushed device score state, the update-store free list,
    and the trainer's RNG key."""
    fa, fb = a.db.fleet, b.db.fleet
    for col in ("ema_num", "ema_den", "ema_num32", "ema_den32", "booster",
                "status", "n_invocations", "n_failures", "dur_len"):
        assert np.array_equal(getattr(fa, col), getattr(fb, col)), col
    assert np.array_equal(fa.durations, fb.durations)
    fa._flush_device()
    fb._flush_device()
    for col in ("num", "den", "booster", "eligible", "ever"):
        assert np.array_equal(np.asarray(getattr(fa._dev, col)),
                              np.asarray(getattr(fb._dev, col))), col
    sa, sb = getattr(a, "store", None), getattr(b, "store", None)
    if sa is not None and sb is not None:
        assert sa._free == sb._free
    assert np.array_equal(np.asarray(a.trainer._key),
                          np.asarray(b.trainer._key))


def assert_fused_matches_stepwise(cfg_kw, model, data, fleet=None,
                                  min_fused_rounds=0):
    """The megastep differential contract: a ``megastep=fused`` run must
    be bit-identical — trace, simulated time, params, and (on the
    columnar plane) the full fleet/device/store end state — to the
    stepwise event-driven oracle, whether or not the fused path ever
    engaged. ``min_fused_rounds > 0`` additionally demands engagement.
    Returns ``(m_stepwise, m_fused)``."""
    n = cfg_kw.get("n_clients", N_CLIENTS)
    runs = {}
    for mode in ("stepwise", "fused"):
        fl = list(fleet) if fleet is not None else det_fleet(n)
        eng = Scheduler(FLConfig(**{**cfg_kw, "megastep": mode}), model,
                        data, fl)
        runs[mode] = (eng, eng.run())
    step, m_step = runs["stepwise"]
    fused, m_fused = runs["fused"]
    assert m_step["megastep_rounds"] == 0
    assert m_fused["megastep_rounds"] >= min_fused_rounds, \
        m_fused["megastep_fallback_reason"]
    assert trace(fused) == trace(step)
    assert m_fused["total_time"] == m_step["total_time"]
    assert m_fused["total_cost_usd"] == m_step["total_cost_usd"]
    assert_params_equal(step.params, fused.params)
    if step.db.columnar and fused.db.columnar:
        assert_fleet_state_equal(step, fused)
    return m_step, m_fused


# ----------------------------------------------------- harness self-tests
def test_det_fleet_is_deterministic_hardware():
    fleet = det_fleet(7)
    assert len(fleet) == 7
    assert all(hw.variability == 0.0 for hw in fleet)
    assert fleet[0].speed == fleet[3].speed          # profiles cycle


def test_megastep_cfg_engagement_preconditions():
    kw = megastep_cfg(rounds=3)
    cfg = FLConfig(**kw)
    assert cfg.strategy == "apodotiko-topk"
    assert cfg.concurrency_ratio == 1.0
    assert cfg.eval_every == 0 and cfg.rounds == 3
    assert cfg.keep_warm >= 1e9


def test_trace_shapes_on_fresh_engine(data, model):
    eng = Scheduler(FLConfig(**base_cfg_kw(strategy="fedavg")), model, data,
                    list(paper_fleet(N_CLIENTS)))
    hist, inv = trace(eng)
    assert hist == [] and inv == []      # nothing ran yet
