"""FleetStore unit tests: columnar membership (id->slot map, free-list,
registration order), duration windows, incremental score terms, bulk ops,
checkpoint state round-trip, and the client-churn storms at scale that
the PR 3/PR 4 `remove_clients` fixes feed into (DESIGN.md §10)."""
import numpy as np
import pytest

from repro.core.database import ClientRecord, Database
from repro.core.fleet_store import IDLE, RUNNING, FleetStore
from repro.core.scoring import calculate_score, decay_rate


def _store(n=8, card=100, batch=10, epochs=5):
    fs = FleetStore()
    for cid in range(n):
        fs.add(cid, card, batch, epochs)
    return fs


# -------------------------------------------------------------- membership
def test_add_remove_slot_map_consistent():
    fs = _store(6)
    assert len(fs) == 6
    assert fs.client_ids() == list(range(6))
    assert fs.remove(3) and not fs.remove(3)
    assert not fs.has(3) and len(fs) == 5
    assert fs.client_ids() == [0, 1, 2, 4, 5]
    # the freed slot is recycled but the re-registered id orders LAST,
    # like a dict pop + re-insert
    fs.add(99, 1, 1, 1)
    assert fs.client_ids() == [0, 1, 2, 4, 5, 99]
    for cid in fs.client_ids():
        assert fs.ids[fs.slot_of(cid)] == cid


def test_reregister_existing_id_keeps_order_resets_state():
    """Overwriting a live id mirrors dict assignment: position kept,
    record state reset."""
    fs = _store(4)
    fs.mark_running(1, 0)
    fs.mark_complete(1, 7.5)
    fs.add(1, 200, 20, 2)
    assert fs.client_ids() == [0, 1, 2, 3]
    s = fs.slot_of(1)
    assert fs.n_invocations[s] == 0 and fs.dur_len[s] == 0
    assert fs.cardinality[s] == 200


def test_capacity_growth_and_free_list():
    fs = FleetStore(capacity=2)
    for cid in range(50):
        fs.add(cid, 10, 5, 1)
    assert len(fs) == 50 and fs.capacity >= 50
    active = {fs.slot_of(c) for c in range(50)}
    assert len(active) == 50
    assert not (active & set(fs._free))


def test_add_batch_matches_sequential_adds():
    fs1 = FleetStore()
    fs1.add_batch(np.arange(5), np.array([10, 20, 30, 40, 50]), 10, 5)
    fs2 = _store(0)
    for cid, card in enumerate((10, 20, 30, 40, 50)):
        fs2.add(cid, card, 10, 5)
    assert fs1.client_ids() == fs2.client_ids()
    for c in range(5):
        assert fs1.cardinality[fs1.slot_of(c)] == \
            fs2.cardinality[fs2.slot_of(c)]
    with pytest.raises(ValueError):
        fs1.add_batch([3], [1], 1, 1)        # ids must be fresh


def test_remove_batch_matches_sequential_removes():
    """Bulk removal must be indistinguishable from sequential ``remove``
    calls: same free-list order (so later adds recycle the same slots),
    same columns, same survivors."""
    def _build():
        fs = FleetStore()
        fs.add_batch(np.arange(10), np.arange(10) + 100, 10, 5)
        return fs
    bulk, seq = _build(), _build()
    victims = [7, 2, 5, 2, 99]               # dupes + unknown ids skipped
    assert bulk.remove_batch(victims) == [7, 2, 5]
    for cid in victims:
        seq.remove(cid)
    assert bulk._slot == seq._slot
    assert bulk._free == seq._free
    assert np.array_equal(bulk.active, seq.active)
    assert np.array_equal(bulk.ids, seq.ids)
    assert bulk.client_ids() == seq.client_ids()
    # freed slots are recycled in the same LIFO order on both stores
    bulk.add_batch([20, 21], [1, 2], 1, 1)
    for cid in (20, 21):
        seq.add(cid, cid - 19, 1, 1)
    assert bulk._slot == seq._slot and bulk._free == seq._free
    assert bulk.remove_batch([]) == []       # empty batch is a no-op


# ---------------------------------------------------------- duration window
def test_duration_window_newest_first_and_truncated():
    fs = _store(2)
    for i in range(15):                      # exceeds the history window
        fs.mark_running(0, i)
        fs.mark_complete(0, float(i))
    assert fs.recent_durations(0, 5) == [10.0, 11.0, 12.0, 13.0, 14.0]
    assert fs.recent_durations(0, 99) == [float(i) for i in range(5, 15)]
    durs, lens = fs.duration_window(np.array([fs.slot_of(0)]), 10)
    assert list(durs[0]) == [float(i) for i in range(14, 4, -1)]
    assert lens[0] == 10
    assert fs.recent_durations(1, 5) == []
    assert fs.recent_durations(12345, 5) == []   # unknown id


def test_recent_mean_matches_np_mean():
    fs = _store(4)
    seqs = {0: [3.0, 9.0, 1.0], 1: [5.0], 2: []}
    for cid, seq in seqs.items():
        for d in seq:
            fs.mark_running(cid, 0)
            fs.mark_complete(cid, d)
    slots = np.array([fs.slot_of(c) for c in (0, 1, 2)])
    means = fs.recent_mean(slots, 5)
    assert means[0] == np.mean(seqs[0][-5:])
    assert means[1] == np.mean(seqs[1][-5:])
    assert means[2] == 0.0


# -------------------------------------------------- incremental score terms
def test_window_terms_match_oracle_after_streaming():
    """The cached win_num/win_den refreshed per mark_complete must yield
    the exact calculate_score value over the retained window."""
    rng = np.random.default_rng(0)
    fs = _store(3, card=120, batch=10, epochs=5)
    lam = decay_rate(0.2)
    hist = {c: [] for c in range(3)}
    for _ in range(17):
        cid = int(rng.integers(0, 3))
        d = float(rng.uniform(0.5, 80.0))
        fs.mark_running(cid, 0)
        fs.mark_complete(cid, d)
        hist[cid].append(d)
    for cid in range(3):
        slots = np.array([fs.slot_of(cid)])
        got = fs.window_scores(slots, 10, lam)[0]
        want = calculate_score(1.0, list(reversed(hist[cid][-10:])),
                               120, 5, 10, lam)
        assert got == want                   # bitwise


def test_window_scores_fallback_other_window():
    fs = _store(2, card=100)
    for d in (4.0, 8.0, 16.0):
        fs.mark_running(0, 0)
        fs.mark_complete(0, d)
    slots = np.array([fs.slot_of(0)])
    lam = 0.8
    fast = fs.window_scores(slots, 10, lam)[0]
    slow = fs.window_scores(slots, 2, lam)[0]    # forces the recompute path
    want2 = calculate_score(1.0, [16.0, 8.0], 100, 5, 10, lam)
    assert slow == want2 and fast != slow


def test_decay_setter_rebuilds_terms():
    fs = _store(1, card=100)
    for d in (2.0, 4.0):
        fs.mark_running(0, 0)
        fs.mark_complete(0, d)
    slots = np.array([fs.slot_of(0)])
    before = fs.window_scores(slots, 10, 0.8)[0]
    fs.decay = 0.5
    after = fs.window_scores(slots, 10, 0.5)[0]
    assert after == calculate_score(1.0, [4.0, 2.0], 100, 5, 10, 0.5)
    assert before != after


# ------------------------------------------------------------- persistence
def test_state_dict_roundtrip_identity():
    rng = np.random.default_rng(1)
    fs = _store(12)
    for _ in range(40):
        cid = int(rng.integers(0, 12))
        if not fs.has(cid):
            continue
        fs.mark_running(cid, int(rng.integers(0, 5)))
        if rng.random() < 0.8:
            fs.mark_complete(cid, float(rng.uniform(1, 50)))
        else:
            fs.mark_failed(cid)
    fs.remove(2)
    fs.add(77, 10, 5, 1)
    fs2 = FleetStore.from_state(fs.state_dict())
    assert fs2.client_ids() == fs.client_ids()
    assert fs2._free == fs._free
    assert fs2._next_seq == fs._next_seq
    for name in FleetStore.COLUMNS:
        np.testing.assert_array_equal(getattr(fs2, name), getattr(fs, name))
    np.testing.assert_array_equal(fs2.durations, fs.durations)
    # and it keeps working: a new registration lands in a consistent slot
    fs2.add(500, 1, 1, 1)
    assert fs2.ids[fs2.slot_of(500)] == 500


# ------------------------------------------------------------ churn storms
def test_churn_storm_10k_consistency():
    """ClientJoined/ClientLeft storms at M=10k: the id->slot map, the
    free-list, and the selection masks stay mutually consistent."""
    M = 10_000
    fs = FleetStore()
    rng = np.random.default_rng(0)
    fs.add_batch(np.arange(M), rng.integers(10, 500, M), 10, 5)
    live = set(range(M))
    next_id = M
    for wave in range(6):
        leave = rng.choice(sorted(live), size=2000, replace=False)
        for cid in leave:
            assert fs.remove(int(cid))
            live.discard(int(cid))
        joins = range(next_id, next_id + 1500)
        fs.add_batch(np.array(list(joins)),
                     rng.integers(10, 500, 1500), 10, 5)
        live.update(joins)
        next_id += 1500
        for cid in rng.choice(sorted(live), size=200, replace=False):
            fs.mark_running(int(cid), wave)
            fs.mark_complete(int(cid), float(rng.uniform(1, 30)))
    assert len(fs) == len(live)
    assert set(fs.client_ids()) == live
    # slot map is a bijection onto active slots; free-list is its complement
    slots = [fs.slot_of(c) for c in fs.client_ids()]
    assert len(set(slots)) == len(slots)
    assert fs.active[slots].all()
    assert not set(slots) & set(fs._free)
    assert len(slots) + len(fs._free) == fs.capacity
    # selection masks agree with membership
    assert set(fs.ids[fs.idle_slots()].tolist()) <= live
    # ordering is registration order (seq strictly increasing)
    seqs = fs.seq[np.array(slots)]
    assert (np.diff(fs.seq[fs.ordered_slots()]) > 0).all()
    assert len(seqs) == len(slots)


def test_churn_storm_bulk_path_matches_per_event():
    """The same storm driven through remove_batch/add_batch (the traffic
    plane's flash-crowd path) ends bit-identical to per-event churn and
    keeps every membership invariant."""
    M = 10_000
    rng_a, rng_b = (np.random.default_rng(1) for _ in range(2))
    # same starting capacity: growth schedules (bulk _ensure vs per-add
    # doubling) would otherwise legitimately differ
    bulk, ev = FleetStore(capacity=M), FleetStore(capacity=M)
    cards = np.random.default_rng(9).integers(10, 500, M * 3)
    bulk.add_batch(np.arange(M), cards[:M], 10, 5)
    for cid in range(M):
        ev.add(cid, int(cards[cid]), 10, 5)
    live = list(range(M))
    next_id = M
    for wave in range(4):
        leave = rng_a.choice(live, size=3000, replace=False)
        assert rng_b.choice(live, size=3000, replace=False).tolist() == \
            leave.tolist()
        assert bulk.remove_batch(leave) == leave.tolist()
        for cid in leave:
            assert ev.remove(int(cid))
        gone = set(leave.tolist())
        live = [c for c in live if c not in gone]
        joins = np.arange(next_id, next_id + 2500)
        bulk.add_batch(joins, cards[joins], 10, 5)
        for cid in joins:
            ev.add(int(cid), int(cards[cid]), 10, 5)
        live.extend(joins.tolist())
        next_id += 2500
    assert bulk._slot == ev._slot
    assert bulk._free == ev._free
    for col in ("active", "ids", "seq", "cardinality", "status"):
        assert np.array_equal(getattr(bulk, col), getattr(ev, col)), col
    # invariants survive the bulk storm
    slots = [bulk.slot_of(c) for c in bulk.client_ids()]
    assert len(set(slots)) == len(slots) == len(live)
    assert bulk.active[slots].all()
    assert not set(slots) & set(bulk._free)
    assert (np.diff(bulk.seq[bulk.ordered_slots()]) > 0).all()


def test_churn_matches_object_plane_ordering():
    """After interleaved joins/leaves/overwrites, the columnar candidate
    ordering must equal the object plane's dict ordering."""
    rng = np.random.default_rng(3)
    obj = Database(control_plane="object")
    col = Database(control_plane="columnar")
    live = set()
    next_id = 0
    for _ in range(300):
        r = rng.random()
        if r < 0.5 or not live:
            cid = next_id if r < 0.45 or not live else \
                int(rng.choice(sorted(live)))     # sometimes overwrite
            next_id = max(next_id, cid + 1)
            rec = ClientRecord(client_id=cid, hardware="h",
                               data_cardinality=10, batch_size=5,
                               local_epochs=1)
            obj.register_client(rec)
            col.register_client(rec)
            live.add(cid)
        elif r < 0.75:
            cid = int(rng.choice(sorted(live)))
            assert obj.unregister_client(cid) == col.unregister_client(cid)
            live.discard(cid)
        else:
            cid = int(rng.choice(sorted(live)))
            obj.mark_running(cid, 0)
            col.mark_running(cid, 0)
            if rng.random() < 0.7:
                d = float(rng.uniform(1, 9))
                obj.mark_complete(cid, d)
                col.mark_complete(cid, d)
    assert obj.client_ids() == col.client_ids()
    assert obj.idle_client_ids() == col.idle_client_ids()
    assert obj.any_idle() == col.any_idle()
    for cid in obj.client_ids():
        assert obj.recent_durations(cid, 5) == col.recent_durations(cid, 5)


# ------------------------------------------------------ device top-k select
def test_select_topk_bootstrap_then_score_order():
    fs = _store(6, card=100)
    # clients 0..2 have history: 0 fastest, 2 slowest; 3..5 uninvoked
    for cid, d in ((0, 1.0), (1, 10.0), (2, 100.0)):
        fs.mark_running(cid, 0)
        fs.mark_complete(cid, d)
    sel = fs.select_topk(4, beta=1.2)
    assert set(sel[:3]) == {3, 4, 5}          # uninvoked first (bootstrap)
    assert sel[3] == 0                        # then highest-throughput
    # busy clients are masked out
    fs.mark_running(0, 1)
    sel = fs.select_topk(6, beta=1.2)
    assert 0 not in sel
    assert len(sel) == 5


def test_select_topk_empty_and_overask():
    fs = FleetStore()
    assert fs.select_topk(4, 1.2) == []
    fs.add(0, 10, 5, 1)
    assert fs.select_topk(8, 1.2) == [0]


def test_state_dict_preserves_device_topk_booster():
    """The device-owned top-k booster survives checkpoint/resume — a
    resumed apodotiko-topk run must not restart every booster at 1.0."""
    fs = _store(6, card=100)
    for cid, d in ((0, 1.0), (1, 2.0), (2, 4.0)):
        fs.mark_running(cid, 0)
        fs.mark_complete(cid, d)
    fs.select_topk(2, beta=1.5)         # promotes the unselected idle
    before = np.asarray(fs._dev.booster)
    assert (before > 1.0).any()
    fs2 = FleetStore.from_state(fs.state_dict())
    np.testing.assert_array_equal(np.asarray(fs2._dev.booster), before)
    # and selection continues identically on both stores
    assert fs.select_topk(3, beta=1.5) == fs2.select_topk(3, beta=1.5)


def test_register_prepopulated_record_matches_object_plane():
    """A ClientRecord carrying history registers identically on both
    planes: scores, counters, and the retained duration window agree."""
    rec = ClientRecord(client_id=0, hardware="h", data_cardinality=120,
                       batch_size=10, local_epochs=5, n_invocations=3,
                       n_failures=1, invoked_rounds=[0, 1, 2],
                       durations=[5.0, 7.0, 9.0])
    fresh = ClientRecord(client_id=1, hardware="h", data_cardinality=80,
                         batch_size=10, local_epochs=5)
    dbs = {cp: Database(control_plane=cp) for cp in ("object", "columnar")}
    for db in dbs.values():
        db.register_client(rec)
        db.register_client(fresh)
    from repro.core.selection import select_clients
    gens = {cp: np.random.default_rng(3) for cp in dbs}
    for t in range(4):
        sel = {cp: select_clients(db, 1, gens[cp]) for cp, db in dbs.items()}
        assert sel["object"] == sel["columnar"]
        for cp, db in dbs.items():
            for cid in sel[cp]:
                db.mark_running(cid, t)
                db.mark_complete(cid, 3.0 + t)
    col = dbs["columnar"].clients[0]
    assert col.n_invocations >= 3 and col.n_failures == 1
    assert dbs["columnar"].recent_durations(0, 3) == \
        dbs["object"].recent_durations(0, 3)
