"""Columnar control plane (DESIGN.md §10): golden-trace bit-identity of
``REPRO_CONTROL_PLANE=columnar`` vs the ``object`` oracle across
strategies / engines / update planes / data planes, vectorized-scoring
bit-equality, checkpoint/resume parity of the columnar fleet state, the
plane resolution order, and fleet-scale selection without per-client
Python objects."""
import numpy as np
import pytest

import jax

from repro.core.controller import Controller, FLConfig
from repro.core.database import ClientRecord, Database
from repro.core.scheduler import Scheduler
from repro.core.scoring import calculate_score, calculate_scores
from repro.core.selection import select_clients
from repro.core.services import resolve_control_plane
from repro.faas.hardware import paper_fleet

from trace_harness import (ALL_STRATEGIES, N_CLIENTS, REACTIVE, base_cfg_kw,
                           data, model, run_flag_pair,
                           trace as _trace)  # noqa: F401

_cfg_kw = base_cfg_kw


def _assert_planes_identical(cfg_kw, model, data, engine_cls=Scheduler):
    """One run per control plane; everything observable must be bit-equal
    (common asserts live in trace_harness.run_flag_pair)."""
    runs = run_flag_pair(cfg_kw, "control_plane", ("columnar", "object"),
                         model, data, engine_cls=engine_cls)
    col, m_col = runs["columnar"]
    obj, m_obj = runs["object"]
    assert m_col["total_cost_usd"] == m_obj["total_cost_usd"]
    assert m_col["invocation_counts"] == m_obj["invocation_counts"]
    assert m_col["control_plane"] == "columnar"
    assert m_obj["control_plane"] == "object"
    # end-of-run fleet state agrees too (boosters evolve every selection)
    for cid, rec in obj.db.clients.items():
        mat = col.db.clients[cid]
        assert mat.booster == rec.booster
        assert mat.durations == rec.durations[-col.db.fleet.history:]
        assert mat.n_invocations == rec.n_invocations
        assert mat.n_failures == rec.n_failures
        assert mat.status == rec.status
    return m_col, m_obj


# ------------------------------------------------------------ golden traces
@pytest.mark.parametrize("strategy", ALL_STRATEGIES + REACTIVE)
def test_golden_controlplane_scheduler(strategy, data, model):
    _assert_planes_identical(_cfg_kw(strategy=strategy), model, data)


@pytest.mark.parametrize("strategy", ("fedavg", "apodotiko", "fedlesscan"))
def test_golden_controlplane_legacy_engine(strategy, data, model):
    _assert_planes_identical(_cfg_kw(strategy=strategy), model, data,
                             engine_cls=Controller)


@pytest.mark.parametrize("strategy", ("apodotiko", "scaffold"))
def test_golden_controlplane_blob_update_plane(strategy, data, model):
    _assert_planes_identical(_cfg_kw(strategy=strategy, update_plane="blob"),
                             model, data)


@pytest.mark.parametrize("strategy", ("apodotiko", "fedlesscan"))
def test_golden_controlplane_host_data_plane(strategy, data, model):
    _assert_planes_identical(_cfg_kw(strategy=strategy, data_plane="host"),
                             model, data)


def test_golden_controlplane_with_failures(data, model):
    """Failure bookkeeping (mark_failed / hedge-sibling incr_failures)
    takes the same paths on both planes."""
    _assert_planes_identical(_cfg_kw(strategy="apodotiko", failure_rate=0.3,
                                     rounds=3), model, data)
    _assert_planes_identical(_cfg_kw(strategy="apodotiko-hedge",
                                     failure_rate=0.3, rounds=3,
                                     cold_start_s=60.0, keep_warm=30.0),
                             model, data)


def test_golden_controlplane_longer_run_boosters_compound(data, model):
    """More rounds than the CR gate fills -> boosters promote repeatedly;
    the f64 booster column must track the oracle bit-for-bit."""
    _assert_planes_identical(_cfg_kw(strategy="apodotiko", rounds=6),
                             model, data)


# ---------------------------------------------------------- runtime churn
def test_churn_mid_run_planes_identical(data, model):
    """add/remove mid-run (ClientLeft cancels in-flight work, frees rows,
    reorders candidates) must leave both planes in identical state."""
    engines = {}
    for cp in ("columnar", "object"):
        eng = Scheduler(FLConfig(**_cfg_kw(strategy="apodotiko",
                                           control_plane=cp)), model, data,
                        list(paper_fleet(N_CLIENTS)))
        eng.run()
        eng.remove_clients([1, 4])
        eng.add_clients(
            [ClientRecord(client_id=N_CLIENTS + 7, hardware="cpu2",
                          data_cardinality=int(data.n[0]), batch_size=5,
                          local_epochs=1)],
            [list(paper_fleet(N_CLIENTS))[0]])
        engines[cp] = eng
    col, obj = engines["columnar"], engines["object"]
    assert col.db.client_ids() == obj.db.client_ids()
    assert col.db.idle_client_ids() == obj.db.idle_client_ids()
    sel_c = col.strategy.select(col.db, col.db.round)
    sel_o = obj.strategy.select(obj.db, obj.db.round)
    assert sel_c == sel_o


# ----------------------------------------------------- vectorized scoring
def test_calculate_scores_bitwise_vs_scalar():
    rng = np.random.default_rng(0)
    M, W = 500, 10
    lens = rng.integers(0, W + 1, M)
    durs = rng.uniform(0.3, 900.0, (M, W))      # newest first
    card = rng.integers(1, 100_000, M).astype(np.int64)
    epochs = rng.integers(1, 9, M).astype(np.int64)
    batch = rng.integers(1, 64, M).astype(np.int64)
    boost = rng.uniform(1.0, 4.0, M)
    vec = calculate_scores(boost, durs, lens, card, epochs, batch, 0.8)
    ref = np.array([
        calculate_score(float(boost[i]),
                        [float(d) for d in durs[i, :lens[i]]],
                        int(card[i]), int(epochs[i]), int(batch[i]), 0.8)
        for i in range(M)])
    assert np.array_equal(ref, vec)


def test_selection_stream_identical_over_rounds():
    """Shared RNG stream, evolving state: selections stay identical
    selection after selection (the bench CI gate, in-process)."""
    rng = np.random.default_rng(5)
    dbs = {cp: Database(control_plane=cp) for cp in ("object", "columnar")}
    card = rng.integers(20, 400, 64)
    for cp, db in dbs.items():
        for cid in range(64):
            db.register_client(ClientRecord(
                client_id=cid, hardware="h", data_cardinality=int(card[cid]),
                batch_size=10, local_epochs=5))
    gens = {cp: np.random.default_rng(11) for cp in dbs}
    for t in range(8):
        sel = {cp: select_clients(db, 12, gens[cp])
               for cp, db in dbs.items()}
        assert sel["object"] == sel["columnar"]
        for cp, db in dbs.items():
            for j, cid in enumerate(sel[cp]):
                db.mark_running(cid, t)
                db.mark_complete(cid, 1.0 + ((cid * 13 + 7 * j + t) % 40))


# ------------------------------------------------------- resolution order
def test_resolve_control_plane(monkeypatch):
    monkeypatch.delenv("REPRO_CONTROL_PLANE", raising=False)
    assert resolve_control_plane("auto") == "columnar"
    assert resolve_control_plane("") == "columnar"
    assert resolve_control_plane("object") == "object"
    monkeypatch.setenv("REPRO_CONTROL_PLANE", "object")
    assert resolve_control_plane("auto") == "object"
    assert resolve_control_plane("columnar") == "columnar"  # explicit wins
    with pytest.raises(ValueError):
        resolve_control_plane("dict")


# ------------------------------------------------------ checkpoint/resume
def test_columnar_checkpoint_resume_parity(tmp_path, data, model):
    """Satellite: Database.save/load round-trips the columnar fleet state
    (durations, boosters, live EMA/window terms) and a resumed columnar
    run continues bit-identically to a resumed object run."""
    resumed = {}
    for cp in ("columnar", "object"):
        ckpt = str(tmp_path / f"fl_{cp}")
        cfg = FLConfig(**_cfg_kw(strategy="apodotiko", rounds=2,
                                 control_plane=cp, checkpoint_dir=ckpt,
                                 checkpoint_every=1))
        eng = Scheduler(cfg, model, data, list(paper_fleet(N_CLIENTS)))
        eng.run()
        eng.checkpoint()
        cfg2 = FLConfig(**_cfg_kw(strategy="apodotiko", rounds=4,
                                  control_plane=cp, checkpoint_dir=ckpt))
        eng2 = Scheduler.resume(cfg2, model, data,
                                list(paper_fleet(N_CLIENTS)))
        assert eng2.db.round == 2
        assert eng2.control_plane == cp
        # fleet state survived the round-trip exactly
        for cid, rec in eng.db.clients.items():
            rec2 = eng2.db.clients[cid]
            assert rec2.booster == rec.booster
            assert rec2.durations == rec.durations
            assert rec2.n_invocations == rec.n_invocations
        m = eng2.run()
        resumed[cp] = (_trace(eng2), m["total_time"],
                       jax.tree.leaves(eng2.params))
    assert resumed["columnar"][0] == resumed["object"][0]
    assert resumed["columnar"][1] == resumed["object"][1]
    for a, b in zip(resumed["columnar"][2], resumed["object"][2]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_columnar_fleet_live_terms_roundtrip(tmp_path):
    """The live score buffers (EMA + window terms) are part of the saved
    state, not recomputed: save -> load -> bitwise equality."""
    db = Database(control_plane="columnar")
    rng = np.random.default_rng(2)
    for cid in range(20):
        db.register_client(ClientRecord(client_id=cid, hardware="h",
                                        data_cardinality=50 + cid,
                                        batch_size=10, local_epochs=5))
    for t in range(30):
        cid = int(rng.integers(0, 20))
        db.mark_running(cid, t)
        db.mark_complete(cid, float(rng.uniform(1, 60)))
    db.round = 7
    db.save(str(tmp_path / "db"))
    db2 = Database.load(str(tmp_path / "db"))
    assert db2.control_plane == "columnar" and db2.round == 7
    for col in ("ema_num", "ema_den", "win_num", "win_den", "booster",
                "dur_len", "ids"):
        np.testing.assert_array_equal(getattr(db2.fleet, col),
                                      getattr(db.fleet, col))
    np.testing.assert_array_equal(db2.fleet.durations, db.fleet.durations)
    # and the restored store scores identically
    slots = db.fleet._registered_slots()
    np.testing.assert_array_equal(db.fleet.window_scores(slots, 10, 0.8),
                                  db2.fleet.window_scores(
                                      np.asarray(slots), 10, 0.8))


# -------------------------------------------------------- fleet-scale path
def test_fleet_scale_selection_no_python_objects():
    """Selection + scoring at a large simulated fleet without a single
    ClientRecord: bulk registration, bulk history, vectorized select,
    device top-k — the M=1e6 bench path at test-sized M."""
    M = 50_000
    fs_db = Database(control_plane="columnar")
    rng = np.random.default_rng(0)
    fs_db.fleet.add_batch(np.arange(M), rng.integers(10, 500, M), 10, 5)
    fs_db.fleet.bulk_history(rng.uniform(1.0, 60.0, (M, 3)))
    sel = select_clients(fs_db, 100, np.random.default_rng(1))
    assert len(sel) == 100 and len(set(sel)) == 100
    topk = fs_db.fleet.select_topk(100, 1.2)
    assert len(topk) == 100 and len(set(topk)) == 100
    assert not fs_db._clients        # no object materialization happened


def test_topk_strategy_runs_on_scheduler(data, model):
    """apodotiko-topk end-to-end: deterministic device-side selection on
    the columnar plane, both engines."""
    for engine_cls in (Scheduler, Controller):
        eng = engine_cls(FLConfig(**_cfg_kw(strategy="apodotiko-topk",
                                            rounds=2)), model, data,
                         list(paper_fleet(N_CLIENTS)))
        m = eng.run()
        assert m["rounds"] == 2
        assert np.isfinite(m["final_accuracy"])
    # and it refuses the object plane
    with pytest.raises(ValueError):
        Scheduler(FLConfig(**_cfg_kw(strategy="apodotiko-topk",
                                     control_plane="object")),
                  model, data, list(paper_fleet(N_CLIENTS))).run()
