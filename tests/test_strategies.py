"""Strategy behaviour tests (selection policy, gating, staleness windows)."""
import numpy as np
import pytest

from repro.core.database import ClientRecord, Database, ResultRecord
from repro.core.strategies.base import STRATEGIES, StrategyConfig, build_strategy


def _db(n=20, invoked=None, durations=None, control_plane="object"):
    db = Database(control_plane=control_plane)
    for cid in range(n):
        rec = ClientRecord(client_id=cid, hardware="cpu1",
                           data_cardinality=100, batch_size=10, local_epochs=5)
        db.register_client(rec)
        if invoked and cid in invoked:
            if db.columnar:
                db.mark_running(cid, 0)
                db.mark_running(cid, 1)
                db.mark_complete(cid, durations.get(cid, 10.0)
                                 if durations else 10.0)
            else:
                rec.n_invocations = 2
                rec.durations = ([durations.get(cid, 10.0)] if durations
                                 else [10.0])
    return db


def _cfg(**kw):
    return StrategyConfig(clients_per_round=8, **kw)


def test_all_strategies_registered():
    assert set(STRATEGIES) == {"fedavg", "fedprox", "scaffold", "fedlesscan",
                               "fedbuff", "apodotiko", "apodotiko-topk"}


@pytest.mark.parametrize("name", list(STRATEGIES))
def test_selection_count_and_uniqueness(name):
    s = build_strategy(name, _cfg())
    # apodotiko-topk selects over the columnar plane's device score state
    plane = "columnar" if name == "apodotiko-topk" else "object"
    db = _db(20, invoked=set(range(20)), control_plane=plane)
    sel = s.select(db, round_=3)
    assert len(sel) == 8 and len(set(sel)) == 8


def test_topk_requires_columnar_plane():
    s = build_strategy("apodotiko-topk", _cfg())
    with pytest.raises(ValueError):
        s.select(_db(20), round_=0)


def test_sync_strategies_need_all_results():
    for name in ("fedavg", "fedprox", "scaffold"):
        s = build_strategy(name, _cfg())
        assert not s.is_async
        assert s.results_needed() == 8


def test_async_strategies_gate_on_concurrency_ratio():
    for name in ("fedbuff", "apodotiko"):
        s = build_strategy(name, _cfg(concurrency_ratio=0.3))
        assert s.is_async
        assert s.results_needed() == int(np.ceil(8 * 0.3))


def test_sync_usable_only_current_round():
    s = build_strategy("fedavg", _cfg())
    cur = ResultRecord(0, round=5, n_samples=10, train_duration=1, t_available=0)
    old = ResultRecord(1, round=4, n_samples=10, train_duration=1, t_available=0)
    assert s.usable(cur, 5) and not s.usable(old, 5)


def test_async_usable_within_staleness_window():
    s = build_strategy("apodotiko", _cfg(max_staleness=5))
    assert s.usable(ResultRecord(0, round=3, n_samples=1, train_duration=1,
                                 t_available=0), 8)
    assert not s.usable(ResultRecord(0, round=2, n_samples=1, train_duration=1,
                                     t_available=0), 8)


def test_apodotiko_weight_combines_staleness_and_cardinality():
    s = build_strategy("apodotiko", _cfg())
    fresh = ResultRecord(0, round=10, n_samples=100, train_duration=1, t_available=0)
    stale = ResultRecord(1, round=8, n_samples=100, train_duration=1, t_available=0)
    assert s.result_weight(fresh, 10) / s.result_weight(stale, 10) == \
        pytest.approx(np.sqrt(3))


def test_fedlesscan_prefers_fast_cluster():
    durations = {cid: (1.0 if cid < 10 else 500.0) for cid in range(20)}
    s = build_strategy("fedlesscan", _cfg())
    db = _db(20, invoked=set(range(20)), durations=durations)
    sel = s.select(db, round_=3)
    fast = sum(1 for c in sel if c < 10)
    assert fast >= 6  # fills from the fastest duration tier first


def test_fedprox_has_proximal_term():
    s = build_strategy("fedprox", _cfg(prox_mu=0.05))
    assert s.prox_mu == pytest.approx(0.05)
    assert build_strategy("fedavg", _cfg()).prox_mu == 0.0


def test_scaffold_flags_control_variates():
    assert build_strategy("scaffold", _cfg()).needs_scaffold
    assert not build_strategy("apodotiko", _cfg()).needs_scaffold
