"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
ref.py, executed in interpret mode (Mosaic targets a real TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

K0 = jax.random.PRNGKey(0)


# -- staleness_agg -------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3, 16])
@pytest.mark.parametrize("n", [1024, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_staleness_agg_sweep(k, n, dtype):
    u = jax.random.normal(K0, (k, n), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (k,), jnp.float32)
    w = w / w.sum()
    out = ops.staleness_agg(u, w, interpret=True)
    expect = ref.staleness_agg(u, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_staleness_agg_weights_delta():
    """Weight vector (1,0,...,0) must return the first update exactly."""
    u = jax.random.normal(K0, (4, 2048), jnp.float32)
    w = jnp.array([1.0, 0.0, 0.0, 0.0])
    out = ops.staleness_agg(u, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(u[0]), rtol=1e-6)


def test_aggregate_pytree_roundtrip():
    ups = [{"a": jax.random.normal(jax.random.PRNGKey(i), (37, 5)),
            "b": jax.random.normal(jax.random.PRNGKey(i + 9), (11,))}
           for i in range(3)]
    w = np.array([0.2, 0.5, 0.3], np.float32)
    out = ops.aggregate_pytree(ups, w, interpret=True)
    expect_a = sum(wi * np.asarray(u["a"]) for wi, u in zip(w, ups))
    np.testing.assert_allclose(np.asarray(out["a"]), expect_a, rtol=1e-5,
                               atol=1e-6)
    assert out["a"].shape == (37, 5) and out["b"].shape == (11,)


# -- quant8 ---------------------------------------------------------------------

@pytest.mark.parametrize("n_tiles", [1, 4])
def test_quant8_matches_ref(n_tiles):
    n = 8 * 256 * n_tiles
    x = jax.random.normal(K0, (n,), jnp.float32) * 3.0
    q, s = ops.quantize_q8(x, interpret=True)
    qr, sr = ref.quantize_q8(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    d = ops.dequantize_q8(q, s, interpret=True)
    np.testing.assert_allclose(np.asarray(d), np.asarray(ref.dequantize_q8(qr, sr)),
                               rtol=1e-6)


def test_quant8_error_bound():
    """Per-block error <= scale/2 = max|block| / 254."""
    x = jax.random.normal(jax.random.PRNGKey(3), (8 * 256,), jnp.float32)
    q, s = ops.quantize_q8(x, interpret=True)
    d = ops.dequantize_q8(q, s, interpret=True)
    err = np.abs(np.asarray(d) - np.asarray(x)).reshape(-1, 256)
    bound = np.asarray(s)[:, None] * 0.5 + 1e-7
    assert (err <= bound).all()


@pytest.mark.parametrize("n", [1, 255, 256, 257, 2048, 2049, 5000])
def test_quant8_arbitrary_n_round_trip(n):
    """N need not be tile-aligned: the pad-to-block is internal, outputs
    are trimmed, and zero padding never perturbs a block's max-abs scale
    — so tail-block values quantize exactly as in an aligned buffer."""
    x = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32) * 2.0
    q, s = ops.quantize_q8(x, interpret=True)
    nb = -(-n // 256)
    assert q.shape == (n,) and s.shape == (nb,)
    d = ops.dequantize_q8(q, s, interpret=True)
    assert d.shape == (n,)
    err = np.zeros(nb * 256, np.float32)
    err[:n] = np.abs(np.asarray(d) - np.asarray(x))
    bound = np.asarray(s)[:, None] * 0.5 + 1e-7
    assert (err.reshape(nb, 256) <= bound).all()
    # full prefix blocks must quantize identically to an aligned run
    n0 = (n // 256) * 256
    if n0:
        q0, s0 = ops.quantize_q8(x[:n0], interpret=True)
        np.testing.assert_array_equal(np.asarray(q[:n0]), np.asarray(q0))
        np.testing.assert_array_equal(np.asarray(s[:n0 // 256]),
                                      np.asarray(s0))


def test_compress_update_error_feedback():
    u = {"w": jax.random.normal(K0, (300, 7)), "b": jnp.ones((13,))}
    (q, s, meta), err = ops.compress_update(u, interpret=True)
    back = ops.decompress_update(q, s, meta, interpret=True)
    assert back["w"].shape == (300, 7) and back["b"].shape == (13,)
    # decompressed + error == original (error feedback is exact)
    flat_u = np.concatenate([np.asarray(u["b"]).ravel(),
                             np.asarray(u["w"]).ravel()])
    flat_b = np.concatenate([np.asarray(back["b"]).ravel(),
                             np.asarray(back["w"]).ravel()])
    np.testing.assert_allclose(flat_b + np.asarray(err), flat_u, atol=1e-5)


# -- fused_adam ------------------------------------------------------------------

@pytest.mark.parametrize("t", [1, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adam_sweep(t, dtype):
    n = 8 * 1024
    p = jax.random.normal(K0, (n,), dtype)
    m = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32) * 0.1
    v = jax.random.uniform(jax.random.PRNGKey(2), (n,), jnp.float32) * 0.01
    g = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
    po, mo, vo = ops.fused_adam(p, m, v, g, jnp.int32(t), lr=1e-3,
                                interpret=True)
    pr, mr, vr = ref.fused_adam(p, m, v, g, lr=1e-3, t=t)
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(po, np.float32),
                               np.asarray(pr, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr), rtol=1e-5,
                               atol=1e-6)


# -- flash attention --------------------------------------------------------------

@pytest.mark.parametrize("s,t", [(128, 128), (256, 128), (128, 256)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(s, t, causal):
    if causal and s > t:
        pytest.skip("causal requires S <= T in this harness")
    B, H, D = 1, 2, 64
    q = jax.random.normal(K0, (B, H, s, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, t, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, t, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    expect = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    B, H, S, D = 1, 1, 128, 64
    q = jax.random.normal(K0, (B, H, S, D), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


# -- masked top-k (control-plane cohort selection) ----------------------------

@pytest.mark.parametrize("m,k,block", [
    (64, 5, 32),        # small fleets still hit the kernel via small blocks
    (1024, 1, 256),
    (3000, 17, 1024),   # ragged tail pads with -inf
    (4096, 100, 1024),
])
def test_masked_topk_pallas_matches_xla(m, k, block):
    rng = np.random.default_rng(m * 100 + k)
    s = jnp.asarray(rng.normal(size=m).astype(np.float32))
    v_x, i_x = ops.masked_topk(s, k, path="xla")
    v_p, i_p = ops.masked_topk(s, k, path="pallas", interpret=True,
                               block=block)
    np.testing.assert_array_equal(np.asarray(v_x), np.asarray(v_p))
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))


def test_masked_topk_masked_entries():
    """-inf-masked entries rank last and keep value -inf so the caller can
    filter invalid picks."""
    s = np.full(2048, -np.inf, np.float32)
    s[[5, 900, 1999]] = [3.0, 1.0, 2.0]
    v, i = ops.masked_topk(jnp.asarray(s), 8, path="pallas", interpret=True)
    v, i = np.asarray(v), np.asarray(i)
    assert list(i[:3]) == [5, 1999, 900]
    assert (v[3:] == -np.inf).all()


def test_masked_topk_ties_break_low_index():
    s = np.zeros(4096, np.float32)
    s[[7, 2000, 3000]] = 1.0              # equal scores across blocks
    for path in ("xla", "pallas"):
        _, i = ops.masked_topk(jnp.asarray(s), 3, path=path, interpret=True)
        assert list(np.asarray(i)) == [7, 2000, 3000]


def test_resolve_topk_path(monkeypatch):
    monkeypatch.delenv("REPRO_TOPK_PATH", raising=False)
    assert ops.resolve_topk_path("xla") == "xla"
    assert ops.resolve_topk_path("pallas") == "pallas"
    assert ops.resolve_topk_path(None) in ("xla", "pallas")  # auto: backend
    monkeypatch.setenv("REPRO_TOPK_PATH", "pallas")
    assert ops.resolve_topk_path(None) == "pallas"
    with pytest.raises(ValueError):
        ops.resolve_topk_path("mosaic")
