"""Fused round megastep (core.megastep, DESIGN.md §11).

The differential contract under test: a ``megastep=fused`` run must be
bit-identical — selections, round boundaries, invocation records, final
params, fleet/device/store end state, total simulated time — to the
stepwise event-driven oracle, (a) when the fused path engages, (b) when
it falls back, and (c) across the full strategy x update-plane x
data-plane matrix where it never engages at all. Fallback-boundary tests
additionally pin that an ineligible plan mutates nothing ("identical to
never entering"), and seeded randomized sweeps (the in-tree stand-in for
the hypothesis layer in test_properties.py, which needs the dev-only
dep) fuzz fleets, knobs, and churn schedules against the same contract.
"""
import heapq

import numpy as np
import pytest

from repro.core.controller import FLConfig
from repro.core.megastep import _plan
from repro.core.scheduler import Scheduler
from repro.core.services import resolve_megastep
from repro.faas.hardware import HardwareProfile, paper_fleet

from trace_harness import (ALL_STRATEGIES, N_CLIENTS, REACTIVE, base_cfg_kw,
                           assert_fleet_state_equal,
                           assert_fused_matches_stepwise, assert_params_equal,
                           data, det_fleet, megastep_cfg, model,
                           trace)  # noqa: F401


# ------------------------------------------------------- resolution order
def test_resolve_megastep(monkeypatch):
    monkeypatch.delenv("REPRO_MEGASTEP", raising=False)
    assert resolve_megastep("auto") == "fused"
    assert resolve_megastep("") == "fused"
    assert resolve_megastep("stepwise") == "stepwise"
    monkeypatch.setenv("REPRO_MEGASTEP", "stepwise")
    assert resolve_megastep("auto") == "stepwise"
    assert resolve_megastep("fused") == "fused"      # explicit beats env
    with pytest.raises(ValueError):
        resolve_megastep("turbo")


def test_scheduler_resolves_env_megastep(data, model, monkeypatch):
    monkeypatch.setenv("REPRO_MEGASTEP", "stepwise")
    eng = Scheduler(FLConfig(**megastep_cfg()), model, data,
                    det_fleet(N_CLIENTS))
    assert eng.megastep == "stepwise"
    assert eng.metrics()["megastep_rounds"] == 0


# ------------------------------------------------------------- engagement
def test_megastep_engages_and_is_bit_identical(data, model):
    """The headline: ceil(10/4)=3 stepwise bootstrap rounds (top-k invokes
    uninvoked clients first), then the remaining 5 rounds run as ONE fused
    scan — and every observable equals the stepwise oracle bitwise."""
    m_step, m_fused = assert_fused_matches_stepwise(
        megastep_cfg(), model, data, min_fused_rounds=5)
    assert m_fused["megastep_scans"] >= 1
    assert m_fused["megastep_fallback_reason"] == "eligible"
    assert m_step["megastep_rounds"] == 0


# -------------------------------------------------------- acceptance matrix
MATRIX = ALL_STRATEGIES + REACTIVE + ("apodotiko-topk",)


@pytest.mark.parametrize("data_plane", ("device", "host"))
@pytest.mark.parametrize("update_plane", ("device", "blob"))
@pytest.mark.parametrize("strategy", MATRIX)
def test_fused_vs_stepwise_matrix(strategy, update_plane, data_plane,
                                  data, model):
    """Every strategy x update plane x data plane on the (noisy) paper
    fleet: the fused scheduler must be indistinguishable from stepwise —
    here via eligibility fallback, since variability > 0."""
    assert_fused_matches_stepwise(
        base_cfg_kw(strategy=strategy, update_plane=update_plane,
                    data_plane=data_plane),
        model, data, fleet=paper_fleet(N_CLIENTS))


@pytest.mark.parametrize("kw,engages", [
    (dict(), True),
    (dict(update_plane="blob"), False),
    (dict(data_plane="host"), False),
    (dict(eval_every=1), False),
    (dict(failure_rate=0.2), False),
    (dict(concurrency_ratio=0.5), False),
])
def test_eligibility_gates(kw, engages, data, model):
    """Each gate flips exactly the engagement bit; bit-identity holds on
    both sides of it."""
    m_step, m_fused = assert_fused_matches_stepwise(
        megastep_cfg(rounds=5, **kw), model, data)
    assert (m_fused["megastep_rounds"] > 0) == engages


# ------------------------------------------------------ fallback boundaries
def test_fallback_timer_armed_then_cleared(data, model):
    """An armed timer (the hedge barrier) must keep the fused path out —
    side-effect free — and clearing it re-admits the very same rounds."""
    eng = Scheduler(FLConfig(**megastep_cfg(rounds=3)), model, data,
                    det_fleet(N_CLIENTS))
    eng.run()
    assert eng.megastep_rounds == 0          # bootstrap rounds only
    eng.cfg.rounds = 5
    heapq.heappush(eng._timers, (eng.loop.now + 5.0, 0, eng.db.round,
                                 "hedge"))
    before = (len(eng.history), eng.db.round, list(eng.store._free))
    plan, reason = _plan(eng)
    assert plan is None and reason == "timer armed"
    assert (len(eng.history), eng.db.round, list(eng.store._free)) == before
    heapq.heappop(eng._timers)
    plan, reason = _plan(eng)
    assert plan is not None and reason == "eligible"
    m = eng.run()
    assert m["megastep_rounds"] == 2


def test_fallback_k_exceeds_idle_pool(data, model):
    """ClientLeft shrinking the idle pool below K: the plan refuses and
    mutates nothing."""
    eng = Scheduler(FLConfig(**megastep_cfg(rounds=5)), model, data,
                    det_fleet(N_CLIENTS))
    m = eng.run()
    assert m["megastep_rounds"] > 0
    eng.remove_clients(list(range(7)))       # 3 idle < K=4
    eng.cfg.rounds = 6
    before = (len(eng.history), eng.db.round, list(eng.store._free))
    plan, reason = _plan(eng)
    assert plan is None and reason == "K exceeds idle-client count"
    assert (len(eng.history), eng.db.round, list(eng.store._free)) == before


def test_fallback_noisy_hardware(data, model):
    """One client with nonzero duration variability poisons the whole
    eligibility proof — every round stays stepwise, runs stay identical."""
    fleet = det_fleet(N_CLIENTS)
    fleet[3] = HardwareProfile("noisy", speed=1.45, vcpus=1.0, mem_gib=2.0,
                               variability=0.05)
    m_step, m_fused = assert_fused_matches_stepwise(
        megastep_cfg(rounds=5), model, data, fleet=fleet)
    assert m_fused["megastep_rounds"] == 0
    assert m_fused["megastep_fallback_reason"] \
        == "client hardware has nonzero variability"


def test_fallback_cold_horizon(data, model):
    """A short keep-warm window breaks the warm-horizon proof (an
    instance would go cold mid-scan): no round fuses, runs stay
    identical including the cold-start records."""
    m_step, m_fused = assert_fused_matches_stepwise(
        megastep_cfg(rounds=5, keep_warm=0.5), model, data)
    assert m_fused["megastep_rounds"] == 0


def test_fallback_progress_callback(data, model):
    """A per-round progress callback may mutate the engine mid-run, which
    the already-computed scan could not observe — so it gates fusion."""
    logs = []
    eng = Scheduler(FLConfig(**megastep_cfg()), model, data,
                    det_fleet(N_CLIENTS))
    m = eng.run(progress=logs.append)
    assert m["megastep_rounds"] == 0
    assert "progress callback" in m["megastep_fallback_reason"]
    assert len(logs) == 8


def test_churn_between_runs_stays_identical(data, model):
    """ClientLeft between run segments: both modes remove the same
    clients, extend the horizon, and must still agree bitwise — with the
    fused path re-engaging on the shrunken fleet."""
    engines = {}
    for mode in ("stepwise", "fused"):
        eng = Scheduler(FLConfig(**megastep_cfg(rounds=5, megastep=mode)),
                        model, data, det_fleet(N_CLIENTS))
        eng.run()
        eng.remove_clients([2, 7])
        eng.cfg.rounds = 8
        eng.run()
        engines[mode] = eng
    step, fused = engines["stepwise"], engines["fused"]
    assert fused.megastep_rounds > 0
    assert trace(fused) == trace(step)
    assert_params_equal(step.params, fused.params)
    assert_fleet_state_equal(step, fused)


# --------------------------------------------------- randomized properties
@pytest.mark.parametrize("seed", range(5))
def test_eligibility_never_admits_divergent_round(seed, data, model):
    """Seeded property sweep: random fleets (mixed zero/nonzero
    variability, duration ties included), cohort sizes, CR gates,
    keep-warm windows and failure rates — whatever subset of rounds the
    eligibility check admits, the run must stay bit-identical to
    stepwise."""
    rng = np.random.default_rng(seed)
    fleet = [HardwareProfile(f"p{i}",
                             speed=float(rng.choice([1.0, 1.3, 1.7])),
                             vcpus=1.0, mem_gib=2.0,
                             variability=float(rng.choice([0.0, 0.0, 0.1])))
             for i in range(N_CLIENTS)]
    kw = megastep_cfg(rounds=int(rng.integers(3, 7)),
                      clients_per_round=int(rng.integers(2, 5)),
                      concurrency_ratio=float(rng.choice([0.5, 1.0])),
                      keep_warm=float(rng.choice([2.0, 1e9])),
                      failure_rate=float(rng.choice([0.0, 0.0, 0.25])),
                      seed=seed)
    assert_fused_matches_stepwise(kw, model, data, fleet=fleet)


@pytest.mark.parametrize("seed", range(3))
def test_random_churn_schedule_stays_identical(seed, data, model):
    """Seeded churn-schedule property: random horizon, random victims
    removed between segments, random extension — fused == stepwise on
    the full two-segment trace and end state."""
    engines = {}
    for mode in ("stepwise", "fused"):
        rng = np.random.default_rng(100 + seed)      # same draws per mode
        eng = Scheduler(
            FLConfig(**megastep_cfg(rounds=int(rng.integers(3, 6)),
                                    megastep=mode, seed=seed)),
            model, data, det_fleet(N_CLIENTS))
        eng.run()
        victims = rng.choice(N_CLIENTS, size=int(rng.integers(1, 3)),
                             replace=False)
        eng.remove_clients([int(v) for v in victims])
        eng.cfg.rounds += int(rng.integers(1, 4))
        eng.run()
        engines[mode] = eng
    step, fused = engines["stepwise"], engines["fused"]
    assert trace(fused) == trace(step)
    assert_params_equal(step.params, fused.params)
    assert_fleet_state_equal(step, fused)
