"""Controller integration: end-to-end rounds per strategy, async overlap,
fault tolerance (client failures, checkpoint/resume), elasticity."""
import jax
import numpy as np
import pytest

from repro.core.controller import Controller, FLConfig
from repro.core.database import ClientRecord
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
from repro.models.proxy_models import ProxyCNN

N_CLIENTS = 12


@pytest.fixture(scope="module")
def data():
    return make_federated_dataset("speech", n_clients=N_CLIENTS, scale=0.08,
                                  seed=0)


@pytest.fixture(scope="module")
def model():
    return ProxyCNN(35)


def _cfg(**kw):
    base = dict(n_clients=N_CLIENTS, clients_per_round=4, rounds=3,
                local_epochs=1, batch_size=5, base_step_time=0.5,
                round_timeout=200.0, seed=0)
    base.update(kw)
    return FLConfig(**base)


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "scaffold",
                                      "fedlesscan", "fedbuff", "apodotiko"])
def test_every_strategy_runs_rounds(strategy, data, model):
    ctl = Controller(_cfg(strategy=strategy), model, data,
                     list(paper_fleet(N_CLIENTS)))
    m = ctl.run()
    assert m["rounds"] == 3
    assert np.isfinite(m["final_accuracy"])
    assert m["total_cost_usd"] > 0
    assert m["n_invocations"] >= 3 * 4


def test_async_rounds_overlap(data, model):
    """Apodotiko's CR gating: a round ends before all invoked clients finish,
    so sim round durations are much shorter than the slowest client."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * (N_CLIENTS // 2) + \
            [HARDWARE_PROFILES["gpu"]] * (N_CLIENTS - N_CLIENTS // 2)
    ctl = Controller(_cfg(strategy="apodotiko", concurrency_ratio=0.5,
                          rounds=4), model, data, fleet)
    ctl.run()
    # stale (previous-round) updates were aggregated at least once
    assert any(l.n_stale >= 0 for l in ctl.history)
    # async: some rounds completed while slow clients still ran
    assert ctl.loop.pending >= 0


def test_client_failures_tolerated(data, model):
    ctl = Controller(_cfg(strategy="apodotiko", failure_rate=0.3, rounds=3),
                     model, data, list(paper_fleet(N_CLIENTS)))
    m = ctl.run()
    assert m["rounds"] >= 1  # progress despite failures
    fails = sum(c.n_failures for c in ctl.db.clients.values())
    assert fails > 0


def test_checkpoint_resume(tmp_path, data, model):
    cfg = _cfg(strategy="apodotiko", rounds=2,
               checkpoint_dir=str(tmp_path / "fl"), checkpoint_every=1)
    ctl = Controller(cfg, model, data, list(paper_fleet(N_CLIENTS)))
    ctl.run()
    ctl.checkpoint()
    # resume: round counter, client records, global model all restored
    cfg2 = _cfg(strategy="apodotiko", rounds=4,
                checkpoint_dir=str(tmp_path / "fl"))
    ctl2 = Controller.resume(cfg2, model, data, list(paper_fleet(N_CLIENTS)))
    assert ctl2.db.round == 2
    durs = [c for c in ctl2.db.clients.values() if c.durations]
    assert durs  # training history survived the restart
    m = ctl2.run()
    assert m["rounds"] >= 1  # continues from round 2


def test_elastic_add_remove_clients(data, model):
    ctl = Controller(_cfg(strategy="apodotiko", rounds=2), model, data,
                     list(paper_fleet(N_CLIENTS)))
    ctl.run()
    # scale the pool down and continue
    ctl.remove_clients([0, 1])
    assert len(ctl.db.clients) == N_CLIENTS - 2
    sel = ctl.strategy.select(ctl.db, 2)
    assert not ({0, 1} & set(sel))


def test_sync_timeout_bounds_round_duration(data, model):
    """FedAvg round duration <= timeout + aggregation overhead even with a
    very slow straggler fleet."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    ctl = Controller(_cfg(strategy="fedavg", round_timeout=30.0, rounds=2,
                          base_step_time=5.0), model, data, fleet)
    ctl.run()
    for log in ctl.history:
        assert log.t_end - log.t_start <= 30.0 * 3 + 1e-6


def test_time_to_accuracy_metric(data, model):
    ctl = Controller(_cfg(strategy="fedavg", rounds=2), model, data,
                     list(paper_fleet(N_CLIENTS)))
    ctl.run()
    assert ctl.time_to_accuracy(0.0) is not None
    assert ctl.time_to_accuracy(1.1) is None
