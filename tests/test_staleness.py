"""Tests for the staleness weighting functions (paper Eq. 1 / Eq. 2, Fig. 2)."""
import numpy as np
import pytest

from repro.core.staleness import eq1_fedlesscan, eq2_apodotiko


def test_eq2_current_round_weight_is_one():
    for t in (0, 1, 5, 100):
        assert eq2_apodotiko(t, t) == pytest.approx(1.0)


def test_eq2_monotonically_decreasing_in_staleness():
    w = [eq2_apodotiko(10 - s, 10) for s in range(6)]
    assert all(a > b for a, b in zip(w, w[1:]))


def test_eq2_formula():
    # 1 / sqrt(T - t_i + 1)
    assert eq2_apodotiko(8, 10) == pytest.approx(1 / np.sqrt(3))


def test_eq2_consistent_along_equal_staleness_diagonal():
    # the paper's Fig. 2b argument: weight depends only on T - t_i
    assert eq2_apodotiko(3, 5) == pytest.approx(eq2_apodotiko(33, 35))
    assert eq2_apodotiko(0, 5) == pytest.approx(eq2_apodotiko(95, 100))


def test_eq1_inconsistent_along_diagonal():
    # the paper's Fig. 2a criticism: one-round-late weight grows with T
    early = eq1_fedlesscan(1, 2)
    late = eq1_fedlesscan(99, 100)
    assert late > early


def test_eq1_formula():
    assert eq1_fedlesscan(8, 10) == pytest.approx(0.8)
