"""Device-resident update plane: UpdateStore lifecycle, the row-index
aggregation fast path, blob-path equivalence over full async runs, and
checkpoint/resume of live un-aggregated rows (DESIGN.md §2)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows
from repro.core.controller import Controller, FLConfig, resolve_update_plane
from repro.core.update_store import UpdateStore
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import paper_fleet
from repro.kernels.ops import RavelSpec
from repro.models.proxy_models import ProxyCNN

N_CLIENTS = 12


@pytest.fixture(scope="module")
def data():
    return make_federated_dataset("speech", n_clients=N_CLIENTS, scale=0.08,
                                  seed=0)


@pytest.fixture(scope="module")
def model():
    return ProxyCNN(35)


def _cfg(**kw):
    base = dict(n_clients=N_CLIENTS, clients_per_round=4, rounds=3,
                local_epochs=1, batch_size=5, base_step_time=0.5,
                round_timeout=200.0, seed=0)
    base.update(kw)
    return FLConfig(**base)


# ------------------------------------------------------------- UpdateStore
def test_store_geometry_invariants():
    """Capacity is a sublane multiple and rows are block-padded so the
    kernel path never pays a padding copy."""
    store = UpdateStore(n_params=33, capacity=3)
    assert store.capacity % 8 == 0
    assert store.row_width % 1024 == 0 and store.row_width >= 33


def test_store_put_gather_roundtrip():
    store = UpdateStore(n_params=33, capacity=2)
    rows = np.random.default_rng(0).normal(size=(5, 33)).astype(np.float32)
    ids = store.put(jnp.asarray(rows))
    assert len(ids) == 5 and store.live_count == 5
    got = np.asarray(store.gather(ids))
    np.testing.assert_array_equal(got[:, :33], rows)
    np.testing.assert_array_equal(got[:, 33:], 0.0)  # zero tail pad
    np.testing.assert_array_equal(np.asarray(store.row(int(ids[2])))[:33],
                                  rows[2])


def test_store_free_recycles_rows():
    store = UpdateStore(n_params=8, capacity=4)
    a = store.put(jnp.ones((4, 8)))
    store.free(a)
    assert store.live_count == 0
    cap = store.capacity
    b = store.put(jnp.full((4, 8), 2.0))
    # recycled, not grown: same slots, same capacity
    assert set(map(int, b)) <= set(range(cap))
    assert store.capacity == cap
    store.free(b)
    store.free(b)  # double-free is a no-op
    assert store.live_count == 0


def test_freed_nan_rows_cannot_poison_aggregate():
    """A diverged client's NaN row, freed without aggregation (failure or
    staleness prune), must not leak into later aggregates through the
    full-buffer weight-0 sweep (0 * nan = nan): the finiteness guard
    recomputes over just the referenced rows."""
    rng = np.random.default_rng(7)
    ups = [_tree(rng) for _ in range(3)]
    spec = RavelSpec(ups[0])
    store = UpdateStore(spec.n_params)
    bad = store.put(jnp.full((1, spec.n_params), jnp.nan))
    ids = store.put(jnp.stack([spec.ravel(u) for u in ups]))
    store.free(bad)  # freed but not overwritten: still NaN in the buffer
    w = np.array([0.5, 0.3, 0.2], np.float32)
    got = weighted_aggregate_rows(store.buffer, ids, w, spec)
    want = weighted_aggregate(ups, w)
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert np.all(np.isfinite(np.asarray(x)))
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_store_grows_when_free_list_dry():
    store = UpdateStore(n_params=8, capacity=2)
    first = store.put(jnp.arange(16, dtype=jnp.float32).reshape(2, 8))
    ids = store.put(jnp.arange(80, dtype=jnp.float32).reshape(10, 8))
    assert store.capacity >= 12
    # growth preserved previously written rows
    np.testing.assert_array_equal(
        np.asarray(store.gather(first))[:, :8].ravel(),
        np.arange(16, dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(store.gather(ids))[:, :8].ravel(),
        np.arange(80, dtype=np.float32))


def test_store_put_stacked_matches_ravel():
    rng = np.random.default_rng(2)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 2, 5)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)}
    spec = RavelSpec(jax.tree.map(lambda x: x[0], tree))
    store = UpdateStore(spec.n_params)
    ids = store.put_stacked(tree)
    want = np.asarray(spec.ravel_stacked(tree))
    got = np.asarray(store.gather(ids))[:, :spec.n_params]
    np.testing.assert_array_equal(got, want)


def test_store_write_at_specific_ids():
    store = UpdateStore(n_params=4, capacity=2)
    rows = np.arange(8, dtype=np.float32).reshape(2, 4)
    store.write_at([5, 1], rows)
    assert store.capacity >= 6
    assert store.live_count == 2
    np.testing.assert_array_equal(np.asarray(store.gather([5, 1]))[:, :4],
                                  rows)
    # freshly allocated ids never collide with the rehydrated ones
    new = store.put(jnp.zeros((3, 4)))
    assert not ({5, 1} & set(map(int, new)))


# ------------------------------------------------------ row-index fast path
def _tree(rng):
    return {"conv": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
            "bias": jnp.asarray(rng.normal(size=(7,)), jnp.float32),
            "scale": jnp.asarray(rng.normal(), jnp.float32)}


@pytest.mark.parametrize("k", [1, 3, 8, 9])  # crosses the sublane multiple
def test_rows_path_matches_blob_path(k):
    rng = np.random.default_rng(k)
    ups = [_tree(rng) for _ in range(k)]
    w = rng.dirichlet(np.ones(k)).astype(np.float32)
    spec = RavelSpec(ups[0])
    store = UpdateStore(spec.n_params, capacity=2)
    ids = store.put(jnp.stack([spec.ravel(u) for u in ups]))
    got = weighted_aggregate_rows(store.buffer, ids, w, spec)
    want = weighted_aggregate(ups, w)
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert x.shape == y.shape
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_rows_path_pallas_vs_xla(monkeypatch):
    rng = np.random.default_rng(3)
    ups = [_tree(rng) for _ in range(4)]
    w = rng.dirichlet(np.ones(4)).astype(np.float32)
    spec = RavelSpec(ups[0])
    store = UpdateStore(spec.n_params)
    ids = store.put(jnp.stack([spec.ravel(u) for u in ups]))
    from repro.core import aggregation
    a = weighted_aggregate_rows(store.buffer, ids, w, spec, path="pallas")
    assert aggregation.last_path() == "pallas"
    b = weighted_aggregate_rows(store.buffer, ids, w, spec, path="xla")
    assert aggregation.last_path() == "xla"
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_rows_path_respects_out_dtype():
    rng = np.random.default_rng(5)
    ups = [_tree(rng) for _ in range(2)]
    spec = RavelSpec(ups[0])
    store = UpdateStore(spec.n_params)
    ids = store.put(jnp.stack([spec.ravel(u) for u in ups]))
    out = weighted_aggregate_rows(store.buffer, ids,
                                  np.array([0.6, 0.4], np.float32), spec,
                                  out_dtype=jnp.bfloat16)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(out))


def test_sparse_reference_set_uses_gather_and_stays_exact():
    """Once the buffer has grown far past the live set, aggregation reads
    only the referenced rows instead of sweeping the whole capacity."""
    rng = np.random.default_rng(11)
    ups = [_tree(rng) for _ in range(3)]
    spec = RavelSpec(ups[0])
    store = UpdateStore(spec.n_params, capacity=64)  # >= 4 * max(K, 8)
    ids = store.put(jnp.stack([spec.ravel(u) for u in ups]))
    w = np.array([0.2, 0.5, 0.3], np.float32)
    got = weighted_aggregate_rows(store.buffer, ids, w, spec)
    want = weighted_aggregate(ups, w)
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_resolve_update_plane(monkeypatch):
    assert resolve_update_plane("blob") == "blob"
    assert resolve_update_plane("device") == "device"
    monkeypatch.setenv("REPRO_UPDATE_PLANE", "blob")
    assert resolve_update_plane("auto") == "blob"
    monkeypatch.delenv("REPRO_UPDATE_PLANE")
    assert resolve_update_plane("auto") == "device"
    with pytest.raises(ValueError, match="unknown update plane"):
        resolve_update_plane("mongo")


# -------------------------------------------- full-run numeric equivalence
def test_blob_and_device_runs_equivalent(data, model):
    """Multi-round async (apodotiko) run: both transports must produce the
    same accuracy trajectory (atol 1e-5) and the same final global model."""
    runs = {}
    for plane in ("blob", "device"):
        ctl = Controller(_cfg(strategy="apodotiko", rounds=4,
                              concurrency_ratio=0.5, update_plane=plane),
                         model, data, list(paper_fleet(N_CLIENTS)))
        m = ctl.run()
        assert m["update_plane"] == plane
        runs[plane] = (m, ctl.params)
    hb = [a for _, _, a in runs["blob"][0]["history"]]
    hd = [a for _, _, a in runs["device"][0]["history"]]
    assert len(hb) == len(hd) >= 2  # stale updates were exercised
    np.testing.assert_allclose(hd, hb, atol=1e-5)
    for x, y in zip(jax.tree.leaves(runs["device"][1]),
                    jax.tree.leaves(runs["blob"][1])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_device_plane_moves_no_update_bytes(data, model):
    ctl = Controller(_cfg(strategy="apodotiko", update_plane="device"),
                     model, data, list(paper_fleet(N_CLIENTS)))
    m = ctl.run()
    assert m["update_host_bytes"] == 0
    ctl = Controller(_cfg(strategy="apodotiko", update_plane="blob"),
                     model, data, list(paper_fleet(N_CLIENTS)))
    m = ctl.run()
    assert m["update_host_bytes"] > 0


def test_device_plane_recycles_rows(data, model):
    ctl = Controller(_cfg(strategy="apodotiko", rounds=4,
                          update_plane="device"),
                     model, data, list(paper_fleet(N_CLIENTS)))
    ctl.run()
    # every live row is accounted for: it backs either an un-aggregated
    # pending result or an in-flight invocation (client still "running"
    # when the run ended) — aggregated/pruned/failed rows were recycled
    pending = {r.update_row for r in ctl.db.results if not r.aggregated}
    n_inflight = sum(1 for c in ctl.db.clients.values()
                     if c.status == "running")
    live = set(map(int, ctl.store.live_rows()))
    assert pending <= live
    assert len(live) == len(pending) + n_inflight


# ----------------------------------------------- checkpoint/resume of rows
def test_checkpoint_resume_live_rows_bit_exact(tmp_path, data, model):
    cfg = _cfg(strategy="apodotiko", rounds=2, update_plane="device",
               checkpoint_dir=str(tmp_path / "fl"))
    ctl = Controller(cfg, model, data, list(paper_fleet(N_CLIENTS)))
    # drive one cohort to completion WITHOUT aggregating, so the checkpoint
    # carries live un-aggregated rows (the async in-flight state)
    sel = ctl.strategy.select(ctl.db, 0)
    ctl.invoke_round(0, sel)
    assert ctl.loop.run_until(lambda: len(ctl.db.results) >= len(sel),
                              max_time=1e8)
    ctl.checkpoint()
    ids = [r.update_row for r in ctl.db.results if not r.aggregated]
    assert ids
    before = np.asarray(ctl.store.gather(ids))

    ctl2 = Controller.resume(cfg, model, data, list(paper_fleet(N_CLIENTS)))
    ids2 = [r.update_row for r in ctl2.db.results if not r.aggregated]
    assert ids2 == ids  # handles survived verbatim
    np.testing.assert_array_equal(np.asarray(ctl2.store.gather(ids2)), before)
    m = ctl2.run()  # the rehydrated rows are aggregatable
    assert m["rounds"] >= 1


def test_cross_plane_resume_with_pending_results_rejected(tmp_path, data,
                                                          model):
    """Blob records carry update_row=-1 (which would silently index the
    last buffer row); resuming a checkpoint with in-flight results under
    the other plane must fail loudly, not corrupt the aggregate."""
    cfg = _cfg(strategy="apodotiko", rounds=2, update_plane="device",
               checkpoint_dir=str(tmp_path / "fl"))
    ctl = Controller(cfg, model, data, list(paper_fleet(N_CLIENTS)))
    sel = ctl.strategy.select(ctl.db, 0)
    ctl.invoke_round(0, sel)
    assert ctl.loop.run_until(lambda: len(ctl.db.results) >= len(sel),
                              max_time=1e8)
    ctl.checkpoint()
    cfg_blob = _cfg(strategy="apodotiko", rounds=2, update_plane="blob",
                    checkpoint_dir=str(tmp_path / "fl"))
    with pytest.raises(ValueError, match="update_plane"):
        Controller.resume(cfg_blob, model, data, list(paper_fleet(N_CLIENTS)))


def test_checkpoint_resume_full_run(tmp_path, data, model):
    cfg = _cfg(strategy="apodotiko", rounds=2, update_plane="device",
               checkpoint_dir=str(tmp_path / "fl"), checkpoint_every=1)
    ctl = Controller(cfg, model, data, list(paper_fleet(N_CLIENTS)))
    ctl.run()
    ctl.checkpoint()
    cfg2 = _cfg(strategy="apodotiko", rounds=4, update_plane="device",
                checkpoint_dir=str(tmp_path / "fl"))
    ctl2 = Controller.resume(cfg2, model, data, list(paper_fleet(N_CLIENTS)))
    assert ctl2.db.round == 2
    m = ctl2.run()
    assert m["rounds"] >= 1


# ----------------------------------------------------- evaluation fast path
def test_eval_scan_matches_batched_loop(data, model):
    ctl = Controller(_cfg(), model, data, list(paper_fleet(N_CLIENTS)))
    fast = ctl.evaluate()
    # reference: exact accuracy over the whole eval set in one batch
    xs, ys = data.eval_x, data.eval_y
    acc = float(jnp.mean(
        (jnp.argmax(model.predict(ctl.params, jnp.asarray(xs)), -1)
         == jnp.asarray(ys)).astype(jnp.float32)))
    assert fast == pytest.approx(acc, abs=1e-6)


def test_eval_falls_back_without_predict(data, model):
    class AccOnly:
        def __init__(self, inner):
            self._inner = inner

        def init(self, rng):
            return self._inner.init(rng)

        def loss(self, p, b):
            return self._inner.loss(p, b)

        def accuracy(self, p, b):
            return self._inner.accuracy(p, b)

    ctl = Controller(_cfg(rounds=1), AccOnly(model), data,
                     list(paper_fleet(N_CLIENTS)))
    assert np.isfinite(ctl.evaluate())


# ------------------------------------------------------- compile-cache key
def test_compile_cache_key_not_id_based(data):
    """Two distinct model objects must never share a cache entry via id()
    reuse; the weak-token key is unique per live object and never recycled."""
    from repro.core.client import _COMPILE_CACHE, _model_token
    m1, m2 = ProxyCNN(35), ProxyCNN(35)
    t1, t2 = _model_token(m1), _model_token(m2)
    assert t1 != t2
    assert _model_token(m1) == t1  # stable across calls
    ctl1 = Controller(_cfg(rounds=1), m1, data, list(paper_fleet(N_CLIENTS)))
    n0 = len(_COMPILE_CACHE)
    ctl1.run()
    assert len(_COMPILE_CACHE) > n0
    # same model object reused by a second controller: cache entries shared
    n1 = len(_COMPILE_CACHE)
    Controller(_cfg(rounds=1), m1, data, list(paper_fleet(N_CLIENTS))).run()
    assert len(_COMPILE_CACHE) == n1
