"""Sweep engine: grid expansion, deterministic seeding, result-table
schema/derivations, concurrent-vs-serial equivalence, and a tiny real
end-to-end sweep."""
import copy

import numpy as np
import pytest

from repro.sweep import (
    PRESETS,
    SCHEMA,
    LocalRunner,
    ResultTable,
    RunSpec,
    SweepScale,
    SweepSpec,
    expand_grid,
    get_preset,
    run_sweep,
)


def small_spec(**kw):
    base = dict(name="t", datasets=("mnist", "speech"),
                strategies=("fedavg", "fedbuff", "apodotiko"),
                seeds=(0, 1), scale=SweepScale(rounds=4))
    base.update(kw)
    return SweepSpec(**base)


class FakeRunner:
    """Deterministic canned metrics: apodotiko converges 2x faster than
    fedavg, fedbuff 1.25x; cold starts and cost scale the same way."""

    SPEED = {"fedavg": 1.0, "fedbuff": 1.25, "apodotiko": 2.0}

    def __init__(self, fail_on=()):
        self.calls = []
        self.fail_on = set(fail_on)

    def __call__(self, run: RunSpec) -> dict:
        self.calls.append(run.key)
        if run.strategy in self.fail_on:
            raise RuntimeError("boom")
        v = self.SPEED[run.strategy]
        hist = [(t * 100.0 / v, r, 0.1 * (t + 1)) for t, r in
                zip(range(8), range(8))]
        return {"strategy": run.strategy, "rounds": 8,
                "final_accuracy": 0.8, "history": hist,
                "total_time": 800.0 / v, "total_cost_usd": 4.0 / v,
                "cold_start_ratio": 0.4 / v, "n_invocations": 100}


# ------------------------------------------------------------------- grid
def test_expand_grid_full_product_unique_keys():
    spec = small_spec()
    runs = expand_grid(spec)
    assert len(runs) == spec.n_runs == 2 * 3 * 2
    keys = [r.key for r in runs]
    assert len(set(keys)) == len(keys)


def test_expand_grid_deterministic():
    a = expand_grid(small_spec())
    b = expand_grid(small_spec())
    assert a == b  # same cells, same order


def test_seeds_flow_into_cells_and_config():
    runs = expand_grid(small_spec(seeds=(7, 13)))
    assert sorted({r.seed for r in runs}) == [7, 13]
    runner = LocalRunner(SweepScale(n_clients=6, clients_per_round=3))
    run = next(r for r in runs if r.seed == 13 and r.strategy == "apodotiko")
    cfg = runner.config(run)
    assert cfg.seed == 13 and cfg.strategy == "apodotiko"
    assert cfg.n_clients == 6 and cfg.clients_per_round == 3
    # data partition seed is sweep-wide, not per-cell
    assert runner.scale.data_seed == 0


def test_overrides_reach_flconfig():
    spec = small_spec(overrides=(("failure_rate", 0.1), ("local_epochs", 2)))
    run = expand_grid(spec)[0]
    cfg = LocalRunner(spec.scale).config(run)
    assert cfg.failure_rate == 0.1 and cfg.local_epochs == 2


# ------------------------------------------------------------------ table
def test_result_table_schema_and_speedups():
    spec = small_spec(seeds=(0,))
    table = run_sweep(spec, runner=FakeRunner())
    assert len(table.rows) == spec.n_runs
    for row in table.rows:
        assert set(row) == set(SCHEMA)
        assert row["error"] is None
    for row in table.rows:
        if row["strategy"] == "fedavg":
            assert row["speedup_vs_fedavg"] == pytest.approx(1.0)
            assert row["cost_vs_fedavg"] == pytest.approx(1.0)
        if row["strategy"] == "apodotiko":
            assert row["speedup_vs_fedavg"] == pytest.approx(2.0, rel=0.01)
            assert row["cold_start_reduction_vs_fedavg"] == pytest.approx(
                2.0, rel=0.01)
    assert table.mean_speedup("fedbuff") == pytest.approx(1.25, rel=0.01)


def test_concurrent_matches_serial():
    spec = small_spec()
    serial = run_sweep(spec, runner=FakeRunner(), max_workers=1)
    threaded = run_sweep(spec, runner=FakeRunner(), max_workers=4)
    assert serial.rows == threaded.rows


def test_empty_history_run_does_not_poison_target():
    """A run that never completed an eval (sim budget blown in round 1)
    must not drag the group's common-accuracy target to 0."""

    class EmptyHistoryRunner(FakeRunner):
        def __call__(self, run):
            m = super().__call__(run)
            if run.strategy == "fedbuff":
                m["history"] = []
                m["rounds"] = 0
            return m

    table = run_sweep(small_spec(seeds=(0,)), runner=EmptyHistoryRunner())
    by_strat = {r["strategy"]: r for r in table.rows
                if r["dataset"] == "mnist"}
    assert by_strat["fedavg"]["target_acc"] > 0
    assert by_strat["fedbuff"]["time_to_target_s"] is None
    assert by_strat["fedbuff"]["speedup_vs_fedavg"] is None
    # the healthy strategies keep a meaningful comparison
    assert by_strat["apodotiko"]["speedup_vs_fedavg"] == pytest.approx(
        2.0, rel=0.01)


def test_failed_cell_keeps_row():
    spec = small_spec(seeds=(0,))
    table = run_sweep(spec, runner=FakeRunner(fail_on={"fedbuff"}))
    bad = [r for r in table.rows if r["strategy"] == "fedbuff"]
    good = [r for r in table.rows if r["strategy"] != "fedbuff"]
    assert all("boom" in r["error"] for r in bad)
    assert all(r["time_to_target_s"] is None for r in bad)
    assert all(r["error"] is None for r in good)


def test_renderers():
    table = run_sweep(small_spec(seeds=(0,)), runner=FakeRunner())
    md = table.to_markdown(columns=("dataset", "strategy",
                                    "speedup_vs_fedavg"))
    assert "apodotiko" in md and md.count("\n") == len(table.rows) + 2
    csv = table.to_csv()
    lines = csv.strip().split("\n")
    assert lines[0].split(",") == list(SCHEMA)
    assert len(lines) == len(table.rows) + 1
    sub = table.select(dataset="mnist", strategy="apodotiko")
    assert len(sub.rows) == 1


def test_presets_registry():
    assert "paper_mnist" in PRESETS and "paper_tables" in PRESETS
    spec = get_preset("paper_mnist")
    assert len(spec.strategies) == 6
    with pytest.raises(KeyError, match="unknown sweep preset"):
        get_preset("nope")


def test_preset_specs_are_immutable():
    spec = get_preset("smoke")
    with pytest.raises(Exception):
        spec.name = "hacked"
    assert copy.deepcopy(spec) == spec


# ------------------------------------------------------------ end-to-end
def test_tiny_real_sweep_end_to_end():
    """Two strategies, real training on the simulator, real table."""
    spec = SweepSpec(name="e2e", datasets=("mnist",),
                     strategies=("fedavg", "apodotiko"),
                     scale=SweepScale(n_clients=6, clients_per_round=3,
                                      rounds=3, data_scale=0.05,
                                      local_epochs=1, sim_budget=300.0,
                                      eval_every=1))
    table = run_sweep(spec, max_workers=2)
    assert [r["strategy"] for r in table.rows] == ["fedavg", "apodotiko"]
    for row in table.rows:
        assert row["error"] is None
        assert row["rounds"] >= 1
        assert row["sim_time_s"] > 0
        assert 0.0 <= row["final_acc"] <= 1.0
        assert row["cost_usd"] > 0
        assert row["n_invocations"] >= 3
    assert table.rows[0]["speedup_vs_fedavg"] == pytest.approx(1.0)


def test_local_runner_shares_setup():
    scale = SweepScale(n_clients=6, clients_per_round=3, rounds=2,
                       data_scale=0.05, local_epochs=1)
    runner = LocalRunner(scale)
    runs = expand_grid(SweepSpec(name="s", datasets=("mnist",),
                                 strategies=("fedavg", "apodotiko"),
                                 scale=scale))
    runner.warm(runs)
    assert runner.data("mnist") is runner.data("mnist")
    assert runner.model("mnist") is runner.model("mnist")
    f1, f2 = runner.fleet("heterogeneous"), runner.fleet("heterogeneous")
    assert f1 is f2
    assert np.sum([p.is_gpu for p in f1]) >= 0  # built from paper mix


def test_control_plane_axis_expands():
    spec = small_spec(strategies=("apodotiko",), datasets=("mnist",),
                      seeds=(0,), control_planes=("columnar", "object"))
    runs = expand_grid(spec)
    assert len(runs) == spec.n_runs == 2
    assert {r.control_plane for r in runs} == {"columnar", "object"}
    assert all("/ctl=" in r.key for r in runs)
    assert len({r.group for r in runs}) == 2  # planes never share a baseline
    runner = LocalRunner(SweepScale(n_clients=6, clients_per_round=3))
    cfg = runner.config(runs[0])
    assert cfg.control_plane == runs[0].control_plane


def test_fault_profile_axis_expands():
    spec = small_spec(strategies=("apodotiko",), datasets=("mnist",),
                      seeds=(0,), fault_profiles=("none", "crash-heavy"))
    runs = expand_grid(spec)
    assert len(runs) == spec.n_runs == 2
    assert {r.fault_profile for r in runs} == {"none", "crash-heavy"}
    assert all("/faults=" in r.key for r in runs)
    # schedules never share a baseline: a chaos cell's speedup must be
    # ratioed against the FedAvg that suffered the same faults
    assert len({r.group for r in runs}) == 2
    runner = LocalRunner(SweepScale(n_clients=6, clients_per_round=3))
    cfg = runner.config(runs[1])
    assert cfg.fault_profile == "crash-heavy"
    # default stays out of the key so pre-existing cache keys are stable
    assert "/faults=" not in expand_grid(small_spec())[0].key


def test_chaos_preset_registered():
    spec = get_preset("chaos")
    assert "none" in spec.fault_profiles
    assert {"crash-heavy", "outage-window", "lossy-network"} <= set(
        spec.fault_profiles)
    assert dict(spec.overrides)["retry_budget"] > 0
    assert len(expand_grid(spec)) == spec.n_runs


def test_controlplane_presets_registered():
    spec = get_preset("controlplane_ablation")
    assert set(spec.control_planes) == {"columnar", "object"}
    assert len(expand_grid(spec)) == spec.n_runs
    fleet = get_preset("fleet_scale")
    assert fleet.control_planes == ("columnar",)
    assert "apodotiko-topk" in fleet.strategies
    assert fleet.scale.n_clients >= 256
