"""Model-zoo correctness: exact paper param counts, MoE dispatch vs dense
reference, SSD chunked vs quadratic oracle, prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import build_model
from repro.models.paper_models import (
    FemnistCNN,
    MnistCNN,
    ShakespeareLSTM,
    SpeechCNN,
)

RNG = jax.random.PRNGKey(0)


def _n_params(model):
    params, _ = model.init(RNG)
    return sum(int(x.size) for x in jax.tree.leaves(params))


# -- the paper's exact trainable parameter counts (IV-A2) ---------------------

def test_mnist_cnn_param_count():
    assert _n_params(MnistCNN()) == 582_026


def test_femnist_cnn_param_count():
    assert _n_params(FemnistCNN()) == 6_603_710


def test_shakespeare_lstm_param_count():
    assert _n_params(ShakespeareLSTM()) == 818_402


def test_speech_cnn_param_count():
    assert _n_params(SpeechCNN()) == 67_267


def test_paper_models_train_step_reduces_loss():
    model = MnistCNN()
    params, _ = model.init(RNG)
    x = jax.random.normal(RNG, (16, 28, 28, 1))
    y = jax.random.randint(RNG, (16,), 0, 10)
    loss0, _ = model.loss(params, {"x": x, "y": y})

    @jax.jit
    def step(p):
        g = jax.grad(lambda p_: model.loss(p_, {"x": x, "y": y})[0])(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    for _ in range(10):
        params = step(params)
    loss1, _ = model.loss(params, {"x": x, "y": y})
    assert float(loss1) < float(loss0)


# -- MoE sort-based dispatch vs masked-dense reference ------------------------

def test_moe_dispatch_matches_reference():
    from repro.models.moe import init_moe, moe_forward, moe_reference
    from repro.models.common import ParamFactory

    cfg = get_config("deepseek-v2-lite-16b", smoke=True).with_(
        capacity_factor=8.0)  # high capacity: no drops -> exact match
    pf = ParamFactory(RNG, jnp.float32)
    init_moe(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    y, aux = moe_forward(pf.params, x, cfg)
    y_ref = moe_reference(pf.params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models.moe import init_moe, moe_forward
    from repro.models.common import ParamFactory

    cfg = get_config("deepseek-v2-lite-16b", smoke=True).with_(
        capacity_factor=0.25)  # force drops
    pf = ParamFactory(RNG, jnp.float32)
    init_moe(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_forward(pf.params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


# -- Mamba2 SSD: chunked scan vs quadratic dual-form oracle -------------------

def test_ssd_chunked_matches_quadratic_oracle():
    from repro.models.ssm import ssd_chunked, ssd_reference

    B, S, H, P, N = 2, 64, 4, 8, 16
    k = jax.random.PRNGKey(2)
    xd = jax.random.normal(k, (B, S, H, P)) * 0.2
    a = -jax.random.uniform(jax.random.PRNGKey(3), (B, S, H)) * 0.5
    Bm = jax.random.normal(jax.random.PRNGKey(4), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(5), (B, S, N)) * 0.3
    for chunk in (8, 16, 64):
        y, _ = ssd_chunked(xd, a, Bm, Cm, chunk)
        y_ref = ssd_reference(xd, a, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_prefill_states():
    """Run S steps of recurrent decode; compare to chunked prefill output."""
    from repro.configs.base import get_config as gc
    from repro.models.common import ParamFactory
    from repro.models.ssm import (
        mamba2_cache_shape, mamba2_decode_step, mamba2_forward, init_mamba2)

    cfg = gc("mamba2-370m", smoke=True)
    pf = ParamFactory(RNG, jnp.float32)
    init_mamba2(pf, cfg)
    p = pf.params
    B, S = 1, 8
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, cfg.d_model)) * 0.3
    y_full, _ = mamba2_forward(p, x, cfg)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         mamba2_cache_shape(cfg, B, jnp.float32))
    ys = []
    for t in range(S):
        y_t, cache = mamba2_decode_step(p, x[:, t:t + 1], cfg, cache)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


# -- prefill -> decode consistency for attention LMs --------------------------

@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-lite-16b"])
def test_prefill_then_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(9), (B, S + 1), 0,
                                cfg.vocab_size)
    # full forward logits at position S-1 predict token S
    logits_full, _, _ = model.apply(params, {"tokens": tokens[:, :S]})
    # prefill S-1 tokens into a cache of length S+1, then decode token S-1
    logits_pre, caches, _ = model.apply(params, {"tokens": tokens[:, :S - 1]},
                                        make_cache=True, cache_len=S + 1)
    logits_dec, caches = model.decode_step(params, caches,
                                           tokens[:, S - 1:S],
                                           jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
