"""Property-based tests (hypothesis) on the sharding rule engine.

The rule engine's contract is *silent degradation*: a logical-axis rule
only ever shards a dim by a mesh-axis product that divides it exactly,
falling back to replication otherwise — never uneven shards, never
padding. These properties pin that contract over random meshes/dims
(the deterministic examples live in tests/test_sharding.py).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.sharding.rules import (  # noqa: E402
    DEFAULT_RULES,
    logical_spec,
    zero1_extend,
)

PROP = dict(max_examples=80, deadline=None)
AXES = st.fixed_dictionaries({"data": st.sampled_from([1, 2, 4, 8, 16]),
                              "model": st.sampled_from([1, 2, 4, 8, 16])})


class _FakeMesh:
    """Shape-only mesh stand-in (rule resolution reads only .shape)."""

    def __init__(self, shape):
        self.shape = shape


def _axes_of(part):
    if part is None:
        return ()
    return (part,) if isinstance(part, str) else tuple(part)


@given(AXES, st.integers(1, 4096))
@settings(**PROP)
def test_prop_divisibility_never_violated(shape, dim):
    """Non-divisible dims degrade to replication — the sharded product
    always divides the dim exactly."""
    mesh = _FakeMesh(shape)
    spec = logical_spec(("batch", "ffn"), (dim, dim), mesh, DEFAULT_RULES)
    parts = list(spec) + [None] * (2 - len(spec))
    for part in parts:
        n = 1
        for a in _axes_of(part):
            n *= shape[a]
        assert dim % n == 0


@given(AXES,
       st.lists(st.sampled_from([None, "batch", "ffn", "heads", "vocab",
                                 "seq"]),
                min_size=1, max_size=4),
       st.data())
@settings(**PROP)
def test_prop_each_mesh_axis_used_at_most_once(shape, names, data):
    mesh = _FakeMesh(shape)
    dims = tuple(data.draw(st.integers(1, 2048)) for _ in names)
    spec = logical_spec(names, dims, mesh, DEFAULT_RULES)
    used = [a for part in spec for a in _axes_of(part)]
    assert len(used) == len(set(used))


@given(AXES,
       st.lists(st.sampled_from([None, "batch", "ffn", "heads", "vocab"]),
                min_size=1, max_size=3),
       st.data())
@settings(**PROP)
def test_prop_tuple_rules_resolve_to_listed_axes(shape, names, data):
    """Whatever a rule resolves to is a subset of the axes it listed —
    the engine never invents an axis."""
    mesh = _FakeMesh(shape)
    dims = tuple(data.draw(st.integers(1, 2048)) for _ in names)
    spec = logical_spec(names, dims, mesh, DEFAULT_RULES)
    for name, part in zip(names, list(spec) + [None] * len(names)):
        rule = DEFAULT_RULES.get(name) if name else None
        allowed = set(_axes_of(rule)) if rule else set()
        assert set(_axes_of(part)) <= allowed


@given(AXES, st.integers(1, 4096), st.integers(1, 4096))
@settings(**PROP)
def test_prop_zero1_only_adds_divisible_data_axis(shape, d0, d1):
    """zero1_extend either returns the spec unchanged or shards exactly
    one previously-replicated dim by 'data' — and only when it divides."""
    mesh = _FakeMesh(shape)
    base = P(None, "model") if d1 % shape["model"] == 0 else P()
    out = zero1_extend(base, (d0, d1), mesh)
    parts = list(out) + [None] * (2 - len(out))
    base_parts = list(base) + [None] * (2 - len(base))
    added = [(i, p) for i, (p, b) in enumerate(zip(parts, base_parts))
             if p != b]
    if not added:
        return
    assert len(added) == 1
    i, p = added[0]
    assert p == "data" and base_parts[i] is None
    assert (d0, d1)[i] % shape["data"] == 0
