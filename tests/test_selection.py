"""Tests for Algorithm 3 (probabilistic client selection + booster)."""
import numpy as np
import pytest

from repro.core.database import ClientRecord, Database
from repro.core.selection import select_clients


def _db(n=10, invoked=0, busy=(), durations=None):
    db = Database()
    for cid in range(n):
        rec = ClientRecord(client_id=cid, hardware="cpu1",
                           data_cardinality=100, batch_size=10, local_epochs=5)
        if cid < invoked:
            rec.n_invocations = 1
            rec.durations = [durations[cid] if durations else 10.0]
        if cid in busy:
            rec.status = "running"
        db.register_client(rec)
    return db


def test_uninvoked_clients_prioritized():
    db = _db(n=10, invoked=0)
    sel = select_clients(db, 5, np.random.default_rng(0))
    assert len(sel) == 5
    assert len(set(sel)) == 5


def test_partial_uninvoked_pool_fills_from_scored():
    db = _db(n=10, invoked=8)
    sel = select_clients(db, 5, np.random.default_rng(0))
    # the two uninvoked clients (8, 9) must be included first
    assert {8, 9} <= set(sel)
    assert len(sel) == 5


def test_busy_clients_never_selected():
    db = _db(n=10, invoked=10, busy={0, 1, 2})
    for seed in range(5):
        sel = select_clients(db, 5, np.random.default_rng(seed))
        assert not ({0, 1, 2} & set(sel))


def test_fast_clients_selected_more_often():
    # clients 0-4 are 20x faster than 5-9 -> far higher selection probability
    durations = [1.0] * 5 + [20.0] * 5
    counts = np.zeros(10)
    for seed in range(200):
        db = _db(n=10, invoked=10, durations=durations)
        sel = select_clients(db, 3, np.random.default_rng(seed))
        counts[sel] += 1
    assert counts[:5].sum() > 2.5 * counts[5:].sum()


def test_booster_reset_on_selection_and_promoted_otherwise():
    db = _db(n=6, invoked=6)
    sel = select_clients(db, 3, np.random.default_rng(0),
                         adjustment_rate=0.2)
    for cid, rec in db.clients.items():
        if cid in sel:
            assert rec.booster == pytest.approx(1.0)
        else:
            assert rec.booster == pytest.approx(1.2)


def test_booster_compounds_for_repeatedly_skipped():
    durations = [1.0] * 5 + [1000.0] * 5  # 5-9 are heavy stragglers
    db = _db(n=10, invoked=10, durations=durations)
    for seed in range(4):
        select_clients(db, 2, np.random.default_rng(seed + 1))
    # some straggler never selected: booster grew ~1.2^k, k>=1
    max_boost = max(db.clients[c].booster for c in range(5, 10))
    assert max_boost >= 1.2 ** 2


def test_selection_never_exceeds_pool():
    db = _db(n=3, invoked=3)
    sel = select_clients(db, 10, np.random.default_rng(0))
    assert len(sel) == 3
