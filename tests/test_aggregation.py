"""Tests for staleness-weighted asynchronous aggregation (paper III-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (
    incremental_aggregate,
    staleness_weights,
    weighted_aggregate,
)


def _trees(k, seed=0):
    rng = np.random.default_rng(seed)
    return [{"a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
             "b": {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}}
            for _ in range(k)]


def test_weighted_aggregate_matches_manual():
    ups = _trees(3)
    w = np.array([0.5, 0.3, 0.2], np.float32)
    out = weighted_aggregate(ups, w)
    manual = sum(wi * np.asarray(u["a"]) for wi, u in zip(w, ups))
    np.testing.assert_allclose(np.asarray(out["a"]), manual, rtol=1e-6)


def test_uniform_weights_equal_mean():
    ups = _trees(4)
    w = np.full(4, 0.25, np.float32)
    out = weighted_aggregate(ups, w)
    mean = np.mean([np.asarray(u["b"]["w"]) for u in ups], axis=0)
    np.testing.assert_allclose(np.asarray(out["b"]["w"]), mean, rtol=1e-6)


def test_staleness_weights_normalized():
    w = staleness_weights(rounds=[10, 9, 7], cardinalities=[100, 50, 200],
                          current_round=10)
    assert w.sum() == pytest.approx(1.0, rel=1e-6)
    assert (w > 0).all()


def test_staleness_damps_older_updates():
    # same cardinality: current-round update must outweigh stale one
    w = staleness_weights(rounds=[10, 5], cardinalities=[100, 100],
                          current_round=10)
    assert w[0] > w[1]
    assert w[0] / w[1] == pytest.approx(np.sqrt(6), rel=1e-6)


def test_cardinality_weighting():
    w = staleness_weights(rounds=[10, 10], cardinalities=[300, 100],
                          current_round=10)
    assert w[0] / w[1] == pytest.approx(3.0, rel=1e-6)


def test_eq1_option():
    w = staleness_weights(rounds=[4, 2], cardinalities=[1, 1],
                          current_round=4, fn="eq1")
    assert w[0] / w[1] == pytest.approx(2.0, rel=1e-6)


def test_incremental_matches_batch():
    ups = _trees(5, seed=3)
    w = np.array([0.1, 0.2, 0.3, 0.25, 0.15], np.float32)
    batch = weighted_aggregate(ups, w)
    acc = None
    for u, wi in zip(ups, w):
        acc = incremental_aggregate(acc, u, float(wi))
    np.testing.assert_allclose(np.asarray(acc["a"]),
                               np.asarray(batch["a"]), rtol=1e-5, atol=1e-7)


def test_kernel_path_matches_xla_path():
    from repro.kernels import ops
    ups = _trees(3, seed=7)
    w = np.array([0.6, 0.3, 0.1], np.float32)
    a = weighted_aggregate(ups, w)
    b = ops.aggregate_pytree(ups, w, interpret=True)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


# ------------------------------------------------- Pallas default dispatch
def _ragged_trees(k, seed=0):
    """Pytrees with ragged leaf shapes (incl. a scalar) whose total size is
    NOT a multiple of the kernel block — exercises both pad paths."""
    rng = np.random.default_rng(seed)
    return [{"conv": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
             "bias": jnp.asarray(rng.normal(size=(7,)), jnp.float32),
             "scale": jnp.asarray(rng.normal(), jnp.float32),
             "head": {"w": jnp.asarray(rng.normal(size=(2, 3, 4)),
                                       jnp.float32)}}
            for _ in range(k)]


def test_default_path_is_pallas():
    from repro.core import aggregation
    ups = _ragged_trees(3, seed=1)
    w = np.array([0.5, 0.3, 0.2], np.float32)
    weighted_aggregate(ups, w)
    assert aggregation.last_path() == "pallas"


@pytest.mark.parametrize("k", [1, 3, 5, 9])  # crosses the sublane multiple
def test_pallas_matches_xla_on_ragged_pytree(k):
    rng = np.random.default_rng(k)
    ups = _ragged_trees(k, seed=k)
    w = rng.dirichlet(np.ones(k)).astype(np.float32)
    a = weighted_aggregate(ups, w, path="pallas")
    b = weighted_aggregate(ups, w, path="xla")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.shape == y.shape
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


def test_pallas_path_respects_out_dtype():
    ups = _ragged_trees(2, seed=3)
    w = np.array([0.7, 0.3], np.float32)
    out = weighted_aggregate(ups, w, out_dtype=jnp.bfloat16, path="pallas")
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(out))


def test_unknown_path_rejected():
    ups = _ragged_trees(2)
    with pytest.raises(ValueError, match="unknown aggregation path"):
        weighted_aggregate(ups, np.array([0.5, 0.5], np.float32),
                           path="cuda")


def test_auto_size_guard_off_tpu(monkeypatch):
    """Off-TPU, auto dispatch falls back to XLA above the interpret-mode
    size cap (the kernel stays available via path="pallas")."""
    from repro.core import aggregation
    monkeypatch.setattr(aggregation, "_INTERP_MAX_N", 10)
    ups = _ragged_trees(2, seed=9)  # 44 params > 10
    w = np.array([0.5, 0.5], np.float32)
    weighted_aggregate(ups, w)
    assert aggregation.last_path() == "xla"
    weighted_aggregate(ups, w, path="pallas")
    assert aggregation.last_path() == "pallas"


def test_env_var_forces_xla(monkeypatch):
    from repro.core import aggregation
    monkeypatch.setenv("REPRO_AGG_PATH", "xla")
    ups = _ragged_trees(2, seed=5)
    weighted_aggregate(ups, np.array([0.4, 0.6], np.float32))
    assert aggregation.last_path() == "xla"
