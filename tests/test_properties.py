"""Property-based tests (hypothesis) on the system's core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.aggregation import staleness_weights, weighted_aggregate
from repro.core.scoring import calculate_score
from repro.core.staleness import eq2_apodotiko

import jax.numpy as jnp

SETTINGS = dict(max_examples=50, deadline=None)


@given(st.integers(0, 1000), st.integers(0, 50))
@settings(**SETTINGS)
def test_eq2_in_unit_interval(t, staleness):
    w = eq2_apodotiko(t, t + staleness)
    assert 0 < w <= 1.0
    if staleness == 0:
        assert w == 1.0


@given(st.lists(st.floats(0.5, 1e4), min_size=1, max_size=12),
       st.floats(1.0, 3.0), st.integers(1, 10_000))
@settings(**SETTINGS)
def test_score_positive_and_linear_in_booster(durations, booster, card):
    s1 = calculate_score(1.0, durations, card, 5, 10, 0.8)
    sb = calculate_score(booster, durations, card, 5, 10, 0.8)
    assert s1 > 0
    assert sb == pytest.approx(booster * s1, rel=1e-9)


@given(st.lists(st.floats(1.0, 100.0), min_size=1, max_size=8))
@settings(**SETTINGS)
def test_score_bounded_by_best_and_worst_round(durations):
    """Weighted average of per-round scores lies within their range."""
    card, E, B = 100, 5, 10
    per_round = [card * (card * E / B) / d for d in durations]
    s = calculate_score(1.0, durations, card, E, B, 0.8)
    assert min(per_round) - 1e-6 <= s <= max(per_round) + 1e-6


@given(st.lists(st.tuples(st.integers(0, 20), st.integers(1, 1000)),
                min_size=1, max_size=10),
       st.integers(20, 25))
@settings(**SETTINGS)
def test_staleness_weights_form_distribution(pairs, T):
    rounds = [p[0] for p in pairs]
    cards = [p[1] for p in pairs]
    w = staleness_weights(rounds, cards, T)
    assert w.shape == (len(pairs),)
    assert abs(float(w.sum()) - 1.0) < 1e-5
    assert (w >= 0).all()


@given(st.integers(1, 6), st.integers(1, 32))
@settings(max_examples=20, deadline=None)
def test_aggregation_convex_hull(k, n):
    """With weights summing to 1, each output element lies within the
    [min, max] envelope of the inputs (convex combination)."""
    rng = np.random.default_rng(k * 100 + n)
    ups = [{"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
           for _ in range(k)]
    w = rng.dirichlet(np.ones(k)).astype(np.float32)
    out = np.asarray(weighted_aggregate(ups, w)["w"])
    stack = np.stack([np.asarray(u["w"]) for u in ups])
    assert (out <= stack.max(0) + 1e-5).all()
    assert (out >= stack.min(0) - 1e-5).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_quantization_roundtrip_error_bound(seed):
    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(0.1, 10),
                               size=(8 * 256,)), jnp.float32)
    q, s = ops.quantize_q8(x, interpret=True)
    d = ops.dequantize_q8(q, s, interpret=True)
    err = np.abs(np.asarray(d) - np.asarray(x)).reshape(-1, 256)
    assert (err <= np.asarray(s)[:, None] * 0.5 + 1e-6).all()


@given(st.integers(1, 40), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_selection_respects_pool_and_busy(n_clients, per_round):
    from repro.core.database import ClientRecord, Database
    from repro.core.selection import select_clients
    db = Database()
    rng = np.random.default_rng(n_clients)
    busy = set(rng.choice(n_clients, size=n_clients // 3, replace=False).tolist())
    for cid in range(n_clients):
        rec = ClientRecord(client_id=cid, hardware="cpu1", data_cardinality=10,
                           batch_size=5, local_epochs=1)
        rec.n_invocations = int(rng.integers(0, 3))
        if rec.n_invocations:
            rec.durations = [float(rng.uniform(1, 50))]
        if cid in busy:
            rec.status = "running"
        db.register_client(rec)
    sel = select_clients(db, per_round, rng)
    assert len(sel) == len(set(sel))
    assert len(sel) <= per_round
    assert not (set(sel) & busy)


@given(st.lists(st.floats(0.1, 1e4), min_size=1, max_size=40),
       st.floats(0.3, 0.95), st.integers(1, 10_000))
@settings(**SETTINGS)
def test_incremental_ema_matches_full_recompute(durations, decay, card):
    """The O(1) ema_push state equals the O(history) full recompute over
    the complete duration history (Horner vs direct evaluation of the same
    decay-weighted sum)."""
    from repro.core.scoring import calculate_score, ema_push, ema_score
    num, den = 0.0, 0.0
    E, B = 5, 10
    upd = card * E / B
    for t in durations:                      # oldest -> newest
        num, den = ema_push(num, den, card * (upd / max(t, 1e-9)), decay)
    incremental = ema_score(2.0, num, den)
    full = calculate_score(2.0, list(reversed(durations)), card, E, B, decay)
    assert incremental == pytest.approx(full, rel=1e-9)
    assert ema_score(2.0, 0.0, 0.0) == 0.0


@given(st.lists(st.floats(0.5, 1e4), min_size=1, max_size=10),
       st.floats(0.3, 0.95), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_ema_push_out_of_order_landings(durations, decay, seed):
    """Results land in arrival order, not invocation order. Invariants of
    the fold under ANY landing permutation: the denominator depends only
    on the landing COUNT (bitwise — it is the same geometric sum), and
    the normalized score stays inside the convex hull of the per-round
    scores."""
    from repro.core.scoring import ema_push, per_round_score
    rng = np.random.default_rng(seed)
    card, E, B = 100, 5, 10
    scores = [per_round_score(t, card, E, B) for t in durations]
    shuffled = list(scores)
    rng.shuffle(shuffled)
    num_a = den_a = num_b = den_b = 0.0
    for s in scores:
        num_a, den_a = ema_push(num_a, den_a, s, decay)
    for s in shuffled:
        num_b, den_b = ema_push(num_b, den_b, s, decay)
    assert den_a == den_b                       # count-only, order-free
    for num, den in ((num_a, den_a), (num_b, den_b)):
        assert min(scores) - 1e-9 <= num / den <= max(scores) + 1e-9


@given(st.lists(st.floats(0.5, 1e4), min_size=1, max_size=10),
       st.floats(0.3, 0.95), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_window_accumulate_out_of_order_landings(durations, decay, seed):
    """window_accumulate over a shuffled landing order: the norm depends
    only on the window length (bitwise), and the windowed score stays in
    the per-round-score hull. The incremental EMA fold over the SAME
    landing order equals the windowed recompute of that order's
    newest-first history — the O(1) and O(W) paths agree for every
    arrival permutation, not just the in-order one."""
    from repro.core.scoring import ema_push, per_round_score, window_accumulate
    rng = np.random.default_rng(seed)
    card, E, B = 100, 5, 10
    arrival = list(durations)
    rng.shuffle(arrival)                        # out-of-order landings
    ws_in, norm_in = window_accumulate(list(reversed(durations)),
                                       card, E, B, decay)
    ws_arr, norm_arr = window_accumulate(list(reversed(arrival)),
                                         card, E, B, decay)
    assert norm_in == norm_arr                  # length-only, order-free
    per_round = [per_round_score(t, card, E, B) for t in durations]
    assert min(per_round) - 1e-9 <= ws_arr / norm_arr \
        <= max(per_round) + 1e-9
    num, den = 0.0, 0.0
    for t in arrival:
        num, den = ema_push(num, den, per_round_score(t, card, E, B), decay)
    assert num == pytest.approx(ws_arr, rel=1e-9)
    assert den == pytest.approx(norm_arr, rel=1e-9)


@given(st.integers(2, 60), st.integers(1, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_columnar_selection_equals_object_selection(n_clients, per_round,
                                                    seed):
    """Property form of the control-plane equivalence gate: arbitrary
    fleet states select identically on both planes from a shared RNG."""
    from repro.core.database import ClientRecord, Database
    from repro.core.selection import select_clients
    rng = np.random.default_rng(seed)
    dbs = {cp: Database(control_plane=cp) for cp in ("object", "columnar")}
    for cid in range(n_clients):
        rec = ClientRecord(client_id=cid, hardware="h",
                           data_cardinality=int(rng.integers(1, 500)),
                           batch_size=5, local_epochs=1)
        busy = rng.random() < 0.25
        n_hist = int(rng.integers(0, 4))
        durs = rng.uniform(0.5, 80.0, n_hist)
        for db in dbs.values():
            db.register_client(rec)
            for t, d in enumerate(durs):
                db.mark_running(cid, t)
                db.mark_complete(cid, float(d))
            if busy:
                db.mark_running(cid, 99)
    g = {cp: np.random.default_rng(seed + 1) for cp in dbs}
    sel = {cp: select_clients(db, per_round, g[cp])
           for cp, db in dbs.items()}
    assert sel["object"] == sel["columnar"]
