"""Checkpoint manager: atomicity, retention, dtype fidelity, resume."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def _tree():
    return {
        "dense": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                  "b": jnp.ones(4, jnp.bfloat16)},
        "scalars": (jnp.int32(7), jnp.float32(0.5)),
        "list": [jnp.zeros(2), jnp.ones(2)],
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ckpt"))
    r = restore_pytree(str(tmp_path / "ckpt"))
    np.testing.assert_array_equal(r["dense"]["w"], np.asarray(t["dense"]["w"]))
    assert r["dense"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(r["dense"]["b"].astype(np.float32),
                                  np.ones(4, np.float32))
    assert isinstance(r["scalars"], tuple)
    assert int(r["scalars"][0]) == 7
    assert isinstance(r["list"], list)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 5, 9):
        mgr.save(step, {"w": jnp.full(3, float(step))}, extra={"round": step})
    assert mgr.steps() == [5, 9]  # step 1 garbage-collected
    tree, extra, step = mgr.restore()
    assert step == 9 and extra["round"] == 9
    np.testing.assert_array_equal(tree["w"], np.full(3, 9.0, np.float32))


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"w": jnp.zeros(2)})
    mgr.save(2, {"w": jnp.ones(2)})
    tree, _, step = mgr.restore(1)
    assert step == 1
    np.testing.assert_array_equal(tree["w"], np.zeros(2, np.float32))


def test_no_tmp_dirs_left_behind(tmp_path):
    save_pytree(_tree(), str(tmp_path / "c"))
    save_pytree(_tree(), str(tmp_path / "c"))  # overwrite path
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()
