"""Checkpoint manager: atomicity, retention, dtype fidelity, resume."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def _tree():
    return {
        "dense": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                  "b": jnp.ones(4, jnp.bfloat16)},
        "scalars": (jnp.int32(7), jnp.float32(0.5)),
        "list": [jnp.zeros(2), jnp.ones(2)],
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ckpt"))
    r = restore_pytree(str(tmp_path / "ckpt"))
    np.testing.assert_array_equal(r["dense"]["w"], np.asarray(t["dense"]["w"]))
    assert r["dense"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(r["dense"]["b"].astype(np.float32),
                                  np.ones(4, np.float32))
    assert isinstance(r["scalars"], tuple)
    assert int(r["scalars"][0]) == 7
    assert isinstance(r["list"], list)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 5, 9):
        mgr.save(step, {"w": jnp.full(3, float(step))}, extra={"round": step})
    assert mgr.steps() == [5, 9]  # step 1 garbage-collected
    tree, extra, step = mgr.restore()
    assert step == 9 and extra["round"] == 9
    np.testing.assert_array_equal(tree["w"], np.full(3, 9.0, np.float32))


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"w": jnp.zeros(2)})
    mgr.save(2, {"w": jnp.ones(2)})
    tree, _, step = mgr.restore(1)
    assert step == 1
    np.testing.assert_array_equal(tree["w"], np.zeros(2, np.float32))


def test_no_tmp_dirs_left_behind(tmp_path):
    save_pytree(_tree(), str(tmp_path / "c"))
    save_pytree(_tree(), str(tmp_path / "c"))  # overwrite path
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()


# ------------------------------------------ crash-safe swap (DESIGN.md §14)
def test_rename_aside_survives_crash_between_renames(tmp_path):
    """A kill between the two renames of the swap leaves only the
    ``.old`` aside copy; restore falls back to it."""
    d = str(tmp_path / "ckpt")
    save_pytree({"w": jnp.zeros(3)}, d)
    # simulate the torn state: old checkpoint moved aside, new one gone
    os.replace(d, d + ".old")
    r = restore_pytree(d)
    np.testing.assert_array_equal(r["w"], np.zeros(3, np.float32))


def test_overwrite_never_leaves_zero_checkpoints(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree({"w": jnp.zeros(3)}, d)
    save_pytree({"w": jnp.ones(3)}, d)
    assert not os.path.exists(d + ".old")  # aside copy cleaned up
    np.testing.assert_array_equal(restore_pytree(d)["w"],
                                  np.ones(3, np.float32))


def test_restore_skips_corrupt_newest_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for step in (1, 2, 3):
        mgr.save(step, {"w": jnp.full(2, float(step))}, extra={"round": step})
    # step 3: missing meta.json; step 2: truncated leaves.npz
    os.remove(os.path.join(mgr._step_dir(3), "meta.json"))
    leaves = os.path.join(mgr._step_dir(2), "leaves.npz")
    with open(leaves, "r+b") as f:
        f.truncate(os.path.getsize(leaves) // 2)
    tree, extra, step = mgr.restore()
    assert step == 1 and extra["round"] == 1
    np.testing.assert_array_equal(tree["w"], np.full(2, 1.0, np.float32))


def test_restore_explicit_corrupt_step_still_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"w": jnp.zeros(2)})
    mgr.save(2, {"w": jnp.ones(2)})
    os.remove(os.path.join(mgr._step_dir(2), "meta.json"))
    with pytest.raises(FileNotFoundError):
        mgr.restore(2)


def test_restore_all_corrupt_reports_count(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"w": jnp.zeros(2)})
    os.remove(os.path.join(mgr._step_dir(1), "meta.json"))
    with pytest.raises(FileNotFoundError, match="1 corrupt"):
        mgr.restore()
