"""Unit tests for Algorithm 2 (Client Efficiency Scoring)."""
import numpy as np
import pytest

from repro.core.scoring import calculate_score, decay_rate, n_updates, promotion_rate


def test_n_updates_matches_paper_formula():
    # #updates = N_c * E / B  (Algorithm 2 line 2)
    assert n_updates(200, 5, 10) == 100
    assert n_updates(64, 1, 32) == 2


def test_score_single_round():
    # one round: score = beta * N_c * (#updates / T)
    s = calculate_score(1.0, [10.0], data_cardinality=100, epochs=5,
                        batch_size=10, decay=0.8)
    assert s == pytest.approx(100 * (50 / 10.0))


def test_faster_clients_score_higher():
    fast = calculate_score(1.0, [5.0], 100, 5, 10, 0.8)
    slow = calculate_score(1.0, [50.0], 100, 5, 10, 0.8)
    assert fast > slow


def test_larger_datasets_score_higher_at_equal_throughput():
    # CEF multiplies by N_c to favor data-rich clients (paper III-C)
    small = calculate_score(1.0, [10.0], 100, 5, 10, 0.8)
    # 2x data at 2x duration = identical steps/sec per sample, more data
    large = calculate_score(1.0, [20.0], 200, 5, 10, 0.8)
    assert large > small


def test_exponential_decay_prefers_recent_rounds():
    # newest-first ordering: improving client (fast recent round) must beat
    # degrading client (slow recent round) with the same multiset of durations
    improving = calculate_score(1.0, [5.0, 50.0], 100, 5, 10, 0.8)
    degrading = calculate_score(1.0, [50.0, 5.0], 100, 5, 10, 0.8)
    assert improving > degrading


def test_booster_scales_score_linearly():
    base = calculate_score(1.0, [10.0], 100, 5, 10, 0.8)
    boosted = calculate_score(1.44, [10.0], 100, 5, 10, 0.8)
    assert boosted == pytest.approx(1.44 * base)


def test_weighted_average_normalization():
    # equal durations -> score independent of history length
    s1 = calculate_score(1.0, [10.0], 100, 5, 10, 0.8)
    s3 = calculate_score(1.0, [10.0, 10.0, 10.0], 100, 5, 10, 0.8)
    assert s1 == pytest.approx(s3)


def test_rates_from_adjustment_rate():
    # lambda = 1 - rho, promotion = 1 + rho (default rho = 0.2)
    assert decay_rate(0.2) == pytest.approx(0.8)
    assert promotion_rate(0.2) == pytest.approx(1.2)


def test_empty_history_scores_zero():
    assert calculate_score(1.0, [], 100, 5, 10, 0.8) == 0.0
