"""Device-resident data plane (DESIGN.md §2): DatasetStore residence,
golden-trace bit-identity of `REPRO_DATA_PLANE=device` vs `host` across
strategies / engines / update planes, zero-H2D accounting, the SCAFFOLD
device-resident control-variate buffer, the cohort bucket floor, and the
scheduler's coalesced dispatch."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.client import (_COMPILE_CACHE, CohortTrainer, _bucket,
                               cohort_bucket_floor)
from repro.core.controller import Controller, FLConfig
from repro.core.data_plane import DatasetStore, dataset_store, resolve_data_plane
from repro.core.protocol import (Aggregate, CancelInvocation, Hedge, Invoke,
                                 RoundStarted, SetTimer)
from repro.core.scheduler import Scheduler
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet

from trace_harness import (ALL_STRATEGIES, N_CLIENTS, REACTIVE, base_cfg_kw,
                           data, model, run_flag_pair)  # noqa: F401


def _cfg(**kw):
    return FLConfig(**base_cfg_kw(**kw))


def _assert_planes_identical(cfg_kw, model, data, engine_cls=Scheduler):
    """One run per data plane; everything observable must be bit-equal
    (common asserts live in trace_harness.run_flag_pair)."""
    runs = run_flag_pair(cfg_kw, "data_plane", ("device", "host"), model,
                         data, engine_cls=engine_cls)
    _, m_dev = runs["device"]
    _, m_host = runs["host"]
    # the H2D asymmetry is the whole point
    assert m_dev["data_host_bytes"] == 0
    assert m_host["data_host_bytes"] > 0
    assert m_dev["data_resident_bytes"] == data.nbytes
    assert m_host["data_resident_bytes"] == 0
    return m_dev, m_host


# ------------------------------------------------------------ golden traces
@pytest.mark.parametrize("strategy", ALL_STRATEGIES + REACTIVE)
def test_golden_dataplane_scheduler(strategy, data, model):
    _assert_planes_identical(base_cfg_kw(strategy=strategy), model, data)


@pytest.mark.parametrize("strategy", ("fedavg", "apodotiko", "scaffold"))
def test_golden_dataplane_blob_update_plane(strategy, data, model):
    _assert_planes_identical(base_cfg_kw(strategy=strategy,
                                         update_plane="blob"), model, data)


@pytest.mark.parametrize("strategy", ("fedavg", "apodotiko", "scaffold"))
def test_golden_dataplane_legacy_engine(strategy, data, model):
    _assert_planes_identical(base_cfg_kw(strategy=strategy), model, data,
                             engine_cls=Controller)


def test_golden_dataplane_legacy_engine_blob_plane(data, model):
    """The full legacy stack (poll loop + blob updates) against itself
    across data planes."""
    _assert_planes_identical(base_cfg_kw(strategy="apodotiko",
                                         update_plane="blob"), model, data,
                             engine_cls=Controller)


# ----------------------------------------------------------- resolve + store
def test_resolve_data_plane(monkeypatch):
    monkeypatch.delenv("REPRO_DATA_PLANE", raising=False)
    assert resolve_data_plane("auto") == "device"
    assert resolve_data_plane("") == "device"
    assert resolve_data_plane("host") == "host"
    monkeypatch.setenv("REPRO_DATA_PLANE", "host")
    assert resolve_data_plane("auto") == "host"
    assert resolve_data_plane("device") == "device"   # explicit beats env
    with pytest.raises(ValueError):
        resolve_data_plane("blob")


def test_dataset_store_residence_and_gather(data):
    store = DatasetStore(data)
    assert store.n_clients == N_CLIENTS
    assert store.resident_bytes == data.nbytes
    gx, gy = store.gather([3, 1])
    np.testing.assert_array_equal(np.asarray(gx), data.X[[3, 1]])
    np.testing.assert_array_equal(np.asarray(gy), data.y[[3, 1]])
    # device arrays, not host views
    assert isinstance(store.X, jnp.ndarray) and isinstance(store.y, jnp.ndarray)


def test_dataset_store_cached_per_dataset(data):
    assert dataset_store(data) is dataset_store(data)
    other = make_federated_dataset("mnist", n_clients=4, scale=0.05, seed=1)
    assert dataset_store(other) is not dataset_store(data)


def test_out_of_range_selection_raises(data, model):
    """The resident gather would clamp silently; the runtime must keep the
    host plane's failure mode."""
    sched = Scheduler(_cfg(strategy="fedavg"), model, data,
                      list(paper_fleet(N_CLIENTS)))
    with pytest.raises(IndexError):
        sched.invoke_round(0, [N_CLIENTS + 5])


# ------------------------------------------------------------- SCAFFOLD buf
def test_scaffold_variate_buffer_device_resident(data, model):
    sched = Scheduler(_cfg(strategy="scaffold", rounds=2), model, data,
                      list(paper_fleet(N_CLIENTS)))
    sched.run()
    assert sched.c_buf is not None and sched._c_cap >= N_CLIENTS
    trained = {r.client_id for r in sched.db.results}
    norms = np.asarray(
        sum(jnp.sum(jnp.abs(b), axis=tuple(range(1, b.ndim)))
            for b in jax.tree.leaves(sched.c_buf)))
    assert any(norms[cid] > 0 for cid in trained)
    # removal zeroes the rows: a rejoining id starts from fresh variates
    cid = next(iter(trained))
    sched.remove_clients([cid])
    norms = np.asarray(
        sum(jnp.sum(jnp.abs(b), axis=tuple(range(1, b.ndim)))
            for b in jax.tree.leaves(sched.c_buf)))
    assert norms[cid] == 0


# ------------------------------------------------------------- bucket floor
def test_cohort_bucket_floor_default_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_COHORT_FLOOR", raising=False)
    assert cohort_bucket_floor() == 2
    monkeypatch.setenv("REPRO_COHORT_FLOOR", "8")
    assert cohort_bucket_floor() == 8
    assert _bucket(1, 2) == 2 and _bucket(3, 2) == 4 and _bucket(9, 2) == 16
    assert _bucket(1, 8) == 8 and _bucket(12, 8) == 16


def _tiny_trainer(model, **kw):
    return CohortTrainer(model, optimizer="sgd", lr=0.1, batch_size=2, **kw)


def test_solo_dispatch_pads_to_two_not_eight(data, model):
    """A K=1 dispatch (reinforcement / solo re-invocation) compiles at
    Kp=2 — and a K=2 dispatch reuses that same compiled entry."""
    trainer = _tiny_trainer(model)
    store = dataset_store(data)
    params = model.init(jax.random.PRNGKey(0))[0]
    before = dict(_COMPILE_CACHE)
    out, _, losses = trainer.train_cohort_indexed(
        params, store, [3], data.n[[3]], np.array([1], np.int64))
    new_keys = [k for k in _COMPILE_CACHE if k not in before]
    assert len(new_keys) == 1
    kp = new_keys[0][6]        # config key (6 fields) + (Kp, max_steps, ...)
    assert kp == 2
    assert jax.tree.leaves(out)[0].shape[0] == 1 and losses.shape == (1,)
    n_before = len(_COMPILE_CACHE)
    trainer.train_cohort_indexed(params, store, [1, 4], data.n[[1, 4]],
                                 np.array([1, 1], np.int64))
    assert len(_COMPILE_CACHE) == n_before       # same bucket, no recompile


def test_mixed_selection_sizes_bound_compiles(data, model):
    """K = 1..7 across dispatches compiles at most O(log K) variants
    (buckets 2, 4, 8)."""
    trainer = _tiny_trainer(model)
    store = dataset_store(data)
    params = model.init(jax.random.PRNGKey(0))[0]
    before = len(_COMPILE_CACHE)
    for k in range(1, 8):
        sel = list(range(k))
        trainer.train_cohort_indexed(params, store, sel, data.n[sel],
                                     np.ones(k, np.int64))
    assert len(_COMPILE_CACHE) - before <= 3


def test_cohort_floor_parametrized(data, model):
    """cohort_floor=8 restores the legacy padding (one bucket for K<=8)."""
    trainer = _tiny_trainer(model, cohort_floor=8)
    store = dataset_store(data)
    params = model.init(jax.random.PRNGKey(0))[0]
    before = set(_COMPILE_CACHE)
    for k in (1, 3, 5, 8):
        sel = list(range(k))
        trainer.train_cohort_indexed(params, store, sel, data.n[sel],
                                     np.ones(k, np.int64))
    new_keys = [k for k in _COMPILE_CACHE if k not in before]
    # every size lands in the single Kp=8 bucket (entries may already be
    # warm from earlier tests sharing the trainer config)
    assert len(new_keys) <= 1
    assert all(k[6] == 8 for k in new_keys)


# ---------------------------------------------------------- remove_clients
def test_remove_clients_shared_profile_object(data, model):
    """Two clients sharing one HardwareProfile object: removing one must
    drop ITS fleet entry (by id->position map), not the first entry that
    compares equal — the fleet stays position-consistent with `hw`."""
    P, Q = HARDWARE_PROFILES["cpu1"], HARDWARE_PROFILES["gpu"]
    fleet = [P, Q] + [P] * (N_CLIENTS - 2)       # cids 0 and 2.. share P
    sched = Scheduler(_cfg(strategy="fedavg", rounds=1), model, data, fleet)
    sched.remove_clients([2])
    assert len(sched.fleet) == N_CLIENTS - 1
    assert sched.fleet[0] is P and sched.fleet[1] is Q
    for cid, pos in sched._fleet_pos.items():
        assert sched.fleet[pos] is sched.hw[cid]
    # removing the remaining sharers one by one never corrupts Q's slot
    sched.remove_clients([0, 3])
    assert Q in sched.fleet
    assert sched.fleet[sched._fleet_pos[1]] is Q
    assert len(sched.fleet) == N_CLIENTS - 3


# ------------------------------------------------------- coalesced dispatch
def test_coalesce_merges_invokes_and_hedges(data, model):
    sched = Scheduler(_cfg(strategy="fedavg"), model, data,
                      list(paper_fleet(N_CLIENTS)))
    acts = sched._coalesce([Invoke((0, 1)), SetTimer(5.0, "t"),
                            Invoke((1, 2)), Hedge((3,)), Hedge((4,)),
                            Aggregate(), Invoke((5,))])
    assert acts == [Invoke((0, 1, 2)), SetTimer(5.0, "t"), Hedge((3, 4)),
                    Aggregate(), Invoke((5,))]
    assert sched.n_coalesced == 2


def test_coalesce_respects_barriers(data, model):
    sched = Scheduler(_cfg(strategy="fedavg"), model, data,
                      list(paper_fleet(N_CLIENTS)))
    acts = sched._coalesce([Invoke((0,)), CancelInvocation(0), Invoke((0,))])
    assert acts == [Invoke((0,)), CancelInvocation(0), Invoke((0,))]
    assert sched.n_coalesced == 0
    # Invoke and Hedge are barriers for each other: a hedge must never be
    # reordered before the invocation it targets (and vice versa)
    acts = sched._coalesce([Hedge((3,)), Invoke((5,)), Hedge((5,))])
    assert acts == [Hedge((3,)), Invoke((5,)), Hedge((5,))]
    acts = sched._coalesce([Invoke((0,)), Hedge((0,)), Invoke((1,))])
    assert acts == [Invoke((0,)), Hedge((0,)), Invoke((1,))]
    assert sched.n_coalesced == 0


def test_coalesced_invokes_hit_one_cohort_dispatch(data, model, monkeypatch):
    """Two same-instant Invoke actions train as ONE batched cohort."""
    sched = Scheduler(_cfg(strategy="fedavg"), model, data,
                      list(paper_fleet(N_CLIENTS)))
    calls = []
    monkeypatch.setattr(
        sched, "invoke_round",
        lambda r, sel, **kw: calls.append((r, tuple(sel))))

    class TwoInvokes:
        name = "two-invokes"
        fire_timers_on_drain = True
        strategy = sched.policy.strategy

        def on_event(self, ev, view):
            return [Invoke((0, 1)), Invoke((2,))] \
                if isinstance(ev, RoundStarted) else []

        def metrics(self):
            return {}

    sched.policy = TwoInvokes()
    sched._dispatch(RoundStarted(t=0.0, round=0))
    assert calls == [(0, (0, 1, 2))]
    assert sched.n_coalesced == 1
    assert sched.metrics()["n_coalesced"] == 1
