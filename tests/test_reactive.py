"""Natively-reactive strategies: apodotiko-hedge must beat plain
apodotiko on simulated time-to-target-accuracy in the straggler-heavy
preset shape (the redesign's capability proof), and apodotiko-adaptive
must actually adapt CR from arrival dispersion."""
import numpy as np
import pytest

from repro.core.scheduler import Scheduler
from repro.core.services import FLConfig
from repro.core.strategies.base import StrategyConfig
from repro.core.strategies.reactive import ApodotikoAdaptive
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES
from repro.models.proxy_models import build_bench_model

N_CLIENTS = 12


@pytest.fixture(scope="module")
def data():
    return make_federated_dataset("mnist", n_clients=N_CLIENTS, scale=0.3,
                                  seed=0)


@pytest.fixture(scope="module")
def model():
    return build_bench_model("mnist")


def _straggler_fleet():
    # the sweep "straggler" scenario shape: 75% 1vCPU, 25% GPU
    return [HARDWARE_PROFILES["cpu1"]] * 9 + [HARDWARE_PROFILES["gpu"]] * 3


def _cfg(strategy, **kw):
    # the straggler_hedge preset shape: cold starts dominate (120 s) and
    # keep-warm (30 s) sits below the round cadence, so fresh straggler
    # invocations run cold while hedges ride the warm container
    base = dict(n_clients=N_CLIENTS, clients_per_round=6, rounds=12,
                local_epochs=3, batch_size=5, base_step_time=0.3,
                concurrency_ratio=0.5, cold_start_s=120.0, keep_warm=30.0,
                hedge_fraction=1.0, seed=0, strategy=strategy)
    base.update(kw)
    return FLConfig(**base)


def test_hedge_beats_plain_apodotiko_on_time_to_accuracy(data, model):
    """The acceptance criterion: on a straggler-heavy fleet, the hedging
    policy reaches the common accuracy target earlier AND sustains a
    faster round cadence with fewer cold starts."""
    runs = {}
    for s in ("apodotiko", "apodotiko-hedge"):
        sched = Scheduler(_cfg(s), model, data, _straggler_fleet())
        runs[s] = (sched, sched.run())
    plain, m_plain = runs["apodotiko"]
    hedge, m_hedge = runs["apodotiko-hedge"]

    assert m_hedge["rounds"] == m_plain["rounds"] == 12
    assert m_hedge["n_hedges"] > 0 and m_hedge["n_hedge_wins"] > 0

    # time-to-common-accuracy (the sweep table's target rule: 95% of the
    # weakest run's best) — hedging must reach it strictly earlier
    common = 0.95 * min(max(a for _, _, a in m["history"])
                        for _, m in runs.values())
    t_plain = plain.time_to_accuracy(common)
    t_hedge = hedge.time_to_accuracy(common)
    assert t_plain is not None and t_hedge is not None
    assert t_hedge < t_plain

    # structural wins: faster cadence, fewer cold starts
    assert m_hedge["total_time"] < m_plain["total_time"]
    assert m_hedge["cold_start_ratio"] < m_plain["cold_start_ratio"]


def test_hedge_reuses_trained_update(data, model):
    """Hedges do not retrain: invocation count grows but the update-plane
    row count does not (payloads are shared, freed exactly once)."""
    sched = Scheduler(_cfg("apodotiko-hedge", rounds=4), model, data,
                      _straggler_fleet())
    m = sched.run()
    assert m["n_hedges"] > 0
    # settled races cancel their loser — nothing double-lands
    assert m["n_cancelled"] > 0
    results_by_round = [(r.client_id, r.round) for r in sched.db.results]
    assert len(results_by_round) == len(set(results_by_round))
    # every store row is accounted for — a pending result's handle or an
    # in-flight payload (run ended mid-race) — no leaks from settled races
    live = {r.update_row for r in sched.db.results
            if not r.aggregated and r.update_row >= 0}
    for invs in sched.inflight.values():
        live |= {i.payload.row for i in invs if not i.done}
    assert sched.store._live == live


def test_adaptive_cr_moves_and_stays_bounded(data, model):
    sched = Scheduler(_cfg("apodotiko-adaptive", rounds=8), model, data,
                      _straggler_fleet())
    m = sched.run()
    crs = m["cr_history"]
    assert len(crs) >= 2
    assert any(c != crs[0] for c in crs[1:])         # it adapted
    assert all(0.1 <= c <= 0.9 for c in crs)         # clamped
    assert np.isfinite(m["final_accuracy"])


def test_adaptive_cr_rule_directions():
    """Pure rule: wide landing window lowers CR, tight window raises it,
    both clamped to [0.1, 0.9]."""
    pol = ApodotikoAdaptive(StrategyConfig(concurrency_ratio=0.5))
    # spread = (40 - 2) / 21 = 1.81 > HIGH -> lower
    assert pol.next_cr([2.0, 21.0, 40.0]) < 0.5
    pol.strategy.cfg.concurrency_ratio = 0.5
    # spread = (11 - 10) / 10.5 = 0.095 < LOW -> raise
    assert pol.next_cr([10.0, 10.5, 11.0]) > 0.5
    pol.strategy.cfg.concurrency_ratio = 0.88
    for _ in range(5):
        pol.strategy.cfg.concurrency_ratio = pol.next_cr([10.0, 10.5, 11.0])
    assert pol.strategy.cfg.concurrency_ratio <= 0.9
    pol.strategy.cfg.concurrency_ratio = 0.12
    for _ in range(5):
        pol.strategy.cfg.concurrency_ratio = pol.next_cr([2.0, 21.0, 40.0])
    assert pol.strategy.cfg.concurrency_ratio >= 0.1
    # fewer than two arrivals: no information, CR unchanged
    pol.strategy.cfg.concurrency_ratio = 0.4
    assert pol.next_cr([3.0]) == 0.4


def test_sweep_preset_runs_reactive_strategies():
    """The smoke_hedge preset wires reactive strategies through the sweep
    engine (build_engine routes them onto the scheduler)."""
    from repro.sweep import expand_grid, get_preset
    from repro.sweep.presets import REACTIVE_STRATEGIES

    spec = get_preset("smoke_hedge")
    runs = expand_grid(spec)
    assert {r.strategy for r in runs} == {"apodotiko", "apodotiko-hedge"}
    straggler = get_preset("straggler_hedge")
    assert "apodotiko-hedge" in straggler.strategies
    assert straggler.scenarios == ("straggler",)
    assert set(REACTIVE_STRATEGIES) == {"apodotiko-hedge",
                                        "apodotiko-adaptive"}
