"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
each family runs one forward/train step on CPU, asserting output shapes and
no NaNs; plus one decode step against a cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(RNG, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(RNG, (B, S, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, axes = model.init(RNG)
    # every param leaf has a matching logical-axes tuple
    p_leaves = jax.tree.leaves(params)
    a_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(p_leaves) == len(a_leaves)
    batch = _batch(cfg)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    new = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = loss_fn(new)
    assert np.isfinite(float(loss2))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    B, S = 2, 16
    if cfg.family == "encdec":
        struct, _ = model.cache_struct(B, S, S)
    else:
        struct, _ = model.cache_struct(B, S)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)
    tok = jax.random.randint(RNG, (B, 1), 0, cfg.vocab_size)
    logits, new_caches = model.decode_step(params, caches, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache tree structure is preserved (scan-carry friendly)
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_published_size(arch):
    """Abstract init (no allocation) of the FULL config lands near the
    published parameter count."""
    published_b = {
        "qwen3-1.7b": 1.7, "granite-8b": 8.1, "yi-6b": 6.1, "qwen3-4b": 4.0,
        "llama-3.2-vision-11b": 9.8,  # text backbone (ViT frontend stubbed)
        "zamba2-2.7b": 2.4, "deepseek-v2-lite-16b": 15.7, "arctic-480b": 477,
        "mamba2-370m": 0.37, "seamless-m4t-large-v2": 2.0,
    }
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda r: model.init(r)[0], RNG)
    n = sum(int(x.size) for x in jax.tree.leaves(shapes)) / 1e9
    assert n == pytest.approx(published_b[arch], rel=0.12)
