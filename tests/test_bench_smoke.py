"""Bench smoke layer: every benchmarks/ module imports cleanly, each
bench_round section's ``--smoke`` path runs end to end, writes its JSON
artifact, and keeps its CI gate green — and the per-mode RNG seeding is
independent, so sections are comparable run-to-run (every timed mode
rebuilds identically seeded state instead of mutating a shared one)."""
import importlib
import json
import pathlib
import sys

import numpy as np
import pytest

BENCH_DIR = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
MODULES = sorted(p.stem for p in BENCH_DIR.glob("*.py"))


def _import(name):
    if str(BENCH_DIR) not in sys.path:
        sys.path.insert(0, str(BENCH_DIR))
    return importlib.import_module(name)


@pytest.mark.parametrize("name", MODULES)
def test_bench_module_imports(name):
    assert _import(name) is not None


@pytest.fixture(scope="module")
def bench_round():
    return _import("bench_round")


def test_round_smoke(bench_round, tmp_path):
    path = tmp_path / "round.json"
    cells = bench_round.run(smoke=True, json_path=str(path))
    assert cells and cells[0]["K"] == 10
    assert cells[0]["plane_host_bytes"] == 0
    assert json.loads(path.read_text())["smoke"] is True


def test_controlplane_smoke(bench_round, tmp_path):
    path = tmp_path / "cp.json"
    out = bench_round.run_controlplane(smoke=True, json_path=str(path))
    assert out["selection_identical"] is True
    assert json.loads(path.read_text())["cells"]


def test_scheduler_smoke(bench_round, tmp_path):
    path = tmp_path / "sched.json"
    out = bench_round.run_scheduler(smoke=True, json_path=str(path))
    assert out["eventloop"]["plain_events_per_s"] > 0
    assert len(out["dispatch"]) == 2
    assert path.exists()


def test_dataplane_smoke(bench_round, tmp_path):
    path = tmp_path / "dp.json"
    out = bench_round.run_dataplane(smoke=True, json_path=str(path))
    e2e_dev = next(r for r in out["end_to_end"]
                   if r["data_plane"] == "device")
    assert e2e_dev["data_host_bytes"] == 0
    assert json.loads(path.read_text())["cells"]


def test_megastep_smoke_gate(bench_round, tmp_path):
    """The --megastep CI gate: fused engages, dispatches zero Python
    events per quiescent round, and stays bit-identical to stepwise."""
    path = tmp_path / "ms.json"
    out = bench_round.run_megastep(smoke=True, json_path=str(path))
    assert out["bit_identical"] is True
    assert out["python_dispatches_per_quiescent_round"] == 0.0
    assert out["fused"]["megastep_rounds"] > 0
    assert out["stepwise"]["events_per_round"] > 0
    assert "python_overhead_share" in json.loads(path.read_text())


def test_traffic_smoke_gate(bench_round, tmp_path):
    """The --traffic CI gate: schedule compile throughput reported, the
    bulk availability path stays bit-identical to the per-event oracle,
    and the SLO table covers three strategies under diurnal load."""
    path = tmp_path / "traffic.json"
    out = bench_round.run_traffic(smoke=True, json_path=str(path))
    assert out["apply"]["bulk_matches_per_event"] is True
    assert out["apply"]["bulk_speedup"] > 1.0
    assert out["compile"][0]["events_per_s"] > 0
    assert [r["strategy"] for r in out["slo"]] == \
        ["fedavg", "apodotiko", "apodotiko-hedge"]
    for r in out["slo"]:
        assert r["p99_round_latency_s"] >= r["p50_round_latency_s"] > 0
        assert r["cost_per_round_usd"] > 0
        assert r["n_traffic_joins"] + r["n_traffic_leaves"] > 0
    assert json.loads(path.read_text())["bench"] == "traffic"


def test_controlplane_modes_independently_seeded(bench_round):
    """Two builds of a mode's state are bitwise identical — no mode
    consumes another's RNG stream or mutated fleet state."""
    a = bench_round._control_states(500, planes=("columnar",))[1]
    b = bench_round._control_states(500, planes=("columnar",))[1]
    for col in ("ema_num", "ema_den", "win_num", "win_den", "booster",
                "dur_len"):
        np.testing.assert_array_equal(getattr(a.fleet, col),
                                      getattr(b.fleet, col))
    np.testing.assert_array_equal(a.fleet.durations, b.fleet.durations)
    obj = bench_round._control_states(500, planes=("object",))[0]
    assert obj is not None and len(obj.clients) == 500
    # and the skip threshold still guards the object wall
    assert bench_round._control_states(300_000, planes=("object",))[0] is None


def test_sharding_smoke_gate(bench_round, tmp_path):
    """The --sharding CI gate: mesh='1x1' bitwise-identical to the
    default path, and the sharded cell's update-store buffer actually
    splits into equal per-device tiles (the wall-clock scaling gate
    only arms on hosts with >= 8 real cores)."""
    path = tmp_path / "sharding.json"
    out = bench_round.run_sharding(smoke=True, json_path=str(path))
    assert out["identity_1x1_bitwise"] is True
    assert out["structural_ok"] is True
    cells = {c["mesh"]: c for c in out["cells"]}
    assert set(cells) == {"auto", "1x1", "2x1"}
    assert cells["auto"]["params_sha"] == cells["1x1"]["params_sha"]
    sharded = cells["2x1"]
    assert sharded["devices"] == 2 and sharded["n_shards"] == 2
    assert sharded["store_device_bytes"] * 2 == sharded["store_total_bytes"]
    assert sharded["K"] == 2 * cells["1x1"]["K"]      # weak scaling
    for c in out["cells"]:
        assert c["rounds_per_s"] > 0 and c["rounds_timed"] > 0
    assert json.loads(path.read_text())["bench"] == "sharding"


def test_durability_smoke_gate(bench_round, tmp_path):
    """The --durability CI gate: journal overhead within the round-sync
    budget and a crash-mid-journal resume bit-identical to the golden
    run (snapshot cadence pushed out in the sync cells, so fsync counts
    reflect journal policy alone)."""
    path = tmp_path / "durability.json"
    out = bench_round.run_durability(smoke=True, json_path=str(path))
    assert out["gate"]["resume_identical"] is True
    assert out["gate"]["round_sync_overhead_ok"] is True
    assert out["gate"]["replayed"] >= 0
    by_label = {r["label"]: r for r in out["sync"]}
    assert by_label["journal+event"]["journal_fsyncs"] >= \
        by_label["journal+event"]["journal_records"]
    assert by_label["journal+round"]["journal_fsyncs"] < \
        by_label["journal+round"]["journal_records"]
    assert by_label["journal+round"]["n_snapshots"] == 0
    assert out["fleet"][0]["snapshot_ms"] > 0
    assert out["fleet"][0]["resume_ms"] > 0
    assert json.loads(path.read_text())["bench"] == "durability"
