import os
import sys

# Tests see exactly ONE CPU device (the dry-run's 512-device flag must never
# leak here — see launch/dryrun.py).
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
