"""Open-loop traffic plane tests (DESIGN.md §13).

Covers the full subsystem contract: spec grammar + env resolution,
seeded schedule compilation (bit-identical replay, event-by-event
presence oracle, capacity overflow accounting), bulk vs per-event
FleetStore application, cold starts on rejoin (``scale_down``),
cross-engine golden traces per profile, the megastep boundary
interaction, and the SLO metrics layer.
"""
import numpy as np
import pytest

from repro.core.controller import FLConfig
from repro.core.database import ClientRecord, Database
from repro.core.fleet_store import FleetStore
from repro.core.scheduler import Scheduler
from repro.faas.hardware import HARDWARE_PROFILES
from repro.faas.platform import FaaSPlatform
from repro.traffic import (TRAFFIC_PROFILES, DiurnalTraffic, FlashCrowd,
                           PoissonTraffic, TraceTraffic,
                           build_traffic_schedule, compile_traffic_schedule,
                           parse_traffic, resolve_traffic_profile,
                           round_latencies, slo_summary)

from trace_harness import (assert_engines_equivalent,  # noqa: F401
                           assert_fused_matches_stepwise, base_cfg_kw, data,
                           det_fleet, megastep_cfg, model, run_flag_pair)

try:  # property tests widen coverage when the dev-only dep is present
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------ spec grammar
def test_parse_full_grammar():
    spec = parse_traffic("init:0.25,window:10,horizon:500,"
                         "poisson:0.5:60,diurnal:1:0.5:600:30,"
                         "flash:100:50,trace:90=+2;210=-2")
    assert spec.init_frac == 0.25
    assert spec.window == 10.0 and spec.horizon == 500.0
    assert spec.sources == (PoissonTraffic(rate=0.5, dwell=60.0),
                            DiurnalTraffic(rate=1.0, depth=0.5,
                                           period=600.0, dwell=30.0),
                            FlashCrowd(t=100.0, n=50, dwell=0.0),
                            TraceTraffic(events=((90.0, 2), (210.0, -2))))
    assert spec.active and spec.stochastic


def test_parse_off_and_inactive():
    for s in ("", "none", "off", "  "):
        spec = parse_traffic(s)
        assert not spec.active and not spec.stochastic
    # init:1.0 alone is the closed-loop default, not traffic
    assert not parse_traffic("init:1.0").active
    assert parse_traffic("init:0.5").active
    assert not parse_traffic("trace:10=+1").stochastic


@pytest.mark.parametrize("bad", [
    "bogus:1", "init:1.5", "init:-0.1", "window:0", "horizon:-5",
    "poisson", "poisson:abc", "poisson:-1", "diurnal:1:2:600",
    "diurnal:1:0.5:0", "flash:10", "flash:-1:5", "trace:",
    "trace:10", "trace:x=+1", "trace:-5=+1",
])
def test_parse_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        parse_traffic(bad)


def test_resolve_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_TRAFFIC", raising=False)
    assert resolve_traffic_profile("auto") == ""
    assert resolve_traffic_profile(None) == ""
    monkeypatch.setenv("REPRO_TRAFFIC", "diurnal")
    assert resolve_traffic_profile("auto") == "diurnal"
    assert resolve_traffic_profile("") == "diurnal"
    # explicit config beats env; none/off disable
    assert resolve_traffic_profile("steady-churn") == "steady-churn"
    assert resolve_traffic_profile("none") == ""
    assert resolve_traffic_profile("off") == ""
    # raw spec strings resolve too, but invalid ones fail fast
    assert resolve_traffic_profile("init:0.5") == "init:0.5"
    with pytest.raises(ValueError):
        resolve_traffic_profile("bogus:1")
    with pytest.raises(ValueError):
        resolve_traffic_profile(7)


def test_canned_profiles_all_parse_and_compile():
    for name, raw in TRAFFIC_PROFILES.items():
        spec = parse_traffic(raw)
        assert spec.active, name
        sched = build_traffic_schedule(name, 64, seed=0)
        assert sched is not None and sched.capacity == 64


def test_build_returns_none_when_off():
    assert build_traffic_schedule("", 100, seed=0) is None
    assert build_traffic_schedule("init:1.0", 100, seed=0) is None


# ------------------------------------------------- schedule compilation
def _assert_schedules_identical(a, b):
    assert np.array_equal(a.initial, b.initial)
    assert a.n_dropped == b.n_dropped
    assert len(a.segments) == len(b.segments)
    for sa, sb in zip(a.segments, b.segments):
        assert sa.start == sb.start and sa.end == sb.end
        assert np.array_equal(sa.joins, sb.joins)
        assert np.array_equal(sa.leaves, sb.leaves)


PROPERTY_SPECS = [
    "init:0.5,window:10,horizon:400,poisson:0.2:60",
    "init:0.25,window:15,horizon:600,diurnal:0.3:0.9:200:50",
    "init:0.5,window:10,horizon:300,flash:45:30:80,poisson:0.1",
    "init:0.0,window:5,horizon:200,poisson:0.5:40",
    "init:0.75,window:10,horizon:300,trace:20=+5;60=-3;90=+2",
    "init:0.5,window:10,horizon:300,flash:50:200:60",   # overflows M=64
]


def _check_replay(spec, seed, capacity):
    """Same (spec, seed, capacity) -> the same schedule, forever."""
    a = build_traffic_schedule(spec, capacity, seed=seed)
    b = build_traffic_schedule(spec, capacity, seed=seed)
    _assert_schedules_identical(a, b)


def _check_presence_oracle(spec, seed, M=64):
    """The vectorized presence mask equals a per-event replay, and the
    segment stream respects the membership invariants."""
    sched = build_traffic_schedule(spec, M, seed=seed)
    present = set(sched.initial.tolist())
    assert all(0 <= c < M for c in present)
    last_start = 0.0
    window = sched.spec.window
    for seg in sched.segments:
        assert seg.start > last_start          # strictly increasing
        assert seg.start == pytest.approx(
            window * round(seg.start / window))  # window-aligned
        last_start = seg.start
        for t, kind, cid in ((seg.start, "leave", int(c))
                             for c in seg.leaves):
            assert cid in present, "leave of absent id"
            present.discard(cid)
        for cid in seg.joins.tolist():
            assert cid not in present, "join of present id"
            assert 0 <= cid < M
            present.add(cid)
        mask = sched.presence_at(seg.start)
        assert set(np.flatnonzero(mask).tolist()) == present
    # the events() oracle visits exactly the segment deltas, in order
    ev = list(sched.events())
    n_ev = sum(len(s.joins) + len(s.leaves) for s in sched.segments)
    assert len(ev) == n_ev


@pytest.mark.parametrize("seed", [0, 7, 123])
@pytest.mark.parametrize("spec", PROPERTY_SPECS)
def test_schedule_replays_bit_identically(spec, seed):
    for capacity in (16, 64, 257):
        _check_replay(spec, seed, capacity)


@pytest.mark.parametrize("seed", [0, 7, 123])
@pytest.mark.parametrize("spec", PROPERTY_SPECS)
def test_presence_matches_event_oracle(spec, seed):
    _check_presence_oracle(spec, seed)


if HAVE_HYPOTHESIS:
    @given(spec=st.sampled_from(PROPERTY_SPECS),
           seed=st.integers(0, 2**31 - 1),
           capacity=st.sampled_from([16, 64, 257]))
    @settings(max_examples=30, deadline=None)
    def test_schedule_replay_property(spec, seed, capacity):
        _check_replay(spec, seed, capacity)

    @given(spec=st.sampled_from(PROPERTY_SPECS), seed=st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_presence_oracle_property(spec, seed):
        _check_presence_oracle(spec, seed)


def test_flash_overflow_drops_and_counts():
    sched = build_traffic_schedule(
        "init:0.5,window:10,horizon:100,flash:20:100:0", 64, seed=0)
    # 32 present, 32 free: a 100-client flash drops 68
    assert sched.n_dropped == 68
    assert len(sched.initial) == 32
    mask = sched.presence_at(100.0)
    assert mask.all()                           # fleet saturated


def test_trace_removes_earliest_joined():
    sched = build_traffic_schedule(
        "init:0.5,window:10,horizon:100,trace:20=-2", 8, seed=0)
    (seg,) = sched.segments
    # initial ids 0..3 joined earliest, in id order
    assert seg.leaves.tolist() == [0, 1]
    assert len(seg.joins) == 0


def test_horizon_cap_truncates():
    full = build_traffic_schedule("init:0.5,window:10,poisson:0.2:60",
                                  64, seed=3)
    capped = build_traffic_schedule("init:0.5,window:10,poisson:0.2:60",
                                    64, seed=3, horizon_cap=100.0)
    assert capped.horizon == 100.0
    assert all(s.start <= 100.0 for s in capped.segments)
    assert len(capped.segments) < len(full.segments)


# ------------------------------------------- bulk vs per-event application
def _check_bulk_matches_per_event(spec, seed, M=64):
    """Segment-bulk application through the Database API leaves the
    FleetStore bit-identical to the per-event ClientRecord path."""
    sched = build_traffic_schedule(spec, M, seed=seed)
    cards = np.random.default_rng(0).integers(10, 100, M)

    def seeded():
        db = Database(control_plane="columnar")
        db.fleet = FleetStore(capacity=M)
        if len(sched.initial):
            db.register_clients_bulk(sched.initial, cards[sched.initial],
                                     5, 1)
        return db

    bulk, ev = seeded(), seeded()
    for seg in sched.segments:
        if len(seg.leaves):
            bulk.unregister_clients_bulk(seg.leaves)
        if len(seg.joins):
            bulk.register_clients_bulk(seg.joins, cards[seg.joins], 5, 1)
    for t, kind, cid in sched.events():
        if kind == "leave":
            ev.unregister_client(cid)
        else:
            ev.register_client(ClientRecord(
                client_id=cid, hardware="",
                data_cardinality=int(cards[cid]), batch_size=5,
                local_epochs=1))
    fa, fb = bulk.fleet, ev.fleet
    assert fa._slot == fb._slot
    assert fa._free == fb._free
    for col in ("active", "ids", "seq", "cardinality", "status"):
        assert np.array_equal(getattr(fa, col), getattr(fb, col)), col
    assert bulk.client_ids() == ev.client_ids()


@pytest.mark.parametrize("seed", [0, 42])
@pytest.mark.parametrize("spec", PROPERTY_SPECS)
def test_bulk_apply_matches_per_event_oracle(spec, seed):
    _check_bulk_matches_per_event(spec, seed)


if HAVE_HYPOTHESIS:
    @given(spec=st.sampled_from(PROPERTY_SPECS), seed=st.integers(0, 200))
    @settings(max_examples=15, deadline=None)
    def test_bulk_apply_property(spec, seed):
        _check_bulk_matches_per_event(spec, seed)


# -------------------------------------------------- cold starts on rejoin
def test_scale_down_forces_cold_start_on_rejoin():
    """A traffic leave tears down the client's warm container: the same
    id rejoining must pay a fresh cold start, not inherit the horizon."""
    hw = HARDWARE_PROFILES["cpu2"]
    pf = FaaSPlatform(keep_warm=600.0, cold_start_s=8.0)
    r1 = pf.invoke(7, 0, now=0.0, train_steps=10, hw=hw, base_step_time=0.1)
    assert r1.cold
    r2 = pf.invoke(7, 1, now=r1.duration + 1.0, train_steps=10, hw=hw,
                   base_step_time=0.1)
    assert not r2.cold                          # still inside keep-warm
    pf.scale_down([7])
    r3 = pf.invoke(7, 2, now=r2.t_invoked + r2.duration + 1.0,
                   train_steps=10, hw=hw, base_step_time=0.1)
    assert r3.cold                              # horizon was torn down
    # unknown ids are a no-op
    pf.scale_down([99, 123])


# --------------------------------------------- cross-engine golden traces
# early-boundary variants of the canned profiles, sized so joins/leaves
# actually fire inside a 3-round smoke run
ENGINE_SPECS = [
    "init:0.5,window:10,poisson:0.15:80",                 # steady-churn
    "init:0.5,window:10,diurnal:0.2:0.9:120:60",          # diurnal
    "init:0.25,window:10,flash:20:4:40",                  # flash-crowd
    "init:0.5,window:5,trace:8=+2;25=-1;40=+1",           # trace replay
    "init:0.0,window:10,poisson:0.2:80",                  # empty-fleet start
]


@pytest.mark.parametrize("spec", ENGINE_SPECS)
def test_cross_engine_trace_identical_per_profile(spec, model, data):
    """Controller (legacy poll loop) and Scheduler produce bit-identical
    traces under every traffic profile shape."""
    cfg = FLConfig(**base_cfg_kw(rounds=3, strategy="apodotiko",
                                 traffic_profile=spec))
    assert_engines_equivalent(cfg, model, data, det_fleet(10))


def test_cross_control_plane_trace_identical(model, data):
    runs = run_flag_pair(
        base_cfg_kw(rounds=3, strategy="apodotiko",
                    traffic_profile=ENGINE_SPECS[0]),
        "control_plane", ("columnar", "object"), model, data,
        fleet=det_fleet(10))
    for eng, m in runs.values():
        assert m["n_traffic_joins"] + m["n_traffic_leaves"] > 0


def test_traffic_off_is_bit_identical_to_default(model, data, monkeypatch):
    """"", "off", and auto-with-no-env all draw nothing and match."""
    monkeypatch.delenv("REPRO_TRAFFIC", raising=False)
    runs = run_flag_pair(base_cfg_kw(strategy="apodotiko"),
                         "traffic_profile", ("auto", "", "off"),
                         model, data)
    for eng, m in runs.values():
        assert m["traffic_profile"] == ""
        assert m["n_traffic_joins"] == 0 and m["n_traffic_leaves"] == 0
        assert eng.traffic is None


def test_traffic_env_flag_applies(model, data, monkeypatch):
    monkeypatch.setenv("REPRO_TRAFFIC", ENGINE_SPECS[3])
    eng = Scheduler(FLConfig(**base_cfg_kw(rounds=3)), model, data,
                    det_fleet(10))
    m = eng.run()
    assert m["traffic_profile"] == ENGINE_SPECS[3]
    assert m["n_traffic_joins"] > 0


# --------------------------------------------------- megastep interaction
def test_megastep_refuses_stochastic_traffic(model, data):
    eng = Scheduler(FLConfig(**megastep_cfg(
        rounds=8, megastep="fused",
        traffic_profile="init:1,window:30,poisson:0:600")),
        model, data, det_fleet(10))
    m = eng.run()
    assert m["megastep_rounds"] == 0
    assert m["megastep_fallback_reason"] == "stochastic traffic profile active"


def test_megastep_fuses_to_traffic_boundary(model, data):
    """Deterministic trace traffic: the fused path engages, shrinks its
    horizon to each boundary, and stays bit-identical to stepwise."""
    m_step, m_fused = assert_fused_matches_stepwise(
        megastep_cfg(rounds=10,
                     traffic_profile="init:1,window:5,trace:40=-2"),
        model, data, min_fused_rounds=1)
    assert m_fused["n_traffic_leaves"] == 2


# ---------------------------------------------------------- SLO metrics
def test_slo_summary_pure_function():
    class Log:
        def __init__(self, s, e):
            self.t_start, self.t_end = s, e
    hist = [Log(0, 10), Log(10, 14), Log(14, 30)]
    assert round_latencies(hist).tolist() == [10.0, 4.0, 16.0]
    out = slo_summary(hist, cold_start_ratio=0.25, total_cost_usd=0.3,
                      time_to_accuracy=12.5)
    assert out["p50_round_latency_s"] == 10.0
    assert out["p99_round_latency_s"] == pytest.approx(
        np.percentile([10.0, 4.0, 16.0], 99))
    assert out["cold_start_rate"] == 0.25
    assert out["cost_per_round_usd"] == pytest.approx(0.1)
    assert out["time_to_accuracy_s"] == 12.5
    empty = slo_summary([], 0.0, 0.0)
    assert empty["p50_round_latency_s"] == 0.0
    assert empty["cost_per_round_usd"] == 0.0


def test_metrics_report_slo_and_traffic_counters(model, data):
    eng = Scheduler(FLConfig(**base_cfg_kw(
        rounds=3, strategy="apodotiko",
        traffic_profile=ENGINE_SPECS[0])), model, data, det_fleet(10))
    m = eng.run()
    for key in ("p50_round_latency_s", "p99_round_latency_s",
                "cold_start_rate", "cost_per_round_usd",
                "n_traffic_dropped", "traffic_segments_applied"):
        assert key in m, key
    assert m["p99_round_latency_s"] >= m["p50_round_latency_s"] > 0
    assert m["cost_per_round_usd"] > 0
    lat = round_latencies(eng.history)
    assert m["p50_round_latency_s"] == pytest.approx(
        np.percentile(lat, 50))
