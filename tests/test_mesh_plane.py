"""Mesh plane (DESIGN.md §15): flag resolution, alignment, golden traces.

Contract under test:

* ``"1x1"`` (the default) is the bit-exact oracle — no mesh object is
  constructed and every observable of a run (trace, simulated time,
  params) is byte-identical to pre-mesh builds, across engines and
  update planes.
* Real meshes (``"<data>x<model>"``) keep selections/timing identical
  and params allclose (the psum and batch split reassociate float
  reductions), and the fused megastep stays BIT-identical to the
  stepwise oracle *at the same mesh* — the regression guard for the
  SPMD-partitioner hazards documented in kernels/ops.py and
  core/megastep.py.

The test process itself keeps one CPU device; everything that needs a
real multi-device mesh runs in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the same
pattern as tests/test_sharding.py).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core.services import FLConfig
from repro.core.scheduler import Scheduler
from repro.launch.mesh import _debug_mesh_shape
from repro.sharding import flmesh

from trace_harness import data, model  # noqa: F401
from trace_harness import base_cfg_kw, det_fleet, megastep_cfg, run_flag_pair


# ------------------------------------------------------------- unit layer
def test_parse_mesh():
    assert flmesh.parse_mesh("1x1") == (1, 1)
    assert flmesh.parse_mesh("2x4") == (2, 4)
    assert flmesh.parse_mesh("16X16") == (16, 16)
    for bad in ("", "2", "2x", "x4", "2x4x2", "axb", "0x4", "2x-1", "auto"):
        with pytest.raises(ValueError):
            flmesh.parse_mesh(bad)


def test_resolve_mesh_flag_oracle(monkeypatch):
    """Explicit config > REPRO_MESH > '1x1', validated eagerly."""
    monkeypatch.delenv("REPRO_MESH", raising=False)
    assert flmesh.resolve_mesh("auto") == "1x1"
    assert flmesh.resolve_mesh(None) == "1x1"
    assert flmesh.resolve_mesh("2x4") == "2x4"
    monkeypatch.setenv("REPRO_MESH", "2x2")
    assert flmesh.resolve_mesh("auto") == "2x2"
    assert flmesh.resolve_mesh("1x1") == "1x1"      # explicit beats env
    monkeypatch.setenv("REPRO_MESH", "nonsense")
    with pytest.raises(ValueError):
        flmesh.resolve_mesh("auto")


def test_build_fl_mesh_1x1_is_none_and_cached():
    assert flmesh.build_fl_mesh("1x1") is None
    assert flmesh.mesh_axes(None) == (1, 1)
    assert flmesh.mesh_token(None) == ()


def test_build_fl_mesh_rejects_oversubscription():
    """A spec needing more devices than are visible fails loudly, naming
    the XLA_FLAGS remedy."""
    need = jax.device_count() * 2
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        flmesh.build_fl_mesh(f"{need}x1")


class _FakeMesh:
    """Shape-only stand-in: alignment helpers read only .shape."""

    def __init__(self, data_ax, model_ax):
        self.shape = {"data": data_ax, "model": model_ax}


def test_alignment_gains_mesh_divisibility():
    assert flmesh.row_align(None, 128) == 128
    assert flmesh.capacity_align(None, 8) == 8
    m = _FakeMesh(2, 3)
    assert flmesh.row_align(m, 128) == 384          # lcm(128, model=3)
    assert flmesh.capacity_align(m, 8) == 8         # data=2 divides 8
    assert flmesh.capacity_align(_FakeMesh(16, 1), 8) == 16
    assert flmesh.mesh_axes(m) == (2, 3)
    tok = flmesh.mesh_token(m)
    assert tok[0] == "mesh" and tok[1] == (2, 3)


@pytest.mark.parametrize("n,expect", [
    (0, (1, 1)), (1, (1, 1)), (2, (1, 2)), (3, (1, 3)), (4, (1, 4)),
    (5, (5, 1)), (6, (2, 3)), (7, (7, 1)), (8, (2, 4)), (9, (3, 3)),
    (11, (11, 1)), (12, (3, 4)), (256, (64, 4)),
])
def test_debug_mesh_shape_covers_every_device_count(n, expect):
    """Every device count factorizes into a valid mesh covering exactly
    max(n, 1) devices (the old // 4 arithmetic lost devices for n % 4
    and produced a zero-extent axis for n < 4)."""
    d, m = _debug_mesh_shape(n)
    assert (d, m) == expect
    assert d * m == max(n, 1)
    assert d >= 1 and m >= 1


def test_scheduler_rejects_mesh_without_devices(data, model):
    """Engine construction resolves the mesh eagerly: asking for more
    devices than the process has is an immediate, explicit error."""
    need = jax.device_count() * 2
    cfg = FLConfig(**base_cfg_kw(mesh=f"{need}x1"))
    with pytest.raises(ValueError, match="devices"):
        Scheduler(cfg, model, data, det_fleet(10))


# ----------------------------------------------------- 1x1 oracle layer
@pytest.mark.parametrize("update_plane", ["device", "blob"])
def test_mesh_1x1_is_bit_exact_oracle(data, model, update_plane):
    """mesh='1x1' and mesh='auto' (no env) must be byte-identical on
    both update planes — resolution alone never perturbs a run."""
    os.environ.pop("REPRO_MESH", None)
    kw = base_cfg_kw(rounds=3, strategy="apodotiko",
                     update_plane=update_plane)
    run_flag_pair(kw, "mesh", ("auto", "1x1"), model, data)


def test_mesh_1x1_fused_megastep_unperturbed(data, model):
    """The megastep eligibility proof gains mesh obligations; at 1x1
    they are vacuous and the fused path still engages bit-exactly."""
    from trace_harness import assert_fused_matches_stepwise
    kw = megastep_cfg(rounds=6, mesh="1x1")
    m_step, m_fused = assert_fused_matches_stepwise(kw, model, data,
                                                    min_fused_rounds=1)
    assert m_fused["mesh"] == "1x1"


def test_metrics_report_mesh_spec(data, model):
    cfg = FLConfig(**base_cfg_kw(rounds=1, mesh="1x1"))
    eng = Scheduler(cfg, model, data, det_fleet(10))
    assert eng.run()["mesh"] == "1x1"


# ------------------------------------------------- multi-device layer
# Run in a subprocess so the test process keeps 1 device; one script
# amortizes startup + data/model build across every sharded check.
SHARDED_RUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.pop("REPRO_MESH", None)
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.services import FLConfig
from repro.core.scheduler import Scheduler
from repro.core.update_store import UpdateStore
from repro.core.aggregation import weighted_aggregate_rows
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HardwareProfile
from repro.kernels.ops import RavelSpec
from repro.models.proxy_models import build_bench_model
from repro.sharding import flmesh

out = {}
mesh = flmesh.build_fl_mesh("2x4")
d_ax, m_ax = flmesh.mesh_axes(mesh)
out["mesh_axes"] = [d_ax, m_ax]

# --- sharded UpdateStore round-trip + psum aggregation vs 1-device oracle
tpl = {"w": jnp.zeros((37, 5), jnp.float32), "b": jnp.zeros((11,), jnp.float32)}
spec = RavelSpec(tpl)
rows = [jax.random.normal(jax.random.PRNGKey(i), (spec.n_params,))
        for i in range(5)]
store_m = UpdateStore(spec.n_params, capacity=8, mesh=mesh)
store_0 = UpdateStore(spec.n_params, capacity=8, mesh=None)
ids_m = store_m.put(jnp.stack(rows))
ids_0 = store_0.put(jnp.stack(rows))
out["ids_equal"] = list(map(int, ids_m)) == list(map(int, ids_0))
out["row_spec_ok"] = (store_m.buffer.sharding.spec == flmesh.ROW_SPEC)
out["cap_aligned"] = (store_m.buffer.shape[0] % d_ax == 0
                      and store_m.buffer.shape[1] % m_ax == 0)
out["gather_equal"] = bool(np.array_equal(
    np.asarray(store_m.gather(ids_m))[:, :spec.n_params],
    np.asarray(store_0.gather(ids_0))[:, :spec.n_params]))
w = np.array([0.4, 0.3, 0.2, 0.1], np.float32)
agg_m = weighted_aggregate_rows(store_m.buffer, np.asarray(ids_m[:4]), w,
                                spec, mesh=mesh)
agg_0 = weighted_aggregate_rows(store_0.buffer, np.asarray(ids_0[:4]), w,
                                spec, mesh=None)
err = max(float(np.max(np.abs(np.asarray(agg_m[k]) - np.asarray(agg_0[k]))))
          for k in tpl)
out["agg_err"] = err

# --- blob plane is incompatible with a mesh: loud error, not corruption
data = make_federated_dataset("mnist", n_clients=10, scale=0.05, seed=0)
model = build_bench_model("mnist")

def fleet(n=10, speeds=(1.0, 1.45, 1.9)):
    return [HardwareProfile(f"det{i % len(speeds)}",
                            speed=speeds[i % len(speeds)], vcpus=1.0,
                            mem_gib=2.0, variability=0.0) for i in range(n)]

cfg_kw = dict(n_clients=10, clients_per_round=4, rounds=5, local_epochs=1,
              batch_size=5, base_step_time=0.5, strategy="apodotiko-topk",
              concurrency_ratio=1.0, eval_every=0, keep_warm=1e9, seed=0)
try:
    Scheduler(FLConfig(**cfg_kw, mesh="2x4", update_plane="blob"),
              model, data, fleet())
    out["blob_rejected"] = False
except ValueError:
    out["blob_rejected"] = True

# --- golden traces: 2x4 vs 1x1 stepwise; fused vs stepwise AT 2x4
def run(mesh_spec, megastep, K=4):
    cfg = FLConfig(**{**cfg_kw, "clients_per_round": K}, mesh=mesh_spec,
                   megastep=megastep)
    eng = Scheduler(cfg, model, data, fleet())
    m = eng.run()
    tr = ([(l.round, l.t_start, l.t_end, l.n_aggregated) for l in eng.history],
          [(r.client_id, r.round, r.t_invoked, r.duration)
           for r in eng.platform.invocations])
    flat = np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(eng.params)])
    return tr, flat, m

tr_0, p_0, m_0 = run("1x1", "stepwise")
tr_s, p_s, m_s = run("2x4", "stepwise")
tr_f, p_f, m_f = run("2x4", "fused")
out["trace_2x4_eq_1x1"] = (tr_s == tr_0)
out["params_2x4_vs_1x1_err"] = float(np.max(np.abs(p_s - p_0)))
out["fused_trace_eq"] = (tr_f == tr_s)
out["fused_bitwise_err"] = float(np.max(np.abs(p_f - p_s)))
out["fused_rounds"] = int(m_f.get("megastep_rounds", 0))
out["fallback"] = m_f.get("megastep_fallback_reason")

# K=3 exercises the Kp>K cohort pad (the constant-map gather path)
tr_s3, p_s3, _ = run("2x4", "stepwise", K=3)
tr_f3, p_f3, m_f3 = run("2x4", "fused", K=3)
out["fused_k3_trace_eq"] = (tr_f3 == tr_s3)
out["fused_k3_bitwise_err"] = float(np.max(np.abs(p_f3 - p_s3)))
out["fused_k3_rounds"] = int(m_f3.get("megastep_rounds", 0))
print(json.dumps(out))
"""


def test_sharded_plane_on_8_devices(tmp_path):
    script = tmp_path / "sharded.py"
    script.write_text(SHARDED_RUN)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_MESH", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["mesh_axes"] == [2, 4]
    # store/aggregation layer
    assert rec["ids_equal"] and rec["row_spec_ok"] and rec["cap_aligned"]
    assert rec["gather_equal"]
    assert rec["agg_err"] <= 1e-5          # psum reassociation only
    assert rec["blob_rejected"]
    # golden traces: identical selections/timing, allclose params
    assert rec["trace_2x4_eq_1x1"]
    assert rec["params_2x4_vs_1x1_err"] <= 1e-4
    # fused megastep at the SAME mesh is BIT-identical to stepwise —
    # the guard for the SPMD-partitioner hazards (kernels/ops.py,
    # core/megastep.py): in-trace threefry splits consumed by a sharded
    # shard_map operand and concatenate-of-repeated-slice pads both
    # silently corrupt values when miscompiled.
    assert rec["fused_trace_eq"] and rec["fused_bitwise_err"] == 0.0
    assert rec["fused_rounds"] >= 1, rec["fallback"]
    assert rec["fused_k3_trace_eq"] and rec["fused_k3_bitwise_err"] == 0.0
    assert rec["fused_k3_rounds"] >= 1
