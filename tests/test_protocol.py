"""Protocol layer: event/action dispatch, DatabaseView, adapter phases,
cancellation & hedging mechanics, elasticity through the protocol."""
import numpy as np
import pytest

from repro.core.controller import Controller, FLConfig
from repro.core.protocol import (Aggregate, CancelInvocation, ClientJoined,
                                 ClientLeft, Hedge, Invoke, ReactivePolicy,
                                 ResultLanded, RoundStarted, SetTimer,
                                 TimerFired)
from repro.core.scheduler import Scheduler, build_engine
from repro.core.strategies.reactive import (LegacyStrategyAdapter,
                                            is_reactive, make_policy)
from repro.core.strategies.base import StrategyConfig, build_strategy
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
from repro.models.proxy_models import build_bench_model

N_CLIENTS = 8


@pytest.fixture(scope="module")
def data():
    return make_federated_dataset("mnist", n_clients=N_CLIENTS, scale=0.05,
                                  seed=0)


@pytest.fixture(scope="module")
def model():
    return build_bench_model("mnist")


def _cfg(**kw):
    base = dict(n_clients=N_CLIENTS, clients_per_round=4, rounds=2,
                local_epochs=1, batch_size=5, base_step_time=0.5,
                round_timeout=200.0, seed=0)
    base.update(kw)
    return FLConfig(**base)


class Recorder(ReactivePolicy):
    """Wraps a policy, recording every dispatched event."""

    def __init__(self, inner):
        self.inner = inner
        self.strategy = inner.strategy
        self.name = inner.name
        self.fire_timers_on_drain = inner.fire_timers_on_drain
        self.events = []

    def on_event(self, event, view):
        self.events.append(event)
        return self.inner.on_event(event, view)


def _sched(cfg, model, data, fleet=None, policy=None):
    return Scheduler(cfg, model, data,
                     list(fleet or paper_fleet(N_CLIENTS)), policy=policy)


# ------------------------------------------------------------ event stream


def test_event_stream_shape(data, model):
    cfg = _cfg(strategy="apodotiko")
    rec = Recorder(make_policy("apodotiko",
                               StrategyConfig(clients_per_round=4,
                                              concurrency_ratio=0.3)))
    sched = _sched(cfg, model, data, policy=rec)
    sched.run()
    kinds = [type(e).__name__ for e in rec.events]
    assert kinds.count("RoundStarted") == 2
    assert kinds[0] == "RoundStarted"
    assert "ResultLanded" in kinds
    # ResultLanded events carry the landed record, in sim-time order
    landed = [e for e in rec.events if isinstance(e, ResultLanded)]
    assert all(e.result.t_available == e.t for e in landed)
    assert [e.t for e in rec.events] == sorted(e.t for e in rec.events)
    assert sched.n_events == len(rec.events)


def test_timerfired_on_sync_deadline(data, model):
    """A straggler fleet: the sync deadline timer fires with the round's
    tag and the round closes exactly at t0 + timeout."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    rec = Recorder(make_policy("fedavg", StrategyConfig(clients_per_round=4,
                                                        round_timeout=30.0)))
    sched = _sched(_cfg(strategy="fedavg", round_timeout=30.0,
                        base_step_time=5.0), model, data, fleet, policy=rec)
    sched.run()
    timers = [e for e in rec.events if isinstance(e, TimerFired)]
    assert any(t.tag == "deadline" for t in timers)
    for log in sched.history:
        assert log.t_end - log.t_start <= 30.0 * 3 + 1e-6


def test_view_is_read_only(data, model):
    sched = _sched(_cfg(), model, data)
    view = sched.view
    with pytest.raises(TypeError):
        view.clients[99] = "nope"
    assert isinstance(view.results, tuple)
    assert view.round == 0
    assert view.max_sim_time == sched.cfg.max_sim_time


# ------------------------------------------------------- adapter unit tests


def test_adapter_round_start_returns_invoke(data, model):
    sched = _sched(_cfg(strategy="fedavg"), model, data)
    adapter = LegacyStrategyAdapter(build_strategy(
        "fedavg", StrategyConfig(clients_per_round=4)))
    acts = adapter.on_event(RoundStarted(t=0.0, round=0), sched.view)
    kinds = [type(a) for a in acts]
    assert kinds[0] is Invoke and SetTimer in kinds
    assert len(acts[0].clients) == 4
    assert adapter._phase == "gated"


def test_adapter_stale_timer_ignored(data, model):
    sched = _sched(_cfg(strategy="fedavg"), model, data)
    adapter = LegacyStrategyAdapter(build_strategy(
        "fedavg", StrategyConfig(clients_per_round=4)))
    adapter.on_event(RoundStarted(t=0.0, round=0), sched.view)
    # a timer from round -1 (db.round is 0) must do nothing
    assert adapter.on_event(TimerFired(t=5.0, round=-1, tag="deadline"),
                            sched.view) == []


def test_make_policy_names():
    cfg = StrategyConfig()
    assert make_policy("fedavg", cfg).name == "fedavg"
    assert make_policy("apodotiko-hedge", cfg).name == "apodotiko-hedge"
    assert is_reactive("apodotiko-adaptive")
    assert not is_reactive("fedavg")
    with pytest.raises(KeyError):
        make_policy("nope", cfg)


def test_build_engine_routing(data, model):
    fleet = list(paper_fleet(N_CLIENTS))
    assert isinstance(build_engine(_cfg(engine="legacy"), model, data,
                                   list(fleet)), Controller)
    sched = build_engine(_cfg(engine="scheduler"), model, data, list(fleet))
    assert isinstance(sched, Scheduler)
    # reactive strategies cannot run on the poll loop
    with pytest.raises(ValueError):
        build_engine(_cfg(engine="legacy", strategy="apodotiko-hedge"),
                     model, data, list(fleet))


def test_resolve_engine_env(monkeypatch):
    from repro.core.services import resolve_engine
    assert resolve_engine("legacy") == "legacy"
    monkeypatch.setenv("REPRO_ENGINE", "legacy")
    assert resolve_engine("auto") == "legacy"
    monkeypatch.delenv("REPRO_ENGINE")
    assert resolve_engine("auto") == "scheduler"
    with pytest.raises(ValueError):
        resolve_engine("polling")


# ------------------------------------------- cancellation & hedge mechanics


def test_cancel_invocation_frees_row_and_idles_client(data, model):
    sched = _sched(_cfg(strategy="fedavg"), model, data)
    sched._open_round()                       # invokes 4 clients
    cid = next(iter(sched.inflight))
    free_before = len(sched.store._free)
    sched._execute(CancelInvocation(client_id=cid))
    assert cid not in sched.inflight
    assert sched.db.clients[cid].status == "idle"
    assert len(sched.store._free) == free_before + 1
    assert sched.n_cancelled == 1
    # the cancelled completion never fires; the round still closes (the
    # pump drives timers + events exactly as run() does after opening)
    while sched._pump_one():
        pass
    assert cid not in {r.client_id for r in sched.db.results
                       if r.round == 0}


def test_hedge_races_and_first_result_wins(data, model):
    """A hedged straggler: the warm re-invocation lands first, the
    original is cancelled, exactly one result exists for the client."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    sched = _sched(_cfg(strategy="fedavg", cold_start_s=120.0, rounds=1),
                   model, data, fleet)
    sched._open_round()
    cid = next(iter(sched.inflight))
    sched._execute(Hedge(clients=(cid,)))
    assert sched.n_hedges == 1
    invs = sched.inflight[cid]
    assert len(invs) == 2 and invs[0].payload is invs[1].payload
    assert invs[1].rec.cold is False          # rides the warm container
    assert invs[1].rec.duration < invs[0].rec.duration
    while sched._pump_one():
        pass
    results = [r for r in sched.db.results if r.client_id == cid]
    assert len(results) == 1
    assert sched.n_hedge_wins == 1
    assert sched.n_cancelled == 1             # the losing original
    # both invocations were billed
    assert sum(1 for r in sched.platform.invocations
               if r.client_id == cid) == 2


def test_hedge_idempotent_per_client(data, model):
    sched = _sched(_cfg(strategy="fedavg"), model, data)
    sched._open_round()
    cid = next(iter(sched.inflight))
    assert sched.hedge_invocations([cid]) == [cid]
    assert sched.hedge_invocations([cid]) == []   # already hedged
    assert sched.n_hedges == 1


# --------------------------------------------------- elasticity (satellite)


def test_remove_clients_cleans_hw_fleet_and_inflight(data, model):
    """The satellite fix: remove_clients must drop hw + fleet entries and
    cancel the removed client's in-flight invocation."""
    sched = _sched(_cfg(strategy="fedavg"), model, data)
    sched._open_round()
    running = next(iter(sched.inflight))
    idle = next(c for c in sched.db.clients if c not in sched.inflight)
    n_fleet = len(sched.fleet)
    free_before = len(sched.store._free)
    sched.remove_clients([running, idle])
    for cid in (running, idle):
        assert cid not in sched.db.clients
        assert cid not in sched.hw
        assert cid not in sched.inflight
    assert len(sched.fleet) == n_fleet - 2
    assert len(sched.store._free) == free_before + 1  # in-flight row freed
    while sched._pump_one():                   # no KeyError on completions
        pass
    assert running not in {r.client_id for r in sched.db.results}


def test_membership_events_reach_policy(data, model):
    from repro.core.database import ClientRecord
    rec = Recorder(make_policy("fedavg", StrategyConfig(clients_per_round=4)))
    sched = _sched(_cfg(strategy="fedavg"), model, data, policy=rec)
    sched.remove_clients([0])
    sched.add_clients(
        [ClientRecord(client_id=99, hardware="cpu1", data_cardinality=10,
                      batch_size=5, local_epochs=1)],
        [HARDWARE_PROFILES["cpu1"]])
    kinds = [type(e) for e in rec.events]
    assert ClientLeft in kinds and ClientJoined in kinds


def test_metrics_survive_remove_clients(data, model):
    """Cost/metrics resolve hardware for historical invocations of
    since-removed clients (hw is pruned, the history map is not)."""
    sched = _sched(_cfg(strategy="fedavg", rounds=1), model, data)
    sched.run()
    invoked = sched.platform.invocations[0].client_id
    sched.remove_clients([invoked])
    m = sched.metrics()                        # must not KeyError
    assert m["total_cost_usd"] > 0


def test_cancelled_invocation_billed_partially(data, model):
    """A cancelled invocation bills only its elapsed fraction, and the
    killed container's busy/keep-warm clocks stop at the cancellation."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    sched = _sched(_cfg(strategy="fedavg", cold_start_s=120.0, rounds=1),
                   model, data, fleet)
    sched._open_round()
    cid = next(iter(sched.inflight))
    rec = sched.inflight[cid][0].rec
    full = rec.duration
    sched.loop.now = rec.t_invoked + 1.0       # cancel 1 s in
    sched._execute(CancelInvocation(client_id=cid))
    assert rec.cancelled and rec.duration == pytest.approx(1.0)
    assert rec.duration < full
    inst = sched.platform._instances[cid]
    assert inst.busy_until == pytest.approx(sched.loop.now)
    assert inst.warm_until == pytest.approx(
        sched.loop.now + sched.platform.keep_warm)


def test_hedge_loser_billing_keeps_winner_warmth(data, model):
    """Cancelling the race loser must not roll back the keep-warm window
    the winning invocation legitimately opened."""
    fleet = [HARDWARE_PROFILES["cpu1"]] * N_CLIENTS
    sched = _sched(_cfg(strategy="fedavg", cold_start_s=120.0, rounds=1),
                   model, data, fleet)
    sched._open_round()
    cid = next(iter(sched.inflight))
    sched._execute(Hedge(clients=(cid,)))
    orig, hedge = sched.inflight[cid]
    while sched._pump_one():
        pass
    assert orig.rec.cancelled and not hedge.rec.cancelled
    # loser billed only until the winner landed
    assert orig.rec.t_completed == pytest.approx(hedge.rec.t_completed)
    inst = sched.platform._instances[cid]
    assert inst.warm_until == pytest.approx(
        hedge.rec.t_completed + sched.platform.keep_warm)


def test_legacy_remove_clients_also_fixed(data, model):
    """The fix applies to the legacy engine too (shared runtime)."""
    ctl = Controller(_cfg(strategy="apodotiko"), model, data,
                     list(paper_fleet(N_CLIENTS)))
    ctl.run()
    n_fleet = len(ctl.fleet)
    ctl.remove_clients([0, 1])
    assert 0 not in ctl.hw and 1 not in ctl.hw
    assert len(ctl.fleet) == n_fleet - 2
    assert len(ctl.db.clients) == N_CLIENTS - 2
