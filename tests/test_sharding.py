"""Sharding rule-engine tests + a subprocess mini dry-run on 8 host devices."""
import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

import jax

from repro.sharding.rules import (
    DEFAULT_RULES,
    axis_rules,
    logical_spec,
    shard_act,
    zero1_extend,
)


@pytest.fixture(scope="module")
def mesh2d():
    # 1-device test process: trivial mesh still exercises the rule engine
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Shape-only mesh stand-in for pure rule-resolution tests."""

    def __init__(self, shape):
        self.shape = shape


def test_divisible_dims_shard():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = logical_spec(("batch", "seq", "ffn"), (256, 4096, 14336), mesh,
                        DEFAULT_RULES)
    assert spec == P("data", None, "model")


def test_non_divisible_falls_back_to_replication():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # kv_heads = 8 does not divide 16 -> replicated, never padded
    spec = logical_spec(("batch", "kv_heads", None), (128, 8, 128), mesh,
                        DEFAULT_RULES)
    assert spec == P("data")


def test_multi_axis_rule_greedy_drop():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # batch 16 can't take (pod,data)=32 -> drops 'pod', uses data
    spec = logical_spec(("batch",), (16,), mesh, DEFAULT_RULES)
    assert spec == P("data")
    # batch 32 takes both
    spec = logical_spec(("batch",), (32,), mesh, DEFAULT_RULES)
    assert spec == P(("pod", "data"))


def test_axis_never_used_twice():
    mesh = _FakeMesh({"data": 4, "model": 4})
    spec = logical_spec(("ffn", "ffn"), (64, 64), mesh, DEFAULT_RULES)
    # second ffn dim cannot reuse 'model'
    assert spec == P("model")


def test_zero1_extends_largest_free_dim():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = zero1_extend(P(None, "model"), (4096, 14336), mesh)
    assert spec == P("data", "model")


def test_zero1_skips_when_nothing_divides():
    mesh = _FakeMesh({"data": 16})
    spec = zero1_extend(P(), (7, 9), mesh)
    assert spec == P()


def test_shard_act_is_noop_outside_axis_rules():
    """Un-meshed model code must run untouched: no constraint, same
    object identity semantics (value + sharding unchanged)."""
    import jax.numpy as jnp
    x = jnp.arange(12.0).reshape(3, 4)
    y = shard_act(x, ("batch", "ffn"))
    assert y is x


def test_shard_act_constrains_inside_axis_rules(mesh2d):
    """Under axis_rules the constraint is value-preserving, and the spec
    it resolves is the rule-table one (checked via logical_spec — eager
    with_sharding_constraint on one device normalizes the sharding)."""
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding

    x = jnp.arange(8.0).reshape(2, 4)
    with axis_rules(mesh2d):
        y = shard_act(x, ("batch", "ffn"))
        spec = logical_spec(("batch", "ffn"), x.shape, mesh2d,
                            DEFAULT_RULES)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert spec == P("data", "model")
    assert y.sharding.is_equivalent_to(NamedSharding(mesh2d, spec), x.ndim)


def test_tuple_rule_resolves_multiple_axes():
    """A tuple rule uses every listed axis present on the mesh (in order)
    when the product divides; missing axes are skipped, not fatal."""
    mesh = _FakeMesh({"data": 4, "model": 2})
    # 'batch' rule is ('pod', 'data'); no 'pod' axis here -> just data
    assert logical_spec(("batch",), (8,), mesh, DEFAULT_RULES) == P("data")
    rules = dict(DEFAULT_RULES, batch=("data", "model"))
    assert logical_spec(("batch",), (8,), mesh, rules) == P(("data", "model"))
    # 8 % (4*2) == 0 but 4 % 8 != 0 -> greedy drop of the leading axis
    assert logical_spec(("batch",), (4,), mesh, rules) == P("model")


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.launch.steps import build_cell

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("mini_train", 64, 8, "train")
cell = build_cell("qwen3-1.7b", shape, mesh,
                  overrides=dict(n_layers=2, d_model=64, n_heads=4,
                                 n_kv_heads=2, head_dim=16, d_ff=128,
                                 vocab_size=256, param_dtype="float32",
                                 compute_dtype="float32", remat=False))
compiled = cell.lower().compile()
mem = compiled.memory_analysis()
print(json.dumps({"ok": True,
                  "args_bytes": mem.argument_size_in_bytes,
                  "n_devices": mesh.size}))
"""


def test_mini_dryrun_on_8_devices(tmp_path):
    """End-to-end: build_cell -> lower -> compile on a real (2,4) mesh in a
    subprocess (the test process itself must keep 1 device)."""
    script = tmp_path / "mini.py"
    script.write_text(MINI_DRYRUN)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["n_devices"] == 8
