"""Optimizer tests: convergence on a quadratic, state shapes, adafactor
memory factorization, fused-Adam kernel dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor,
    adam,
    adam_fused,
    apply_updates,
    build_optimizer,
    momentum,
    sgd,
)


def _minimize(opt, steps=200):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.02),
                                     ("adam", 0.1), ("adafactor", 0.3)])
def test_optimizers_converge_on_quadratic(name, lr):
    assert _minimize(build_optimizer(name, lr)) < 1e-2


def test_adam_state_mirrors_params():
    opt = adam(1e-3)
    params = {"a": jnp.zeros((4, 5)), "b": {"c": jnp.zeros(7)}}
    st = opt.init(params)
    assert st["m"]["a"].shape == (4, 5)
    assert st["v"]["b"]["c"].shape == (7,)
    assert st["m"]["a"].dtype == jnp.float32


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.zeros((128, 256)), "b": jnp.zeros(16)}
    st = opt.init(params)
    # rank-2 leaf: row [128] + col [256] instead of 128*256
    assert st["s"]["w"]["row"].shape == (128,)
    assert st["s"]["w"]["col"].shape == (256,)
    assert st["s"]["b"]["v"].shape == (16,)
    n_state = sum(int(x.size) for x in jax.tree.leaves(st))
    n_params = 128 * 256 + 16
    assert n_state < n_params / 50  # >50x smaller than Adam's m+v


def test_adam_matches_reference_formula():
    opt = adam(0.1, b1=0.9, b2=0.999)
    params = {"w": jnp.array([1.0])}
    st = opt.init(params)
    g = {"w": jnp.array([0.5])}
    upd, st = opt.update(g, st, params)
    # t=1: mhat = g, vhat = g^2 -> update = -lr * g/(|g|+eps) ~= -lr
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1], rtol=1e-4)


# ------------------------------------------------------- fused Adam kernel
def test_fused_adam_self_check_passes():
    from repro.optim.optimizers import _fused_adam_validated
    assert _fused_adam_validated()


def test_fused_adam_matches_xla_adam_over_steps():
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(13, 7)), jnp.float32),
              "b": {"c": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}}
    of, ox = adam_fused(1e-3), adam(1e-3)
    sf, sx = of.init(params), ox.init(params)
    for step in range(3):
        grads = jax.tree.map(
            lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32),
            params)
        uf, sf = of.update(grads, sf, params)
        ux, sx = ox.update(grads, sx, params)
        for a, b in zip(jax.tree.leaves(uf), jax.tree.leaves(ux)):
            assert a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        params = apply_updates(params, uf)


def test_fused_adam_converges_on_quadratic():
    assert _minimize(adam_fused(0.1)) < 1e-2


def test_adam_path_env_dispatch(monkeypatch):
    monkeypatch.setenv("REPRO_ADAM_PATH", "fused")
    assert build_optimizer("adam", 1e-3).name == "adam-fused"
    monkeypatch.setenv("REPRO_ADAM_PATH", "xla")
    assert build_optimizer("adam", 1e-3).name == "adam"
    monkeypatch.setenv("REPRO_ADAM_PATH", "cuda")
    with pytest.raises(ValueError, match="unknown adam path"):
        build_optimizer("adam", 1e-3)
    monkeypatch.delenv("REPRO_ADAM_PATH")
    # auto off-TPU: interpret-mode fused adam in the training inner loop
    # would be a slowdown, so auto keeps the XLA implementation
    from repro.kernels.ops import on_tpu
    if not on_tpu():
        assert build_optimizer("adam", 1e-3).name == "adam"
