"""Optimizer tests: convergence on a quadratic, state shapes, adafactor
memory factorization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adam, apply_updates, build_optimizer, momentum, sgd


def _minimize(opt, steps=200):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.02),
                                     ("adam", 0.1), ("adafactor", 0.3)])
def test_optimizers_converge_on_quadratic(name, lr):
    assert _minimize(build_optimizer(name, lr)) < 1e-2


def test_adam_state_mirrors_params():
    opt = adam(1e-3)
    params = {"a": jnp.zeros((4, 5)), "b": {"c": jnp.zeros(7)}}
    st = opt.init(params)
    assert st["m"]["a"].shape == (4, 5)
    assert st["v"]["b"]["c"].shape == (7,)
    assert st["m"]["a"].dtype == jnp.float32


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.zeros((128, 256)), "b": jnp.zeros(16)}
    st = opt.init(params)
    # rank-2 leaf: row [128] + col [256] instead of 128*256
    assert st["s"]["w"]["row"].shape == (128,)
    assert st["s"]["w"]["col"].shape == (256,)
    assert st["s"]["b"]["v"].shape == (16,)
    n_state = sum(int(x.size) for x in jax.tree.leaves(st))
    n_params = 128 * 256 + 16
    assert n_state < n_params / 50  # >50x smaller than Adam's m+v


def test_adam_matches_reference_formula():
    opt = adam(0.1, b1=0.9, b2=0.999)
    params = {"w": jnp.array([1.0])}
    st = opt.init(params)
    g = {"w": jnp.array([0.5])}
    upd, st = opt.update(g, st, params)
    # t=1: mhat = g, vhat = g^2 -> update = -lr * g/(|g|+eps) ~= -lr
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1], rtol=1e-4)
