"""FaaS platform simulation tests: cold starts, scale-to-zero, costs, events."""
import numpy as np
import pytest

from repro.faas.cost import CostModel
from repro.faas.events import EventLoop
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
from repro.faas.platform import FaaSPlatform


def test_event_loop_ordering():
    loop = EventLoop()
    seen = []
    loop.schedule(5.0, lambda: seen.append("b"))
    loop.schedule(1.0, lambda: seen.append("a"))
    loop.schedule(9.0, lambda: seen.append("c"))
    loop.run_all()
    assert seen == ["a", "b", "c"]
    assert loop.now == pytest.approx(9.0)


def test_event_loop_predicate_stop():
    loop = EventLoop()
    seen = []
    for t in (1, 2, 3, 4):
        loop.schedule(t, lambda t=t: seen.append(t))
    loop.run_until(lambda: len(seen) >= 2)
    assert seen == [1, 2]


def test_event_loop_cancel_skips_callback():
    loop = EventLoop()
    seen = []
    ev = loop.schedule(1.0, lambda: seen.append("cancelled"))
    loop.schedule(2.0, lambda: seen.append("kept"))
    loop.cancel(ev)
    loop.cancel(ev)  # idempotent
    loop.run_all()
    assert seen == ["kept"]
    assert loop.pending == 0


def test_event_loop_compacts_tombstones():
    """Heavy hedging/cancellation: the heap must stay bounded by the live
    count, not grow one tombstone per cancel forever."""
    loop = EventLoop()
    live = [loop.schedule(1e6 + i, lambda: None) for i in range(10)]
    for i in range(10_000):
        ev = loop.schedule(float(i), lambda: None)
        loop.cancel(ev)
        # tombstones never exceed half the heap (+1 for the pre-compact peek)
        assert loop._n_cancelled <= len(loop._heap) // 2 + 1
    assert len(loop._heap) < 40          # ~10 live, not 10k tombstones
    assert loop.pending == 10            # O(1), counts only live events
    for ev in live[:5]:
        loop.cancel(ev)
    assert loop.pending == 5


def test_event_loop_peek_and_step():
    loop = EventLoop()
    seen = []
    a = loop.schedule(1.0, lambda: seen.append("a"))
    loop.schedule(2.0, lambda: seen.append("b"))
    assert loop.peek() == pytest.approx(1.0)
    loop.cancel(a)
    assert loop.peek() == pytest.approx(2.0)  # skips the tombstone
    assert loop.step() is True
    assert seen == ["b"] and loop.now == pytest.approx(2.0)
    assert loop.step() is False and loop.peek() is None


def test_event_loop_cancel_after_pop_is_noop():
    loop = EventLoop()
    ev = loop.schedule(1.0, lambda: None)
    loop.schedule(2.0, lambda: None)
    loop.step()          # pops ev
    loop.cancel(ev)      # already ran: must not corrupt the tombstone count
    assert loop.pending == 1
    assert loop._n_cancelled == 0


def test_first_invocation_is_cold():
    p = FaaSPlatform(keep_warm=600, cold_start_s=8)
    hw = HARDWARE_PROFILES["cpu1"]
    rec = p.invoke(0, 0, now=0.0, train_steps=10, hw=hw, base_step_time=1.0)
    assert rec.cold


def test_warm_within_keep_warm_window():
    p = FaaSPlatform(keep_warm=600, cold_start_s=8)
    hw = HARDWARE_PROFILES["cpu1"]
    r1 = p.invoke(0, 0, now=0.0, train_steps=10, hw=hw, base_step_time=1.0)
    r2 = p.invoke(0, 1, now=r1.t_completed + 100, train_steps=10, hw=hw,
                  base_step_time=1.0)
    assert not r2.cold


def test_cold_after_scale_to_zero():
    p = FaaSPlatform(keep_warm=600, cold_start_s=8)
    hw = HARDWARE_PROFILES["cpu1"]
    r1 = p.invoke(0, 0, now=0.0, train_steps=10, hw=hw, base_step_time=1.0)
    r2 = p.invoke(0, 1, now=r1.t_completed + 601, train_steps=10, hw=hw,
                  base_step_time=1.0)
    assert r2.cold
    assert p.cold_start_ratio() == pytest.approx(1.0)


def test_gpu_clients_faster_than_cpu():
    p = FaaSPlatform(seed=1)
    cpu_rec = p.invoke(0, 0, 0.0, 1000, HARDWARE_PROFILES["cpu1"], 0.1)
    gpu_rec = p.invoke(1, 0, 0.0, 1000, HARDWARE_PROFILES["gpu"], 0.1)
    assert gpu_rec.duration < cpu_rec.duration / 4


def test_paper_fleet_mix():
    fleet = paper_fleet(200)
    names = [h.name for h in fleet]
    assert len(fleet) == 200
    assert names.count("cpu1") == 130
    assert names.count("cpu2") == 50
    assert names.count("gpu") == 20


def test_cost_model_gpu_premium():
    cm = CostModel()
    p = FaaSPlatform(seed=0)
    cpu = p.invoke(0, 0, 0.0, 1000, HARDWARE_PROFILES["cpu1"], 0.1)
    gpu = p.invoke(1, 0, 0.0, 1000, HARDWARE_PROFILES["gpu"], 0.1)
    c_cpu = cm.invocation_cost(cpu, HARDWARE_PROFILES["cpu1"])
    c_gpu = cm.invocation_cost(gpu, HARDWARE_PROFILES["gpu"])
    assert c_cpu > 0 and c_gpu > 0
    # GPU costs more per second (hourly P100 fraction dominates)
    assert c_gpu / gpu.duration > c_cpu / cpu.duration


def test_failures_injected():
    p = FaaSPlatform(seed=0, failure_rate=0.5)
    hw = HARDWARE_PROFILES["cpu1"]
    recs = [p.invoke(i, 0, 0.0, 100, hw, 0.1) for i in range(50)]
    fails = sum(r.failed for r in recs)
    assert 10 < fails < 40
