"""Chaos suite (DESIGN.md §12): fault injection + retry/backoff recovery.

Layers under test:

* ``faas.faults`` — spec parsing, profile resolution, the fixed-draw
  determinism contract, phase attribution (OOM tiers, outage groups).
* ``faas.platform`` — the crashed-container keep-warm bugfix, zombie
  (lost-result) warm semantics, ``cancel`` edge cases.
* cross-engine chaos bit-identity — identical seeded schedules through
  the legacy poll loop and the event scheduler produce identical traces,
  with no leaked update rows / blobs / in-flight entries after storms.
* the recovery layer — per-invocation timeouts, backoff retries with a
  per-round budget, the quarantine circuit breaker (FleetStore columns
  feeding the selection mask), and partial-cohort quorum rounds.
* megastep interaction — recovery knobs and stochastic schedules refuse
  fusion with an attributable reason; deterministic outage windows only
  shrink the horizon, and fusion re-engages once the window has passed.
"""
import numpy as np
import pytest

from chaos_harness import (assert_chaos_invariants, chaos_trace,
                           run_chaos_pair)
from trace_harness import (ALL_STRATEGIES, N_CLIENTS,
                           assert_engines_equivalent, base_cfg_kw, data,
                           model, det_fleet, megastep_cfg,
                           assert_fused_matches_stepwise)  # noqa: F401

from repro.core.controller import FLConfig
from repro.core.scheduler import Scheduler
from repro.core.recovery import RecoveryPolicy, recovery_enabled
from repro.faas.faults import (FAULT_PROFILES, CrashFault, FaultModel,
                               FaultSchedule, OOMFault, OutageWindow,
                               ResultLossFault, SlowdownFault,
                               build_fault_model, parse_faults,
                               resolve_fault_profile)
from repro.faas.hardware import HardwareProfile, paper_fleet
from repro.faas.platform import FaaSPlatform


HW = HardwareProfile("t", speed=1.0, vcpus=1.0, mem_gib=2.0)


# ---------------------------------------------------------------- faults unit
def test_parse_faults_all_kinds():
    faults = parse_faults("crash:train:0.2,slow:2.5:0.1,loss:0.15:0.2:45,"
                          "oom:2.0:0.3,outage:150-400:mod3=1")
    assert faults == (CrashFault("train", 0.2), SlowdownFault(0.1, 2.5),
                      ResultLossFault(0.15, 0.2, 45.0), OOMFault(0.3, 2.0),
                      OutageWindow(150.0, 400.0, 3, 1))


def test_parse_faults_explicit_outage_clients():
    (w,) = parse_faults("outage:10-20:3+7")
    assert w.clients == (3, 7)
    assert w.hits(3, 15.0) and w.hits(7, 10.0)
    assert not w.hits(4, 15.0)          # explicit list overrides mod/rem
    assert not w.hits(3, 20.0)          # end-exclusive


def test_parse_faults_rejects_unknown():
    with pytest.raises(ValueError, match="unknown fault spec"):
        parse_faults("meteor:0.5")
    with pytest.raises(ValueError, match="unknown crash phase"):
        parse_faults("crash:teardown:0.5")


def test_resolve_fault_profile_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert resolve_fault_profile("auto") == ""
    monkeypatch.setenv("REPRO_FAULTS", "crash-heavy")
    assert resolve_fault_profile("auto") == "crash-heavy"
    assert resolve_fault_profile("") == "crash-heavy"
    # explicit config beats the env var; none/off disable
    assert resolve_fault_profile("lossy-network") == "lossy-network"
    assert resolve_fault_profile("none") == ""
    assert resolve_fault_profile("off") == ""
    with pytest.raises(ValueError):
        resolve_fault_profile("not:a:profile")


def test_build_fault_model_off_is_none():
    assert build_fault_model("", 0) is None
    model = build_fault_model("crash-heavy", 3)
    assert model is not None and model.active
    assert len(model.stochastic) == 3


def test_fault_model_is_replayable():
    def outcomes(seed):
        m = FaultModel(FaultSchedule(seed=seed, faults=parse_faults(
            "crash:train:0.3,loss:0.2:0.5:10,slow:2.0:0.3")))
        return [m.evaluate(cid, float(t), HW)
                for t in range(50) for cid in range(4)]

    a, b = outcomes(7), outcomes(7)
    assert a == b                        # same seed: bit-identical outcomes
    assert outcomes(8) != a              # seed actually matters
    kinds = {o.failed_phase for o in a}
    assert "train" in kinds and ("loss" in kinds or
                                 any(o.late_by for o in a))


def test_outage_window_is_deterministic_no_draws():
    sched = FaultSchedule(seed=0, faults=parse_faults("outage:10-20:mod2=0"))
    m = FaultModel(sched)
    assert m.evaluate(2, 15.0, HW).failed_phase == "outage"
    assert m.evaluate(3, 15.0, HW).failed_phase == ""
    assert m.evaluate(2, 25.0, HW).failed_phase == ""
    # outage-only schedules consume exactly one draw (the frac) per call,
    # so two fresh models at the same seed stay in lockstep forever
    m1, m2 = FaultModel(sched), FaultModel(sched)
    for t in range(30):
        assert m1.evaluate(t % 5, float(t), HW) == \
            m2.evaluate(t % 5, float(t), HW)


def test_oom_keys_on_hardware_tier():
    m = FaultModel(FaultSchedule(seed=0, faults=(OOMFault(rate=1.0,
                                                          mem_below_gib=2.0),)))
    big = HardwareProfile("big", speed=1.0, vcpus=2.0, mem_gib=4.0)
    assert m.evaluate(0, 0.0, HW).failed_phase == "oom"
    assert m.evaluate(0, 0.0, big).failed_phase == ""


# ----------------------------------------------------------- platform faults
def test_crashed_container_goes_cold():
    """Satellite bugfix: a crashed instance must NOT stay warm — the next
    invocation pays a cold start again."""
    p = FaaSPlatform(seed=0, failure_rate=1.0, keep_warm=600.0)
    rec = p.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    assert rec.failed and rec.failed_phase == "train"
    assert p._instances[0].warm_until == rec.t_completed
    rec2 = p.invoke(0, 1, rec.t_completed + 1.0, 10.0, HW, 0.5)
    assert rec2.cold                     # pre-fix: warm (the bug)


def test_zombie_keeps_container_warm():
    """A lost (zombie) invocation ran to completion: the container
    survives and stays warm for the keep-warm window."""
    p = FaaSPlatform(seed=0, keep_warm=600.0,
                     faults=build_fault_model("loss:1.0", 0))
    rec = p.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    assert rec.failed and rec.lost and rec.failed_phase == "loss"
    assert p._instances[0].warm_until == rec.t_completed + 600.0
    rec2 = p.invoke(0, 1, rec.t_completed + 1.0, 10.0, HW, 0.5)
    assert not rec2.cold


def test_fault_injection_attributes_phases():
    p = FaaSPlatform(seed=0, faults=build_fault_model(
        "crash:startup:0.3,crash:upload:0.3", 1))
    recs = [p.invoke(i % 4, 0, float(i * 100), 10.0, HW, 0.5)
            for i in range(60)]
    phases = {r.failed_phase for r in recs if r.failed}
    assert phases <= {"startup", "upload"}
    assert len(phases) == 2
    for r in recs:
        if r.failed_phase == "startup":
            # crashed during boot: duration is a fraction of startup only
            assert r.duration < p.cold_start_s * 1.3
    assert any(not r.failed for r in recs)


def test_slowdown_stretches_train_time():
    slow = FaaSPlatform(seed=0, faults=build_fault_model("slow:3.0:1.0", 0))
    base = FaaSPlatform(seed=0)
    r_slow = slow.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    r_base = base.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    assert not r_slow.failed
    assert r_slow.duration > r_base.duration   # train time tripled


def test_late_landing_extends_duration():
    late = FaaSPlatform(seed=0, faults=build_fault_model("loss:1.0:1.0:60", 0))
    base = FaaSPlatform(seed=0)
    r_late = late.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    r_base = base.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    assert not r_late.failed and not r_late.lost
    assert r_late.duration == pytest.approx(r_base.duration + 60.0)


# ------------------------------------------------------------- cancel edges
def test_cancel_after_completion_is_noop():
    p = FaaSPlatform(seed=0)
    rec = p.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    d = rec.duration
    p.cancel(rec, rec.t_completed + 5.0)
    assert not rec.cancelled and rec.duration == d


def test_cancel_truncates_and_stops_clocks():
    p = FaaSPlatform(seed=0, keep_warm=600.0)
    rec = p.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    mid = rec.t_completed / 2
    p.cancel(rec, mid)
    assert rec.cancelled and rec.duration == mid and rec.t_completed == mid
    assert p._instances[0].busy_until == mid
    assert p._instances[0].warm_until == mid + 600.0


def test_cancel_hedge_loser_respects_live_sibling():
    """Cancelling the hedge loser must roll clocks back only to the
    surviving sibling's completion, not to ``now``."""
    p = FaaSPlatform(seed=0, keep_warm=600.0)
    a = p.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    b = p.invoke(0, 0, 1.0, 10.0, HW, 0.5)     # hedge on the same instance
    winner, loser = (a, b) if a.t_completed <= b.t_completed else (b, a)
    p.cancel(loser, winner.t_completed, live_until=winner.t_completed)
    assert loser.cancelled
    assert p._instances[0].busy_until == winner.t_completed
    assert p._instances[0].warm_until == winner.t_completed + 600.0


def test_cancel_failed_invocation_midflight():
    p = FaaSPlatform(seed=0, failure_rate=1.0)
    rec = p.invoke(0, 0, 0.0, 10.0, HW, 0.5)
    assert rec.failed
    mid = rec.t_completed / 2
    p.cancel(rec, mid)
    assert rec.cancelled and rec.failed and rec.t_completed == mid


# ----------------------------------------------- cross-engine chaos identity
@pytest.mark.parametrize("profile", sorted(FAULT_PROFILES))
def test_cross_engine_identity_under_profile(profile, data, model):
    run_chaos_pair(base_cfg_kw(strategy="fedavg", fault_profile=profile),
                   model, data)


def test_cross_engine_identity_blob_plane(data, model):
    run_chaos_pair(base_cfg_kw(strategy="fedavg", fault_profile="crash-heavy",
                               update_plane="blob"), model, data)


def test_cross_engine_identity_async_strategy(data, model):
    run_chaos_pair(base_cfg_kw(strategy="apodotiko",
                               fault_profile="lossy-network"), model, data)


def test_chaos_run_is_replayable(data, model):
    kw = base_cfg_kw(strategy="fedavg", fault_profile="crash-heavy")
    runs = []
    for _ in range(2):
        eng = Scheduler(FLConfig(**kw), model, data,
                        list(paper_fleet(N_CLIENTS)))
        eng.run()
        runs.append(chaos_trace(eng))
    assert runs[0] == runs[1]


@pytest.mark.parametrize("update_plane", ("device", "blob"))
def test_crash_storm_leaves_no_leaks(update_plane, data, model):
    kw = base_cfg_kw(strategy="apodotiko", update_plane=update_plane,
                     fault_profile="crash:train:0.5,crash:startup:0.2,"
                                   "crash:upload:0.2")
    eng = Scheduler(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m = eng.run()
    assert m["n_failures"] > 0
    assert set(m["failures_by_phase"]) <= {"startup", "train", "upload"}
    assert_chaos_invariants(eng)


def test_outage_targets_only_its_group(data, model):
    kw = base_cfg_kw(strategy="fedavg",
                     fault_profile="outage:0-100000:mod2=1")
    eng = Scheduler(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m = eng.run()
    assert m["n_failures"] > 0
    for r in eng.platform.invocations:
        if r.client_id % 2 == 1:
            assert r.failed and r.failed_phase == "outage"
        else:
            assert not r.failed
    assert_chaos_invariants(eng)


def test_faults_off_matches_pre_fault_trace(data, model):
    """fault_profile="" must be a true no-op: same trace as a run where
    the platform has no fault model at all."""
    kw = base_cfg_kw(strategy="fedavg")
    a = Scheduler(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    a.run()
    assert a.platform.faults is None
    b = Scheduler(FLConfig(**kw, fault_profile="none"), model, data,
                  list(paper_fleet(N_CLIENTS)))
    b.run()
    assert chaos_trace(a) == chaos_trace(b)


# --------------------------------------------------------------- recovery
class _StubDB:
    def __init__(self, consec=0, quarantined=False):
        self._consec = consec
        self._quar = quarantined

    def consecutive_failures(self, cid):
        return self._consec

    def is_quarantined(self, cid):
        return self._quar


class _StubView:
    def __init__(self, round_=0, **db_kw):
        self.round = round_
        self.db = _StubDB(**db_kw)


class _StubEvent:
    def __init__(self, cid, round_):
        self.client_id = cid
        self.round = round_


class _StubInner:
    """Minimal inner policy: records the events it was shown."""

    strategy = None
    name = "stub"
    fire_timers_on_drain = False

    def __init__(self):
        self.seen = []

    def on_event(self, ev, view):
        self.seen.append(ev)
        return []


def _recovery_cfg(**kw):
    return FLConfig(**base_cfg_kw(strategy="fedavg", **kw))


def _recovery_policy(cfg):
    return RecoveryPolicy(_StubInner(), cfg)


def test_recovery_enabled_gate():
    assert not recovery_enabled(_recovery_cfg())
    assert recovery_enabled(_recovery_cfg(retry_budget=1))
    assert recovery_enabled(_recovery_cfg(invocation_timeout=10.0))
    assert recovery_enabled(_recovery_cfg(quarantine_threshold=3))


def test_retry_backoff_is_exponential_and_budgeted():
    cfg = _recovery_cfg(retry_budget=3, retry_base_delay=2.0,
                        retry_backoff=2.0, retry_jitter=0.0)
    pol = _recovery_policy(cfg)
    view = _StubView(round_=0)
    delays = [pol._recover(_StubEvent(5, 0), view) for _ in range(4)]
    assert [a[0].delay for a in delays[:3]] == [2.0, 4.0, 8.0]
    assert delays[3] == []               # per-round budget exhausted
    # a new round resets attempts and budget
    from repro.core.protocol import RoundStarted
    pol.on_event(RoundStarted(t=0.0, round=1), _StubView(round_=1))
    assert pol._budget == 3 and pol._attempts == {}
    assert [a.delay for a in pol._recover(_StubEvent(5, 1),
                                          _StubView(round_=1))] == [2.0]


def test_retry_jitter_is_seeded_and_bounded():
    cfg = _recovery_cfg(retry_budget=50, retry_base_delay=2.0,
                        retry_backoff=1.0, retry_jitter=0.25)
    a, b = _recovery_policy(cfg), _recovery_policy(cfg)
    view = _StubView(round_=0)
    da = [a._recover(_StubEvent(i, 0), view)[0].delay for i in range(20)]
    db = [b._recover(_StubEvent(i, 0), view)[0].delay for i in range(20)]
    assert da == db                      # same seed: same jitter stream
    assert all(2.0 <= d < 2.0 * 1.25 for d in da)
    assert len(set(da)) > 1              # jitter actually varies


def test_timeout_event_translated_for_inner_policy():
    from repro.core.protocol import (InvocationFailed, InvocationTimedOut,
                                     Retry)
    cfg = _recovery_cfg(retry_budget=1, retry_jitter=0.0)
    pol = _recovery_policy(cfg)
    acts = pol.on_event(InvocationTimedOut(t=3.0, round=0, client_id=7),
                        _StubView(round_=0))
    assert any(isinstance(a, Retry) for a in acts)
    (seen,) = pol.inner.seen
    assert isinstance(seen, InvocationFailed)   # inner never sees the
    assert seen.client_id == 7 and seen.t == 3.0  # new event type


def test_retry_skips_stale_round_failures():
    cfg = _recovery_cfg(retry_budget=3, retry_jitter=0.0)
    pol = _recovery_policy(cfg)
    # a failure from a previous round gets no retry (round-scoped budget)
    assert pol._recover(_StubEvent(5, 0), _StubView(round_=1)) == []


def test_quarantine_preempts_retry():
    from repro.core.protocol import Quarantine
    cfg = _recovery_cfg(retry_budget=3, quarantine_threshold=2,
                        quarantine_rounds=4)
    pol = _recovery_policy(cfg)
    acts = pol._recover(_StubEvent(5, 0), _StubView(round_=0, consec=2))
    assert len(acts) == 1 and isinstance(acts[0], Quarantine)
    assert acts[0].until_round == 4
    # breaker already open: no duplicate action
    assert pol._recover(_StubEvent(5, 0),
                        _StubView(round_=0, consec=3, quarantined=True)) == []


def test_retries_recover_failures_end_to_end(data, model):
    kw = base_cfg_kw(strategy="fedavg", failure_rate=0.4, retry_budget=8,
                     retry_jitter=0.0, rounds=2)
    eng = Scheduler(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m = eng.run()
    assert isinstance(eng.policy, RecoveryPolicy)
    assert m["n_retries"] > 0
    assert m["retry_latency_s"] > 0.0
    assert m["n_retries"] <= 8 * kw["rounds"]
    assert_chaos_invariants(eng)


def test_invocation_timeout_kills_stragglers(data, model):
    kw = base_cfg_kw(strategy="fedavg", invocation_timeout=5.0, rounds=2)
    eng = Scheduler(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m = eng.run()
    assert m["n_timeouts"] > 0
    assert m["n_failures"] >= m["n_timeouts"]
    timed_out = [r for r in eng.platform.invocations if r.timed_out]
    assert timed_out
    for r in timed_out:
        assert r.failed and r.cancelled and r.failed_phase == "timeout"
        assert r.duration <= 5.0 + 1e-9
    assert "timeout" in m["failures_by_phase"]
    assert_chaos_invariants(eng)


def test_quarantine_circuit_breaker_and_reentry(data, model):
    """A client inside a permanent outage trips the breaker, sits out
    ``quarantine_rounds`` rounds, and re-enters the selection mask."""
    bad = 3
    kw = base_cfg_kw(strategy="fedavg", clients_per_round=N_CLIENTS,
                     rounds=8, fault_profile=f"outage:0-1000000:{bad}",
                     quarantine_threshold=2, quarantine_rounds=2)
    eng = Scheduler(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m = eng.run()
    assert m["n_quarantined"] >= 1
    rounds_invoked = sorted({r.round for r in eng.platform.invocations
                             if r.client_id == bad})
    all_rounds = sorted({r.round for r in eng.platform.invocations})
    sat_out = set(all_rounds) - set(rounds_invoked)
    assert sat_out, "breaker never removed the client from selection"
    # re-entry: invoked again in a round after a quarantine gap
    gaps = [(a, b) for a, b in zip(rounds_invoked, rounds_invoked[1:])
            if b - a > 1]
    assert gaps, "client never re-entered after quarantine"
    assert_chaos_invariants(eng)


def test_apodotiko_selection_survives_zero_score_pool(data, model):
    """Regression: clients whose every invocation failed have no duration
    history, so Algorithm 3 scores them 0 — the probabilistic draw must
    cap at the nonzero-probability count instead of raising
    ``Fewer non-zero entries in p than size`` (both control planes)."""
    for plane in ("columnar", "object"):
        kw = base_cfg_kw(strategy="apodotiko", rounds=4,
                         control_plane=plane, fault_profile="crash-heavy",
                         invocation_timeout=300.0, retry_budget=8,
                         quarantine_threshold=3)
        eng = Scheduler(FLConfig(**kw), model, data,
                        list(paper_fleet(N_CLIENTS)))
        m = eng.run()
        assert m["n_failures"] > 0
        assert_chaos_invariants(eng)


def test_quorum_closes_partial_cohort_earlier(data, model):
    kw = base_cfg_kw(strategy="fedavg", clients_per_round=8, rounds=2)
    full = Scheduler(FLConfig(**kw), model, data,
                     list(paper_fleet(N_CLIENTS)))
    m_full = full.run()
    part = Scheduler(FLConfig(**kw, quorum_fraction=0.5), model, data,
                     list(paper_fleet(N_CLIENTS)))
    m_part = part.run()
    assert m_part["total_time"] < m_full["total_time"]
    # every quorum round closed with a partial cohort (at least half of
    # that round's selection, never the full 8 the full gate waits for)
    assert part.history and all(l.n_aggregated >= 1 for l in part.history)
    assert all(l.n_aggregated < 8 for l in part.history)
    assert all(l.n_aggregated == 8 for l in full.history)
    assert_chaos_invariants(part)


# --------------------------------------------------------------- megastep
def test_recovery_knobs_refuse_megastep(data, model):
    for kw, reason in (
            (dict(invocation_timeout=500.0), "retry/timeout recovery enabled"),
            (dict(retry_budget=2), "retry/timeout recovery enabled"),
            (dict(quorum_fraction=0.5), "partial-cohort quorum enabled"),
            (dict(fault_profile="crash:train:0.3"),
             "stochastic fault schedule active")):
        cfg = FLConfig(**megastep_cfg(rounds=2, megastep="fused", **kw))
        eng = Scheduler(cfg, model, data, det_fleet(N_CLIENTS))
        m = eng.run()
        assert m["megastep_rounds"] == 0, kw
        assert m["megastep_fallback_reason"] == reason, kw


def test_megastep_refuses_overlapping_outage_window(data, model):
    """A fleet-wide outage window opening right at the fused horizon:
    megastep must refuse with an attributable reason, and the fused run
    must stay bit-identical to the stepwise oracle."""
    kw = megastep_cfg(rounds=3, clients_per_round=N_CLIENTS)
    cal = Scheduler(FLConfig(**kw, megastep="stepwise"), model, data,
                    det_fleet(N_CLIENTS))
    cal.run()
    t1 = cal.history[1].t_start          # round-1 launch instant
    faulted = dict(kw, fault_profile=f"outage:{t1 - 0.5}-1000000:mod1=0")
    m_step, m_fused = assert_fused_matches_stepwise(
        faulted, model, data, fleet=det_fleet(N_CLIENTS))
    assert m_fused["megastep_rounds"] == 0
    assert m_fused["megastep_fallback_reason"] == \
        "fault window overlaps horizon"


def test_megastep_reengages_after_outage_window(data, model):
    """A brief outage over round 3's launches: fusion stops short of the
    window, the faulted rounds run stepwise, and fusion re-engages once
    every instance is warm again — all bit-identical to stepwise."""
    kw = megastep_cfg(rounds=8, clients_per_round=N_CLIENTS)
    cal = Scheduler(FLConfig(**kw, megastep="stepwise"), model, data,
                    det_fleet(N_CLIENTS))
    cal.run()
    t3 = cal.history[3].t_start
    faulted = dict(kw,
                   fault_profile=f"outage:{t3 - 0.25}-{t3 + 0.25}:mod2=1")
    m_step, m_fused = assert_fused_matches_stepwise(
        faulted, model, data, fleet=det_fleet(N_CLIENTS),
        min_fused_rounds=1)
    assert m_fused["megastep_scans"] >= 2       # re-engaged after the window
    assert 0 < m_fused["megastep_rounds"] < kw["rounds"] - 1
    assert m_fused["n_failures"] > 0            # the outage really struck
    assert m_fused["failures_by_phase"] == {"outage": m_fused["n_failures"]}


def test_megastep_engages_with_future_window(data, model):
    """A window entirely beyond the run's horizon must not refuse."""
    kw = megastep_cfg(rounds=4, clients_per_round=N_CLIENTS,
                      fault_profile="outage:1e7-2e7:mod1=0")
    m_step, m_fused = assert_fused_matches_stepwise(
        kw, model, data, fleet=det_fleet(N_CLIENTS), min_fused_rounds=1)
    assert m_fused["megastep_scans"] >= 1


# ------------------------------------------------------------ strategies
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_invocation_failed_all_strategies_both_engines(strategy, data, model):
    """Satellite: the InvocationFailed path stays bit-identical across
    engines for every legacy strategy."""
    cfg = FLConfig(**base_cfg_kw(strategy=strategy, failure_rate=0.3,
                                 rounds=2))
    assert_engines_equivalent(cfg, model, data, paper_fleet(N_CLIENTS))
