"""Durability subsystem tests (DESIGN.md §14): crash-at-any-boundary
resume bit-identity across engines × control planes × update planes,
SIGKILL subprocess fuzzing, torn-file recovery, and the off-path
golden-trace guarantee.

The heavy lifting lives in tests/chaos_harness.py (``run_crash_sweep``
and friends); this file picks the configurations and the crash points —
including the mid-traffic-window and mid-quarantine boundaries the
tentpole calls out.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from chaos_harness import (N_CLIENTS, assert_chaos_invariants,  # noqa: F401
                           assert_resume_identical, base_cfg_kw, chaos_trace,
                           crash_resume_trace, data, durable_cfg,
                           golden_durable_run, model, run_crash_sweep,
                           spot_ks)
from trace_harness import assert_params_equal

from repro.core.journal import Journal, encode_line
from repro.core.scheduler import build_engine
from repro.core.services import (FLConfig, resolve_durability,
                                 resolve_durability_sync)
from repro.durability import (JournalDivergence, SimulatedCrash,
                              find_latest_snapshot, list_snapshots,
                              resume_durable)
from repro.faas.hardware import paper_fleet

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ off path
def test_off_path_draws_nothing_and_matches(tmp_path, data, model):
    """durability=off is the default, constructs nothing, and the
    journal-armed run produces the exact same observable trace."""
    kw = base_cfg_kw(strategy="apodotiko")
    off = build_engine(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m_off = off.run()
    assert off.durability is None
    assert m_off["durability"] == "off"

    on, m_on, _ = golden_durable_run(kw, model, data, tmp_path / "on")
    assert chaos_trace(on) == chaos_trace(off)
    assert m_on["history"] == m_off["history"]
    assert m_on["total_time"] == m_off["total_time"]
    assert_params_equal(on.params, off.params)


def test_resolvers():
    assert resolve_durability("off") == "off"
    assert resolve_durability("journal") == "journal"
    with pytest.raises(ValueError):
        resolve_durability("bogus")
    assert resolve_durability_sync("auto") in ("event", "round")
    with pytest.raises(ValueError):
        resolve_durability_sync("bogus")


def test_journal_requires_checkpoint_dir(data, model):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        build_engine(FLConfig(durability="journal",
                              **base_cfg_kw(strategy="fedavg")),
                     model, data, list(paper_fleet(N_CLIENTS)))


# ----------------------------------------- crash-at-every-boundary sweeps
def test_every_boundary_scheduler_columnar(tmp_path, data, model):
    n = run_crash_sweep(base_cfg_kw(strategy="apodotiko"), model, data,
                        tmp_path)
    assert n >= 10


def test_every_boundary_legacy_object(tmp_path, data, model):
    n = run_crash_sweep(
        base_cfg_kw(strategy="apodotiko", engine="legacy",
                    control_plane="object"),
        model, data, tmp_path)
    assert n >= 10


def _spot_sweep(kw, tmp_path, data, model):
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    n = gold[1]["journal_records"]
    for k in spot_ks(n):
        res = crash_resume_trace(kw, model, data, tmp_path / f"c{k}", k)
        assert_resume_identical(*gold, *res)


def test_spot_legacy_columnar_eval_gap(tmp_path, data, model):
    # eval_every=2 exercises the accuracy-carryover (_acc) restore
    _spot_sweep(base_cfg_kw(strategy="fedavg", engine="legacy", eval_every=2),
                tmp_path, data, model)


def test_spot_blob_update_plane(tmp_path, data, model):
    _spot_sweep(base_cfg_kw(strategy="fedavg", update_plane="blob"),
                tmp_path, data, model)


def test_spot_hedge_policy(tmp_path, data, model):
    _spot_sweep(base_cfg_kw(strategy="apodotiko-hedge"), tmp_path, data, model)


def test_spot_adaptive_policy(tmp_path, data, model):
    _spot_sweep(base_cfg_kw(strategy="apodotiko-adaptive"),
                tmp_path, data, model)


def test_spot_scaffold(tmp_path, data, model):
    _spot_sweep(base_cfg_kw(strategy="scaffold"), tmp_path, data, model)


def _targeted_ks(root, kinds, pad=1):
    """Crash boundaries at (and right after) records of the given kinds —
    the mid-window boundaries the tentpole calls out explicitly."""
    records, _ = Journal.read(os.path.join(str(root), "journal.wal"))
    ks = set()
    for r in records:
        if r["k"] in kinds:
            for d in range(pad + 1):
                ks.add(r["q"] + 1 + d)      # crash_after is 1-based
    return sorted(k for k in ks if 1 <= k <= len(records))


def test_mid_quarantine_crash_points(tmp_path, data, model):
    """Crash while retry timers are armed and quarantines are open: the
    recovery layer's RNG, attempt counts, budget, and timer heap must
    all survive the resume."""
    kw = base_cfg_kw(strategy="apodotiko", fault_profile="crash-heavy",
                     invocation_timeout=40.0, retry_budget=2,
                     quarantine_threshold=2, quarantine_rounds=2)
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    assert gold[1]["n_timeouts"] + gold[1]["n_failures"] > 0, \
        "fault schedule produced no failures — test is vacuous"
    ks = _targeted_ks(tmp_path / "golden",
                      ("InvocationFailed", "InvocationTimedOut"))
    assert ks, "no failure events to crash at"
    for k in ks:
        res = crash_resume_trace(kw, model, data, tmp_path / f"c{k}", k)
        assert_resume_identical(*gold, *res)


def test_mid_traffic_window_crash_points(tmp_path, data, model):
    """Crash right at membership-shift boundaries: the traffic cursor
    and the bulk join/leave effects must replay identically."""
    kw = base_cfg_kw(strategy="apodotiko", traffic_profile="steady-churn",
                     rounds=3)
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    ks = _targeted_ks(tmp_path / "golden", ("ClientsJoined", "ClientsLeft"))
    if not ks:          # schedule produced no mid-run churn at this scale
        ks = spot_ks(gold[1]["journal_records"])
    for k in ks:
        res = crash_resume_trace(kw, model, data, tmp_path / f"c{k}", k)
        assert_resume_identical(*gold, *res)


# ------------------------------------------------------ SIGKILL fuzzing
def test_sigkill_subprocess_resume(tmp_path, data, model):
    """A real SIGKILL mid-run (no atexit, no flush beyond os.write), then
    an in-process resume: trace and journal must match the uncrashed
    golden run byte for byte."""
    child = os.path.join(REPO, "scripts", "durable_crash_child.py")
    sys.path.insert(0, os.path.dirname(child))
    try:
        from durable_crash_child import child_config
    finally:
        sys.path.pop(0)

    gold_dir = tmp_path / "golden"
    gold_eng = build_engine(child_config(str(gold_dir)), model, data,
                            list(paper_fleet(10)))
    gold_m = gold_eng.run()
    with open(gold_dir / "journal.wal", "rb") as f:
        gold_bytes = f.read()

    for k in (3, 6):
        d = tmp_path / f"kill_{k}"
        env = dict(os.environ,
                   REPRO_CRASH_AFTER_EVENTS=str(k),
                   REPRO_CRASH_MODE="sigkill")
        env.pop("REPRO_DURABILITY", None)
        proc = subprocess.run([sys.executable, child, str(d)], env=env,
                              capture_output=True, timeout=600)
        assert proc.returncode == -9, (proc.returncode, proc.stderr[-800:])
        records, _ = Journal.read(str(d / "journal.wal"))
        assert len(records) == k, "os.write must persist every record"

        resumed = resume_durable(child_config(str(d)), model, data,
                                 list(paper_fleet(10)))
        m = resumed.run()
        with open(d / "journal.wal", "rb") as f:
            jbytes = f.read()
        assert m["history"] == gold_m["history"]
        assert m["total_time"] == gold_m["total_time"]
        assert jbytes == gold_bytes
        assert_params_equal(resumed.params, gold_eng.params)
        assert_chaos_invariants(resumed)


# --------------------------------------------------- torn-file recovery
def _crashed_run(tmp_path, kw, k, data, model):
    d = tmp_path / "crashed"
    eng = build_engine(durable_cfg(d, **kw), model, data,
                       list(paper_fleet(N_CLIENTS)))
    eng.durability.crash_after = k
    with pytest.raises(SimulatedCrash):
        eng.run()
    return d


def test_torn_journal_tail_truncated_to_prefix(tmp_path, data, model):
    kw = base_cfg_kw(strategy="apodotiko")
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    d = _crashed_run(tmp_path, kw, 8, data, model)
    jpath = d / "journal.wal"
    size = os.path.getsize(jpath)
    with open(jpath, "r+b") as f:        # tear the last record mid-line
        f.truncate(size - 3)
    records, good = Journal.read(str(jpath))
    assert len(records) == 7 and good < size - 3

    resumed = resume_durable(durable_cfg(d, **kw), model, data,
                             list(paper_fleet(N_CLIENTS)))
    m = resumed.run()
    with open(jpath, "rb") as f:
        jbytes = f.read()
    assert_resume_identical(*gold, resumed, m, jbytes)


def test_garbage_journal_tail_truncated(tmp_path, data, model):
    kw = base_cfg_kw(strategy="apodotiko")
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    d = _crashed_run(tmp_path, kw, 6, data, model)
    with open(d / "journal.wal", "ab") as f:
        f.write(b'{"q": 6, "half a record and no frame')
    resumed = resume_durable(durable_cfg(d, **kw), model, data,
                             list(paper_fleet(N_CLIENTS)))
    m = resumed.run()
    with open(d / "journal.wal", "rb") as f:
        jbytes = f.read()
    assert_resume_identical(*gold, resumed, m, jbytes)


def test_corrupt_snapshot_falls_back(tmp_path, data, model):
    """A snapshot with a torn npz fails its manifest CRC and is skipped
    in favor of an older one (or genesis) — resume stays bit-identical,
    just replaying more of the journal."""
    # rounds=3 so two snapshots survive GC when the crash lands on the
    # final round-close record (its own snapshot is never written: the
    # journal record precedes the snapshot, and the crash fires between)
    kw = base_cfg_kw(strategy="apodotiko", rounds=3)
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    k = gold[1]["journal_records"] - 1
    d = _crashed_run(tmp_path, kw, k, data, model)
    seqs = list_snapshots(str(d))
    assert len(seqs) >= 2
    newest = os.path.join(str(d), f"snap_{seqs[-1]:010d}")
    target = os.path.join(newest, "db", "blobs.npz")
    with open(target, "r+b") as f:       # partial npz: truncate mid-file
        f.truncate(max(os.path.getsize(target) // 2, 1))
    assert find_latest_snapshot(str(d)).seq == seqs[-2]

    resumed = resume_durable(durable_cfg(d, **kw), model, data,
                             list(paper_fleet(N_CLIENTS)))
    m = resumed.run()
    with open(d / "journal.wal", "rb") as f:
        jbytes = f.read()
    assert_resume_identical(*gold, resumed, m, jbytes)
    assert m["journal_replayed"] > 0


def test_manifestless_snapshot_ignored(tmp_path, data, model):
    kw = base_cfg_kw(strategy="apodotiko")
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    k = gold[1]["journal_records"] - 1
    d = _crashed_run(tmp_path, kw, k, data, model)
    seqs = list_snapshots(str(d))
    newest = os.path.join(str(d), f"snap_{seqs[-1]:010d}")
    os.remove(os.path.join(newest, "MANIFEST.json"))
    resumed = resume_durable(durable_cfg(d, **kw), model, data,
                             list(paper_fleet(N_CLIENTS)))
    m = resumed.run()
    with open(d / "journal.wal", "rb") as f:
        jbytes = f.read()
    assert_resume_identical(*gold, resumed, m, jbytes)


def test_resume_with_no_snapshot_replays_from_genesis(tmp_path, data, model):
    kw = base_cfg_kw(strategy="apodotiko")
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    d = _crashed_run(tmp_path, kw, 3, data, model)   # before first round close
    assert list_snapshots(str(d)) == []
    resumed = resume_durable(durable_cfg(d, **kw), model, data,
                             list(paper_fleet(N_CLIENTS)))
    m = resumed.run()
    with open(d / "journal.wal", "rb") as f:
        jbytes = f.read()
    assert_resume_identical(*gold, resumed, m, jbytes)
    assert m["journal_replayed"] == 3


# ------------------------------------------------------ guard behaviour
def test_config_mismatch_refused(tmp_path, data, model):
    kw = base_cfg_kw(strategy="apodotiko")
    d = _crashed_run(tmp_path, kw, 5, data, model)
    other = dict(kw, seed=1)
    with pytest.raises(ValueError, match="different experiment config"):
        resume_durable(durable_cfg(d, **other), model, data,
                       list(paper_fleet(N_CLIENTS)))


def test_divergence_detected(tmp_path, data, model):
    """A journal record the replay cannot reproduce (tampered payload,
    valid CRC) aborts the resume instead of silently forking."""
    kw = base_cfg_kw(strategy="apodotiko")
    d = _crashed_run(tmp_path, kw, 7, data, model)   # past first snapshot
    jpath = str(d / "journal.wal")
    records, _ = Journal.read(jpath)
    assert list_snapshots(str(d)), "need a snapshot so the tail validates"
    records[-1]["t"] += 1.0                           # plausible but wrong
    with open(jpath, "wb") as f:
        for r in records:
            f.write(encode_line(r))
    with pytest.raises(JournalDivergence):
        resume_durable(durable_cfg(d, **kw), model, data,
                       list(paper_fleet(N_CLIENTS))).run()


# ------------------------------------------------- sync/snapshot knobs
def test_sync_policies_same_bytes_different_fsyncs(tmp_path, data, model):
    kw = base_cfg_kw(strategy="fedavg")
    _, m_round, b_round = golden_durable_run(
        dict(kw, durability_sync="round"), model, data, tmp_path / "r")
    _, m_event, b_event = golden_durable_run(
        dict(kw, durability_sync="event"), model, data, tmp_path / "e")
    assert b_round == b_event, "sync policy must not change journal content"
    assert m_event["journal_fsyncs"] >= m_event["journal_records"]
    assert m_round["journal_fsyncs"] < m_round["journal_records"]


def test_snap_every_sparse_snapshots(tmp_path, data, model):
    kw = base_cfg_kw(strategy="apodotiko", rounds=4, durability_snap_every=2)
    gold = golden_durable_run(kw, model, data, tmp_path / "golden")
    assert gold[1]["n_snapshots"] == 2
    n = gold[1]["journal_records"]
    for k in (n // 2, n - 1):
        res = crash_resume_trace(kw, model, data, tmp_path / f"c{k}", k)
        assert_resume_identical(*gold, *res)


def test_megastep_gated_off_under_durability(tmp_path, data, model):
    """Fused rounds emit no events, so the journal gates fusion off; the
    run still matches the fused durability-off trace (megastep contract:
    fused == stepwise bit-identical)."""
    from trace_harness import megastep_cfg
    kw = megastep_cfg()
    off = build_engine(FLConfig(**kw), model, data, list(paper_fleet(N_CLIENTS)))
    m_off = off.run()
    on, m_on, _ = golden_durable_run(kw, model, data, tmp_path / "on")
    assert m_on["megastep_rounds"] == 0
    assert m_on["megastep_fallback_reason"] == "durability journal active"
    assert m_on["history"] == m_off["history"]
    assert m_on["total_time"] == m_off["total_time"]
    assert_params_equal(on.params, off.params)


def test_metrics_expose_journal_counters(tmp_path, data, model):
    _, m, _ = golden_durable_run(base_cfg_kw(strategy="fedavg"), model, data,
                                 tmp_path)
    assert m["durability"] == "journal"
    assert m["journal_records"] > 0
    assert m["journal_bytes"] > 0
    assert m["n_snapshots"] >= 1
    assert m["journal_replayed"] == 0


# ------------------------------------------------------- journal format
def test_journal_record_framing(tmp_path, data, model):
    _, m, jbytes = golden_durable_run(base_cfg_kw(strategy="fedavg"),
                                      model, data, tmp_path)
    lines = jbytes.decode().strip().split("\n")
    assert len(lines) == m["journal_records"]
    for i, line in enumerate(lines):
        body, _, crc = line.rpartition("|")
        rec = json.loads(body)
        assert rec["q"] == i
        assert set(rec) == {"q", "k", "t", "r", "p", "g"}
    assert json.loads(lines[0].rpartition("|")[0])["k"] == "genesis"
    assert json.loads(lines[-1].rpartition("|")[0])["k"] == "run_end"
