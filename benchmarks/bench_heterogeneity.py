"""Paper Fig 1 + Fig 3: the motivating experiment — FedAvg vs FedLesScan vs
Apodotiko across hardware-distribution scenarios (homogeneous / two-tier /
heterogeneous CPU+GPU), plus per-hardware client training durations."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    best_accuracy,
    fleet_for,
    run_experiment,
    time_to_accuracy,
)

SCENARIOS = ("homogeneous", "two-tier", "heterogeneous")


def run() -> list[dict]:
    rows = []
    for scenario in SCENARIOS:
        runs = {s: run_experiment(dataset="shakespeare", strategy=s,
                                  scenario=scenario)
                for s in ("fedavg", "fedlesscan", "apodotiko")}
        target = 0.95 * min(best_accuracy(m) for m in runs.values())
        base = time_to_accuracy(runs["fedavg"], target)
        for s, m in runs.items():
            t = time_to_accuracy(m, target)
            rows.append({"scenario": scenario, "strategy": s,
                         "time_to_target_s": None if t is None else round(t, 1),
                         "speedup_vs_fedavg": (round(base / t, 2)
                                               if t and base else None)})
    return rows


def fig3_durations() -> dict:
    """Client training duration spread per hardware class (sim model)."""
    from repro.faas.hardware import HARDWARE_PROFILES
    from repro.faas.platform import FaaSPlatform
    p = FaaSPlatform(seed=0)
    out = {}
    for name, hw in HARDWARE_PROFILES.items():
        durs = [p.invoke(i, 0, 0.0, train_steps=60, hw=hw,
                         base_step_time=6.0).duration for i in range(30)]
        out[name] = {"p50": round(float(np.median(durs)), 1),
                     "p95": round(float(np.percentile(durs, 95)), 1)}
    return out


def main(emit) -> None:
    for r in run():
        t = r["time_to_target_s"]
        emit(f"fig1/{r['scenario']}/{r['strategy']}",
             0.0 if t is None else t * 1e6,
             f"speedup_vs_fedavg={r['speedup_vs_fedavg']}")
    for hw, d in fig3_durations().items():
        emit(f"fig3/{hw}", d["p50"] * 1e6, f"p95={d['p95']}")
