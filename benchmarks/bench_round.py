"""Round hot-path benchmark: blob transport vs. the device-resident update
plane (DESIGN.md §2, "update plane").

Measures the aggregation+transfer component of one controller round — the
path between cohort training finishing and the new global model existing —
at K ∈ {10, 100} clients x N ∈ {1e4, 1e6} parameters:

  * **blob** (legacy, ``REPRO_UPDATE_PLANE=blob``): copy the [K, ...] cohort
    output to host, slice K per-client pytrees, store them as blobs, then
    re-upload every blob and run ``weighted_aggregate`` (ravel + stack +
    kernel + unravel).
  * **plane** (default): flatten to [K, N] rows inside jit, scatter into the
    persistent ``UpdateStore`` buffer, then ``weighted_aggregate_rows``
    (index gather -> kernel -> one unravel). Zero host round-trips.

Emits ``BENCH_round.json`` next to the repo root and ``name,us,derived``
CSV lines like every other bench. ``--smoke`` runs only the smallest cell
with few iterations (the CI invocation); ``--json PATH`` overrides the
output location.
"""
from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows
from repro.core.update_store import UpdateStore
from repro.kernels.ops import RavelSpec

ITEMSIZE = 4  # fp32


def _cohort_output(K: int, N: int, seed: int = 0):
    """Stand-in for CohortTrainer's stacked device output: [K, ...] leaves.
    Two ragged leaves so the ravel/unravel work is exercised honestly."""
    rng = np.random.default_rng(seed)
    n_b = min(257, N // 2)
    tree = {"w": jnp.asarray(rng.normal(size=(K, N - n_b)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(K, n_b)), jnp.float32)}
    jax.block_until_ready(tree)
    return tree


def _blob_round(stacked, weights, template) -> tuple[object, int]:
    """The legacy path _invoke_round + _aggregate perform per round."""
    host = jax.tree.map(np.asarray, stacked)                 # device -> host
    down = sum(l.nbytes for l in jax.tree.leaves(host))
    K = weights.shape[0]
    blobs = [jax.tree.map(lambda x: x[k], host) for k in range(K)]
    ups = [jax.tree.map(jnp.asarray, b) for b in blobs]      # host -> device
    up = sum(l.nbytes for u in ups for l in jax.tree.leaves(u))
    out = weighted_aggregate(ups, weights, out_dtype=jnp.float32)
    jax.block_until_ready(out)
    return out, down + up


def _plane_round(stacked, weights, spec, store) -> tuple[object, int]:
    """The update-plane path: rows stay on device end-to-end. The ravel +
    scatter into the donated buffer happens in one fused jit (the same
    write the controller's cohort fn performs in-program)."""
    ids = store.put_stacked(stacked)
    out = weighted_aggregate_rows(store.buffer, ids, weights, spec,
                                  out_dtype=jnp.float32)
    jax.block_until_ready(out)
    store.free(ids)
    return out, 0


def bench_cell(K: int, N: int, iters: int) -> dict:
    stacked = _cohort_output(K, N)
    template = jax.tree.map(lambda x: x[0], stacked)
    spec = RavelSpec(template)
    weights = (np.ones(K) / K).astype(np.float32)
    store = UpdateStore(spec.n_params, capacity=K)

    def run(fn, *args):
        fn(*args)  # warmup/compile
        times = []
        byts = 0
        for _ in range(iters):
            t0 = time.perf_counter()
            _, byts = fn(*args)
            times.append(time.perf_counter() - t0)
        return float(np.median(times)), byts

    blob_s, blob_bytes = run(_blob_round, stacked, weights, template)
    plane_s, plane_bytes = run(_plane_round, stacked, weights, spec, store)

    # correctness guard: both transports must agree on the aggregate
    a, _ = _blob_round(stacked, weights, template)
    b, _ = _plane_round(stacked, weights, spec, store)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)

    return {"K": K, "N": N, "blob_s": blob_s, "plane_s": plane_s,
            "speedup": blob_s / plane_s if plane_s > 0 else float("inf"),
            "blob_host_bytes": int(blob_bytes),
            "plane_host_bytes": int(plane_bytes)}


def run(smoke: bool = False, json_path: str = "") -> list[dict]:
    cells = ([(10, 10_000)] if smoke
             else [(10, 10_000), (100, 10_000),
                   (10, 1_000_000), (100, 1_000_000)])
    iters = 3 if smoke else 5
    results = []
    for K, N in cells:
        cell = bench_cell(K, N, iters)
        results.append(cell)
        print(f"round/K{K}_N{N}/blob,{cell['blob_s'] * 1e6:.0f},"
              f"bytes={cell['blob_host_bytes']}")
        print(f"round/K{K}_N{N}/plane,{cell['plane_s'] * 1e6:.0f},"
              f"bytes={cell['plane_host_bytes']} "
              f"speedup={cell['speedup']:.2f}x")
    out = {"bench": "round_update_plane", "smoke": smoke,
           "backend": jax.default_backend(), "cells": results}
    path = json_path or os.path.join(_ROOT, "BENCH_round.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    jp = ""
    if "--json" in sys.argv:
        jp = sys.argv[sys.argv.index("--json") + 1]
    run(smoke=smoke, json_path=jp)
