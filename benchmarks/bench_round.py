"""Round hot-path benchmark: blob transport vs. the device-resident update
plane (DESIGN.md §2, "update plane"), plus the scheduler-dispatch
microbenchmark (``--scheduler``, DESIGN.md §7): event-loop throughput
under hedge-style cancellation churn, end-to-end protocol dispatch rate
(events/sec), and the overhead of the hedging policy vs plain apodotiko.
The scheduler numbers land in ``BENCH_scheduler.json``.

``--controlplane`` measures the *control* plane (DESIGN.md §10): the
score+select dispatch of Algorithm 3 — candidate partition, CEF scoring,
probabilistic draw, booster bookkeeping — on the object plane (per-client
``ClientRecord`` Python loop, the oracle) vs the columnar ``FleetStore``
(vectorized f64 window scoring, bit-identical selections) vs the
device-resident masked top-k selector (``FleetStore.select_topk``), at
fleet sizes M ∈ {1e3, 1e4, 1e5, 1e6}. The object plane is skipped at
M=1e6 (that is the point: a million ClientRecord objects is the wall the
columnar plane removes). Lands in ``BENCH_controlplane.json``; exits
nonzero if object and columnar selections diverge on the shared RNG
stream (the CI equivalence gate).

``--megastep`` measures the fused round megastep (DESIGN.md §11): a
provably quiescent run driven by the stepwise event engine (one Python
pump + several jit dispatches per round) vs ``REPRO_MEGASTEP=fused``
(the whole run of rounds lowered into one jitted ``lax.scan``). Reports
wall time per round for both modes, protocol events dispatched per
round (0 for fused steady state — the headline), and the Python-overhead
share the fusion removes. Lands in ``BENCH_megastep.json``; exits
nonzero if the fused run diverges bitwise from the stepwise oracle or
dispatches any Python event during quiescent rounds (the CI gate).

``--dataplane`` measures the *input* half of the transport story
(DESIGN.md §2, "data plane"): per-cohort-dispatch latency and H2D
training-input bytes with the dataset resident on device
(``REPRO_DATA_PLANE=device``, index-vector dispatch + on-jit gather) vs
the legacy host fancy-index + per-dispatch upload, plus end-to-end FL
runs on both planes (events/s re-measure, ``data_host_bytes``
accounting). Lands in ``BENCH_dataplane.json``; exits nonzero if the
device plane moved any training-input bytes (the CI gate).

``--faults`` measures the fault-injection subsystem (DESIGN.md §12): the
same seeded run clean, under the crash-heavy chaos profile, and under
that profile with the retry/timeout/quarantine recovery layer armed —
failure/retry counts, simulated-time impact, recovery wall overhead.
Lands in ``BENCH_faults.json``; exits nonzero if a seeded fault schedule
replays differently on the two engines (the cross-engine chaos gate).

``--durability`` measures the durability subsystem (DESIGN.md §14):
journal overhead per round at each sync policy (off / round / event
fsync), coordinated-snapshot write and resume latency as the fleet
grows, and the resume-identity gate — a run crashed mid-journal must
resume bit-identically (history, simulated clock, journal bytes).
Lands in ``BENCH_durability.json``; exits nonzero if round-sync
journaling costs more than 5% wall overhead or the resumed trace
diverges.

``--traffic`` measures the open-loop traffic plane (DESIGN.md §13):
arrival-schedule compile throughput at M ∈ {1e5, 1e6}, bulk (windowed
``add_batch``/``remove_batch`` segments) vs per-event Python application
of a 1e4-client flash crowd over an M=1e5 ``FleetStore``, and the
per-strategy SLO table — p50/p99 round latency, cold-start rate,
cost-per-round — under the diurnal profile. Lands in
``BENCH_traffic.json``; exits nonzero if the bulk path diverges from the
per-event oracle (the CI gate).

``--sharding`` measures the mesh plane (DESIGN.md §15): a weak-scaling
curve over the ("data", "model") device mesh — each cell is a subprocess
with its own forced host-device count (XLA fixes the count at startup),
cohort size growing with the data axis, wall rounds/s plus the
structural metrics (bottleneck-device update-store bytes, equal-tile
split). Lands in ``BENCH_sharding.json``; exits nonzero if mesh='1x1'
diverges bitwise from the default path, the buffer does not split into
equal per-device tiles, or (on hosts with >= 8 cores) weak-scaled
throughput at 8 devices is below 1.5x the 1x1 oracle.

Measures the aggregation+transfer component of one controller round — the
path between cohort training finishing and the new global model existing —
at K ∈ {10, 100} clients x N ∈ {1e4, 1e6} parameters:

  * **blob** (legacy, ``REPRO_UPDATE_PLANE=blob``): copy the [K, ...] cohort
    output to host, slice K per-client pytrees, store them as blobs, then
    re-upload every blob and run ``weighted_aggregate`` (ravel + stack +
    kernel + unravel).
  * **plane** (default): flatten to [K, N] rows inside jit, scatter into the
    persistent ``UpdateStore`` buffer, then ``weighted_aggregate_rows``
    (index gather -> kernel -> one unravel). Zero host round-trips.

Emits ``BENCH_round.json`` next to the repo root and ``name,us,derived``
CSV lines like every other bench. ``--smoke`` runs only the smallest cell
with few iterations (the CI invocation); ``--json PATH`` overrides the
output location.
"""
from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows
from repro.core.update_store import UpdateStore
from repro.kernels.ops import RavelSpec

ITEMSIZE = 4  # fp32


def _cohort_output(K: int, N: int, seed: int = 0):
    """Stand-in for CohortTrainer's stacked device output: [K, ...] leaves.
    Two ragged leaves so the ravel/unravel work is exercised honestly."""
    rng = np.random.default_rng(seed)
    n_b = min(257, N // 2)
    tree = {"w": jnp.asarray(rng.normal(size=(K, N - n_b)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(K, n_b)), jnp.float32)}
    jax.block_until_ready(tree)
    return tree


def _blob_round(stacked, weights, template) -> tuple[object, int]:
    """The legacy path invoke_round + aggregate_round perform per round."""
    host = jax.tree.map(np.asarray, stacked)                 # device -> host
    down = sum(l.nbytes for l in jax.tree.leaves(host))
    K = weights.shape[0]
    blobs = [jax.tree.map(lambda x: x[k], host) for k in range(K)]
    ups = [jax.tree.map(jnp.asarray, b) for b in blobs]      # host -> device
    up = sum(l.nbytes for u in ups for l in jax.tree.leaves(u))
    out = weighted_aggregate(ups, weights, out_dtype=jnp.float32)
    jax.block_until_ready(out)
    return out, down + up


def _plane_round(stacked, weights, spec, store) -> tuple[object, int]:
    """The update-plane path: rows stay on device end-to-end. The ravel +
    scatter into the donated buffer happens in one fused jit (the same
    write the controller's cohort fn performs in-program)."""
    ids = store.put_stacked(stacked)
    out = weighted_aggregate_rows(store.buffer, ids, weights, spec,
                                  out_dtype=jnp.float32)
    jax.block_until_ready(out)
    store.free(ids)
    return out, 0


def bench_cell(K: int, N: int, iters: int) -> dict:
    stacked = _cohort_output(K, N)
    template = jax.tree.map(lambda x: x[0], stacked)
    spec = RavelSpec(template)
    weights = (np.ones(K) / K).astype(np.float32)
    store = UpdateStore(spec.n_params, capacity=K)

    def run(fn, *args):
        fn(*args)  # warmup/compile
        times = []
        byts = 0
        for _ in range(iters):
            t0 = time.perf_counter()
            _, byts = fn(*args)
            times.append(time.perf_counter() - t0)
        return float(np.median(times)), byts

    blob_s, blob_bytes = run(_blob_round, stacked, weights, template)
    plane_s, plane_bytes = run(_plane_round, stacked, weights, spec, store)

    # correctness guard: both transports must agree on the aggregate
    a, _ = _blob_round(stacked, weights, template)
    b, _ = _plane_round(stacked, weights, spec, store)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)

    return {"K": K, "N": N, "blob_s": blob_s, "plane_s": plane_s,
            "speedup": blob_s / plane_s if plane_s > 0 else float("inf"),
            "blob_host_bytes": int(blob_bytes),
            "plane_host_bytes": int(plane_bytes)}


def run(smoke: bool = False, json_path: str = "") -> list[dict]:
    cells = ([(10, 10_000)] if smoke
             else [(10, 10_000), (100, 10_000),
                   (10, 1_000_000), (100, 1_000_000)])
    iters = 3 if smoke else 5
    results = []
    for K, N in cells:
        cell = bench_cell(K, N, iters)
        results.append(cell)
        print(f"round/K{K}_N{N}/blob,{cell['blob_s'] * 1e6:.0f},"
              f"bytes={cell['blob_host_bytes']}")
        print(f"round/K{K}_N{N}/plane,{cell['plane_s'] * 1e6:.0f},"
              f"bytes={cell['plane_host_bytes']} "
              f"speedup={cell['speedup']:.2f}x")
    out = {"bench": "round_update_plane", "smoke": smoke,
           "backend": jax.default_backend(), "cells": results}
    path = json_path or os.path.join(_ROOT, "BENCH_round.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    return results


# ---------------------------------------------------- scheduler dispatch


def _bench_eventloop(n_events: int) -> dict:
    """Raw EventLoop throughput: plain schedule/pop, and a hedge-style
    churn where 60% of scheduled events are cancelled mid-flight (the
    tombstone-compaction path — the heap must stay bounded by the live
    count, not the cancellation history)."""
    from repro.faas.events import EventLoop

    loop = EventLoop()
    t0 = time.perf_counter()
    for i in range(n_events):
        loop.schedule(float(i % 97), lambda: None)
    loop.run_all()
    plain_s = time.perf_counter() - t0

    loop = EventLoop()
    t0 = time.perf_counter()
    evs = []
    peak_heap = 0
    for i in range(n_events):
        evs.append(loop.schedule(float(i % 97) + 1.0, lambda: None))
        if i % 5 == 4:                      # cancel 3 of every 5, distinct
            for j in (i, i - 1, i - 2):
                loop.cancel(evs[j])
        if i % 1024 == 0:
            peak_heap = max(peak_heap, len(loop._heap))
    peak_heap = max(peak_heap, len(loop._heap))
    loop.run_all()
    churn_s = time.perf_counter() - t0

    return {"n_events": n_events,
            "plain_events_per_s": round(n_events / plain_s),
            "cancel_churn_events_per_s": round(n_events / churn_s),
            "churn_peak_heap": peak_heap}


def _bench_protocol_overhead(sched, n: int) -> float:
    """Pure protocol cost: µs per dispatched no-op event (adapter ignores
    ClientJoined) — event construction + policy dispatch + view plumbing,
    no training, no platform work."""
    from repro.core.protocol import ClientJoined

    t0 = time.perf_counter()
    for i in range(n):
        sched._dispatch(ClientJoined(t=sched.loop.now, client_id=-1))
    return 1e6 * (time.perf_counter() - t0) / n


def _bench_dispatch(model, data, strategy: str, rounds: int,
                    **cfg_overrides) -> dict:
    """End-to-end reactive run on a tiny straggler-heavy FL setup (shared
    pre-warmed model, so compile time stays out of the comparison):
    events dispatched per wall-second including the real JAX training the
    events trigger, plus the pure protocol overhead per event."""
    from repro.core.scheduler import Scheduler
    from repro.core.services import FLConfig
    from repro.faas.hardware import HARDWARE_PROFILES

    n = len(data.n)
    fleet = [HARDWARE_PROFILES["cpu1"]] * (n - 2) + \
            [HARDWARE_PROFILES["gpu"]] * 2
    cfg = FLConfig(n_clients=n, clients_per_round=4, rounds=rounds,
                   local_epochs=1, batch_size=5, base_step_time=0.8,
                   concurrency_ratio=0.5, cold_start_s=120.0, keep_warm=30.0,
                   hedge_fraction=1.0, seed=0, strategy=strategy,
                   **cfg_overrides)
    sched = Scheduler(cfg, model, data, fleet)
    t0 = time.perf_counter()
    m = sched.run()
    wall = time.perf_counter() - t0
    overhead_us = _bench_protocol_overhead(sched, 2000)
    return {"strategy": strategy, "rounds": m["rounds"], "wall_s": round(wall, 3),
            "n_events": m["n_events"],
            "events_per_s": round(m["n_events"] / wall, 1),
            "protocol_overhead_us_per_event": round(overhead_us, 2),
            "sim_time_s": round(m["total_time"], 1),
            "n_hedges": m["n_hedges"], "n_hedge_wins": m["n_hedge_wins"],
            "n_invocations": m["n_invocations"],
            "data_plane": m["data_plane"],
            "data_host_bytes": m["data_host_bytes"]}


def run_scheduler(smoke: bool = False, json_path: str = "") -> dict:
    from repro.data.synthetic import make_federated_dataset
    from repro.models.proxy_models import build_bench_model

    n_events = 20_000 if smoke else 200_000
    rounds = 3 if smoke else 8
    ev = _bench_eventloop(n_events)
    data = make_federated_dataset("mnist", n_clients=8, scale=0.06, seed=0)
    model = build_bench_model("mnist")
    _bench_dispatch(model, data, "apodotiko", 1)   # compile warmup, discarded
    plain = _bench_dispatch(model, data, "apodotiko", rounds)
    hedge = _bench_dispatch(model, data, "apodotiko-hedge", rounds)
    overhead = {
        # wall delta of the hedging policy (can be negative at smoke
        # scale — recompile noise swamps the µs-level dispatch cost)...
        "wall_delta_s": round(hedge["wall_s"] - plain["wall_s"], 3),
        "wall_delta_per_hedge_us": (round(1e6 * (hedge["wall_s"]
                                                 - plain["wall_s"])
                                          / hedge["n_hedges"])
                                    if hedge["n_hedges"] else None),
        # ...bought this much simulated time (the point of hedging)
        "sim_speedup": (round(plain["sim_time_s"] / hedge["sim_time_s"], 2)
                        if hedge["sim_time_s"] else None),
    }
    print(f"scheduler/eventloop,{1e6 / ev['plain_events_per_s']:.2f},"
          f"churn={ev['cancel_churn_events_per_s']}ev/s "
          f"peak_heap={ev['churn_peak_heap']}")
    for d in (plain, hedge):
        print(f"scheduler/dispatch/{d['strategy']},"
              f"{d['protocol_overhead_us_per_event']},"
              f"end_to_end={d['events_per_s']}ev/s n_events={d['n_events']}")
    print(f"scheduler/hedge_overhead,"
          f"{overhead['wall_delta_per_hedge_us'] or 0},"
          f"sim_speedup={overhead['sim_speedup']}x "
          f"hedges={hedge['n_hedges']} wins={hedge['n_hedge_wins']}")
    out = {"bench": "scheduler_dispatch", "smoke": smoke,
           "eventloop": ev, "dispatch": [plain, hedge],
           "hedge_overhead": overhead}
    path = json_path or os.path.join(_ROOT, "BENCH_scheduler.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    return out


# ------------------------------------------------------------- data plane


def _synthetic_fed(M: int, n_max: int, seed: int = 0):
    """A FederatedDataset with exact shapes (proxy-model 8x8x1 features)
    so each cell's cohort-input volume is controlled precisely."""
    from repro.data.synthetic import FederatedDataset

    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (M, n_max, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, (M, n_max)).astype(np.int32)
    n = np.full((M,), n_max, np.int64)
    ex = X[0, :64].copy()
    ey = y[0, :64].copy()
    return FederatedDataset(X, y, n, ex, ey, name="bench")


class _BenchMLP:
    """Minimal real model (64 -> 16 -> 10 MLP, classifier loss surface):
    keeps the full-dispatch measurement on the real trainer path without
    XLA-CPU conv cost swamping the transport difference."""

    input_shape = (8, 8, 1)

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        p = {"w1": jax.random.normal(k1, (64, 16)) * 0.1,
             "b1": jnp.zeros((16,)),
             "w2": jax.random.normal(k2, (16, 10)) * 0.1,
             "b2": jnp.zeros((10,))}
        return p, None

    def predict(self, p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss(self, p, batch):
        from repro.models.common import softmax_cross_entropy
        logits = self.predict(p, batch["x"])
        return softmax_cross_entropy(logits, batch["y"]), logits


def _dataplane_cell(K: int, cohort_floats: int, iters: int) -> dict:
    """One cell: K clients whose cohort training input totals
    ~``cohort_floats`` fp32 elements.

    Two measurements per plane, mirroring how the update-plane cells
    isolate the agg+transfer component:

      * **input path** (the headline, ``speedup``): what each plane does
        to get the cohort's training data in front of the jitted cohort
        fn — host: fancy-index ``X[sel]`` + pad-concat to the cohort
        bucket + device upload; device: upload of the ``[Kp] int32``
        index vector (the dataset is already resident). This is the
        component the data plane exists to remove.
      * **full dispatch** (``train_speedup``): the same comparison
        through the real ``CohortTrainer`` end to end, minimal local
        work (steps=1, tiny MLP, batch 2). On CPU the "upload" is a
        memcpy, so this improves modestly; on PCIe-attached accelerators
        the input path is the dispatch tail that the device plane
        deletes."""
    from repro.core.client import CohortTrainer, _bucket
    from repro.core.data_plane import DatasetStore

    feat = 8 * 8
    n_max = max(cohort_floats // (K * feat), 2)
    data = _synthetic_fed(2 * K, n_max)
    store = DatasetStore(data)
    model = _BenchMLP()
    params = model.init(jax.random.PRNGKey(0))[0]
    sel = np.arange(0, 2 * K, 2)                # K clients, strided gather
    n_i = data.n[sel]
    steps = np.ones(K, np.int64)

    def make_trainer():
        return CohortTrainer(model, optimizer="adam", lr=1e-3, batch_size=2)

    trainer = make_trainer()
    Kp = _bucket(K, trainer.cohort_floor)

    # -- input path only ---------------------------------------------------
    def host_input():
        X, y, n = data.cohort(sel)
        if Kp != K:
            padt = lambda a: np.concatenate(
                [a, np.repeat(a[-1:], Kp - K, axis=0)])
            X, y = padt(X), padt(y)
        up = (jnp.asarray(X), jnp.asarray(y))
        jax.block_until_ready(up)
        return X.nbytes + y.nbytes

    def device_input():
        s = sel
        if Kp != K:
            s = np.concatenate([s, np.repeat(s[-1:], Kp - K)])
        jax.block_until_ready(jnp.asarray(s))
        return 0

    def timed(fn):
        fn()                                    # warmup
        times, byts = [], 0
        for _ in range(iters):
            t0 = time.perf_counter()
            byts = fn()
            times.append(time.perf_counter() - t0)
        return float(np.median(times)), byts

    host_in_s, host_in_bytes = timed(host_input)
    dev_in_s, _ = timed(device_input)

    # -- full dispatch through the trainer ---------------------------------
    def host_dispatch():
        X, y, n = data.cohort(sel)
        trainer.train_cohort(params, X, y, n, steps)
        return 0

    def device_dispatch():
        trainer.train_cohort_indexed(params, store, sel, n_i, steps)
        return 0

    b0 = trainer.data_h2d_bytes
    host_s, _ = timed(host_dispatch)
    host_bytes = (trainer.data_h2d_bytes - b0) // (iters + 1)
    b0 = trainer.data_h2d_bytes
    dev_s, _ = timed(device_dispatch)
    dev_bytes = (trainer.data_h2d_bytes - b0) // (iters + 1)

    # correctness guard: identical trained params from identical RNG state
    trainer_a, trainer_b = make_trainer(), make_trainer()
    X, y, n = data.cohort(sel)
    out_a = trainer_a.train_cohort(params, X, y, n, steps)[0]
    out_b = trainer_b.train_cohort_indexed(params, store, sel, n_i, steps)[0]
    for a, b in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    return {"K": K, "cohort_floats": K * n_max * feat, "n_max": n_max,
            "host_input_s": host_in_s, "device_input_s": dev_in_s,
            "speedup": (host_in_s / dev_in_s if dev_in_s > 0
                        else float("inf")),
            "host_train_s": host_s, "device_train_s": dev_s,
            "train_speedup": host_s / dev_s if dev_s > 0 else float("inf"),
            "host_h2d_bytes": int(host_bytes),
            "device_h2d_bytes": int(dev_bytes),
            "host_input_bytes": int(host_in_bytes),
            "resident_bytes": store.resident_bytes}


def run_dataplane(smoke: bool = False, json_path: str = "") -> dict:
    from repro.data.synthetic import make_federated_dataset
    from repro.models.proxy_models import build_bench_model

    cells_spec = ([(4, 50_000)] if smoke
                  else [(10, 100_000), (10, 1_000_000),
                        (100, 1_000_000), (100, 4_000_000)])
    iters = 3 if smoke else 7
    cells = []
    for K, floats in cells_spec:
        cell = _dataplane_cell(K, floats, iters)
        cells.append(cell)
        tag = f"dataplane/K{K}_F{cell['cohort_floats']}"
        print(f"{tag}/input/host,{cell['host_input_s'] * 1e6:.0f},"
              f"bytes={cell['host_input_bytes']}")
        print(f"{tag}/input/device,{cell['device_input_s'] * 1e6:.0f},"
              f"bytes=0 speedup={cell['speedup']:.2f}x")
        print(f"{tag}/train,{cell['device_train_s'] * 1e6:.0f},"
              f"host={cell['host_train_s'] * 1e6:.0f}us "
              f"train_speedup={cell['train_speedup']:.2f}x "
              f"h2d={cell['host_h2d_bytes']}->{cell['device_h2d_bytes']}")

    # end-to-end: the same scheduler microbench on both planes — dispatch
    # rate plus the run-level H2D accounting the CI gate checks (full-size
    # client shards outside smoke, so the input path is a real fraction of
    # each dispatch)
    rounds = 2 if smoke else 6
    data = make_federated_dataset("mnist", n_clients=8,
                                  scale=0.06 if smoke else 1.0, seed=0)
    model = build_bench_model("mnist")
    for dp in ("device", "host"):       # compile warmup, discarded
        _bench_dispatch(model, data, "apodotiko", 1, data_plane=dp)
    runs = [_bench_dispatch(model, data, "apodotiko", rounds, data_plane=dp)
            for dp in ("device", "host")]
    for d in runs:
        print(f"dataplane/e2e/{d['data_plane']},{d['wall_s'] * 1e6:.0f},"
              f"events_per_s={d['events_per_s']} "
              f"data_host_bytes={d['data_host_bytes']}")

    out = {"bench": "data_plane", "smoke": smoke,
           "backend": jax.default_backend(), "cells": cells,
           "end_to_end": runs}
    path = json_path or os.path.join(_ROOT, "BENCH_dataplane.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")

    # CI gate: the device plane must move ZERO training-input bytes
    leaked = [c["device_h2d_bytes"] for c in cells if c["device_h2d_bytes"]]
    e2e_dev = next(r for r in runs if r["data_plane"] == "device")
    if leaked or e2e_dev["data_host_bytes"]:
        print(f"FAIL: device data plane moved host bytes "
              f"(cells={leaked}, e2e={e2e_dev['data_host_bytes']})")
        sys.exit(1)
    return out


# ----------------------------------------------------------- control plane


def _control_states(M: int, seed: int = 0, history: int = 3,
                    planes=("object", "columnar")):
    """Identical fleet state on both control planes: M clients, everyone
    invoked `history` times with shared random durations (so selection
    exercises the scored path, not the uninvoked bootstrap)."""
    from repro.core.database import ClientRecord, Database

    rng = np.random.default_rng(seed)
    card = rng.integers(50, 500, M).astype(np.int64)
    durs = rng.uniform(1.0, 60.0, (M, history))

    col = None
    if "columnar" in planes:
        col = Database(control_plane="columnar")
        col.fleet.add_batch(np.arange(M), card, 10, 5)
        col.fleet.bulk_history(durs)

    obj = None
    if "object" in planes and M <= 200_000:
        # a million ClientRecords is the wall itself
        obj = Database(control_plane="object")
        for cid in range(M):
            rec = ClientRecord(client_id=cid, hardware="cpu1",
                               data_cardinality=int(card[cid]),
                               batch_size=10, local_epochs=5,
                               n_invocations=history,
                               durations=[float(d) for d in durs[cid]])
            obj.register_client(rec)
    return obj, col


def _controlplane_cell(M: int, K: int, iters: int) -> dict:
    """Each timed mode gets its own freshly built, identically seeded
    fleet state and its own identically seeded draw stream. Selection
    mutates the state it times (booster promotions), so sharing one state
    across modes made later sections depend on how many iterations the
    earlier ones ran — rebuilding per mode keeps every section comparable
    run-to-run and section-to-section."""
    from repro.core.selection import select_clients

    def timed(fn):
        fn(np.random.default_rng(99))               # warmup/compile
        times = []
        for i in range(iters):
            r = np.random.default_rng(1000 + i)
            t0 = time.perf_counter()
            fn(r)
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    col = _control_states(M, planes=("columnar",))[1]
    col_s = timed(lambda r: select_clients(col, K, r))
    col = _control_states(M, planes=("columnar",))[1]
    topk_s = timed(lambda r: col.fleet.select_topk(K, 1.2))
    obj = _control_states(M, planes=("object",))[0]
    obj_s = timed(lambda r: select_clients(obj, K, r)) if obj else None
    return {"M": M, "K": K, "object_s": obj_s, "columnar_s": col_s,
            "topk_s": topk_s,
            "speedup": (obj_s / col_s if obj_s else None),
            "topk_speedup": (obj_s / topk_s if obj_s else None)}


def _controlplane_gate(M: int = 1000, K: int = 64, rounds: int = 5) -> bool:
    """Object and columnar selection must stay bit-identical over evolving
    state: shared RNG stream, same completions folded back in each step."""
    from repro.core.selection import select_clients

    obj, col = _control_states(M)
    r_obj, r_col = (np.random.default_rng(7), np.random.default_rng(7))
    for t in range(rounds):
        s_obj = select_clients(obj, K, r_obj)
        s_col = select_clients(col, K, r_col)
        if s_obj != s_col:
            return False
        for db in (obj, col):
            for j, cid in enumerate(s_obj):
                db.mark_running(cid, t)
                db.mark_complete(cid, 1.0 + ((cid * 7 + j + t) % 50))
    return True


def run_controlplane(smoke: bool = False, json_path: str = "") -> dict:
    cells_spec = ([(1_000, 64)] if smoke
                  else [(1_000, 100), (10_000, 100),
                        (100_000, 100), (1_000_000, 100)])
    iters = 3 if smoke else 5
    cells = []
    for M, K in cells_spec:
        cell = _controlplane_cell(M, K, iters)
        cells.append(cell)
        obj_us = (f"{cell['object_s'] * 1e6:.0f}" if cell["object_s"]
                  else "skipped")
        sp = (f"{cell['speedup']:.1f}x" if cell["speedup"] else "n/a")
        tsp = (f"{cell['topk_speedup']:.1f}x" if cell["topk_speedup"]
               else "n/a")
        print(f"controlplane/M{M}/object,{obj_us},")
        print(f"controlplane/M{M}/columnar,{cell['columnar_s'] * 1e6:.0f},"
              f"speedup={sp}")
        print(f"controlplane/M{M}/topk,{cell['topk_s'] * 1e6:.0f},"
              f"topk_speedup={tsp}")
    identical = _controlplane_gate()
    out = {"bench": "control_plane", "smoke": smoke,
           "backend": jax.default_backend(), "cells": cells,
           "selection_identical": identical}
    path = json_path or os.path.join(_ROOT, "BENCH_controlplane.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    if not identical:
        print("FAIL: columnar selection diverged from the object oracle")
        sys.exit(1)
    return out


# --------------------------------------------------------------- megastep


def _megastep_engine(mode: str, rounds: int, model, data):
    """A run the fused path provably engages on: zero-variability fleet,
    deterministic top-k selection, CR gate = full cohort, no eval or
    checkpoint barriers, instances never cool."""
    from repro.core.scheduler import Scheduler
    from repro.core.services import FLConfig
    from repro.faas.hardware import HardwareProfile

    n = len(data.n)
    fleet = [HardwareProfile(f"det{i % 3}", speed=(1.0, 1.45, 1.9)[i % 3],
                             vcpus=1.0, mem_gib=2.0, variability=0.0)
             for i in range(n)]
    cfg = FLConfig(n_clients=n, clients_per_round=4, rounds=rounds,
                   local_epochs=1, batch_size=5, base_step_time=0.5,
                   strategy="apodotiko-topk", concurrency_ratio=1.0,
                   eval_every=0, keep_warm=1e9, seed=0, megastep=mode)
    return Scheduler(cfg, model, data, fleet)


def _run_trace(engine):
    hist = [(l.round, l.t_start, l.t_end, l.accuracy, l.n_aggregated,
             l.n_stale) for l in engine.history]
    inv = [(r.client_id, r.round, r.t_invoked, r.cold, r.duration, r.failed)
           for r in engine.platform.invocations]
    return hist, inv


def run_megastep(smoke: bool = False, json_path: str = "") -> dict:
    from repro.data.synthetic import make_federated_dataset
    from repro.models.proxy_models import build_bench_model

    B = 3                              # ceil(10/4) stepwise bootstrap rounds
    R = 6 if smoke else 32             # quiescent rounds per timed segment
    data = make_federated_dataset("mnist", n_clients=10, scale=0.05, seed=0)
    model = build_bench_model("mnist")

    def segment(mode):
        """Bootstrap, then two warmup segments of R rounds (the first
        compiles the scan on the fused path, the second settles runtime
        warmup), then a timed warm segment of R more."""
        eng = _megastep_engine(mode, B, model, data)
        eng.run()
        for _ in range(2):
            eng.cfg.rounds += R
            eng.run()
        ev0, r0 = eng.n_events, eng.db.round
        eng.cfg.rounds += R
        t0 = time.perf_counter()
        m = eng.run()
        wall = time.perf_counter() - t0
        n_rounds = eng.db.round - r0
        return m, {"mode": mode, "wall_s": round(wall, 4),
                   "rounds_timed": n_rounds,
                   "wall_us_per_round": round(1e6 * wall / n_rounds, 1),
                   "events_per_round": round(
                       (eng.n_events - ev0) / n_rounds, 3)}

    m_f, fused = segment("fused")
    _, step = segment("stepwise")
    fused["megastep_scans"] = m_f["megastep_scans"]
    fused["megastep_rounds"] = m_f["megastep_rounds"]
    share = ((step["wall_s"] - fused["wall_s"]) / step["wall_s"]
             if step["wall_s"] > 0 else 0.0)

    # divergence gate: fresh full runs on both modes, compared bitwise
    engines = {}
    for mode in ("stepwise", "fused"):
        eng = _megastep_engine(mode, B + R, model, data)
        engines[mode] = (eng, eng.run())
    s_eng, s_m = engines["stepwise"]
    f_eng, f_m = engines["fused"]
    identical = (
        _run_trace(s_eng) == _run_trace(f_eng)
        and s_m["total_time"] == f_m["total_time"]
        and all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(s_eng.params),
                                jax.tree.leaves(f_eng.params))))

    print(f"megastep/stepwise,{step['wall_us_per_round']:.0f},"
          f"events_per_round={step['events_per_round']}")
    print(f"megastep/fused,{fused['wall_us_per_round']:.0f},"
          f"events_per_round={fused['events_per_round']} "
          f"scans={fused['megastep_scans']} "
          f"rounds={fused['megastep_rounds']}")
    print(f"megastep/python_overhead_share,{share:.3f},"
          f"speedup={step['wall_s'] / fused['wall_s']:.2f}x "
          f"bit_identical={identical}")
    out = {"bench": "megastep", "smoke": smoke,
           "backend": jax.default_backend(),
           "bootstrap_rounds": B, "rounds_per_segment": R,
           "stepwise": step, "fused": fused,
           "python_overhead_share": round(share, 4),
           "python_dispatches_per_quiescent_round":
               fused["events_per_round"],
           "bit_identical": identical}
    path = json_path or os.path.join(_ROOT, "BENCH_megastep.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    if not identical:
        print("FAIL: fused megastep diverged from the stepwise oracle")
        sys.exit(1)
    if fused["events_per_round"] != 0.0:
        print("FAIL: fused path dispatched Python events during "
              f"quiescent rounds ({fused['events_per_round']}/round)")
        sys.exit(1)
    return out


# --------------------------------------------------------------- sharding


# One worker process per mesh cell: XLA's host-device count is fixed at
# process startup, so every device count needs its own interpreter with
# XLA_FLAGS set before jax imports (the same constraint the multi-device
# tests live under — tests/test_mesh_plane.py, tests/test_sharding.py).
_SHARDING_WORKER = r"""
import os, sys, json, time, hashlib
n_dev = int(os.environ["REPRO_SH_DEVICES"])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
os.environ.pop("REPRO_MESH", None)
spec = os.environ.get("REPRO_SH_MESH", "")      # "" = config default (auto)
import numpy as np
import jax

from repro.core.scheduler import Scheduler
from repro.core.services import FLConfig
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HardwareProfile
from repro.models.proxy_models import build_bench_model
from repro.sharding import flmesh

K = int(os.environ["REPRO_SH_K"])
R = int(os.environ["REPRO_SH_ROUNDS"])
n_clients = max(10, 3 * K)
data = make_federated_dataset("mnist", n_clients=n_clients, scale=0.05,
                              seed=0)
model = build_bench_model("mnist")
fleet = [HardwareProfile(f"det{i % 3}", speed=(1.0, 1.45, 1.9)[i % 3],
                         vcpus=1.0, mem_gib=2.0, variability=0.0)
         for i in range(n_clients)]
kw = dict(n_clients=n_clients, clients_per_round=K, rounds=R,
          local_epochs=1, batch_size=5, base_step_time=0.5,
          strategy="apodotiko-topk", concurrency_ratio=1.0, eval_every=0,
          keep_warm=1e9, seed=0)
if spec:
    kw["mesh"] = spec
eng = Scheduler(FLConfig(**kw), model, data, fleet)
eng.run()                                       # bootstrap + compile
eng.cfg.rounds += R                             # settle runtime warmup
eng.run()
r0 = eng.db.round
eng.cfg.rounds += R                             # timed warm segment
t0 = time.perf_counter()
eng.run()
wall = time.perf_counter() - t0
n_rounds = eng.db.round - r0

flat = np.concatenate([np.asarray(x).ravel()
                       for x in jax.tree.leaves(eng.params)])
buf = eng.store.buffer
shard_bytes = [s.data.nbytes for s in buf.addressable_shards]
mesh = flmesh.build_fl_mesh(flmesh.resolve_mesh(kw.get("mesh", "auto")))
d_ax, m_ax = flmesh.mesh_axes(mesh)
print(json.dumps({
    "mesh": spec or "auto", "devices": n_dev, "K": K,
    "data_axis": d_ax, "model_axis": m_ax,
    "rounds_timed": int(n_rounds), "wall_s": round(wall, 4),
    "rounds_per_s": round(n_rounds / wall, 3),
    "clients_per_s": round(n_rounds * K / wall, 3),
    "store_total_bytes": int(buf.nbytes),
    "store_device_bytes": int(max(shard_bytes)),
    "n_shards": len(shard_bytes),
    "params_sha": hashlib.sha256(flat.tobytes()).hexdigest(),
}))
"""


def _sharding_cell(spec: str, n_dev: int, K: int, rounds: int,
                   workdir: str) -> dict:
    import subprocess

    path = os.path.join(workdir, f"sharding_{spec or 'default'}.py")
    with open(path, "w") as f:
        f.write(_SHARDING_WORKER)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_MESH", None)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["REPRO_SH_MESH"] = spec
    env["REPRO_SH_DEVICES"] = str(n_dev)
    env["REPRO_SH_K"] = str(K)
    env["REPRO_SH_ROUNDS"] = str(rounds)
    out = subprocess.run([sys.executable, path], capture_output=True,
                         text=True, env=env, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"sharding worker {spec or 'default'!r} failed:\n"
                           + out.stderr[-3000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_sharding(smoke: bool = False, json_path: str = "") -> dict:
    """Sharded-plane bench (DESIGN.md §15): a weak-scaling curve over the
    ("data", "model") mesh — cohort size K grows with the data axis, so
    ideal scaling holds wall time per round flat while client-updates/s
    grows with the device count. On a host with fewer cores than forced
    devices the wall numbers measure oversubscription, not scaling, so
    the wall-clock gate only arms when ``os.cpu_count() >= 8``; the
    structural metrics (bottleneck-device update-store bytes, per-device
    cohort lanes) hold on any host and are always gated. The 1x1
    bitwise-identity gate — mesh resolution alone must not perturb a run
    — always arms. Lands in ``BENCH_sharding.json``."""
    import tempfile

    rounds = 3 if smoke else 5
    cells_spec = ([("", 1, 4), ("1x1", 1, 4), ("2x1", 2, 8)] if smoke
                  else [("", 1, 4), ("1x1", 1, 4), ("2x1", 2, 8),
                        ("2x2", 4, 8), ("2x4", 8, 16)])
    cells = []
    with tempfile.TemporaryDirectory(prefix="bench_sharding_") as work:
        for spec, n_dev, K in cells_spec:
            cell = _sharding_cell(spec, n_dev, K, rounds, work)
            cells.append(cell)
            print(f"sharding/{cell['mesh']}/d{cell['data_axis']}"
                  f"m{cell['model_axis']},"
                  f"{1e6 / cell['rounds_per_s']:.0f},"
                  f"K={cell['K']} clients_per_s={cell['clients_per_s']} "
                  f"device_bytes={cell['store_device_bytes']}"
                  f"/{cell['store_total_bytes']}")

    base = next(c for c in cells if c["mesh"] == "1x1")
    default = next(c for c in cells if c["mesh"] == "auto")
    identity_ok = (default["params_sha"] == base["params_sha"])

    # structural gates (host-independent): the buffer actually splits
    # into d*m equal tiles, and the bottleneck device holds 1/(d*m)
    # of the update-store bytes
    structural_ok = True
    for c in cells:
        n_tiles = c["data_axis"] * c["model_axis"]
        structural_ok &= (c["n_shards"] == n_tiles)
        structural_ok &= (c["store_device_bytes"] * n_tiles
                          == c["store_total_bytes"])

    # weak-scaled throughput relative to the 1x1 oracle
    for c in cells:
        c["throughput_vs_1x1"] = round(c["clients_per_s"]
                                       / base["clients_per_s"], 3)
    biggest = max(cells, key=lambda c: c["devices"])
    cpu_count = os.cpu_count() or 1
    wall_gate_armed = cpu_count >= 8 and biggest["devices"] >= 8
    wall_ok = (biggest["throughput_vs_1x1"] > 1.5 if wall_gate_armed
               else None)
    print(f"sharding/identity,0,bitwise={identity_ok} "
          f"structural={structural_ok}")
    print(f"sharding/scaling,{biggest['throughput_vs_1x1']},"
          f"devices={biggest['devices']} cpu_count={cpu_count} "
          f"wall_gate={'armed' if wall_gate_armed else 'skipped'}")

    out = {"bench": "sharding", "smoke": smoke,
           "backend": "cpu-subprocess", "cpu_count": cpu_count,
           "rounds_per_segment": rounds, "cells": cells,
           "identity_1x1_bitwise": identity_ok,
           "structural_ok": structural_ok,
           "wall_gate": ("armed" if wall_gate_armed else
                         f"skipped (cpu_count={cpu_count})"),
           "wall_scaling_ok": wall_ok}
    path = json_path or os.path.join(_ROOT, "BENCH_sharding.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    if not identity_ok:
        print("FAIL: mesh='1x1' diverged bitwise from the default path")
        sys.exit(1)
    if not structural_ok:
        print("FAIL: update-store buffer did not split into equal "
              "per-device tiles")
        sys.exit(1)
    if wall_gate_armed and not wall_ok:
        print(f"FAIL: weak-scaled throughput at {biggest['devices']} "
              f"devices is {biggest['throughput_vs_1x1']}x the 1x1 "
              "oracle (< 1.5x gate)")
        sys.exit(1)
    return out


# ----------------------------------------------------------------- faults


def _fault_engine(engine_cls, model, data, rounds: int, **cfg_overrides):
    """One seeded FL run under a fault profile (paper hardware mix, the
    same tiny setup as the scheduler dispatch bench)."""
    from repro.core.services import FLConfig
    from repro.faas.hardware import paper_fleet

    n = len(data.n)
    cfg_overrides.setdefault("strategy", "apodotiko")
    cfg = FLConfig(n_clients=n, clients_per_round=4, rounds=rounds,
                   local_epochs=1, batch_size=5, base_step_time=0.8,
                   concurrency_ratio=0.5, seed=0,
                   **cfg_overrides)
    eng = engine_cls(cfg, model, data, list(paper_fleet(n)))
    t0 = time.perf_counter()
    m = eng.run()
    wall = time.perf_counter() - t0
    return eng, m, wall


def _fault_trace(eng):
    """The chaos-trace observables (tests/chaos_harness.py): round history
    plus per-invocation fault attribution."""
    hist = [(l.round, l.t_start, l.t_end, l.accuracy, l.n_aggregated,
             l.n_stale) for l in eng.history]
    inv = [(r.client_id, r.round, r.t_invoked, r.cold, r.duration, r.failed,
            r.failed_phase, r.lost, r.timed_out, r.cancelled)
           for r in eng.platform.invocations]
    return hist, inv


def run_faults(smoke: bool = False, json_path: str = "") -> dict:
    """Fault-injection overhead + recovery benefit (DESIGN.md §12): the
    same seeded run clean, under the crash-heavy chaos profile, and under
    the same profile with the retry/timeout/quarantine recovery layer
    armed. Reports failure/retry counts, simulated time, and the recovery
    layer's wall-clock overhead. The CI gate replays a seeded schedule
    through both engines and exits nonzero on any trace divergence."""
    from repro.core.controller import Controller
    from repro.core.scheduler import Scheduler
    from repro.data.synthetic import make_federated_dataset
    from repro.models.proxy_models import build_bench_model

    rounds = 3 if smoke else 8
    data = make_federated_dataset("mnist", n_clients=8, scale=0.06, seed=0)
    model = build_bench_model("mnist")
    _fault_engine(Scheduler, model, data, 1)    # compile warmup, discarded

    recovery = dict(invocation_timeout=300.0, retry_budget=8,
                    quarantine_threshold=3)
    modes = [("clean", "", {}),
             ("crash-heavy", "crash-heavy", {}),
             ("crash-heavy+recovery", "crash-heavy", recovery)]
    runs = []
    for label, profile, rec in modes:
        _, m, wall = _fault_engine(Scheduler, model, data, rounds,
                                   fault_profile=profile, **rec)
        d = {"label": label, "fault_profile": profile,
             "recovery": bool(rec), "rounds": m["rounds"],
             "wall_s": round(wall, 3),
             "sim_time_s": round(m["total_time"], 1),
             "final_acc": round(m.get("final_accuracy", 0.0), 4),
             "n_invocations": m["n_invocations"],
             "n_failures": m["n_failures"], "n_retries": m["n_retries"],
             "n_timeouts": m["n_timeouts"],
             "n_quarantined": m["n_quarantined"],
             "retry_latency_s": round(m["retry_latency_s"], 1),
             "failures_by_phase": m["failures_by_phase"]}
        runs.append(d)
        print(f"faults/{label},{wall * 1e6:.0f},"
              f"sim={d['sim_time_s']}s failures={d['n_failures']} "
              f"retries={d['n_retries']} quarantined={d['n_quarantined']}")

    clean, chaos, recov = runs
    overhead = {
        # what the chaos profile costs an unprotected run
        "chaos_sim_slowdown": (round(chaos["sim_time_s"]
                                     / clean["sim_time_s"], 3)
                               if clean["sim_time_s"] else None),
        # what the recovery layer claws back (or costs) under chaos
        "recovery_sim_ratio": (round(recov["sim_time_s"]
                                     / chaos["sim_time_s"], 3)
                               if chaos["sim_time_s"] else None),
        "recovery_wall_overhead_s": round(recov["wall_s"]
                                          - chaos["wall_s"], 3),
    }
    print(f"faults/recovery_overhead,{overhead['recovery_wall_overhead_s']},"
          f"chaos_slowdown={overhead['chaos_sim_slowdown']}x "
          f"recovery_ratio={overhead['recovery_sim_ratio']}")

    # CI gate: a seeded schedule must replay bit-identically on both
    # engines (recovery off — it is scheduler-only by design)
    gate_profiles = (("crash-heavy",) if smoke
                     else ("crash-heavy", "lossy-network", "outage-window"))
    gate = {}
    for profile in gate_profiles:
        legacy = _fault_engine(Controller, model, data, rounds,
                               fault_profile=profile)[0]
        sched = _fault_engine(Scheduler, model, data, rounds,
                              fault_profile=profile)[0]
        gate[profile] = _fault_trace(legacy) == _fault_trace(sched)
        print(f"faults/gate/{profile},0,identical={gate[profile]}")

    out = {"bench": "faults", "smoke": smoke,
           "backend": jax.default_backend(), "runs": runs,
           "overhead": overhead, "cross_engine_identical": gate}
    path = json_path or os.path.join(_ROOT, "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    if not all(gate.values()):
        bad = sorted(p for p, ok in gate.items() if not ok)
        print(f"FAIL: chaos trace diverged across engines for {bad}")
        sys.exit(1)
    return out


# ---------------------------------------------------------------- traffic


def _traffic_apply_bulk(schedule, db, cards):
    """Apply a compiled schedule through the traffic plane's vectorized
    path: one ``unregister_clients_bulk`` + one ``register_clients_bulk``
    per windowed segment (what ``services._apply_traffic_segment`` runs)."""
    for seg in schedule.segments:
        if len(seg.leaves):
            db.unregister_clients_bulk(seg.leaves)
        if len(seg.joins):
            db.register_clients_bulk(seg.joins, cards[seg.joins], 5, 1)


def _traffic_apply_per_event(schedule, db, cards):
    """The per-event Python path the traffic plane replaces: one
    ``ClientRecord`` built and registered (or unregistered) per
    ClientJoined/ClientLeft event — the runtime's pre-traffic membership
    API, as used by the registration loop and churn tests."""
    from repro.core.database import ClientRecord
    for t, kind, cid in schedule.events():
        if kind == "leave":
            db.unregister_client(cid)
        else:
            db.register_client(ClientRecord(
                client_id=cid, hardware="",
                data_cardinality=int(cards[cid]),
                batch_size=5, local_epochs=1))


def _traffic_seed_store(schedule, cards):
    from repro.core.database import Database
    from repro.core.fleet_store import FleetStore
    db = Database(control_plane="columnar")
    db.fleet = FleetStore(capacity=schedule.capacity)
    init = schedule.initial
    if len(init):
        db.register_clients_bulk(init, cards[init], 5, 1)
    return db


def _traffic_cell(M: int, n_flash: int, iters: int) -> dict:
    """Bulk vs per-event application of a flash-crowd + churn schedule
    over an M-client FleetStore (the ISSUE's >=10x acceptance gate)."""
    from repro.traffic import build_traffic_schedule

    spec = (f"init:0.5,window:30,horizon:900,"
            f"flash:60:{n_flash}:300,poisson:2.0:120")
    sched = build_traffic_schedule(spec, M, seed=0)
    rng = np.random.default_rng(0)
    cards = rng.integers(20, 200, M)
    n_events = sum(len(s.joins) + len(s.leaves) for s in sched.segments)

    def _time(apply_fn):
        best = float("inf")
        for _ in range(iters):
            db = _traffic_seed_store(sched, cards)
            t0 = time.perf_counter()
            apply_fn(sched, db, cards)
            best = min(best, time.perf_counter() - t0)
        return best, db.fleet

    bulk_s, fs_bulk = _time(_traffic_apply_bulk)
    ev_s, fs_ev = _time(_traffic_apply_per_event)
    identical = (
        fs_bulk._slot == fs_ev._slot
        and fs_bulk._free == fs_ev._free
        and np.array_equal(fs_bulk.active, fs_ev.active)
        and np.array_equal(fs_bulk.ids, fs_ev.ids)
        and np.array_equal(fs_bulk.seq, fs_ev.seq)
        and np.array_equal(fs_bulk.cardinality, fs_ev.cardinality))
    return {"M": M, "segments": len(sched.segments), "events": n_events,
            "n_dropped": sched.n_dropped,
            "bulk_ms": round(bulk_s * 1e3, 3),
            "per_event_ms": round(ev_s * 1e3, 3),
            "bulk_speedup": round(ev_s / bulk_s, 1) if bulk_s else None,
            "bulk_matches_per_event": identical}


def _traffic_compile_cell(M: int, rate: float) -> dict:
    """Schedule-compile (mask-generation) throughput: arrival processes
    -> windowed bulk segments, the work that replaces per-event Python."""
    from repro.traffic import build_traffic_schedule

    spec = f"init:0.5,window:60,horizon:20000,diurnal:{rate}:0.9:3600:1800"
    t0 = time.perf_counter()
    sched = build_traffic_schedule(spec, M, seed=0)
    wall = time.perf_counter() - t0
    n_events = sum(len(s.joins) + len(s.leaves) for s in sched.segments)
    return {"M": M, "arrival_rate": rate, "segments": len(sched.segments),
            "events": n_events, "compile_ms": round(wall * 1e3, 1),
            "events_per_s": (round(n_events / wall) if wall else None)}


def run_traffic(smoke: bool = False, json_path: str = "") -> dict:
    """Open-loop traffic bench (DESIGN.md §13): schedule-compile
    throughput at fleet scale, bulk vs per-event FleetStore application
    (the vectorized availability path must beat per-event Python), and
    per-strategy SLO metrics — p50/p99 round latency, cold-start rate,
    cost-per-round — under the diurnal profile. Lands in
    ``BENCH_traffic.json``; exits nonzero if the bulk path diverges from
    the per-event oracle."""
    from repro.core.scheduler import Scheduler
    from repro.data.synthetic import make_federated_dataset
    from repro.models.proxy_models import build_bench_model

    # 1) mask-gen throughput: M=1e5 (and 1e6 outside smoke)
    compile_cells = [_traffic_compile_cell(100_000, 0.5)]
    if not smoke:
        compile_cells.append(_traffic_compile_cell(1_000_000, 5.0))
    for c in compile_cells:
        print(f"traffic/compile/M={c['M']},{c['compile_ms'] * 1e3:.0f},"
              f"events={c['events']} events_per_s={c['events_per_s']}")

    # 2) bulk vs per-event application at M=1e5 (1e4-client flash crowd)
    cell = _traffic_cell(100_000, 10_000, iters=1 if smoke else 3)
    print(f"traffic/apply/M={cell['M']},{cell['bulk_ms'] * 1e3:.0f},"
          f"per_event_ms={cell['per_event_ms']} "
          f"speedup={cell['bulk_speedup']}x "
          f"identical={cell['bulk_matches_per_event']}")

    # 3) SLO table: three strategies under diurnal load. The canned
    # "diurnal" profile's 30 s window outlives a 3-round smoke run, so
    # the bench pins an early-window variant of the same shape — churn
    # must actually fire inside every strategy's run
    diurnal = "init:0.5,window:5,diurnal:0.3:0.9:120:60"
    rounds = 3 if smoke else 8
    data = make_federated_dataset("mnist", n_clients=8, scale=0.06, seed=0)
    model = build_bench_model("mnist")
    _fault_engine(Scheduler, model, data, 1)    # compile warmup, discarded
    slo_runs = []
    for strat in ("fedavg", "apodotiko", "apodotiko-hedge"):
        _, m, wall = _fault_engine(Scheduler, model, data, rounds,
                                   strategy=strat,
                                   traffic_profile=diurnal)
        d = {"strategy": strat, "traffic_profile": diurnal,
             "rounds": m["rounds"], "wall_s": round(wall, 3),
             "sim_time_s": round(m["total_time"], 1),
             "p50_round_latency_s": round(m["p50_round_latency_s"], 2),
             "p99_round_latency_s": round(m["p99_round_latency_s"], 2),
             "cold_start_rate": round(m["cold_start_rate"], 4),
             "cost_per_round_usd": round(m["cost_per_round_usd"], 6),
             "final_acc": round(m.get("final_accuracy", 0.0), 4),
             "n_traffic_joins": m["n_traffic_joins"],
             "n_traffic_leaves": m["n_traffic_leaves"]}
        slo_runs.append(d)
        print(f"traffic/slo/{strat},{wall * 1e6:.0f},"
              f"p50={d['p50_round_latency_s']}s "
              f"p99={d['p99_round_latency_s']}s "
              f"cold={d['cold_start_rate']} "
              f"cost_per_round={d['cost_per_round_usd']}")

    out = {"bench": "traffic", "smoke": smoke,
           "backend": jax.default_backend(),
           "compile": compile_cells, "apply": cell, "slo": slo_runs}
    path = json_path or os.path.join(_ROOT, "BENCH_traffic.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    if not cell["bulk_matches_per_event"]:
        print("FAIL: bulk traffic application diverged from the "
              "per-event oracle")
        sys.exit(1)
    return out


# ------------------------------------------------------------- durability


def _durable_run(model, data, rounds: int, root: str = "", **overrides):
    """One seeded scheduler run, optionally journal-armed at ``root``."""
    from repro.core.scheduler import Scheduler
    if root:
        overrides = dict(overrides, durability="journal",
                         checkpoint_dir=root)
    return _fault_engine(Scheduler, model, data, rounds, **overrides)


def run_durability(smoke: bool = False, json_path: str = "") -> dict:
    """Durability bench (DESIGN.md §14): journal overhead per round at
    each sync policy, snapshot/resume latency vs fleet size, and the
    resume-identity CI gate. Exits nonzero if round-sync journaling
    exceeds 5% wall overhead or a crashed-and-resumed run diverges."""
    import shutil
    import tempfile

    from repro.core.journal import Journal
    from repro.core.scheduler import Scheduler
    from repro.data.synthetic import make_federated_dataset
    from repro.durability import SimulatedCrash, resume_durable
    from repro.durability.snapshot import write_snapshot
    from repro.faas.hardware import paper_fleet
    from repro.models.proxy_models import build_bench_model

    rounds = 3 if smoke else 8
    iters = 2 if smoke else 4
    data = make_federated_dataset("mnist", n_clients=8, scale=0.06, seed=0)
    model = build_bench_model("mnist")
    _durable_run(model, data, 1)               # compile warmup, discarded
    work = tempfile.mkdtemp(prefix="bench_durability_")

    # 1) journal overhead per round: off vs round-fsync vs event-fsync.
    # Snapshot cadence is pushed past the horizon so the cells isolate
    # the *journal* cost (snapshot write cost is measured in part 2).
    # best-of-N wall clock per mode; identical seeded schedule throughout
    sync_runs = []
    for label, sync in (("off", ""), ("journal+round", "round"),
                        ("journal+event", "event")):
        best, metrics = float("inf"), None
        for i in range(iters):
            d = os.path.join(work, f"{label}_{i}")
            os.makedirs(d, exist_ok=True)
            root = d if sync else ""
            kw = ({"durability_sync": sync,
                   "durability_snap_every": 10 ** 9} if sync else {})
            _, m, wall = _durable_run(model, data, rounds, root=root, **kw)
            if wall < best:
                best, metrics = wall, m
        sync_runs.append({
            "label": label, "wall_s": round(best, 3),
            "wall_per_round_ms": round(best / rounds * 1e3, 2),
            "journal_records": metrics.get("journal_records", 0),
            "journal_bytes": metrics.get("journal_bytes", 0),
            "journal_fsyncs": metrics.get("journal_fsyncs", 0),
            "n_snapshots": metrics.get("n_snapshots", 0)})
    off_wall = sync_runs[0]["wall_s"]
    for r in sync_runs[1:]:
        r["overhead_pct"] = (round((r["wall_s"] - off_wall)
                                   / off_wall * 100, 2)
                             if off_wall else None)
        print(f"durability/sync/{r['label']},{r['wall_s'] * 1e6:.0f},"
              f"overhead={r['overhead_pct']}% "
              f"fsyncs={r['journal_fsyncs']} bytes={r['journal_bytes']}")
    round_sync = sync_runs[1]
    # <5% per-round gate, with a small absolute floor so sub-second runs
    # aren't failed by scheduler jitter
    overhead_ok = (round_sync["wall_s"] - off_wall
                   < max(0.05 * off_wall, 0.05))

    # 2) snapshot write + resume latency as the fleet grows
    fleet_cells = []
    for n in ((8,) if smoke else (8, 32, 96)):
        d = os.path.join(work, f"fleet_{n}")
        os.makedirs(d, exist_ok=True)
        dd = make_federated_dataset("mnist", n_clients=n, scale=0.02, seed=0)
        eng, m, _ = _durable_run(model, dd, rounds=2, root=d)
        t0 = time.perf_counter()
        write_snapshot(eng, d, seq=10_000)     # past every journaled seq
        snap_s = time.perf_counter() - t0
        records, _ = Journal.read(os.path.join(d, "journal.wal"))
        t0 = time.perf_counter()
        resume_durable(eng.cfg, model, dd,
                       list(paper_fleet(n)))   # load + install, no run
        resume_s = time.perf_counter() - t0
        snap_bytes = sum(
            os.path.getsize(os.path.join(r, f))
            for r, _, fs in os.walk(os.path.join(d, "snap_0000010000"))
            for f in fs)
        fleet_cells.append({
            "n_clients": n, "journal_records": len(records),
            "snapshot_ms": round(snap_s * 1e3, 2),
            "snapshot_bytes": snap_bytes,
            "resume_ms": round(resume_s * 1e3, 2)})
        print(f"durability/fleet/M={n},{snap_s * 1e6:.0f},"
              f"resume_ms={fleet_cells[-1]['resume_ms']} "
              f"snap_bytes={snap_bytes}")

    # 3) resume-identity gate: crash mid-journal, resume, compare
    gold_d = os.path.join(work, "gate_gold")
    os.makedirs(gold_d, exist_ok=True)
    gold_eng, gold_m, _ = _durable_run(model, data, rounds, root=gold_d)
    with open(os.path.join(gold_d, "journal.wal"), "rb") as f:
        gold_bytes = f.read()
    crash_d = os.path.join(work, "gate_crash")
    os.makedirs(crash_d, exist_ok=True)
    k = gold_m["journal_records"] // 2
    from repro.core.services import FLConfig
    cfg = FLConfig(n_clients=8, clients_per_round=4, rounds=rounds,
                   local_epochs=1, batch_size=5, base_step_time=0.8,
                   concurrency_ratio=0.5, seed=0,
                   durability="journal", checkpoint_dir=crash_d)
    eng2 = Scheduler(cfg, model, data, list(paper_fleet(8)))
    eng2.durability.crash_after = k
    try:
        eng2.run()
        raise RuntimeError("crash injector never fired")
    except SimulatedCrash:
        pass
    t0 = time.perf_counter()
    resumed = resume_durable(cfg, model, data, list(paper_fleet(8)))
    m2 = resumed.run()
    gate_wall = time.perf_counter() - t0
    with open(os.path.join(crash_d, "journal.wal"), "rb") as f:
        crash_bytes = f.read()
    identical = (m2["history"] == gold_m["history"]
                 and m2["total_time"] == gold_m["total_time"]
                 and crash_bytes == gold_bytes)
    print(f"durability/gate/resume_identity,{gate_wall * 1e6:.0f},"
          f"crash_at={k} replayed={m2['journal_replayed']} "
          f"identical={identical}")

    out = {"bench": "durability", "smoke": smoke,
           "backend": jax.default_backend(), "rounds": rounds,
           "sync": sync_runs, "fleet": fleet_cells,
           "gate": {"crash_at": k, "replayed": m2["journal_replayed"],
                    "resume_identical": identical,
                    "round_sync_overhead_ok": overhead_ok}}
    path = json_path or os.path.join(_ROOT, "BENCH_durability.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    shutil.rmtree(work, ignore_errors=True)
    if not identical:
        print("FAIL: crashed-and-resumed run diverged from the golden run")
        sys.exit(1)
    if not overhead_ok:
        print(f"FAIL: round-sync journaling overhead "
              f"{round_sync['overhead_pct']}% exceeds the 5% gate")
        sys.exit(1)
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    jp = ""
    if "--json" in sys.argv:
        jp = sys.argv[sys.argv.index("--json") + 1]
    if "--scheduler" in sys.argv:
        run_scheduler(smoke=smoke, json_path=jp)
    elif "--dataplane" in sys.argv:
        run_dataplane(smoke=smoke, json_path=jp)
    elif "--controlplane" in sys.argv:
        run_controlplane(smoke=smoke, json_path=jp)
    elif "--megastep" in sys.argv:
        run_megastep(smoke=smoke, json_path=jp)
    elif "--faults" in sys.argv:
        run_faults(smoke=smoke, json_path=jp)
    elif "--traffic" in sys.argv:
        run_traffic(smoke=smoke, json_path=jp)
    elif "--durability" in sys.argv:
        run_durability(smoke=smoke, json_path=jp)
    elif "--sharding" in sys.argv:
        run_sharding(smoke=smoke, json_path=jp)
    else:
        run(smoke=smoke, json_path=jp)
