"""Paper Fig 6 + Table II CR rows: Apodotiko concurrencyRatio sensitivity
(CR in {0.3, 0.6, 0.7, 0.8}; the paper finds 0.3 fastest)."""
from __future__ import annotations

from benchmarks.common import best_accuracy, run_experiment, time_to_accuracy

CRS = (0.3, 0.6, 0.7, 0.8)


def run(datasets=("shakespeare", "speech")) -> list[dict]:
    rows = []
    for ds in datasets:
        runs = {cr: run_experiment(dataset=ds, strategy="apodotiko",
                                   concurrency_ratio=cr) for cr in CRS}
        target = 0.95 * min(best_accuracy(m) for m in runs.values())
        t03 = time_to_accuracy(runs[0.3], target)
        for cr, m in runs.items():
            t = time_to_accuracy(m, target)
            rows.append({"dataset": ds, "cr": cr,
                         "time_to_target_s": None if t is None else round(t, 1),
                         "speedup_cr03_vs_this": (round(t / t03, 2)
                                                  if t and t03 else None),
                         "final_acc": round(m["final_accuracy"], 4),
                         "cost_usd": round(m["total_cost_usd"], 4)})
    return rows


def main(emit) -> None:
    for r in run():
        t = r["time_to_target_s"]
        emit(f"fig6/{r['dataset']}/cr{r['cr']}",
             0.0 if t is None else t * 1e6,
             f"final_acc={r['final_acc']};cost={r['cost_usd']}")
