"""Paper Fig 2: the staleness weighting surfaces of Eq. 1 (FedLesScan) vs
Eq. 2 (Apodotiko) — diagonal consistency is the paper's argument for Eq. 2.
Plus an ablation: Apodotiko trained with eq1 vs eq2 weighting."""
from __future__ import annotations

import numpy as np

from repro.core.staleness import eq1_fedlesscan, eq2_apodotiko
from benchmarks.common import best_accuracy, run_experiment


def weight_surface(fn, rounds=10):
    return [[round(fn(t_i, T), 4) if t_i <= T else None
             for t_i in range(1, rounds + 1)] for T in range(1, rounds + 1)]


def diagonal_variance(surface):
    """Variance of weights along equal-staleness diagonals (0 for Eq. 2)."""
    n = len(surface)
    var = []
    for stale in range(1, n):
        diag = [surface[T][T - stale] for T in range(stale, n)]
        if len(diag) > 1:
            var.append(float(np.var(diag)))
    return float(np.mean(var)) if var else 0.0


def run() -> dict:
    s1 = weight_surface(eq1_fedlesscan)
    s2 = weight_surface(eq2_apodotiko)
    out = {
        "eq1_diag_variance": diagonal_variance(s1),
        "eq2_diag_variance": diagonal_variance(s2),
    }
    for fn in ("eq1", "eq2"):
        m = run_experiment(dataset="speech", strategy="apodotiko",
                           staleness_fn=fn)
        out[f"best_acc_{fn}"] = round(best_accuracy(m), 4)
    return out


def main(emit) -> None:
    r = run()
    emit("fig2/eq1", r["eq1_diag_variance"] * 1e6,
         f"best_acc={r['best_acc_eq1']}")
    emit("fig2/eq2", r["eq2_diag_variance"] * 1e6,
         f"best_acc={r['best_acc_eq2']}")
