"""Shared experiment engine for the paper-reproduction benchmarks.

Each benchmark module reproduces one paper table/figure by running (or
loading from the results cache) FL experiments on the serverless simulator
with real JAX local training. Experiments are cached by config hash under
``results/bench_cache`` so the full ``python -m benchmarks.run`` suite
composes tables without re-running shared grids.

Scale: the paper's full setup (200 clients, 100/round, 28x28 CNNs) costs
~150 s/round of pure conv compute on this 1-core container; the default
bench scale keeps the paper's *structure* (client mix 65/25/10, non-IID
schemes, CR values, round counts) at proxy-model scale (DESIGN.md §8).
Pass fidelity="paper" for the exact models.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import asdict, dataclass
from typing import Optional

import numpy as np

from repro.core.controller import FLConfig
from repro.core.scheduler import build_engine
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
from repro.models.proxy_models import build_bench_model

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "bench_cache")

# per-dataset simulated compute weight (1vCPU-seconds per optimizer step),
# calibrated so round durations land in the paper's Fig-1/Fig-3 ranges
BASE_STEP_TIME = {"mnist": 0.8, "femnist": 4.0, "shakespeare": 6.0,
                  "speech": 1.5}
# every strategy gets the SAME simulated wall-clock budget per dataset (the
# paper compares time-to-accuracy, not round counts — async strategies run
# many more, shorter rounds inside the same budget)
SIM_BUDGET = {"mnist": 2_500.0, "femnist": 8_000.0, "shakespeare": 12_000.0,
              "speech": 4_000.0}
LOCAL_EPOCHS = {"mnist": 3, "femnist": 3, "speech": 3, "shakespeare": 2}
# paper IV-B target accuracies (proxy tasks reach different absolute values;
# bench tables report time-to-(fraction of best) instead where needed)
PAPER_TARGETS = {"mnist": 0.98, "femnist": 0.70, "shakespeare": 0.40,
                 "speech": 0.75}

_MODELS: dict[tuple, object] = {}
_DATA: dict[tuple, object] = {}


def bench_scale():
    """(n_clients, clients_per_round, max_rounds, data_scale)."""
    if os.environ.get("BENCH_FULL"):
        return 200, 100, 500, 0.5
    return 24, 10, 100, 0.12


def get_model(dataset: str, fidelity: str = "proxy"):
    key = (dataset, fidelity)
    if key not in _MODELS:
        _MODELS[key] = build_bench_model(dataset, fidelity)
    return _MODELS[key]


def get_data(dataset: str, n_clients: int, scale: float, seed: int = 0,
             fidelity: str = "proxy"):
    key = (dataset, n_clients, scale, seed, fidelity)
    if key not in _DATA:
        _DATA[key] = make_federated_dataset(
            dataset, n_clients=n_clients, scale=scale, seed=seed,
            fidelity=fidelity)
    return _DATA[key]


def fleet_for(scenario: str, n_clients: int):
    """Paper hardware scenarios: heterogeneous (IV-A3 mix), homogeneous
    (Fig 1 scenario 1), two-tier (Fig 1 scenario 2)."""
    if scenario == "heterogeneous":
        return list(paper_fleet(n_clients))
    if scenario == "homogeneous":
        return [HARDWARE_PROFILES["cpu2"]] * n_clients
    if scenario == "two-tier":
        rng = np.random.default_rng(0)
        fleet = [HARDWARE_PROFILES["cpu1"]] * round(n_clients * 0.6) + \
                [HARDWARE_PROFILES["cpu2"]] * (n_clients - round(n_clients * 0.6))
        rng.shuffle(fleet)
        return fleet
    raise ValueError(scenario)


def run_experiment(*, dataset: str, strategy: str, scenario: str = "heterogeneous",
                   concurrency_ratio: float = 0.3, clients_per_round: Optional[int] = None,
                   rounds: Optional[int] = None, seed: int = 0,
                   staleness_fn: str = "eq2", use_cache: bool = True) -> dict:
    n_clients, cpr_default, rounds_default, scale = bench_scale()
    cpr = clients_per_round or cpr_default
    rounds = rounds or rounds_default
    key_src = json.dumps([dataset, strategy, scenario, concurrency_ratio,
                          cpr, rounds, seed, staleness_fn, bench_scale()],
                         sort_keys=True)
    key = hashlib.sha1(key_src.encode()).hexdigest()[:16]
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"{key}.json")
    if use_cache and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    epochs = LOCAL_EPOCHS[dataset]
    # paper batch sizes are 10/10/32/5; proxy datasets are ~8x smaller per
    # client, so batches scale down to keep steps-per-epoch comparable
    batch = 8 if dataset == "shakespeare" else 5
    cfg = FLConfig(
        n_clients=n_clients, clients_per_round=cpr, rounds=rounds,
        strategy=strategy, concurrency_ratio=concurrency_ratio,
        local_epochs=epochs, batch_size=batch,
        optimizer="sgd" if dataset == "shakespeare" else "adam",
        lr=0.5 if dataset == "shakespeare" else 1e-3,
        base_step_time=BASE_STEP_TIME[dataset],
        round_timeout=600.0, staleness_fn=staleness_fn, seed=seed,
        eval_every=2, max_sim_time=SIM_BUDGET[dataset])
    model = get_model(dataset)
    data = get_data(dataset, n_clients, scale, seed=0)
    t0 = time.time()
    ctl = build_engine(cfg, model, data, fleet_for(scenario, n_clients))
    metrics = ctl.run()
    metrics["wall_s"] = time.time() - t0
    metrics["dataset"] = dataset
    metrics["scenario"] = scenario
    metrics["concurrency_ratio"] = concurrency_ratio
    with open(path, "w") as f:
        json.dump(metrics, f)
    return metrics


def time_to_accuracy(metrics: dict, target: float) -> Optional[float]:
    for t, _, acc in metrics["history"]:
        if acc >= target:
            return t
    return None


def best_accuracy(metrics: dict) -> float:
    return max((a for _, _, a in metrics["history"]), default=0.0)
