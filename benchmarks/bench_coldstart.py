"""Paper Fig 4c: cold-start ratios per strategy/dataset (the paper's headline
4x average reduction for Apodotiko)."""
from __future__ import annotations

from benchmarks.common import run_experiment
from benchmarks.bench_time_to_accuracy import DATASETS, STRATEGIES


def run(datasets=DATASETS, strategies=STRATEGIES) -> list[dict]:
    rows = []
    for ds in datasets:
        base = None
        for s in strategies:
            m = run_experiment(dataset=ds, strategy=s)
            ratio = m["cold_start_ratio"]
            if s == "fedavg":
                base = ratio
            rows.append({"dataset": ds, "strategy": s,
                         "cold_start_ratio": round(ratio, 4),
                         "reduction_vs_fedavg": (round(base / ratio, 2)
                                                 if base and ratio > 0 else None)})
    return rows


def main(emit) -> None:
    for r in run():
        emit(f"fig4c/{r['dataset']}/{r['strategy']}",
             r["cold_start_ratio"] * 1e6,
             f"reduction_vs_fedavg={r['reduction_vs_fedavg']}")
