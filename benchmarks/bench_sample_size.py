"""Paper Fig 7: impact of clients-per-round (50/100/200 of 200 in the paper;
25%/50%/100% of the pool here)."""
from __future__ import annotations

from benchmarks.common import bench_scale, best_accuracy, run_experiment, time_to_accuracy


def run(strategies=("fedavg", "fedlesscan", "apodotiko")) -> list[dict]:
    n_clients, _, _, _ = bench_scale()
    fractions = (0.25, 0.5, 1.0)
    rows = []
    for s in strategies:
        for frac in fractions:
            cpr = max(2, int(n_clients * frac))
            m = run_experiment(dataset="shakespeare", strategy=s,
                               clients_per_round=cpr)
            rows.append({"strategy": s, "clients_per_round": cpr,
                         "best_acc": round(best_accuracy(m), 4),
                         "sim_time_s": round(m["total_time"], 1)})
    return rows


def main(emit) -> None:
    for r in run():
        emit(f"fig7/{r['strategy']}/cpr{r['clients_per_round']}",
             r["sim_time_s"] * 1e6, f"best_acc={r['best_acc']}")
