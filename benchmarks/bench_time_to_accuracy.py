"""Paper Table II + Fig 4a: total training time to target accuracy across
the six strategies x four datasets (heterogeneous fleet)."""
from __future__ import annotations

from benchmarks.common import (
    best_accuracy,
    run_experiment,
    time_to_accuracy,
)

STRATEGIES = ("fedavg", "fedprox", "scaffold", "fedlesscan", "fedbuff",
              "apodotiko")
DATASETS = ("mnist", "femnist", "shakespeare", "speech")


def run(datasets=DATASETS, strategies=STRATEGIES) -> list[dict]:
    rows = []
    for ds in datasets:
        runs = {s: run_experiment(dataset=ds, strategy=s) for s in strategies}
        # time-to-COMMON-accuracy: the highest level every strategy
        # reaches (95% of the weakest best) — the paper's fixed targets work
        # because its tasks converge; proxy tasks plateau at strategy-
        # dependent ceilings (EXPERIMENTS.md notes this deviation)
        target = 0.95 * min(best_accuracy(m) for m in runs.values())
        base = time_to_accuracy(runs["fedavg"], target)
        for s, m in runs.items():
            t = time_to_accuracy(m, target)
            rows.append({
                "dataset": ds, "strategy": s, "target_acc": round(target, 4),
                "time_to_target_s": None if t is None else round(t, 1),
                "speedup_vs_fedavg": (None if (t is None or base is None)
                                      else round(base / t, 2)),
                "final_acc": round(m["final_accuracy"], 4),
                "sim_time_s": round(m["total_time"], 1),
            })
    return rows


def main(emit) -> None:
    for r in run():
        t = r["time_to_target_s"]
        emit(f"tableII/{r['dataset']}/{r['strategy']}",
             0.0 if t is None else t * 1e6,
             f"speedup={r['speedup_vs_fedavg']};final_acc={r['final_acc']}")
