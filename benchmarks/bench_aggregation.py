"""Microbenchmarks of the aggregation path (the paper's serverless
aggregation function): XLA fused reduction, Pallas staleness_agg kernel
(interpret mode on CPU — TPU numbers come from a real chip), int8-compressed
update pipeline, fused Adam."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_aggregate
from repro.kernels import ops


def _time(fn, *args, iters=5, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[dict]:
    rows = []
    K, N = 16, 1 << 20  # 16 clients x 1M params
    rng = np.random.default_rng(0)
    ups = [{"w": jnp.asarray(rng.normal(size=(N,)), jnp.float32)}
           for _ in range(K)]
    w = (np.ones(K) / K).astype(np.float32)

    us = _time(weighted_aggregate, ups, w, path="xla")
    rows.append({"name": "aggregate/xla_fused", "us_per_call": us,
                 "derived": f"GBps={(K * N * 4 / (us / 1e6)) / 1e9:.2f}"})

    # default dispatch: Pallas below the interpret-mode size cap, XLA above
    # (on CPU at this N the guard picks XLA; on TPU it compiles the kernel)
    us = _time(weighted_aggregate, ups, w)
    from repro.core import aggregation
    rows.append({"name": "aggregate/default_dispatch", "us_per_call": us,
                 "derived": f"path={aggregation.last_path()};"
                            f"GBps={(K * N * 4 / (us / 1e6)) / 1e9:.2f}"})

    stacked = jnp.stack([u["w"] for u in ups])
    us = _time(ops.staleness_agg, stacked, jnp.asarray(w), interpret=True)
    rows.append({"name": "aggregate/pallas_interpret", "us_per_call": us,
                 "derived": "correctness-path; TPU perf needs Mosaic"})

    x = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    us = _time(ops.quantize_q8, x, interpret=True)
    rows.append({"name": "quant8/quantize_interpret", "us_per_call": us,
                 "derived": f"compression=4x"})

    n = 8 * 1024 * 16
    p = jnp.zeros(n); m = jnp.zeros(n); v = jnp.zeros(n)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    us = _time(ops.fused_adam, p, m, v, g, jnp.int32(1), lr=1e-3,
               interpret=True)
    rows.append({"name": "fused_adam/interpret", "us_per_call": us,
                 "derived": f"n={n}"})
    return rows


def main(emit) -> None:
    for r in run():
        emit(r["name"], r["us_per_call"], r["derived"])
