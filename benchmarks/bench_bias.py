"""Paper Fig 4b: client selection bias — the spread of per-client invocation
counts (max-min = bias; plus distribution quantiles for the violin shape)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_experiment
from benchmarks.bench_time_to_accuracy import DATASETS, STRATEGIES


def run(datasets=DATASETS, strategies=STRATEGIES) -> list[dict]:
    rows = []
    for ds in datasets:
        for s in strategies:
            m = run_experiment(dataset=ds, strategy=s)
            counts = np.array(m["invocation_counts"])
            rows.append({
                "dataset": ds, "strategy": s,
                "bias_max_minus_min": int(counts.max() - counts.min()),
                "p10": float(np.percentile(counts, 10)),
                "p50": float(np.percentile(counts, 50)),
                "p90": float(np.percentile(counts, 90)),
                "mean": round(float(counts.mean()), 2),
            })
    return rows


def main(emit) -> None:
    for r in run():
        emit(f"fig4b/{r['dataset']}/{r['strategy']}",
             r["bias_max_minus_min"] * 1e6,
             f"p10={r['p10']};p50={r['p50']};p90={r['p90']}")
