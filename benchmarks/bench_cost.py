"""Paper Table III: total training cost (USD, GCP model) per strategy/dataset."""
from __future__ import annotations

from benchmarks.common import run_experiment
from benchmarks.bench_time_to_accuracy import DATASETS, STRATEGIES


def run(datasets=DATASETS, strategies=STRATEGIES) -> list[dict]:
    rows = []
    for ds in datasets:
        for s in strategies:
            m = run_experiment(dataset=ds, strategy=s)
            rows.append({"dataset": ds, "strategy": s,
                         "cost_usd": round(m["total_cost_usd"], 4),
                         "invocations": m["n_invocations"]})
    return rows


def main(emit) -> None:
    for r in run():
        emit(f"tableIII/{r['dataset']}/{r['strategy']}", r["cost_usd"] * 1e6,
             f"invocations={r['invocations']}")
