"""Paper Fig 5: Apodotiko (CR 0.3/0.6) vs FedBuff (buffer ratio 0.3) —
the paper's closest asynchronous baseline."""
from __future__ import annotations

from benchmarks.common import best_accuracy, run_experiment, time_to_accuracy


def run(datasets=("shakespeare", "speech")) -> list[dict]:
    rows = []
    for ds in datasets:
        runs = {
            ("apodotiko", 0.3): run_experiment(dataset=ds, strategy="apodotiko",
                                               concurrency_ratio=0.3),
            ("apodotiko", 0.6): run_experiment(dataset=ds, strategy="apodotiko",
                                               concurrency_ratio=0.6),
            ("fedbuff", 0.3): run_experiment(dataset=ds, strategy="fedbuff",
                                             concurrency_ratio=0.3),
            ("fedbuff", 0.6): run_experiment(dataset=ds, strategy="fedbuff",
                                             concurrency_ratio=0.6),
        }
        target = 0.95 * min(best_accuracy(m) for m in runs.values())
        tb = time_to_accuracy(runs[("fedbuff", 0.3)], target)
        for (s, cr), m in runs.items():
            t = time_to_accuracy(m, target)
            rows.append({"dataset": ds, "strategy": s, "ratio": cr,
                         "time_to_target_s": None if t is None else round(t, 1),
                         "speedup_vs_fedbuff03": (round(tb / t, 2)
                                                  if t and tb else None)})
    return rows


def main(emit) -> None:
    for r in run():
        t = r["time_to_target_s"]
        emit(f"fig5/{r['dataset']}/{r['strategy']}-{r['ratio']}",
             0.0 if t is None else t * 1e6,
             f"speedup_vs_fedbuff03={r['speedup_vs_fedbuff03']}")
