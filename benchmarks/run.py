"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus section markers). Scale
is bench-sized by default (1-core container); set BENCH_FULL=1 for the
paper-scale grid (hours).

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run fig4 cost  # substring filter
"""
from __future__ import annotations

import sys
import time
import traceback

BENCHES = [
    ("fig2_staleness", "benchmarks.bench_staleness"),
    ("tableII_time_to_accuracy", "benchmarks.bench_time_to_accuracy"),
    ("tableIII_cost", "benchmarks.bench_cost"),
    ("fig4b_bias", "benchmarks.bench_bias"),
    ("fig4c_coldstart", "benchmarks.bench_coldstart"),
    ("fig5_fedbuff", "benchmarks.bench_fedbuff"),
    ("fig6_concurrency_ratio", "benchmarks.bench_cr"),
    ("fig7_sample_size", "benchmarks.bench_sample_size"),
    ("fig1_fig3_heterogeneity", "benchmarks.bench_heterogeneity"),
    ("aggregation_kernels", "benchmarks.bench_aggregation"),
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    for name, module in BENCHES:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(lambda n, us, d="": print(f"{n},{us:.1f},{d}", flush=True))
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
