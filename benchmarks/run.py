"""Benchmark runner — one module per paper table/figure, plus sweep mode.

Prints ``name,us_per_call,derived`` CSV lines (plus section markers). Scale
is bench-sized by default (1-core container); set BENCH_FULL=1 for the
paper-scale grid (hours).

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run fig4 cost  # substring filter

Sweep mode hands off to the strategy-sweep engine (``repro.sweep``) and
prints the paper's comparison tables (speedup vs FedAvg, cold starts, cost):

  python benchmarks/run.py --sweep paper_mnist       # Tables IV-VI, MNIST
  python benchmarks/run.py --sweep smoke             # CI-sized check
  SWEEP_WORKERS=4 python benchmarks/run.py --sweep paper_tables
"""
from __future__ import annotations

import os
import sys
import time
import traceback

# Runnable both as ``python -m benchmarks.run`` and as a plain script with
# no PYTHONPATH: make the repo root (benchmarks pkg) and src/ importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCHES = [
    ("fig2_staleness", "benchmarks.bench_staleness"),
    ("tableII_time_to_accuracy", "benchmarks.bench_time_to_accuracy"),
    ("tableIII_cost", "benchmarks.bench_cost"),
    ("fig4b_bias", "benchmarks.bench_bias"),
    ("fig4c_coldstart", "benchmarks.bench_coldstart"),
    ("fig5_fedbuff", "benchmarks.bench_fedbuff"),
    ("fig6_concurrency_ratio", "benchmarks.bench_cr"),
    ("fig7_sample_size", "benchmarks.bench_sample_size"),
    ("fig1_fig3_heterogeneity", "benchmarks.bench_heterogeneity"),
    ("aggregation_kernels", "benchmarks.bench_aggregation"),
]

SWEEP_COLUMNS = ("dataset", "scenario", "strategy", "seed", "target_acc",
                 "time_to_target_s", "speedup_vs_fedavg", "final_acc",
                 "cold_starts", "cold_start_reduction_vs_fedavg", "cost_usd",
                 "cost_vs_fedavg")


def run_sweep_mode(argv: list[str]) -> None:
    from repro.sweep import get_preset, run_sweep

    i = argv.index("--sweep")
    name = argv[i + 1] if i + 1 < len(argv) else "paper_mnist"
    try:
        spec = get_preset(name)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        sys.exit(2)
    print(f"# sweep {name}: {spec.n_runs} runs "
          f"({len(spec.strategies)} strategies x {len(spec.datasets)} "
          f"datasets), scale={spec.scale.n_clients} clients", flush=True)
    t0 = time.time()
    table = run_sweep(spec, progress=lambda i, n, r, m: print(
        f"#   [{i + 1}/{n}] {r.key}"
        + (f" FAILED: {m['error']}" if "error" in m else ""), flush=True))
    print(f"# sweep done in {time.time() - t0:.1f}s\n", flush=True)
    cols = SWEEP_COLUMNS
    if len({r["data_plane"] for r in table.rows}) > 1:
        # plane-ablation sweeps: show which transport each row ran on
        cols = SWEEP_COLUMNS[:3] + ("data_plane",) + SWEEP_COLUMNS[3:]
    if len({r["traffic_profile"] for r in table.rows}) > 1:
        # open-loop sweeps: label each comparison group's arrival process
        # and surface the SLO columns (DESIGN.md §13)
        cols = (cols[:3] + ("traffic_profile",) + cols[3:]
                + ("p50_round_latency_s", "p99_round_latency_s",
                   "cost_per_round_usd"))
    print(table.to_markdown(columns=cols))
    for s in sorted({r["strategy"] for r in table.rows}):
        if s != "fedavg":
            print(f"# mean speedup vs fedavg [{s}]: {table.mean_speedup(s)}")
    sys.exit(1 if any(r["error"] for r in table.rows) else 0)


def main() -> None:
    if "--sweep" in sys.argv:
        run_sweep_mode(sys.argv)
        return
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    for name, module in BENCHES:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(lambda n, us, d="": print(f"{n},{us:.1f},{d}", flush=True))
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
