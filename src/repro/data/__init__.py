from repro.data.partition import (  # noqa: F401
    dirichlet_partition,
    label_shard_partition,
    lognormal_cardinalities,
)
from repro.data.synthetic import (  # noqa: F401
    FederatedDataset,
    make_federated_dataset,
)
