"""Synthetic proxies for the paper's four datasets (offline container —
MNIST/FEMNIST/Shakespeare/Google-Speech are not redistributable here).

Each proxy preserves the statistical shape that drives the paper's system
behaviour: client count, non-IID scheme (label shards / Dirichlet /
power-law cardinalities), and learnability (class prototypes + noise for
the CNNs; per-client-biased Markov chains for the char-LSTM), so
time-to-accuracy curves exhibit the same relative strategy ordering.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.partition import (
    dirichlet_partition,
    label_shard_partition,
    lognormal_cardinalities,
)


@dataclass
class FederatedDataset:
    """Padded per-client arrays: X [C, N_max, ...], y [C, N_max], n [C].

    The padded layout is the contract both data planes share: the host
    plane fancy-indexes ``X[selection]`` per dispatch, the device plane
    (``core.data_plane.DatasetStore``) uploads ``X``/``y`` once and
    gathers by client index inside the jitted cohort fn."""

    X: np.ndarray
    y: np.ndarray
    n: np.ndarray
    eval_x: np.ndarray
    eval_y: np.ndarray
    name: str = ""

    @property
    def n_clients(self) -> int:
        return self.X.shape[0]

    @property
    def nbytes(self) -> int:
        """Training-input bytes (X + y): what the host plane re-uploads
        over a run and the device plane holds resident once."""
        return int(self.X.nbytes + self.y.nbytes)

    def cohort(self, selection) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host-side cohort slice (X, y, n) — the host-plane dispatch
        input, kept as the oracle for the on-device gather."""
        sel = np.asarray(selection)
        return self.X[sel], self.y[sel], self.n[sel]


def _pad_pack(xs: list[np.ndarray], ys: list[np.ndarray], n_max: int):
    C = len(xs)
    feat = xs[0].shape[1:]
    X = np.zeros((C, n_max) + feat, xs[0].dtype)
    y = np.zeros((C, n_max), np.int32)
    n = np.zeros((C,), np.int64)
    for c, (xc, yc) in enumerate(zip(xs, ys)):
        k = min(len(xc), n_max)
        X[c, :k] = xc[:k]
        y[c, :k] = yc[:k]
        n[c] = k
    return X, y, n


def _prototype_images(protos: np.ndarray, noise: float, n_total: int,
                      rng: np.random.Generator):
    n_classes = protos.shape[0]
    labels = rng.integers(0, n_classes, n_total)
    x = protos[labels] * 0.5 + rng.normal(0, noise, (n_total,) + protos.shape[1:]).astype(np.float32)
    return x.astype(np.float32), labels.astype(np.int32)


def _image_dataset(name: str, n_clients: int, n_classes: int, shape,
                   scheme: str, samples_per_client: int, noise: float,
                   seed: int, n_eval: int = 1000):
    rng = np.random.default_rng(seed)
    protos = rng.normal(0, 1, (n_classes,) + shape).astype(np.float32)
    if scheme == "shards":
        n_total = n_clients * samples_per_client
        x, yl = _prototype_images(protos, noise, n_total, rng)
        parts = label_shard_partition(yl, n_clients, shards_per_client=2, rng=rng)
    else:  # dirichlet + power-law cardinality (LEAF-like)
        card = lognormal_cardinalities(n_clients, mean=samples_per_client,
                                       sigma=0.8, rng=rng)
        n_total = int(card.sum())
        x, yl = _prototype_images(protos, noise, n_total, rng)
        parts = dirichlet_partition(yl, n_clients, alpha=0.3, rng=rng,
                                    cardinalities=card)
    xs = [x[p] for p in parts]
    ys = [yl[p] for p in parts]
    n_max = max(len(p) for p in parts)
    X, y, n = _pad_pack(xs, ys, n_max)
    ex, ey = _prototype_images(protos, noise, n_eval, rng)
    return FederatedDataset(X, y, n, ex, ey, name=name)


def _markov_chains(n_roles: int, vocab: int, rng: np.random.Generator):
    """Role-specific char transition matrices: shared backbone + role bias."""
    base = rng.dirichlet(np.full(vocab, 0.3), size=vocab)
    chains = []
    for _ in range(n_roles):
        bias = rng.dirichlet(np.full(vocab, 0.1), size=vocab)
        chains.append(0.7 * base + 0.3 * bias)
    return chains


def _shakespeare_like(n_clients: int, samples_per_client: int, seq_len: int,
                      vocab: int, seed: int, n_eval: int = 500):
    rng = np.random.default_rng(seed)
    n_roles = 12
    chains = _markov_chains(n_roles, vocab, rng)
    card = lognormal_cardinalities(n_clients, mean=samples_per_client,
                                   sigma=1.0, lo=8, rng=rng)

    def sample_seqs(chain, count):
        seqs = np.zeros((count, seq_len + 1), np.int32)
        state = rng.integers(0, vocab, count)
        seqs[:, 0] = state
        for t in range(1, seq_len + 1):
            probs = chain[state]
            cum = probs.cumsum(axis=1)
            u = rng.random((count, 1))
            state = (u < cum).argmax(axis=1)
            seqs[:, t] = state
        return seqs

    xs, ys = [], []
    roles = rng.integers(0, n_roles, n_clients)
    for c in range(n_clients):
        seqs = sample_seqs(chains[roles[c]], int(card[c]))
        xs.append(seqs[:, :-1])
        ys.append(seqs[:, -1])
    n_max = int(card.max())
    X, y, n = _pad_pack(xs, ys, n_max)
    eval_seqs = np.concatenate(
        [sample_seqs(chains[r], n_eval // n_roles + 1) for r in range(n_roles)])
    rng.shuffle(eval_seqs)
    eval_seqs = eval_seqs[:n_eval]
    return FederatedDataset(X, y.astype(np.int32), n,
                            eval_seqs[:, :-1], eval_seqs[:, -1].astype(np.int32),
                            name="shakespeare")


def make_federated_dataset(name: str, n_clients: int = 200, *,
                           scale: float = 1.0, seed: int = 0,
                           fidelity: str = "proxy") -> FederatedDataset:
    """name in {mnist, femnist, shakespeare, speech}. ``scale`` shrinks
    per-client cardinalities (benchmarks on the 1-core container use
    scale<1; the partition structure is unchanged). ``fidelity``:
    'paper' -> the paper's exact input shapes (28x28 / 32x32 / seq 80);
    'proxy' -> 8x8 images / seq 20 matching repro.models.proxy_models."""
    paper = fidelity == "paper"
    img = {"mnist": (28, 28, 1), "femnist": (28, 28, 1), "speech": (32, 32, 1)}
    shape = img.get(name, (8, 8, 1)) if paper else (8, 8, 1)
    seq_len = 80 if paper else 20
    if name == "mnist":
        # paper: 60k images, 300 shards x 200 -> 2 shards/client label skew
        return _image_dataset("mnist", n_clients, 10, shape, "shards",
                              max(int(300 * scale), 20), noise=0.8, seed=seed)
    if name == "femnist":
        return _image_dataset("femnist", n_clients, 62, shape, "dirichlet",
                              max(int(400 * scale), 20), noise=0.9, seed=seed)
    if name == "speech":
        return _image_dataset("speech", n_clients, 35, shape, "dirichlet",
                              max(int(250 * scale), 16), noise=0.9, seed=seed)
    if name == "shakespeare":
        return _shakespeare_like(n_clients, max(int(160 * scale), 8), seq_len,
                                 82, seed=seed)
    raise ValueError(name)
