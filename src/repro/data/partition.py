"""Non-IID partitioners (paper IV-A1).

- ``label_shard_partition``: the MNIST scheme — sort by label, cut into
  shards (300 shards x 200 images in the paper), deal shards to clients.
  Produces label skew (1-2 classes per client) with mild cardinality skew.
- ``dirichlet_partition``: Dir(alpha) class mixture per client (standard
  non-IID benchmark scheme; LEAF-like unbalancedness for FEMNIST/Speech).
- ``lognormal_cardinalities``: LEAF-style power-law dataset sizes.
"""
from __future__ import annotations

import numpy as np


def label_shard_partition(labels: np.ndarray, n_clients: int,
                          shards_per_client: int = 2,
                          rng: np.random.Generator | None = None) -> list[np.ndarray]:
    """Returns per-client index arrays."""
    rng = rng or np.random.default_rng(0)
    order = np.argsort(labels, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    shard_ids = rng.permutation(n_shards)
    out = []
    for c in range(n_clients):
        ids = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        out.append(np.concatenate([shards[i] for i in ids]))
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 0.3,
                        rng: np.random.Generator | None = None,
                        cardinalities: np.ndarray | None = None) -> list[np.ndarray]:
    """Per-client class mixture ~ Dir(alpha); optional target sizes."""
    rng = rng or np.random.default_rng(0)
    n_classes = int(labels.max()) + 1
    by_class = [rng.permutation(np.where(labels == k)[0]) for k in range(n_classes)]
    ptr = np.zeros(n_classes, np.int64)
    if cardinalities is None:
        cardinalities = np.full(n_clients, len(labels) // n_clients)
    out = []
    for c in range(n_clients):
        mix = rng.dirichlet(np.full(n_classes, alpha))
        counts = rng.multinomial(cardinalities[c], mix)
        idx = []
        for k, cnt in enumerate(counts):
            take = by_class[k][ptr[k]:ptr[k] + cnt]
            # wrap around if a class runs dry (sampling with replacement)
            if len(take) < cnt:
                extra = rng.choice(by_class[k], cnt - len(take)) \
                    if len(by_class[k]) else np.array([], np.int64)
                take = np.concatenate([take, extra])
            ptr[k] += cnt
            idx.append(take)
        out.append(np.concatenate(idx) if idx else np.array([], np.int64))
    return out


def lognormal_cardinalities(n_clients: int, mean: int = 200, sigma: float = 0.6,
                            lo: int = 20, hi: int | None = None,
                            rng: np.random.Generator | None = None) -> np.ndarray:
    rng = rng or np.random.default_rng(0)
    raw = rng.lognormal(np.log(mean), sigma, n_clients)
    hi = hi or mean * 6
    return np.clip(raw, lo, hi).astype(np.int64)
