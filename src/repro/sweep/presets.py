"""Named sweeps reproducing the paper's comparison tables.

Each preset is a ``SweepSpec`` at bench scale (1-core container,
minutes); set ``SWEEP_FULL=1`` to lift any preset to the paper-scale grid
(200 clients, 100/round — hours). Entry points:

    python benchmarks/run.py --sweep paper_mnist
    PYTHONPATH=src python examples/sweep_paper_tables.py [preset]
"""
from __future__ import annotations

import os
from dataclasses import replace

from repro.sweep.grid import (PAPER_SCALE, SMOKE_SCALE, SweepScale,
                              SweepSpec)

ALL_STRATEGIES = ("fedavg", "fedprox", "scaffold", "fedlesscan", "fedbuff",
                  "apodotiko")
# Natively-reactive policies (scheduler-only; repro.core.strategies.reactive)
REACTIVE_STRATEGIES = ("apodotiko-hedge", "apodotiko-adaptive")

# 3-round hedging smoke: long enough for hedges to fire (the CR gate must
# leave stragglers outstanding), short enough for CI
SMOKE_HEDGE_SCALE = SweepScale(n_clients=8, clients_per_round=4, rounds=3,
                               data_scale=0.06, local_epochs=1,
                               sim_budget=1500.0)

# Open-loop load: enough rounds/sim-budget that every canned traffic
# profile actually bites (the flash-crowd surge lands at t=150, past a
# 6-round smoke run's end)
PROD_SCALE = SweepScale(n_clients=8, clients_per_round=4, rounds=12,
                        data_scale=0.06, local_epochs=1, sim_budget=900.0)

# Fleet-scale selection demo: the widest fleet a bench-scale FL run
# affords (selection/scoring at M=1e6 is benchmarked without training in
# benchmarks/bench_round.py --controlplane)
FLEET_SCALE = SweepScale(n_clients=256, clients_per_round=32, rounds=6,
                         data_scale=0.06, local_epochs=1, sim_budget=2_000.0)

PRESETS: dict[str, SweepSpec] = {
    # Tables IV-VI, one dataset at a time (all six strategies, paper's
    # heterogeneous 65/25/10 hardware mix)
    "paper_mnist": SweepSpec(name="paper_mnist", datasets=("mnist",)),
    "paper_femnist": SweepSpec(name="paper_femnist", datasets=("femnist",)),
    "paper_shakespeare": SweepSpec(name="paper_shakespeare",
                                   datasets=("shakespeare",)),
    "paper_speech": SweepSpec(name="paper_speech", datasets=("speech",)),
    # the full Table IV-VI grid
    "paper_tables": SweepSpec(name="paper_tables",
                              datasets=("mnist", "femnist", "shakespeare",
                                        "speech")),
    # Fig 1/3 hardware scenarios: does the speedup survive homogeneity?
    "hardware_scenarios": SweepSpec(
        name="hardware_scenarios", datasets=("mnist",),
        strategies=("fedavg", "fedlesscan", "apodotiko"),
        scenarios=("heterogeneous", "two-tier", "homogeneous")),
    # Fig 6: concurrency-ratio sensitivity of the async strategies
    "cr_sweep": SweepSpec(
        name="cr_sweep", datasets=("mnist",),
        strategies=("fedavg", "fedbuff", "apodotiko"),
        concurrency_ratios=(0.3, 0.5, 0.7)),
    # Eq. 1 vs Eq. 2 staleness damping ablation (paper §III-B)
    "staleness_ablation": SweepSpec(
        name="staleness_ablation", datasets=("mnist",),
        strategies=("fedavg", "apodotiko"), staleness_fns=("eq1", "eq2")),
    # Straggler-heavy hedging comparison: 75/25 cpu1-vs-gpu fleet, big cold
    # starts, keep-warm below the round cadence — every fresh straggler
    # invocation is cold while hedges ride the warm container, so the
    # reactive apodotiko-hedge policy's time-to-accuracy win is structural
    # (tests/test_reactive.py pins it)
    "straggler_hedge": SweepSpec(
        name="straggler_hedge", datasets=("mnist",),
        strategies=("fedavg", "apodotiko", "apodotiko-hedge"),
        scenarios=("straggler",),
        concurrency_ratios=(0.5,),
        overrides=(("cold_start_s", 120.0), ("keep_warm", 30.0),
                   ("hedge_fraction", 1.0))),
    # between-round CR adaptation vs fixed-CR async baselines
    "adaptive_cr": SweepSpec(
        name="adaptive_cr", datasets=("mnist",),
        strategies=("fedbuff", "apodotiko", "apodotiko-adaptive"),
        concurrency_ratios=(0.3,)),
    # device-vs-host data-plane ablation: same strategies, same seeds,
    # only the training-input transport differs — time-to-accuracy must
    # match (bit-identical traces, tests/test_data_plane.py) while wall
    # clock and H2D bytes diverge (BENCH_dataplane.json quantifies it)
    "dataplane_ablation": SweepSpec(
        name="dataplane_ablation", datasets=("mnist",),
        strategies=("fedavg", "apodotiko"),
        data_planes=("device", "host")),
    # columnar-vs-object control-plane ablation: same strategies, same
    # seeds, only the fleet-state backing differs — traces are
    # bit-identical (tests/test_control_plane.py) while the score+select
    # dispatch cost diverges (BENCH_controlplane.json quantifies it)
    "controlplane_ablation": SweepSpec(
        name="controlplane_ablation", datasets=("mnist",),
        strategies=("fedavg", "apodotiko"),
        control_planes=("columnar", "object")),
    # fleet-scale cohort selection: a 256-client fleet on the columnar
    # plane, Algorithm 3 sampling vs the device-resident top-k selector
    "fleet_scale": SweepSpec(
        name="fleet_scale", datasets=("mnist",),
        strategies=("fedavg", "apodotiko", "apodotiko-topk"),
        control_planes=("columnar",),
        scale=FLEET_SCALE),
    # fault-injection robustness grid (DESIGN.md §12): the same two
    # strategies under no faults vs each canned chaos profile, with the
    # retry/quarantine recovery layer armed — `fault_profile` is a group
    # axis, so every speedup ratio compares runs that suffered the same
    # seeded schedule
    "chaos": SweepSpec(
        name="chaos", datasets=("mnist",),
        strategies=("fedavg", "apodotiko"),
        fault_profiles=("none", "crash-heavy", "outage-window",
                        "lossy-network"),
        scale=SMOKE_SCALE,
        overrides=(("retry_budget", 8), ("invocation_timeout", 300.0),
                   ("quarantine_threshold", 3))),
    # open-loop production load (DESIGN.md §13): the same three
    # strategies under a fixed fleet vs each canned traffic profile —
    # `traffic_profile` is a group axis, so every ratio compares runs
    # that faced the same seeded arrival process, and the SLO columns
    # (p50/p99 round latency, cold-start rate, cost-per-round) say which
    # policy earns its keep under churn, diurnal load, and flash crowds
    "production_load": SweepSpec(
        name="production_load", datasets=("mnist",),
        strategies=("fedavg", "apodotiko", "apodotiko-hedge"),
        traffic_profiles=("none", "steady-churn", "diurnal", "flash-crowd"),
        concurrency_ratios=(0.5,),
        scale=PROD_SCALE,
        overrides=(("cold_start_s", 60.0), ("keep_warm", 120.0))),
    # CI-sized end-to-end check (two strategies, seconds)
    "smoke": SweepSpec(name="smoke", datasets=("mnist",),
                       strategies=("fedavg", "apodotiko"),
                       scale=SMOKE_SCALE),
    # CI-sized hedging check: 3-round apodotiko-hedge on the straggler mix
    "smoke_hedge": SweepSpec(
        name="smoke_hedge", datasets=("mnist",),
        strategies=("apodotiko", "apodotiko-hedge"),
        scenarios=("straggler",),
        concurrency_ratios=(0.5,),
        scale=SMOKE_HEDGE_SCALE,
        overrides=(("cold_start_s", 120.0), ("keep_warm", 30.0),
                   ("hedge_fraction", 1.0))),
}


def get_preset(name: str) -> SweepSpec:
    try:
        spec = PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown sweep preset {name!r}; available: "
                       f"{', '.join(sorted(PRESETS))}") from None
    if os.environ.get("SWEEP_FULL"):
        spec = replace(spec, scale=PAPER_SCALE)
    return spec
