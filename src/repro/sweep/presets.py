"""Named sweeps reproducing the paper's comparison tables.

Each preset is a ``SweepSpec`` at bench scale (1-core container,
minutes); set ``SWEEP_FULL=1`` to lift any preset to the paper-scale grid
(200 clients, 100/round — hours). Entry points:

    python benchmarks/run.py --sweep paper_mnist
    PYTHONPATH=src python examples/sweep_paper_tables.py [preset]
"""
from __future__ import annotations

import os
from dataclasses import replace

from repro.sweep.grid import PAPER_SCALE, SMOKE_SCALE, SweepSpec

ALL_STRATEGIES = ("fedavg", "fedprox", "scaffold", "fedlesscan", "fedbuff",
                  "apodotiko")

PRESETS: dict[str, SweepSpec] = {
    # Tables IV-VI, one dataset at a time (all six strategies, paper's
    # heterogeneous 65/25/10 hardware mix)
    "paper_mnist": SweepSpec(name="paper_mnist", datasets=("mnist",)),
    "paper_femnist": SweepSpec(name="paper_femnist", datasets=("femnist",)),
    "paper_shakespeare": SweepSpec(name="paper_shakespeare",
                                   datasets=("shakespeare",)),
    "paper_speech": SweepSpec(name="paper_speech", datasets=("speech",)),
    # the full Table IV-VI grid
    "paper_tables": SweepSpec(name="paper_tables",
                              datasets=("mnist", "femnist", "shakespeare",
                                        "speech")),
    # Fig 1/3 hardware scenarios: does the speedup survive homogeneity?
    "hardware_scenarios": SweepSpec(
        name="hardware_scenarios", datasets=("mnist",),
        strategies=("fedavg", "fedlesscan", "apodotiko"),
        scenarios=("heterogeneous", "two-tier", "homogeneous")),
    # Fig 6: concurrency-ratio sensitivity of the async strategies
    "cr_sweep": SweepSpec(
        name="cr_sweep", datasets=("mnist",),
        strategies=("fedavg", "fedbuff", "apodotiko"),
        concurrency_ratios=(0.3, 0.5, 0.7)),
    # Eq. 1 vs Eq. 2 staleness damping ablation (paper §III-B)
    "staleness_ablation": SweepSpec(
        name="staleness_ablation", datasets=("mnist",),
        strategies=("fedavg", "apodotiko"), staleness_fns=("eq1", "eq2")),
    # CI-sized end-to-end check (two strategies, seconds)
    "smoke": SweepSpec(name="smoke", datasets=("mnist",),
                       strategies=("fedavg", "apodotiko"),
                       scale=SMOKE_SCALE),
}


def get_preset(name: str) -> SweepSpec:
    try:
        spec = PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown sweep preset {name!r}; available: "
                       f"{', '.join(sorted(PRESETS))}") from None
    if os.environ.get("SWEEP_FULL"):
        spec = replace(spec, scale=PAPER_SCALE)
    return spec
