"""Concurrent sweep execution: expand the grid, run every cell, build the
comparison table.

Runs execute on a thread pool (``max_workers`` arg or ``SWEEP_WORKERS`` env,
default 1): JAX dispatch is thread-safe and the simulator releases the GIL
inside jit'd compute, so concurrent cells overlap compile/compute/host work
even on one core. Shared setup (datasets, models, fleets) is pre-warmed
serially before the pool starts, so worker threads never duplicate it.

Results are collected by grid index — the output table is byte-identical
for any worker count or completion order. A cell that raises becomes an
``error`` row instead of poisoning the sweep.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

from repro.sweep.grid import SweepSpec, expand_grid
from repro.sweep.results import ResultTable
from repro.sweep.runner import LocalRunner

Progress = Callable[[int, int, object, dict], None]


def run_sweep(spec: SweepSpec, runner: Optional[Callable] = None,
              max_workers: Optional[int] = None,
              progress: Optional[Progress] = None) -> ResultTable:
    """Execute ``spec`` and return its ``ResultTable``.

    ``runner``: any callable ``RunSpec -> metrics dict`` (defaults to
    ``LocalRunner(spec.scale)``); inject a stub for tests or a remote
    executor for distributed sweeps."""
    runs = expand_grid(spec)
    if runner is None:
        runner = LocalRunner(spec.scale)
    if hasattr(runner, "warm"):
        runner.warm(runs)
    if max_workers is None:
        max_workers = int(os.environ.get("SWEEP_WORKERS", "1"))
    max_workers = max(1, min(max_workers, len(runs)))

    metrics: list[Optional[dict]] = [None] * len(runs)

    def one(i: int) -> None:
        try:
            m = runner(runs[i])
        except Exception as e:  # noqa: BLE001 — keep the sweep alive
            m = {"error": f"{type(e).__name__}: {e}"}
        metrics[i] = m
        if progress:
            progress(i, len(runs), runs[i], m)

    if max_workers == 1:
        for i in range(len(runs)):
            one(i)
    else:
        with ThreadPoolExecutor(max_workers=max_workers) as ex:
            list(ex.map(one, range(len(runs))))

    return ResultTable.from_runs(spec.name, runs, metrics)
