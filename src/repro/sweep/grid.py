"""Declarative sweep grids: strategy x seed x dataset x scenario x CR.

A ``SweepSpec`` names the axes of a comparison experiment (the paper's
tables are strategy x dataset grids on a fixed hardware mix); ``expand_grid``
enumerates it into an ordered, deterministic list of ``RunSpec`` cells. Every
cell shares one ``SweepScale`` — the knobs that trade fidelity for wall-clock
(client counts, rounds, data size; DESIGN.md §8) — so results within a sweep
are directly comparable.

Determinism contract: ``expand_grid`` is a pure function of the spec — same
spec, same list, same order — and each cell's ``seed`` flows into
``FLConfig.seed`` (strategy selection RNG, platform noise, model init) while
the *data* partition seed is shared sweep-wide, so strategies compete on the
identical federated dataset.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import product
from typing import Any, Optional, Sequence, Tuple


@dataclass(frozen=True)
class RunSpec:
    """One cell of a sweep grid (one Controller.run())."""
    dataset: str
    strategy: str
    scenario: str = "heterogeneous"
    seed: int = 0
    concurrency_ratio: float = 0.3       # CR (paper Alg. 1); async only
    staleness_fn: str = "eq2"            # Eq. 2 (Apodotiko) | Eq. 1
    data_plane: str = "auto"             # training-input transport
    #                                      (device | host | auto)
    control_plane: str = "auto"          # fleet-state backing
    #                                      (columnar | object | auto)
    fault_profile: str = "auto"          # fault schedule (repro.faas.faults)
    #                                      (auto = REPRO_FAULTS env, "" off)
    traffic_profile: str = "auto"        # open-loop traffic (repro.traffic)
    #                                      (auto = REPRO_TRAFFIC env, "" off)
    mesh: str = "auto"                   # device mesh (repro.sharding.flmesh)
    #                                      (auto = REPRO_MESH env, 1x1 off)
    overrides: Tuple[Tuple[str, Any], ...] = ()  # extra FLConfig fields

    @property
    def key(self) -> str:
        ov = ";".join(f"{k}={v}" for k, v in self.overrides)
        dp = "" if self.data_plane == "auto" else f"/dp={self.data_plane}"
        cp = ("" if self.control_plane == "auto"
              else f"/ctl={self.control_plane}")
        fp = ("" if self.fault_profile == "auto"
              else f"/faults={self.fault_profile or 'none'}")
        tp = ("" if self.traffic_profile == "auto"
              else f"/traffic={self.traffic_profile or 'none'}")
        ms = "" if self.mesh == "auto" else f"/mesh={self.mesh}"
        return (f"{self.dataset}/{self.scenario}/{self.strategy}"
                f"/cr={self.concurrency_ratio:g}/{self.staleness_fn}"
                f"/seed={self.seed}" + dp + cp + fp + tp + ms
                + (f"/{ov}" if ov else ""))

    @property
    def group(self) -> tuple:
        """Comparison group: strategies within one group share a baseline
        (FedAvg) for speedup / cold-start / cost ratios. The data and
        control planes are group axes: a device/columnar cell must be
        ratioed against the matching-plane FedAvg, never silently against
        another plane's. Likewise the fault profile: a chaos cell's
        speedup is measured against the FedAvg that suffered the same
        schedule. And the traffic profile: under open-loop load, ratios
        compare runs that faced the same arrival process. The mesh is a
        group axis too: sharded cells ratio against the same-mesh
        baseline."""
        return (self.dataset, self.scenario, self.seed, self.data_plane,
                self.control_plane, self.fault_profile,
                self.traffic_profile, self.mesh, self.overrides)


@dataclass(frozen=True)
class SweepScale:
    """Sweep-wide scale knobs, shared by every cell (DESIGN.md §8)."""
    n_clients: int = 16
    clients_per_round: int = 8
    rounds: int = 48
    data_scale: float = 0.12        # fraction of the proxy dataset per sweep
    local_epochs: int = 3
    batch_size: int = 5
    sim_budget: Optional[float] = None  # None -> per-dataset default
    eval_every: int = 2
    data_seed: int = 0              # shared across cells: same partition


# Bench scale keeps the paper's *structure* (client mix, non-IID scheme, CR)
# at 1-core-container cost; paper scale is the real Table IV-VI grid (hours).
BENCH_SCALE = SweepScale()
PAPER_SCALE = SweepScale(n_clients=200, clients_per_round=100, rounds=500,
                         data_scale=0.5, local_epochs=5, batch_size=10)
SMOKE_SCALE = SweepScale(n_clients=8, clients_per_round=4, rounds=6,
                         data_scale=0.06, local_epochs=1, sim_budget=400.0)


@dataclass(frozen=True)
class SweepSpec:
    """A full comparison experiment: the cross product of its axes."""
    name: str
    datasets: Sequence[str] = ("mnist",)
    strategies: Sequence[str] = ("fedavg", "fedprox", "scaffold",
                                 "fedlesscan", "fedbuff", "apodotiko")
    seeds: Sequence[int] = (0,)
    scenarios: Sequence[str] = ("heterogeneous",)
    concurrency_ratios: Sequence[float] = (0.3,)
    staleness_fns: Sequence[str] = ("eq2",)
    data_planes: Sequence[str] = ("auto",)   # device/host transport ablation
    control_planes: Sequence[str] = ("auto",)  # columnar/object fleet state
    fault_profiles: Sequence[str] = ("auto",)  # chaos axis ("" = faults off)
    traffic_profiles: Sequence[str] = ("auto",)  # open-loop load axis
    meshes: Sequence[str] = ("auto",)  # device-mesh axis ("1x1" = off)
    scale: SweepScale = field(default=BENCH_SCALE)
    overrides: Tuple[Tuple[str, Any], ...] = ()

    @property
    def n_runs(self) -> int:
        return (len(self.datasets) * len(self.strategies) * len(self.seeds)
                * len(self.scenarios) * len(self.concurrency_ratios)
                * len(self.staleness_fns) * len(self.data_planes)
                * len(self.control_planes) * len(self.fault_profiles)
                * len(self.traffic_profiles) * len(self.meshes))


def expand_grid(spec: SweepSpec) -> list[RunSpec]:
    """Enumerate the grid in deterministic (dataset-major) order."""
    runs = [
        RunSpec(dataset=ds, strategy=strat, scenario=sc, seed=seed,
                concurrency_ratio=cr, staleness_fn=fn, data_plane=dp,
                control_plane=cp, fault_profile=fp, traffic_profile=tp,
                mesh=ms, overrides=tuple(spec.overrides))
        for ds, sc, seed, cr, fn, dp, cp, fp, tp, ms, strat in product(
            spec.datasets, spec.scenarios, spec.seeds,
            spec.concurrency_ratios, spec.staleness_fns, spec.data_planes,
            spec.control_planes, spec.fault_profiles,
            spec.traffic_profiles, spec.meshes, spec.strategies)
    ]
    keys = [r.key for r in runs]
    if len(set(keys)) != len(keys):
        dupes = sorted({k for k in keys if keys.count(k) > 1})
        raise ValueError(f"sweep {spec.name!r} has duplicate cells: {dupes}")
    return runs
