"""Declarative strategy-sweep engine (paper Tables IV-VI; DESIGN.md §6).

Expand a strategy x seed x config grid, execute the cells concurrently on
the serverless simulator with shared data/model/fleet setup, and derive the
paper's comparison columns (time-to-accuracy, speedup vs. FedAvg, cold
starts, cost)::

    from repro.sweep import get_preset, run_sweep
    table = run_sweep(get_preset("paper_mnist"))
    print(table.to_markdown())
"""
from repro.sweep.engine import run_sweep
from repro.sweep.grid import (
    BENCH_SCALE,
    PAPER_SCALE,
    SMOKE_SCALE,
    RunSpec,
    SweepScale,
    SweepSpec,
    expand_grid,
)
from repro.sweep.presets import (ALL_STRATEGIES, PRESETS,
                                 REACTIVE_STRATEGIES, get_preset)
from repro.sweep.results import SCHEMA, ResultTable
from repro.sweep.runner import LocalRunner

__all__ = [
    "ALL_STRATEGIES", "BENCH_SCALE", "LocalRunner", "PAPER_SCALE", "PRESETS",
    "REACTIVE_STRATEGIES", "ResultTable", "RunSpec", "SCHEMA", "SMOKE_SCALE",
    "SweepScale", "SweepSpec", "expand_grid", "get_preset", "run_sweep",
]
