"""Local in-process sweep executor.

``LocalRunner`` turns a ``RunSpec`` cell into one engine run (the
event-driven ``Scheduler`` by default, the legacy poll loop under
``REPRO_ENGINE=legacy`` — see ``repro.core.scheduler.build_engine``) with
real JAX local training on the serverless simulator. The expensive shared
setup — synthetic federated datasets, proxy models (and their jit caches),
hardware fleets — is built once per (dataset, scenario) and reused by every
cell, including concurrent ones: caches are populated under a lock and the
cached artifacts are read-only for the controllers (each run gets a *copy*
of the fleet list and its own Database).

Optional JSON result caching (``cache_dir``) keys each cell by its
``RunSpec.key`` + scale, so re-running a sweep composes tables without
re-training — the same mechanism ``benchmarks/common`` uses.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from dataclasses import asdict, replace
from typing import Optional

from repro.core.controller import FLConfig
from repro.core.scheduler import build_engine
from repro.sweep.grid import RunSpec, SweepScale

# Per-dataset simulated compute weight (1vCPU-seconds per optimizer step),
# calibrated so round durations land in the paper's Fig-1/Fig-3 ranges.
BASE_STEP_TIME = {"mnist": 0.8, "femnist": 4.0, "shakespeare": 6.0,
                  "speech": 1.5}
# Every strategy gets the SAME simulated wall-clock budget per dataset: the
# paper compares time-to-accuracy, not round counts — async strategies run
# many more, shorter rounds inside the same budget.
SIM_BUDGET = {"mnist": 2_000.0, "femnist": 8_000.0, "shakespeare": 12_000.0,
              "speech": 4_000.0}
OPTIMIZER = {"shakespeare": ("sgd", 0.5)}  # others: (adam, 1e-3)


class LocalRunner:
    """Callable run executor with shared, thread-safe setup caches.

    ``update_plane`` pins every cell to one client-update transport
    ("device" = flat-buffer UpdateStore, "blob" = legacy host pytrees) so a
    sweep compares strategies on identical plumbing; None keeps the
    controller default (REPRO_UPDATE_PLANE env var, then "device")."""

    def __init__(self, scale: SweepScale, *, fidelity: str = "proxy",
                 cache_dir: Optional[str] = None,
                 update_plane: Optional[str] = None):
        self.scale = scale
        self.fidelity = fidelity
        self.cache_dir = cache_dir
        self.update_plane = update_plane
        self._lock = threading.Lock()
        self._models: dict = {}
        self._data: dict = {}
        self._fleets: dict = {}

    # ------------------------------------------------------- shared setup
    def model(self, dataset: str):
        with self._lock:
            if dataset not in self._models:
                from repro.models.proxy_models import build_bench_model
                self._models[dataset] = build_bench_model(dataset,
                                                          self.fidelity)
            return self._models[dataset]

    def data(self, dataset: str):
        with self._lock:
            if dataset not in self._data:
                from repro.data.synthetic import make_federated_dataset
                self._data[dataset] = make_federated_dataset(
                    dataset, n_clients=self.scale.n_clients,
                    scale=self.scale.data_scale, seed=self.scale.data_seed,
                    fidelity=self.fidelity)
            return self._data[dataset]

    def fleet(self, scenario: str) -> list:
        with self._lock:
            if scenario not in self._fleets:
                self._fleets[scenario] = _build_fleet(scenario,
                                                      self.scale.n_clients)
            return self._fleets[scenario]

    def warm(self, runs: list[RunSpec]) -> None:
        """Build all shared artifacts up front (serially), so concurrent
        cells never duplicate the expensive setup work."""
        for ds in {r.dataset for r in runs}:
            self.model(ds)
            self.data(ds)
        for sc in {r.scenario for r in runs}:
            self.fleet(sc)

    # ------------------------------------------------------------- config
    def config(self, run: RunSpec) -> FLConfig:
        s = self.scale
        opt, lr = OPTIMIZER.get(run.dataset, ("adam", 1e-3))
        # paper batch sizes are 10/10/32/5; proxy client shards are ~8x
        # smaller, so batches shrink to keep steps-per-epoch comparable
        batch = 8 if run.dataset == "shakespeare" else s.batch_size
        cfg = FLConfig(
            n_clients=s.n_clients, clients_per_round=s.clients_per_round,
            rounds=s.rounds, strategy=run.strategy,
            concurrency_ratio=run.concurrency_ratio,
            local_epochs=s.local_epochs, batch_size=batch,
            optimizer=opt, lr=lr,
            base_step_time=BASE_STEP_TIME.get(run.dataset, 1.0),
            round_timeout=600.0, staleness_fn=run.staleness_fn,
            seed=run.seed, eval_every=s.eval_every,
            data_plane=run.data_plane,
            control_plane=run.control_plane,
            fault_profile=run.fault_profile,
            traffic_profile=run.traffic_profile,
            mesh=run.mesh,
            max_sim_time=s.sim_budget or SIM_BUDGET.get(run.dataset, 2_000.0))
        if self.update_plane:
            cfg = replace(cfg, update_plane=self.update_plane)
        if run.overrides:
            cfg = replace(cfg, **dict(run.overrides))
        return cfg

    # ---------------------------------------------------------------- run
    def _cache_path(self, run: RunSpec) -> Optional[str]:
        if not self.cache_dir:
            return None
        key_src = json.dumps([run.key, asdict(self.scale), self.fidelity,
                              self.update_plane], sort_keys=True)
        key = hashlib.sha1(key_src.encode()).hexdigest()[:16]
        return os.path.join(self.cache_dir, f"{key}.json")

    def __call__(self, run: RunSpec) -> dict:
        path = self._cache_path(run)
        if path and os.path.exists(path):
            with open(path) as f:
                return json.load(f)
        cfg = self.config(run)
        t0 = time.time()
        ctl = build_engine(cfg, self.model(run.dataset),
                           self.data(run.dataset),
                           list(self.fleet(run.scenario)))
        metrics = ctl.run()
        metrics["wall_s"] = time.time() - t0
        metrics["run_key"] = run.key
        metrics.pop("invocation_counts", None)  # bulky; bias is scalarized
        if path:
            os.makedirs(self.cache_dir, exist_ok=True)
            with open(path, "w") as f:
                json.dump(metrics, f)
        return metrics


def _build_fleet(scenario: str, n_clients: int) -> list:
    """Paper hardware scenarios: heterogeneous (IV-A3 65/25/10 mix),
    homogeneous (Fig 1 scenario 1), two-tier (Fig 1 scenario 2), and
    straggler (75% 1vCPU vs 25% GPU — the widest duration gap, used by the
    hedging presets)."""
    import numpy as np

    from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
    if scenario == "heterogeneous":
        return list(paper_fleet(n_clients))
    if scenario == "homogeneous":
        return [HARDWARE_PROFILES["cpu2"]] * n_clients
    if scenario == "two-tier":
        rng = np.random.default_rng(0)
        fleet = [HARDWARE_PROFILES["cpu1"]] * round(n_clients * 0.6) + \
                [HARDWARE_PROFILES["cpu2"]] * (n_clients - round(n_clients * 0.6))
        rng.shuffle(fleet)
        return fleet
    if scenario == "straggler":
        rng = np.random.default_rng(0)
        n_slow = round(n_clients * 0.75)
        fleet = [HARDWARE_PROFILES["cpu1"]] * n_slow + \
                [HARDWARE_PROFILES["gpu"]] * (n_clients - n_slow)
        rng.shuffle(fleet)
        return fleet
    raise ValueError(f"unknown hardware scenario {scenario!r}")
