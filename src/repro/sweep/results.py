"""Sweep result tables (the paper's Tables IV-VI shapes).

``ResultTable.from_runs`` pairs each grid cell with its metrics dict and
derives the paper's comparison columns within each ``RunSpec.group``
(dataset x scenario x seed):

  * ``target_acc`` — the time-to-accuracy target. The paper fixes absolute
    targets per dataset; proxy tasks plateau at strategy-dependent ceilings,
    so the table uses time-to-COMMON-accuracy: 95% of the weakest
    strategy's best accuracy in the group (every strategy reaches it).
  * ``speedup_vs_fedavg`` — Table IV's headline column (2.75x avg for
    Apodotiko): FedAvg's time-to-target / this strategy's.
  * ``cold_starts`` / ``cold_start_reduction_vs_fedavg`` — Table VI
    (the paper's 4x average reduction).
  * ``cost_usd`` / ``cost_vs_fedavg`` — Table V (FaaS $ cost model).

Rows keep grid order (deterministic regardless of execution concurrency);
failed cells keep their row with an ``error`` and null-valued metrics, so a
partial sweep still renders.
"""
from __future__ import annotations

import io
from typing import Optional, Sequence

from repro.sweep.grid import RunSpec

SCHEMA = (
    "sweep", "dataset", "scenario", "strategy", "seed", "concurrency_ratio",
    "staleness_fn", "data_plane", "fault_profile", "traffic_profile",
    "rounds", "target_acc",
    "time_to_target_s", "speedup_vs_fedavg", "final_acc", "best_acc",
    "sim_time_s", "cold_starts", "cold_start_ratio",
    "cold_start_reduction_vs_fedavg", "cost_usd", "cost_vs_fedavg",
    "p50_round_latency_s", "p99_round_latency_s", "cost_per_round_usd",
    "n_invocations", "n_failures", "n_retries", "n_quarantined", "error",
)

BASELINE = "fedavg"


def _best_acc(metrics: dict) -> float:
    return max((a for _, _, a in metrics.get("history", ())), default=0.0)


def _time_to(metrics: dict, target: float) -> Optional[float]:
    for t, _, acc in metrics.get("history", ()):
        if acc >= target:
            return t
    return None


def _ratio(num, den) -> Optional[float]:
    if num is None or den is None or not den:
        return None
    return round(num / den, 3)


class ResultTable:
    """Ordered rows (dicts over SCHEMA) with render/export helpers."""

    columns = SCHEMA

    def __init__(self, rows: list[dict]):
        self.rows = rows

    # ------------------------------------------------------- construction
    @classmethod
    def from_runs(cls, sweep_name: str, runs: Sequence[RunSpec],
                  metrics_list: Sequence[Optional[dict]],
                  target_quantile: float = 0.95) -> "ResultTable":
        assert len(runs) == len(metrics_list)
        ok = {i: m for i, m in enumerate(metrics_list)
              if m is not None and "error" not in m}
        # per-group common-accuracy target and FedAvg baselines
        groups: dict[tuple, list[int]] = {}
        for i, run in enumerate(runs):
            groups.setdefault(run.group, []).append(i)
        target: dict[tuple, float] = {}
        base: dict[tuple, dict] = {}
        for g, idxs in groups.items():
            # runs that never completed an eval (empty history — e.g. the
            # first round blew the sim budget) carry no accuracy signal;
            # letting their best=0 into min() would drag the common target
            # to 0 and make every time_to_target a first-eval timestamp
            bests = [_best_acc(ok[i]) for i in idxs
                     if i in ok and ok[i].get("history")]
            target[g] = round(target_quantile * min(bests), 4) if bests else 0.0
            for i in idxs:
                if i in ok and runs[i].strategy == BASELINE:
                    base[g] = ok[i]

        rows = []
        for i, run in enumerate(runs):
            row = dict.fromkeys(SCHEMA)
            row.update(sweep=sweep_name, dataset=run.dataset,
                       scenario=run.scenario, strategy=run.strategy,
                       seed=run.seed, concurrency_ratio=run.concurrency_ratio,
                       staleness_fn=run.staleness_fn,
                       data_plane=run.data_plane,
                       fault_profile=run.fault_profile,
                       traffic_profile=run.traffic_profile)
            m = metrics_list[i]
            if m is None or "error" in m:
                row["error"] = (m or {}).get("error", "missing")
                rows.append(row)
                continue
            g = run.group
            tgt = target[g]
            t = _time_to(m, tgt)
            bm = base.get(g)
            bt = _time_to(bm, tgt) if bm else None
            n_inv = m.get("n_invocations", 0)
            cs_ratio = m.get("cold_start_ratio")
            cs = (None if cs_ratio is None
                  else int(round(cs_ratio * n_inv)))
            b_cs = (None if bm is None else
                    int(round(bm.get("cold_start_ratio", 0.0)
                              * bm.get("n_invocations", 0))))
            row.update(
                rounds=m.get("rounds"),
                target_acc=tgt,
                time_to_target_s=None if t is None else round(t, 1),
                speedup_vs_fedavg=_ratio(bt, t),
                final_acc=round(m.get("final_accuracy", 0.0), 4),
                best_acc=round(_best_acc(m), 4),
                sim_time_s=round(m.get("total_time", 0.0), 1),
                cold_starts=cs,
                cold_start_ratio=(None if cs_ratio is None
                                  else round(cs_ratio, 4)),
                cold_start_reduction_vs_fedavg=_ratio(b_cs, cs),
                cost_usd=round(m.get("total_cost_usd", 0.0), 4),
                cost_vs_fedavg=_ratio(m.get("total_cost_usd"),
                                      bm.get("total_cost_usd") if bm else None),
                n_invocations=n_inv,
                n_failures=m.get("n_failures"),
                n_retries=m.get("n_retries"),
                n_quarantined=m.get("n_quarantined"),
                # SLO layer (DESIGN.md §13): tail latency + unit economics
                p50_round_latency_s=(
                    None if m.get("p50_round_latency_s") is None
                    else round(m["p50_round_latency_s"], 1)),
                p99_round_latency_s=(
                    None if m.get("p99_round_latency_s") is None
                    else round(m["p99_round_latency_s"], 1)),
                cost_per_round_usd=(
                    None if m.get("cost_per_round_usd") is None
                    else round(m["cost_per_round_usd"], 5)))
            rows.append(row)
        return cls(rows)

    # ------------------------------------------------------------ queries
    def select(self, **match) -> "ResultTable":
        return ResultTable([r for r in self.rows
                            if all(r.get(k) == v for k, v in match.items())])

    def mean_speedup(self, strategy: str) -> Optional[float]:
        vals = [r["speedup_vs_fedavg"] for r in self.rows
                if r["strategy"] == strategy
                and r["speedup_vs_fedavg"] is not None]
        return round(sum(vals) / len(vals), 3) if vals else None

    # ----------------------------------------------------------- renderers
    def to_markdown(self, columns: Optional[Sequence[str]] = None) -> str:
        cols = list(columns or (c for c in SCHEMA if c != "error"))
        cells = [[_fmt(r.get(c)) for c in cols] for r in self.rows]
        widths = [max(len(c), *(len(row[j]) for row in cells)) if cells
                  else len(c) for j, c in enumerate(cols)]
        out = io.StringIO()
        out.write("| " + " | ".join(c.ljust(w) for c, w in zip(cols, widths))
                  + " |\n")
        out.write("|" + "|".join("-" * (w + 2) for w in widths) + "|\n")
        for row in cells:
            out.write("| " + " | ".join(v.ljust(w)
                                        for v, w in zip(row, widths)) + " |\n")
        return out.getvalue()

    def to_csv(self, columns: Optional[Sequence[str]] = None) -> str:
        cols = list(columns or SCHEMA)
        lines = [",".join(cols)]
        for r in self.rows:
            lines.append(",".join(_fmt(r.get(c)) for c in cols))
        return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:g}"
    return str(v)
