"""Staleness weighting functions (paper §III-B, Eq. 1 and Eq. 2).

``t_i`` is the round a client's local model was trained against; ``T`` is the
round being aggregated. Eq. 1 (FedLesScan) scales by t_i/T, which makes the
weight of one-round-late updates *grow* with T and is inconsistent along
equal-staleness diagonals (paper Fig. 2a). Eq. 2 (adopted from FedAsync)
depends only on the staleness T - t_i, so Apodotiko uses it.
"""
from __future__ import annotations

import numpy as np


def eq1_fedlesscan(t_i: float, T: float) -> float:
    if T <= 0:
        return 1.0
    return float(t_i) / float(T)


def eq2_apodotiko(t_i: float, T: float) -> float:
    staleness = max(float(T) - float(t_i), 0.0)
    return float(1.0 / np.sqrt(staleness + 1.0))


STALENESS_FNS = {"eq1": eq1_fedlesscan, "eq2": eq2_apodotiko}
