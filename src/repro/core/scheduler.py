"""Event-driven round scheduler: the reactive replacement for the
poll-based ``Controller.run`` loop (DESIGN.md §7).

The ``Scheduler`` owns the same :class:`~repro.core.services.FLRuntime`
substrate as the legacy controller but drives it reactively: every
simulation occurrence — an invocation completing or failing, a timer
elapsing, the platform quiescing — is dispatched as a typed protocol
event to a :class:`~repro.core.protocol.ReactivePolicy`, and the returned
actions (``Invoke``/``Aggregate``/``SetTimer``/``CancelInvocation``/
``Hedge``/``Retry``/``Quarantine``/``EndRun``) are executed against the
runtime services. All six
legacy strategies run unchanged through ``LegacyStrategyAdapter`` with
bit-identical round traces (tests/test_golden_trace.py); the natively
reactive policies (``apodotiko-hedge``, ``apodotiko-adaptive``) express
mid-round behaviour the poll loop could not.

Timers live in a separate min-heap, not the platform event heap, so a
policy's armed-but-unreached deadlines never perturb simulated time: they
are dropped when their round closes, and — for legacy-compat policies
(``fire_timers_on_drain=False``) — never fire once the platform has no
future events, exactly like a drained ``run_until`` that never reached
its ``max_time``.

Entry points::

    sched = Scheduler(cfg, model, data, fleet)      # cfg.strategy names a
    metrics = sched.run()                           # legacy strategy or a
                                                    # reactive policy

    ctl = build_engine(cfg, model, data, fleet)     # engine-aware factory
                                                    # (cfg.engine / REPRO_ENGINE)
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.controller import Controller
from repro.core.database import Database
from repro.core.protocol import (Action, Aggregate, CancelInvocation,
                                 DatabaseView, EndRun, Event, Hedge, Invoke,
                                 LoopDrained, Quarantine, ReactivePolicy,
                                 Retry, RoundStarted, SetTimer, TimerFired)
from repro.core.recovery import RecoveryPolicy, recovery_enabled
from repro.core.services import (FLConfig, FLRuntime, Inflight, RoundLog,
                                 resolve_engine, resolve_megastep,
                                 strategy_config)
from repro.core.strategies.reactive import is_reactive, make_policy

#: timer-heap round key for runtime timers (invocation timeouts, retries
#: armed against the *current* round use ``db.round`` instead). The huge
#: sentinel keeps ``_peek_timer``'s round-closed purge from ever dropping
#: a timeout whose invocation outlives its round.
_RUNTIME_ROUND = 1 << 62


@dataclass
class _RetryTag:
    """Timer payload for a pending backoff re-invocation."""

    client_id: int
    t_failed: float     # when the failure fired (retry-latency metric)


class Scheduler(FLRuntime):
    """Reactive round driver: dispatches protocol events to a policy and
    executes its actions (see module docstring)."""

    engine_name = "scheduler"

    def __init__(self, cfg: FLConfig, model, data, fleet, *,
                 policy: Optional[ReactivePolicy] = None,
                 db: Optional[Database] = None, init_params=None):
        if policy is None:
            policy = make_policy(cfg.strategy, strategy_config(cfg))
        if recovery_enabled(cfg) and not isinstance(policy, RecoveryPolicy):
            policy = RecoveryPolicy(policy, cfg)
        self.policy = policy
        super().__init__(cfg, model, data, fleet, db=db,
                         init_params=init_params, strategy=policy.strategy)
        self.view = DatabaseView(self)
        self._timers: list[tuple] = []   # (time, seq, round, tag)
        self._timer_seq = itertools.count()
        self._t0 = self.loop.now
        self._done = False
        self._invoked_this_round = False
        self._progress: Optional[Callable[[RoundLog], None]] = None
        self.n_events = 0               # protocol events dispatched
        self.n_coalesced = 0            # actions merged into batched dispatches
        # fused-round megastep (core.megastep): opportunistic lowering of
        # quiescent-round runs into one jitted lax.scan
        self.megastep = resolve_megastep(cfg.megastep)
        self.megastep_rounds = 0        # rounds executed inside fused scans
        self.megastep_scans = 0         # fused scans entered
        self.megastep_fallback_reason = "unattempted"

    # -------------------------------------------------------------------- run
    def run(self, progress: Optional[Callable[[RoundLog], None]] = None):
        cfg = self.cfg
        self._progress = progress
        self._done = False
        # NOTE: self._acc is NOT reset here — it carries the last
        # evaluated accuracy across a durable resume (eval_every > 1)
        if self.db.round >= cfg.rounds or self.loop.now >= cfg.max_sim_time:
            if self.durability is not None:
                self.durability.finish()
            return self.metrics()
        self._open_round()
        drained = 0
        while not self._done:
            if self._pump_one():
                drained = 0
                continue
            if (not self._invoked_this_round and not self.inflight
                    and not self.db.any_idle()
                    and self._traffic_fast_forward()):
                # stalled for lack of clients (not policy inaction): under
                # open-loop traffic the clock jumps to the next arrival
                # boundary and the round re-opens against the new fleet —
                # the legacy loop's drained re-poll, not an EndRun
                self._t0 = self.loop.now
                self._dispatch(RoundStarted(t=self.loop.now,
                                            round=self.db.round))
                drained = 0
                continue
            drained += 1
            if drained > 1:
                break               # policy made no progress on drain
            self._dispatch(LoopDrained(t=self.loop.now))
        if self.durability is not None:
            self.durability.finish()
        return self.metrics()

    # ------------------------------------------------------------------- pump
    def _peek_timer(self) -> Optional[float]:
        while self._timers:
            t, _, round_, tag = self._timers[0]
            if round_ < self.db.round:
                heapq.heappop(self._timers)     # stale: its round closed
            elif isinstance(tag, Inflight) and tag.done:
                heapq.heappop(self._timers)     # invocation already settled
            else:
                return t
        return None

    def _pump_one(self) -> bool:
        """Advance simulated time by one occurrence — the earliest of the
        next platform event and the next timer (events win ties, matching
        the poll loop's pop-then-check-deadline order: a result landing at
        exactly the timeout instant counts as completed). Returns False
        when quiescent."""
        t_ev = self.loop.peek()
        t_tm = self._peek_timer()
        # runtime timers (timeouts/retries — non-str tags) are scheduler
        # machinery, not policy deadlines: they fire on a drained loop
        # regardless of the policy's legacy-compat fire_timers_on_drain
        runtime_head = bool(self._timers
                            and not isinstance(self._timers[0][3], str))
        fire_timer = t_tm is not None and (
            (t_ev is None and (self.policy.fire_timers_on_drain
                               or runtime_head))
            or (t_ev is not None and t_tm < t_ev))
        if fire_timer:
            t, _, round_, tag = heapq.heappop(self._timers)
            if isinstance(tag, Inflight):
                # never move the clock backward for runtime timers (a
                # budget barrier may already have pushed now past t)
                self.loop.now = max(self.loop.now, t)
                self.timeout_invocation(tag)
                return True
            if isinstance(tag, _RetryTag):
                self.loop.now = max(self.loop.now, t)
                self._fire_retry(tag)
                return True
            # the clock may move backward here: a "budget" barrier armed
            # past max_sim_time replays run_until's ``now = max_time``
            self.loop.now = t
            self._dispatch(TimerFired(t=t, round=round_, tag=tag))
            return True
        if t_ev is None:
            return False
        return self.loop.step()     # completion callbacks _emit protocol events

    # ----------------------------------------------------------- recovery
    def _launch(self, cid: int, round_: int, steps: float, payload,
                n_samples: int, loss: float, *, is_hedge: bool = False
                ) -> Inflight:
        inv = super()._launch(cid, round_, steps, payload, n_samples, loss,
                              is_hedge=is_hedge)
        if self.cfg.invocation_timeout > 0:
            heapq.heappush(self._timers,
                           (self.loop.now + self.cfg.invocation_timeout,
                            next(self._timer_seq), _RUNTIME_ROUND, inv))
        return inv

    def _fire_retry(self, tag: _RetryTag) -> None:
        """A backoff timer elapsed: re-invoke the client against the
        *current* global model — unless it left the fleet, got quarantined
        meanwhile, or is already busy (a hedge or manual re-invoke won the
        race)."""
        cid = tag.client_id
        if (not self.db.has_client(cid) or self.db.is_quarantined(cid)
                or any(not i.done for i in self.inflight.get(cid, ()))):
            return
        self.n_retries += 1
        self.retry_latency_s += self.loop.now - tag.t_failed
        self.invoke_round(self.db.round, [cid], reset_completed=False)

    # --------------------------------------------------------------- dispatch
    def _emit(self, event: Event) -> None:
        self._dispatch(event)

    def _dispatch(self, event: Event) -> None:
        # write-ahead: the journal records the occurrence before any of
        # its actions execute (repro.durability, DESIGN.md §14)
        if self.durability is not None:
            self.durability.record_event(event)
        self.n_events += 1
        actions = self.policy.on_event(event, self.view)
        for action in self._coalesce(actions or ()):
            self._execute(action)

    def _coalesce(self, actions) -> list[Action]:
        """Merge same-instant cohort work: all ``Invoke`` actions a policy
        emits in one dispatch pump collapse into a single batched cohort
        dispatch (one padded jit call instead of several solo ones, each
        padded to the bucket floor), and likewise all ``Hedge`` actions.
        ``Aggregate``/``EndRun``/``CancelInvocation`` are barriers: they
        change what a later ``Invoke`` would mean (a new global model, a
        cancelled client), so merging never crosses them. ``Invoke`` and
        ``Hedge`` are also barriers for *each other*: merging a ``Hedge``
        backward across an ``Invoke`` (or vice versa) would reorder a
        hedge relative to the invocation it targets, so interleaved
        sequences keep their relative order and only same-kind runs
        separated by neutral actions (e.g. ``SetTimer``) merge. Duplicate
        client ids keep their first occurrence."""
        out: list[Action] = []
        inv_at: Optional[int] = None
        hedge_at: Optional[int] = None
        for a in actions:
            if isinstance(a, Invoke):
                hedge_at = None
                if inv_at is None:
                    inv_at = len(out)
                    out.append(a)
                else:
                    prev = out[inv_at]
                    extra = tuple(c for c in a.clients
                                  if c not in prev.clients)
                    out[inv_at] = Invoke(prev.clients + extra)
                    self.n_coalesced += 1
            elif isinstance(a, Hedge):
                inv_at = None
                if hedge_at is None:
                    hedge_at = len(out)
                    out.append(a)
                else:
                    prev = out[hedge_at]
                    extra = tuple(c for c in a.clients
                                  if c not in prev.clients)
                    out[hedge_at] = Hedge(prev.clients + extra)
                    self.n_coalesced += 1
            else:
                out.append(a)
                if isinstance(a, (Aggregate, EndRun, CancelInvocation)):
                    inv_at = hedge_at = None
        return out

    def _execute(self, action: Action) -> None:
        if isinstance(action, Invoke):
            selection = [c for c in action.clients if self.db.has_client(c)]
            if selection:
                self.invoke_round(self.db.round, selection,
                                  reset_completed=not self._invoked_this_round)
                self._invoked_this_round = True
        elif isinstance(action, Hedge):
            self.hedge_invocations(list(action.clients))
        elif isinstance(action, CancelInvocation):
            self.cancel_client(action.client_id)
        elif isinstance(action, SetTimer):
            heapq.heappush(self._timers,
                           (self.loop.now + action.delay,
                            next(self._timer_seq), self.db.round, action.tag))
        elif isinstance(action, Retry):
            # round-scoped (pushed with db.round): a pending retry is
            # abandoned when its round closes
            heapq.heappush(self._timers,
                           (self.loop.now + action.delay,
                            next(self._timer_seq), self.db.round,
                            _RetryTag(action.client_id, self.loop.now)))
        elif isinstance(action, Quarantine):
            self.db.quarantine(action.client_id, action.until_round)
            self.n_quarantined += 1
        elif isinstance(action, Aggregate):
            self._close_round()
        elif isinstance(action, EndRun):
            self._done = True
        else:
            raise TypeError(f"unknown action {action!r}")

    # ------------------------------------------------------------- round flow
    def _open_round(self) -> None:
        # Fused fast path: before handing the round to the policy, try to
        # lower a run of provably quiescent rounds into one jitted scan
        # (core.megastep). The loop re-checks after each scan because the
        # completions it replays extend keep-warm windows, which can make
        # further rounds eligible. Any ineligibility falls through to the
        # event-driven engine — the bit-exact oracle — for this round.
        # fresh-round open is the only point where traffic shifts
        # membership (the legacy loop mirrors this at its loop top), so
        # mid-round adapter re-selects see a stable fleet on both engines
        self._apply_due_traffic()
        if self.megastep == "fused":
            from repro.core.megastep import try_megastep
            while try_megastep(self):
                if (self.db.round >= self.cfg.rounds
                        or self.loop.now >= self.cfg.max_sim_time):
                    self._done = True
                    return
                # the fused horizon may have crossed segment boundaries
                # (it stops short of the next *unapplied* one — _plan)
                self._apply_due_traffic()
        self._t0 = self.loop.now
        self._invoked_this_round = False
        self._dispatch(RoundStarted(t=self.loop.now, round=self.db.round))

    def _close_round(self) -> None:
        """Execute ``Aggregate``: aggregate, evaluate, log, advance the
        round, and either terminate or dispatch the next ``RoundStarted``
        (the legacy loop's tail, round for round)."""
        cfg = self.cfg
        round_ = self.db.round
        n_agg, n_stale, _ = self.aggregate_round(round_)
        if n_agg:
            if cfg.eval_every and round_ % cfg.eval_every == 0:
                self._acc = self.evaluate()
            log = RoundLog(round=round_, t_start=self._t0,
                           t_end=self.loop.now, accuracy=self._acc,
                           n_aggregated=n_agg, n_stale=n_stale,
                           mean_loss=0.0)
            self.history.append(log)
            if self._progress:
                self._progress(log)
        self.db.round = round_ + 1
        self._durability_round_closed()
        if n_agg:
            if cfg.checkpoint_every and self.db.round % cfg.checkpoint_every == 0:
                self.checkpoint()
            if cfg.target_accuracy and self._acc >= cfg.target_accuracy:
                self._done = True
                return
        if self.db.round >= cfg.rounds or self.loop.now >= cfg.max_sim_time:
            self._done = True
            return
        self._open_round()

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        m = super().metrics()
        m["strategy"] = self.policy.name
        m["n_events"] = self.n_events
        m["n_coalesced"] = self.n_coalesced
        m["megastep"] = self.megastep
        m["megastep_rounds"] = self.megastep_rounds
        m["megastep_scans"] = self.megastep_scans
        m["megastep_fallback_reason"] = self.megastep_fallback_reason
        m.update(self.policy.metrics())
        return m


def build_engine(cfg: FLConfig, model, data, fleet, **kwargs):
    """Engine-aware factory: ``cfg.engine`` (> ``REPRO_ENGINE`` >
    'scheduler') picks the round driver. Reactive strategy names require
    the scheduler; everything else runs on either."""
    engine = resolve_engine(cfg.engine)
    if engine == "legacy":
        if is_reactive(cfg.strategy):
            raise ValueError(
                f"strategy {cfg.strategy!r} is a reactive policy; the "
                f"legacy poll loop cannot drive it — use engine='scheduler'")
        return Controller(cfg, model, data, fleet, **kwargs)
    return Scheduler(cfg, model, data, fleet, **kwargs)
