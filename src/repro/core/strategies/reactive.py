"""Reactive policies for the event-driven scheduler (DESIGN.md §7).

``LegacyStrategyAdapter`` translates the old poll-loop query contract
(``select`` / ``results_needed`` / ``usable`` + the sync round timeout)
into the typed event->action protocol, reproducing the legacy
``Controller.run`` loop *bit-exactly* — selections, aggregation round
boundaries, simulated timestamps, accuracies (tests/test_golden_trace.py).
Its state machine mirrors the loop's four waits:

  phase "selecting"        <- run_until(any client idle)        [W1]
  phase "gated" (async)    <- run_until(pending >= CR gate)     [W2]
  phase "gated" (sync)     <- run_until(all completed, deadline) [W3]
  phase "awaiting_usable"  <- run_until(any usable result)      [W4]

with the loop's ``max_time`` barriers expressed as timers ("deadline",
"budget") and its drained-heap fallthroughs handled on ``LoopDrained``.

The two native policies prove the protocol buys capability the poll loop
could not express:

* ``apodotiko-hedge`` — Apodotiko's CR-gated rounds, plus straggler
  hedging: the moment the CR fraction lands, the slowest outstanding
  invocations are speculatively re-invoked on their still-warm containers
  (no cold start, a fresh performance draw), racing the originals. This
  attacks exactly the cold-start + straggler tail the paper measures.
* ``apodotiko-adaptive`` — adjusts CR between rounds from the observed
  result-arrival dispersion: a wide landing window (stragglers dominate)
  lowers CR so rounds stop waiting; a tight window raises it so each
  aggregation uses more results.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.protocol import (Action, Aggregate, ClientJoined, ClientLeft,
                                 DatabaseView, EndRun, Event, Hedge, Invoke,
                                 InvocationFailed, LoopDrained, ReactivePolicy,
                                 ResultLanded, RoundStarted, SetTimer,
                                 TimerFired)
from repro.core.strategies.base import (STRATEGIES, Strategy, StrategyConfig,
                                        build_strategy)


class LegacyStrategyAdapter(ReactivePolicy):
    """Adapts a passive ``Strategy`` to the reactive protocol (see module
    docstring for the phase <-> poll-loop wait correspondence)."""

    fire_timers_on_drain = False  # a drained run_until never reached its
    #                               deadline; reproduce that exactly

    def __init__(self, strategy: Strategy, name: Optional[str] = None):
        self.strategy = strategy
        self.name = name or strategy.name
        self._phase = "idle"
        self._selection: set[int] = set()

    # -- durability (coordinated snapshots, DESIGN.md §14) ----------------
    def state_dict(self) -> dict:
        s = super().state_dict()
        s["phase"] = self._phase
        s["selection"] = sorted(self._selection)
        return s

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        self._phase = state["phase"]
        self._selection = set(int(c) for c in state["selection"])

    # ------------------------------------------------------------- helpers
    def _gate_satisfied(self, view: DatabaseView) -> bool:
        s = self.strategy
        if s.is_async:
            return len(view.pending_results()) >= s.results_needed()
        q = getattr(s.cfg, "quorum_fraction", 1.0)
        if q >= 1.0:
            # the legacy full-cohort gate, kept verbatim for bit-identity
            return self._selection <= view.completed_this_round
        # graceful degradation (DESIGN.md §12): close once a quorum of
        # the selected cohort has landed; the stragglers' results arrive
        # too late and are simply unusable (sync usable() wants round == T)
        need = max(int(np.ceil(q * len(self._selection))), 1)
        return len(self._selection & view.completed_this_round) >= need

    def _open(self, view: DatabaseView) -> list[Action]:
        """Round start (or re-select once a client went idle)."""
        s = self.strategy
        selection = s.select(view.db, view.round)
        if not selection:
            self._phase = "selecting"
            return []
        self._selection = set(selection)
        self._phase = "gated"
        acts: list[Action] = [Invoke(tuple(selection))]
        if s.is_async:
            # the sim-budget barrier of run_until(max_time=max_sim_time)
            acts.append(SetTimer(view.max_sim_time - view.now, "budget"))
            if self._gate_satisfied(view):
                # stale pending results already satisfy the CR gate:
                # aggregate immediately (legacy checks before any pop)
                self._phase = "closing"
                acts.append(Aggregate())
        else:
            acts.append(SetTimer(s.cfg.round_timeout, "deadline"))
        return acts

    def _close(self) -> list[Action]:
        self._phase = "closing"
        return [Aggregate()]

    def _budget_or_drain(self, view: DatabaseView,
                         drained: bool) -> list[Action]:
        """The loop's run_until returned False: either the heap drained or
        a max_time barrier (deadline/budget) was hit."""
        if self._phase == "selecting":
            # W1 has no barrier; only a drain ends the run
            return [EndRun()] if drained else []
        if self._phase == "gated" and self.strategy.is_async:
            # W2: aggregate whatever is pending; nothing at all -> stop
            return self._close() if view.pending_results() else [EndRun()]
        if self._phase in ("gated", "awaiting_usable"):
            # W3/W4: close the round with whatever is usable (possibly
            # nothing — a zero-aggregation round advances the counter)
            return self._close()
        return []

    # ------------------------------------------------------------ dispatch
    def on_event(self, ev: Event, view: DatabaseView) -> Sequence[Action]:
        s = self.strategy
        if isinstance(ev, RoundStarted):
            return self._open(view)
        if isinstance(ev, (ResultLanded, InvocationFailed)):
            if self._phase == "selecting":
                if view.any_idle():
                    return self._open(view)
                return []
            if self._phase == "gated":
                if isinstance(ev, ResultLanded) and self._gate_satisfied(view):
                    return self._close()
                return []
            if self._phase == "awaiting_usable":
                if isinstance(ev, ResultLanded) and s.usable(ev.result,
                                                             view.round):
                    return self._close()
                return []
            return []
        if isinstance(ev, TimerFired):
            if ev.round != view.round:
                return []           # stale timer from a closed round
            if ev.tag == "deadline" and self._phase == "gated":
                # sync deadline: aggregate if anything is usable, else wait
                # for the first usable result under the sim budget
                if any(s.usable(r, view.round)
                       for r in view.pending_results()):
                    return self._close()
                self._phase = "awaiting_usable"
                return [SetTimer(view.max_sim_time - view.now, "budget")]
            if ev.tag == "budget":
                return self._budget_or_drain(view, drained=False)
            return []
        if isinstance(ev, LoopDrained):
            return self._budget_or_drain(view, drained=True)
        if isinstance(ev, (ClientJoined, ClientLeft)):
            return []
        return []


class ApodotikoHedge(LegacyStrategyAdapter):
    """Apodotiko + straggler hedging at the CR gate (module docstring).

    Hedge targets are the un-hedged outstanding invocations (any round in
    the staleness window), slowest-expected first — ranked by the client's
    recent mean duration, unknown clients first (they are the likeliest
    cold stragglers) — capped at ``ceil(hedge_fraction x outstanding)``.
    """

    def __init__(self, cfg: StrategyConfig):
        super().__init__(build_strategy("apodotiko", cfg),
                         name="apodotiko-hedge")
        self.hedge_fraction = cfg.hedge_fraction

    def on_event(self, ev: Event, view: DatabaseView) -> Sequence[Action]:
        acts = list(super().on_event(ev, view))
        if any(isinstance(a, Aggregate) for a in acts):
            hedges = self._pick_hedges(view)
            if hedges:
                # hedge before the aggregate closes the round, so the
                # re-invocations are recorded against the round they rescue
                acts.insert(len(acts) - 1, Hedge(tuple(hedges)))
        return acts

    def _pick_hedges(self, view: DatabaseView) -> list[int]:
        cands = [iv for iv in view.outstanding()
                 if not iv.hedged and not iv.is_hedge]
        if not cands:
            return []
        k = max(1, int(np.ceil(self.hedge_fraction * len(cands))))

        def expected_slowness(iv):
            hist = view.recent_durations(iv.client_id, 5)
            expected = float(np.mean(hist)) if hist else float("inf")
            return (expected, view.now - iv.t_invoked)

        cands.sort(key=expected_slowness, reverse=True)
        return [iv.client_id for iv in cands[:k]]


class ApodotikoAdaptive(LegacyStrategyAdapter):
    """Apodotiko + between-round CR adaptation from result-arrival
    dispersion (module docstring). The adjusted CR feeds straight into the
    underlying strategy's ``results_needed`` for the next round."""

    CR_MIN, CR_MAX = 0.1, 0.9
    STEP = 0.2          # multiplicative CR adjustment per triggered round
    HIGH, LOW = 1.5, 0.6  # dispersion thresholds (landing-window / median)

    def __init__(self, cfg: StrategyConfig):
        super().__init__(build_strategy("apodotiko", cfg),
                         name="apodotiko-adaptive")
        self.cr_history: list[float] = [cfg.concurrency_ratio]

    def state_dict(self) -> dict:
        s = super().state_dict()
        s["cr_history"] = list(self.cr_history)
        return s

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        self.cr_history = list(state["cr_history"])

    def on_event(self, ev: Event, view: DatabaseView) -> Sequence[Action]:
        acts = super().on_event(ev, view)
        if any(isinstance(a, Aggregate) for a in acts):
            arrivals = sorted(r.t_available - view.round_start
                              for r in view.pending_results()
                              if r.round == view.round)
            self.strategy.cfg.concurrency_ratio = self.next_cr(arrivals)
        return acts

    def next_cr(self, arrivals: Sequence[float]) -> float:
        """Pure adjustment rule: dispersion = (last - first arrival) /
        median arrival of the results that filled this round's gate."""
        cr = self.strategy.cfg.concurrency_ratio
        if len(arrivals) >= 2:
            med = max(arrivals[len(arrivals) // 2], 1e-9)
            spread = (arrivals[-1] - arrivals[0]) / med
            if spread > self.HIGH:
                cr *= 1.0 - self.STEP   # stragglers dominate: wait for fewer
            elif spread < self.LOW:
                cr *= 1.0 + self.STEP   # tight landing: afford more results
        cr = float(min(self.CR_MAX, max(self.CR_MIN, cr)))
        self.cr_history.append(cr)
        return cr

    def metrics(self) -> dict:
        return {"cr_history": [round(c, 4) for c in self.cr_history]}


REACTIVE_POLICIES: dict[str, type] = {
    "apodotiko-hedge": ApodotikoHedge,
    "apodotiko-adaptive": ApodotikoAdaptive,
}


def is_reactive(name: str) -> bool:
    """True for natively-reactive policy names (scheduler-only)."""
    return name in REACTIVE_POLICIES


def make_policy(name: str, cfg: StrategyConfig) -> ReactivePolicy:
    """Build the reactive policy for a strategy name: native policies
    directly, legacy strategy names through the adapter."""
    if name in REACTIVE_POLICIES:
        return REACTIVE_POLICIES[name](cfg)
    if name in STRATEGIES:
        return LegacyStrategyAdapter(build_strategy(name, cfg))
    raise KeyError(
        f"unknown strategy {name!r}; legacy: {', '.join(sorted(STRATEGIES))}; "
        f"reactive: {', '.join(sorted(REACTIVE_POLICIES))}")
