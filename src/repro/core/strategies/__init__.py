from repro.core.strategies.base import STRATEGIES, Strategy, build_strategy  # noqa: F401
