"""FL training strategies: Apodotiko + the five baselines the paper
evaluates against (FedAvg, FedProx, SCAFFOLD, FedLesScan, FedBuff).

A strategy decides (a) which clients to invoke each round, (b) when the
controller may aggregate (sync with timeout / semi-async / async with a
concurrency-or-buffer ratio), (c) the aggregation weights for each available
result (cardinality x staleness damping), and (d) client-side training
modifications (proximal term, control variates).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.database import Database, ResultRecord
from repro.core.scoring import promotion_rate
from repro.core.selection import select_clients as apodotiko_select
from repro.core.staleness import eq1_fedlesscan, eq2_apodotiko


@dataclass
class StrategyConfig:
    """Strategy-facing slice of ``FLConfig`` (paper symbols noted inline)."""

    clients_per_round: int = 100   # clients invoked per round (paper: 100)
    concurrency_ratio: float = 0.3  # CR (Alg. 1 line 9): async strategies
    #                                  aggregate once ceil(CR x clientsPerRound)
    #                                  results land; doubles as FedBuff's
    #                                  buffer-size ratio. Fig. 6 sweeps it.
    adjustment_rate: float = 0.2   # rho (Alg. 3): booster adjustment step for
    #                                  the CEF-score probabilistic selection
    max_staleness: int = 5         # staleness cap (§III-B): accept results
    #                                  from at most this many previous rounds
    round_timeout: float = 300.0   # sync-strategy round deadline (sim-seconds)
    prox_mu: float = 0.01          # mu: FedProx proximal term coefficient
    staleness_fn: str = "eq2"      # "eq2" = 1/sqrt(T - t_i + 1) (Eq. 2) |
    #                                  "eq1" = t_i/T (Eq. 1, FedLesScan)
    hedge_fraction: float = 0.5    # apodotiko-hedge: fraction of outstanding
    #                                  invocations re-invoked at the CR gate
    quorum_fraction: float = 1.0   # graceful degradation (DESIGN.md §12):
    #                                  sync rounds close once this fraction
    #                                  of the cohort completed (1.0 = the
    #                                  legacy full-cohort gate, bit-exact)
    seed: int = 0                  # selection RNG seed


class Strategy:
    name = "base"
    is_async = False          # async aggregation (CR-triggered)
    semi_async = False        # FedLesScan: late updates used next round
    needs_scaffold = False
    prox_mu = 0.0

    def __init__(self, cfg: StrategyConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    # -- durability (coordinated snapshots, DESIGN.md §14) ---------------------
    def state_dict(self) -> dict:
        """The mutable strategy state a durable resume must restore: the
        selection RNG position plus ``cfg.concurrency_ratio`` (the one
        config field a policy mutates in place — apodotiko-adaptive)."""
        return {"rng": self.rng.bit_generator.state,
                "concurrency_ratio": self.cfg.concurrency_ratio}

    def load_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self.cfg.concurrency_ratio = state["concurrency_ratio"]

    # -- selection ------------------------------------------------------------
    def select(self, db: Database, round_: int) -> list[int]:
        """Default: uniform random among idle clients (FedAvg/FedProx/etc.).
        ``idle_client_ids`` yields the identical registration-ordered list
        on both control planes, so the shared ``rng.choice`` draw keeps
        selections bit-identical across planes."""
        idle = db.idle_client_ids()
        n = min(self.cfg.clients_per_round, len(idle))
        picks = self.rng.choice(len(idle), size=n, replace=False)
        return [idle[i] for i in picks]

    # -- aggregation gating -----------------------------------------------------
    def results_needed(self) -> int:
        if self.is_async:
            return max(1, int(np.ceil(self.cfg.clients_per_round
                                      * self.cfg.concurrency_ratio)))
        return self.cfg.clients_per_round

    # -- aggregation weights ------------------------------------------------------
    def staleness(self, t_i: int, T: int) -> float:
        return 1.0  # sync strategies only see current-round results

    def result_weight(self, rec: ResultRecord, T: int) -> float:
        return self.staleness(rec.round, T) * rec.n_samples

    def usable(self, rec: ResultRecord, T: int) -> bool:
        """May this un-aggregated result enter round T's aggregation?"""
        if self.is_async or self.semi_async:
            return T - rec.round <= self.cfg.max_staleness
        return rec.round == T


class FedAvg(Strategy):
    name = "fedavg"


class FedProx(Strategy):
    name = "fedprox"

    def __init__(self, cfg):
        super().__init__(cfg)
        self.prox_mu = cfg.prox_mu


class Scaffold(Strategy):
    name = "scaffold"
    needs_scaffold = True


class FedLesScan(Strategy):
    """Semi-asynchronous: clustering-based selection on past training
    durations + Eq. 1 staleness for late updates (the prior SoTA the paper
    improves on)."""

    name = "fedlesscan"
    semi_async = True

    def staleness(self, t_i: int, T: int) -> float:
        return eq1_fedlesscan(t_i, T)

    def select(self, db: Database, round_: int) -> list[int]:
        cfg = self.cfg
        if db.columnar:
            # vectorized twin: identical candidate order, identical means
            # (FleetStore.recent_mean replays np.mean's summation order),
            # identical rng.choice draws -> bit-identical tiers
            fleet = db.fleet
            idle = fleet.idle_slots(db.round)   # quarantine-aware
            ever = fleet.n_invocations[idle] > 0
            unv, inv = idle[~ever], idle[ever]
            if len(unv) >= cfg.clients_per_round:
                picks = self.rng.choice(len(unv), cfg.clients_per_round,
                                        replace=False)
                return fleet.ids[unv[picks]].tolist()
            selection = fleet.ids[unv].tolist()
            if not len(inv):
                return selection
            means = fleet.recent_mean(inv, 5)
            inv_ids = fleet.ids[inv].tolist()
        else:
            clients = list(db.clients.values())
            idle = [c for c in clients if c.status == "idle"
                    and c.quarantined_until <= db.round]
            uninvoked = [c for c in idle if not c.ever_invoked]
            if len(uninvoked) >= cfg.clients_per_round:
                picks = self.rng.choice(len(uninvoked), cfg.clients_per_round,
                                        replace=False)
                return [uninvoked[i].client_id for i in picks]
            selection = [c.client_id for c in uninvoked]
            invoked = [c for c in idle if c.ever_invoked]
            if not invoked:
                return selection
            # cluster invoked clients by mean duration (1-D k-means, k=3)
            means = np.array([np.mean(c.durations[-5:]) if c.durations else 0.0
                              for c in invoked])
            inv_ids = [c.client_id for c in invoked]
        order = np.argsort(means)
        k = 3 if len(inv_ids) >= 3 else 1
        clusters = np.array_split(order, k)  # duration-sorted tiers
        need = cfg.clients_per_round - len(selection)
        for cl in clusters:  # fastest tier first; stragglers fill remainder
            take = min(need, len(cl))
            picks = self.rng.choice(len(cl), take, replace=False)
            selection += [inv_ids[cl[i]] for i in picks]
            need -= take
            if need <= 0:
                break
        return selection


class FedBuff(Strategy):
    """Asynchronous buffered aggregation with *random* selection (the paper's
    closest async baseline; production at Meta). Selection is the base
    uniform-idle draw."""

    name = "fedbuff"
    is_async = True

    def staleness(self, t_i: int, T: int) -> float:
        return eq2_apodotiko(t_i, T)  # 1/sqrt(1+staleness), as in FedBuff


class Apodotiko(Strategy):
    """The paper's strategy: CEF scoring + probabilistic selection +
    CR-gated asynchronous aggregation with Eq. 2 staleness damping."""

    name = "apodotiko"
    is_async = True

    def staleness(self, t_i: int, T: int) -> float:
        if self.cfg.staleness_fn == "eq1":
            return eq1_fedlesscan(t_i, T)
        return eq2_apodotiko(t_i, T)

    def select(self, db: Database, round_: int) -> list[int]:
        return apodotiko_select(db, self.cfg.clients_per_round, self.rng,
                                adjustment_rate=self.cfg.adjustment_rate)


class ApodotikoTopK(Apodotiko):
    """Apodotiko's gating/weighting with fleet-scale *deterministic*
    cohort selection: one jitted masked top-k over the device-resident
    EMA score state (``FleetStore.select_topk``, DESIGN.md §10) instead of
    Algorithm 3's probabilistic host-side sampling. Uninvoked clients rank
    first (the bootstrap), the booster update runs inside the same kernel,
    and no per-client Python executes on the selection path — O(M) device
    work at a million clients. Requires the columnar control plane."""

    name = "apodotiko-topk"

    def select(self, db: Database, round_: int) -> list[int]:
        if not db.columnar:
            raise ValueError(
                "apodotiko-topk selects over the columnar control plane's "
                "device score state; set control_plane='columnar' "
                "(REPRO_CONTROL_PLANE=columnar)")
        return db.fleet.select_topk(
            self.cfg.clients_per_round,
            promotion_rate(self.cfg.adjustment_rate),
            now_round=round_)


STRATEGIES = {
    s.name: s for s in (FedAvg, FedProx, Scaffold, FedLesScan, FedBuff,
                        Apodotiko, ApodotikoTopK)
}


def build_strategy(name: str, cfg: StrategyConfig) -> Strategy:
    return STRATEGIES[name](cfg)
