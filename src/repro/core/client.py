"""Client_Update (paper Algorithm 1, lines 14-23) — real JAX local training.

On real hardware every client is an independent FaaS function. In the
simulator the *learning* is real but executed cohort-vectorized: the local
SGD/Adam loop of every client invoked at the same simulated instant runs
under one ``vmap`` (padded to the cohort's max step count, with per-client
step masking). Simulated durations come from the hardware model, so the
timing behaviour matches per-client execution while the host does one
batched computation (a beyond-paper systems optimization, DESIGN.md §2).

Two data planes feed the cohort fn (DESIGN.md §2, ``core.data_plane``):

  * **device** (default): the fn takes a ``[Kp] int32`` client-index
    vector plus the ``DatasetStore``'s resident buffers and gathers each
    minibatch on device inside the jit — zero H2D training-input bytes
    per dispatch;
  * **host** (oracle): the padded ``[Kp, N_max, ...]`` cohort arrays are
    fancy-indexed on host and uploaded every dispatch (the pre-data-plane
    behaviour; ``data_h2d_bytes`` counts the uploads).

Supports the baseline strategies' client-side modifications:
  - FedProx: proximal term  mu/2 ||w - w_global||^2
  - SCAFFOLD: control-variate-corrected gradients + c_i update
"""
from __future__ import annotations

import functools
import itertools
import math
import os
import weakref
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.update_store import scatter_rows
from repro.optim import apply_updates, build_optimizer
from repro.sharding import flmesh

Pytree = Any


def _l2_sq(a: Pytree, b: Pytree) -> jax.Array:
    return sum(jnp.sum(jnp.square(x - y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _bucket(x: int, floor: int) -> int:
    """Round up to the next power-of-two multiple of ``floor``."""
    b = max(int(floor), 1)
    while b < x:
        b *= 2
    return b


def _steps_bucket(steps: int, floor: int = 8) -> int:
    """Round max step counts to power-of-two buckets to bound recompiles."""
    return _bucket(steps, floor)


# Cohort sizes bucket separately from step counts: a K=1 reinforcement or
# re-invocation used to pad to the step floor of 8 (~8x wasted lanes); the
# cohort floor is 2, so solo dispatches run 2 padded lanes and mixed
# selection sizes still compile O(log K) variants.
DEFAULT_COHORT_FLOOR = 2


def cohort_bucket_floor() -> int:
    """The cohort-size bucket floor (``REPRO_COHORT_FLOOR``, default 2)."""
    return int(os.environ.get("REPRO_COHORT_FLOOR", DEFAULT_COHORT_FLOOR))


# Compiled cohort-train fns shared across Controller instances (strategies
# reuse identical trainer configs; compiles are expensive on the 1-core host).
_COMPILE_CACHE: dict[tuple, Any] = {}

# Cache keys must identify the *model object* the compiled fn closed over.
# ``id(model)`` is unsafe: ids are recycled after GC, so a new model at a
# reused address would be served a stale compiled fn. A weak-keyed token is
# stable for the object's lifetime and never reused afterwards, while still
# letting every trainer built around the same shared model object (sweep
# engine, benchmarks) hit the same compiled entry.
_MODEL_TOKENS: "weakref.WeakKeyDictionary[Any, int]" = weakref.WeakKeyDictionary()
_TOKEN_COUNTER = itertools.count()


def _model_token(model) -> int:
    tok = _MODEL_TOKENS.get(model)
    if tok is None:
        tok = next(_TOKEN_COUNTER)
        _MODEL_TOKENS[model] = tok
    return tok


class CohortTrainer:
    """Vectorized local training over a cohort sharing one model/optimizer."""

    def __init__(self, model, *, optimizer: str, lr: float, batch_size: int,
                 prox_mu: float = 0.0, scaffold: bool = False, seed: int = 0,
                 cohort_floor: Optional[int] = None, mesh=None):
        self.model = model
        self.opt = build_optimizer(optimizer, lr)
        self.lr = lr
        self.batch_size = batch_size
        self.prox_mu = prox_mu
        self.scaffold = scaffold
        self.mesh = mesh
        floor = (cohort_bucket_floor() if cohort_floor is None
                 else int(cohort_floor))
        if mesh is not None:
            # every cohort bucket must split evenly over the "data" axis
            # (shard_map needs Kp % data == 0); power-of-two bucketing
            # preserves multiples of the floor, so lifting the floor to
            # lcm(floor, data) makes every Kp divisible
            floor = math.lcm(floor, flmesh.mesh_axes(mesh)[0])
        self.cohort_floor = floor
        self._key = jax.random.PRNGKey(seed)
        self.data_h2d_bytes = 0   # training-input bytes uploaded (host plane)

    # ----------------------------------------------------------- single fn
    def _make_fn(self, max_steps: int, flat_updates: bool = False,
                 indexed: bool = False):
        model, opt = self.model, self.opt
        B, mu, use_cv, lr = self.batch_size, self.prox_mu, self.scaffold, self.lr

        def local_train(params0, fetch, n_i, steps, key, cg, ci):
            # ``fetch(idx) -> (x, y)`` abstracts the minibatch gather: the
            # host plane indexes this lane's [N_max, ...] slice, the device
            # plane gathers straight out of the resident [M, N_max, ...]
            # buffers — identical values, so the planes stay bit-identical.
            opt_state = opt.init(params0)

            def body(carry, s):
                params, opt_state, key = carry
                key, k = jax.random.split(key)
                idx = jax.random.randint(k, (B,), 0, jnp.maximum(n_i, 1))
                bx, by = fetch(idx)
                batch = {"x": bx, "y": by}

                def loss_fn(p):
                    l, _ = model.loss(p, batch)
                    if mu > 0:
                        l = l + 0.5 * mu * _l2_sq(p, params0)
                    return l

                loss, grads = jax.value_and_grad(loss_fn)(params)
                if use_cv:
                    grads = jax.tree.map(lambda g, a, b: g - a + b, grads, ci, cg)
                upd, new_opt = opt.update(grads, opt_state, params)
                newp = apply_updates(params, upd)
                active = s < steps
                sel = lambda a, b: jnp.where(active, a, b)
                params = jax.tree.map(sel, newp, params)
                opt_state = jax.tree.map(sel, new_opt, opt_state)
                return (params, opt_state, key), jnp.where(active, loss, 0.0)

            (params, _, _), losses = jax.lax.scan(
                body, (params0, opt_state, key), jnp.arange(max_steps))
            mean_loss = jnp.sum(losses) / jnp.maximum(steps, 1)
            if use_cv:
                # c_i' = c_i - c + (w0 - w) / (K * lr)
                denom = jnp.maximum(steps, 1).astype(jnp.float32) * lr
                ci_new = jax.tree.map(
                    lambda c, g, p0, p: c - g + (p0 - p) / denom,
                    ci, cg, params0, params)
            else:
                ci_new = ci
            return params, ci_new, mean_loss

        if indexed:
            # Device data plane: per-lane client index into the resident
            # dataset buffers (unbatched jit args — never re-uploaded, never
            # baked into the program as constants). The lane slices its
            # client's rows ONCE before the scan — a device-device gather —
            # so the per-step minibatch gather sees the same lane-local
            # operand as the host path (a per-step two-level gather from
            # the full buffer lowers to a slow batched-gather on XLA CPU).
            def client_fn(params0, cidx, n_i, steps, key, cg, ci, DX, Dy):
                Xl, yl = DX[cidx], Dy[cidx]
                return local_train(
                    params0, lambda idx: (Xl[idx], yl[idx]),
                    n_i, steps, key, cg, ci)

            v = jax.vmap(client_fn,
                         in_axes=(None, 0, 0, 0, 0, None, 0, None, None))
            if self.mesh is not None:
                # Shard the cohort batch over the "data" axis: each device
                # vmaps its Kp/data lanes against the replicated dataset
                # buffers, so per-lane train work and minibatch gathers are
                # shard-local. Per-lane training is independent, so each
                # lane's outputs are the same values the unsharded vmap
                # produces — only aggregation reassociates floats.
                #
                # The [Kp, 2] lane-key table enters REPLICATED (P()) and
                # each shard slices its own lane block below. Consuming it
                # P("data") would let GSPMD shard the *producing*
                # ``jax.random.split`` when the keys are computed inside
                # the same program (the fused megastep scan) — and with
                # the non-partitionable threefry default that silently
                # changes the key values, breaking the fused/stepwise
                # bit-identity contract. Eager callers are unaffected
                # either way (their keys are concrete before the jit).
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                vv = v

                def _shard_body(params0, cidx, n_i, steps, keys, cg, ci,
                                DX, Dy):
                    kp_l = cidx.shape[0]      # this shard's lane count
                    start = jax.lax.axis_index("data") * kp_l
                    keys_l = jax.lax.dynamic_slice_in_dim(keys, start, kp_l)
                    return vv(params0, cidx, n_i, steps, keys_l, cg, ci,
                              DX, Dy)

                v = shard_map(
                    _shard_body, mesh=self.mesh,
                    in_specs=(P(), P("data"), P("data"), P("data"),
                              P(), P(), P("data"), P(), P()),
                    out_specs=(P("data"), P("data"), P("data")),
                    check_rep=False)
            n_lead = 9
        else:
            def client_fn(params0, X, y, n_i, steps, key, cg, ci):
                return local_train(params0, lambda idx: (X[idx], y[idx]),
                                   n_i, steps, key, cg, ci)

            v = jax.vmap(client_fn, in_axes=(None, 0, 0, 0, 0, 0, None, 0))
            n_lead = 8
        if not flat_updates:
            return jax.jit(v)

        # Update-plane mode: the trained cohort never leaves the device —
        # inside the same jitted program each [K, ...] output leaf lands in
        # its column stripe of the UpdateStore buffer rows (canonical
        # jax.tree.leaves order, the RavelSpec contract; tail pad lanes
        # zeroed). The buffer is *donated* and the chained aliased scatters
        # are in-place writes: zero host round-trips, no buffer copy, no
        # concatenated [K, W] intermediate.
        def cohort_flat(*args):
            lead, (buffer, row_ids) = args[:n_lead], args[n_lead:]
            out_params, ci_new, losses = v(*lead)
            buffer = scatter_rows(buffer, row_ids,
                                  jax.tree.leaves(out_params))
            return buffer, ci_new, losses

        return jax.jit(cohort_flat, donate_argnums=(n_lead,))

    # ------------------------------------------------------------- helpers
    def _pad_variates(self, global_params, c_global, c_clients, Kp, K):
        """Broadcastable zero trees when SCAFFOLD is off; zero-padded
        [Kp, ...] stacked variates when on (pad lanes run 0 steps)."""
        if c_global is None:
            c_global = jax.tree.map(lambda p: jnp.zeros((), p.dtype),
                                    global_params)
            c_clients = jax.tree.map(
                lambda p: jnp.zeros((Kp,) + (1,) * p.ndim, p.dtype),
                global_params)
        elif c_clients is not None and Kp != K:
            c_clients = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((Kp - K,) + a.shape[1:], a.dtype)], axis=0),
                c_clients)
        return c_global, c_clients

    def _cohort_keys(self, Kp):
        self._key, sub = jax.random.split(self._key)
        return jax.random.split(sub, Kp)

    def _compiled(self, cache_key, max_steps, flat_updates, indexed):
        if cache_key not in _COMPILE_CACHE:
            _COMPILE_CACHE[cache_key] = self._make_fn(
                max_steps, flat_updates=flat_updates, indexed=indexed)
        return _COMPILE_CACHE[cache_key]

    def _config_key(self) -> tuple:
        return (_model_token(self.model), self.opt.name, self.lr,
                self.batch_size, self.prox_mu, self.scaffold,
                *flmesh.mesh_token(self.mesh))

    # --------------------------------------------------------------- train
    def train_cohort(self, global_params: Pytree, X: np.ndarray, y: np.ndarray,
                     n_i: np.ndarray, steps: np.ndarray,
                     c_global: Optional[Pytree] = None,
                     c_clients: Optional[Pytree] = None, *,
                     update_sink=None):
        """Host data plane: X [K, N_max, ...], y [K, N_max], n_i/steps [K]
        are uploaded per dispatch (counted in ``data_h2d_bytes``).
        Returns (params [K, ...] stacked, c_clients', mean losses [K]).

        With ``update_sink`` (an ``UpdateStore``) the trained client models
        instead stay on device: the jitted cohort fn flattens them to
        [K, W] fp32 rows (RavelSpec leaf order) and scatters them into the
        sink's donated buffer in the same program; the first return value
        is then the [K] allocated row ids."""
        flat_updates = update_sink is not None
        K = X.shape[0]
        # pad the cohort to a power-of-two bucket: one compile serves every
        # selection size in the bucket (padded entries run 0 active steps)
        Kp = _bucket(K, self.cohort_floor)
        if Kp != K:
            padt = lambda a: np.concatenate(
                [a, np.repeat(a[-1:], Kp - K, axis=0)], axis=0)
            X, y = padt(np.asarray(X)), padt(np.asarray(y))
            n_i = padt(np.asarray(n_i))
            steps = np.concatenate([steps, np.zeros(Kp - K, steps.dtype)])
        max_steps = _steps_bucket(int(steps.max()))
        cache_key = self._config_key() + (Kp, max_steps, X.shape[1:],
                                          y.dtype, flat_updates, "host")
        fn = self._compiled(cache_key, max_steps, flat_updates, indexed=False)
        keys = self._cohort_keys(Kp)
        c_global, c_clients = self._pad_variates(global_params, c_global,
                                                 c_clients, Kp, K)
        X, y = np.asarray(X), np.asarray(y)
        self.data_h2d_bytes += X.nbytes + y.nbytes
        trim = lambda t: jax.tree.map(lambda a: a[:K], t)
        lead = (global_params, jnp.asarray(X), jnp.asarray(y),
                jnp.asarray(n_i), jnp.asarray(steps), keys, c_global,
                c_clients)
        if flat_updates:
            return self._run_flat(fn, lead, update_sink, Kp, K, trim)
        out_params, ci_new, losses = fn(*lead)
        return trim(out_params), trim(ci_new), np.asarray(losses)[:K]

    def train_cohort_indexed(self, global_params: Pytree, store,
                             selection, n_i: np.ndarray, steps: np.ndarray,
                             c_global: Optional[Pytree] = None,
                             c_clients: Optional[Pytree] = None, *,
                             update_sink=None):
        """Device data plane: the cohort is a ``[K]`` vector of client
        indices into ``store`` (a ``DatasetStore``); every minibatch is
        gathered out of the resident buffers inside the jit — zero H2D
        training-input bytes. Pad lanes repeat the last index (mirroring
        the host path's row repeat) and run 0 active steps. The compile
        cache collapses to (cohort bucket, step bucket, flat_updates):
        data shapes are fixed for the store's lifetime."""
        flat_updates = update_sink is not None
        sel = np.asarray(selection, np.int32)
        n_i = np.asarray(n_i)
        K = len(sel)
        Kp = _bucket(K, self.cohort_floor)
        if Kp != K:
            sel = np.concatenate([sel, np.repeat(sel[-1:], Kp - K)])
            n_i = np.concatenate([n_i, np.repeat(n_i[-1:], Kp - K)])
            steps = np.concatenate([steps, np.zeros(Kp - K, steps.dtype)])
        max_steps = _steps_bucket(int(steps.max()))
        cache_key = self._config_key() + (Kp, max_steps, store.X.shape[1:],
                                          store.y.dtype, flat_updates,
                                          "device")
        fn = self._compiled(cache_key, max_steps, flat_updates, indexed=True)
        keys = self._cohort_keys(Kp)
        c_global, c_clients = self._pad_variates(global_params, c_global,
                                                 c_clients, Kp, K)
        trim = lambda t: jax.tree.map(lambda a: a[:K], t)
        lead = (global_params, jnp.asarray(sel), jnp.asarray(n_i),
                jnp.asarray(steps), keys, c_global, c_clients,
                store.X, store.y)
        if flat_updates:
            return self._run_flat(fn, lead, update_sink, Kp, K, trim)
        out_params, ci_new, losses = fn(*lead)
        return trim(out_params), trim(ci_new), np.asarray(losses)[:K]

    def cohort_fn_indexed(self, store, K: int, max_steps_raw: int):
        """The compiled indexed-flat cohort fn for a fixed (K, step-budget)
        regime -> ``(fn, Kp, max_steps)``. Same cache key construction as
        ``train_cohort_indexed`` with ``flat_updates=True``, so the fused
        round megastep (``core.megastep``) calls through the IDENTICAL
        compiled entry the stepwise path dispatches — jit-in-jit inlines it
        into the scan body with the same traced ops."""
        Kp = _bucket(K, self.cohort_floor)
        max_steps = _steps_bucket(int(max_steps_raw))
        cache_key = self._config_key() + (Kp, max_steps, store.X.shape[1:],
                                          store.y.dtype, True, "device")
        fn = self._compiled(cache_key, max_steps, flat_updates=True,
                            indexed=True)
        return fn, Kp, max_steps

    def _run_flat(self, fn, lead, update_sink, Kp, K, trim):
        # padded cohort entries run 0 active steps, so their rows hold
        # the unchanged global model — written then recycled right away
        ids = update_sink.alloc(Kp)
        new_buffer, ci_new, losses = fn(*lead, update_sink.buffer,
                                        jnp.asarray(ids))
        update_sink.buffer = new_buffer
        if Kp != K:
            update_sink.free(ids[K:])
        return ids[:K], trim(ci_new), np.asarray(losses)[:K]
