"""Device-resident update plane: a persistent row buffer for client updates.

The paper's state store holds every un-aggregated client update until the
CR-gated aggregation consumes it (Algorithm 1 lines 6-9). The legacy blob
path materializes each update as a host-side numpy pytree — O(K*N) bytes
copied device->host after training and host->device again at aggregation,
every round. The ``UpdateStore`` keeps the same lifecycle entirely on
device: all in-flight updates are rows of one ``[capacity, W]`` fp32
buffer, written in place by the jitted cohort-train function (the buffer is
*donated* into the jit and each leaf lands in its column stripe through
chained aliased scatters — true in-place writes, no concatenated
intermediate, no buffer copy) and consumed by the ``staleness_agg`` kernel
via scattered per-row weights
(``core.aggregation.weighted_aggregate_rows``) — zero host round-trips on
the round hot path.

Geometry invariants (so the aggregation kernel never pays a padding copy):
``capacity`` is always a multiple of the fp32 sublane (8) and the row width
``W`` is ``n_params`` rounded up to the kernel block (1024); every row
write zeroes the tail pad lanes.

Lifecycle: rows are allocated at invocation time, referenced by
``ResultRecord.update_row`` handles in the database, and recycled through a
free-list when results are aggregated, pruned past the staleness cap, or
their invocation fails. Freeing does no device work: stale rows enter the
full-buffer reduction with weight 0, and the only case where that is not
exact (NaN/Inf left by a diverged client) is caught by the aggregation
layer's finiteness guard, which recomputes via an explicit row gather. The
buffer doubles when the free-list runs dry. Checkpointing serializes only
the live rows (``checkpoint.manager.save_update_store``) and rehydrates
them at their original row ids on resume, so record handles stay valid
bit-exactly.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import SUBLANE
from repro.kernels.staleness_agg import BLOCK_N
from repro.sharding import flmesh


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def gather_rows(buffer, ids) -> jnp.ndarray:
    """[len(ids), W] device row gather (no host copy). Shared by the
    update store, the aggregation gather fallback, and checkpointing."""
    return buffer[jnp.asarray(np.asarray(ids, np.int32))]


def gather_stacked(tree, idx):
    """Per-leaf ``[M, ...] -> [K, ...]`` device gather over a stacked
    pytree — the read half of the persistent-buffer contract shared by the
    SCAFFOLD control-variate buffer (``core.services``) and the
    device-resident dataset (``core.data_plane``)."""
    return jax.tree.map(lambda b: b[idx], tree)


def scatter_stacked_tree(tree, idx, values):
    """Per-leaf row write of ``[K, ...]`` values into a ``[M, ...]``-stacked
    pytree (the write half of ``gather_stacked``)."""
    return jax.tree.map(lambda b, v: b.at[idx].set(v.astype(b.dtype)),
                        tree, values)


def grow_stacked(tree, old_rows: int, new_rows: int):
    """Extend every ``[M, ...]`` leaf of a stacked pytree with zero rows to
    ``[new_rows, ...]`` (persistent-buffer growth on client join)."""
    if new_rows <= old_rows:
        return tree
    return jax.tree.map(
        lambda b: jnp.concatenate(
            [b, jnp.zeros((new_rows - old_rows,) + b.shape[1:], b.dtype)]),
        tree)


def scatter_rows(buffer, ids, leaves):
    """Traceable column-stripe row write: each [K, ...]-stacked leaf lands
    in its stripe of the buffer rows (RavelSpec leaf order), tail pad lanes
    zeroed. When the buffer is donated into the enclosing jit, the chained
    aliased scatters are in-place writes — no concatenated [K, W]
    intermediate, no buffer copy. This is THE buffer-write contract: the
    store's jitted entry points below and the cohort-train fn
    (``core.client``) both trace through it."""
    K = leaves[0].shape[0]
    off = 0
    for l in leaves:
        seg = l.reshape(K, -1).astype(buffer.dtype)
        buffer = buffer.at[ids, off:off + seg.shape[1]].set(seg)
        off += seg.shape[1]
    if off < buffer.shape[1]:
        buffer = buffer.at[ids, off:].set(0.0)
    return buffer


_scatter_stacked = functools.partial(jax.jit, donate_argnums=(0,))(scatter_rows)


class UpdateStore:
    """Free-listed [capacity, W] fp32 device buffer of flat client updates."""

    def __init__(self, n_params: int, capacity: int = 16,
                 dtype=jnp.float32, mesh=None):
        self.n_params = int(n_params)
        self.mesh = mesh
        # alignments gain mesh divisibility so every device owns an equal
        # [capacity/data, W/model] tile; un-meshed these are the seed's
        # BLOCK_N / SUBLANE values exactly (lcm with 1)
        self._row_align = flmesh.row_align(mesh, BLOCK_N)
        self._cap_align = flmesh.capacity_align(mesh, SUBLANE)
        self.row_width = _round_up(self.n_params, self._row_align)
        self.dtype = dtype
        self.capacity = 0
        self.buffer: Optional[jnp.ndarray] = None
        self._free: list[int] = []
        self._live: set[int] = set()
        self._ensure(max(int(capacity), 1))

    # ------------------------------------------------------------ capacity
    def _ensure(self, capacity: int) -> None:
        if capacity <= self.capacity:
            return
        # double (at least) so amortized growth cost is O(1) per row; keep
        # capacity a sublane multiple so the kernel path never pads rows
        cap = _round_up(max(capacity, 2 * self.capacity), self._cap_align)
        grown = jnp.zeros((cap - self.capacity, self.row_width), self.dtype)
        self.buffer = (grown if self.buffer is None
                       else jnp.concatenate([self.buffer, grown], axis=0))
        # re-place after growth: concat output inherits no layout, so pin
        # the [rows over "data", W over "model"] sharding explicitly (the
        # donated scatters below preserve it via GSPMD propagation)
        self.buffer = flmesh.shard_put(self.buffer, self.mesh, flmesh.ROW_SPEC)
        self._free.extend(range(self.capacity, cap))
        self.capacity = cap

    def alloc(self, k: int) -> np.ndarray:
        """Reserve k row ids (grows the buffer if the free-list runs dry)."""
        if len(self._free) < k:
            self._ensure(self.capacity + (k - len(self._free)))
        ids = np.array([self._free.pop() for _ in range(k)], np.int32)
        self._live.update(int(i) for i in ids)
        return ids

    # ---------------------------------------------------------------- rows
    def put(self, rows: jnp.ndarray) -> np.ndarray:
        """Scatter [K, n_params<=W] rows into freshly allocated slots;
        returns ids. One donated device scatter — no host traffic."""
        ids = self.alloc(rows.shape[0])
        self.buffer = _scatter_stacked(self.buffer, jnp.asarray(ids), [rows])
        return ids

    def put_stacked(self, stacked_tree) -> np.ndarray:
        """Write a [K, ...]-stacked pytree (cohort-train output layout)
        straight into the buffer: per-leaf column-stripe scatters in one
        donated jit (mirrors what the cohort fn does on the controller
        path)."""
        leaves = jax.tree.leaves(stacked_tree)
        ids = self.alloc(leaves[0].shape[0])
        self.buffer = _scatter_stacked(self.buffer, jnp.asarray(ids), leaves)
        return ids

    def write_at(self, ids: Sequence[int], rows) -> None:
        """Write rows at specific ids (checkpoint rehydration), reserving
        them. Accepts [L, n_params] or full [L, W] rows; rows saved by a
        store with a WIDER mesh-aligned W are trimmed to this store's W
        (the excess is always tail pad zeros — n_params <= both widths) so
        snapshots restore across mesh specs."""
        ids = np.asarray(ids, np.int32)
        if ids.size == 0:
            return
        self._ensure(int(ids.max()) + 1)
        for i in ids:
            i = int(i)
            if i in self._free:
                self._free.remove(i)
            self._live.add(i)
        rows = jnp.asarray(rows, self.dtype)
        if rows.shape[1] > self.row_width:
            rows = rows[:, : self.row_width]
        self.buffer = _scatter_stacked(self.buffer, jnp.asarray(ids), [rows])

    def gather(self, ids: Sequence[int]) -> jnp.ndarray:
        """[len(ids), W] device gather (no host copy)."""
        return gather_rows(self.buffer, ids)

    def row(self, i: int) -> jnp.ndarray:
        return self.buffer[int(i)]

    def free(self, ids: Sequence[int]) -> None:
        """Recycle rows whose results were aggregated, pruned, or failed —
        a pure free-list operation, no device work. Stale values linger
        until the slot is rewritten; they enter full-buffer reductions with
        weight 0, and the one case where that is not an exact no-op
        (NaN/Inf from a diverged client) is caught by the aggregation
        layer's finiteness guard (``weighted_aggregate_rows``)."""
        for i in ids:
            i = int(i)
            if i in self._live:
                self._live.discard(i)
                self._free.append(i)

    # ----------------------------------------------------------- inventory
    def free_stack(self) -> np.ndarray:
        """The LIFO free-list as an ``[n_free] int32`` array, bottom ->
        top (``alloc`` pops from the END). The fused-round megastep
        (``core.megastep``) carries this stack through its scan so in-scan
        row allocation emits exactly the id sequence ``alloc`` will
        produce when the host replays the rounds afterwards."""
        return np.asarray(self._free, np.int32)

    @property
    def live_count(self) -> int:
        return len(self._live)

    def live_rows(self) -> np.ndarray:
        return np.array(sorted(self._live), np.int32)

    def nbytes(self) -> int:
        return self.capacity * self.row_width * np.dtype("float32").itemsize
