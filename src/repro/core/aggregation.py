"""Staleness-weighted asynchronous aggregation (paper §III-B).

Given K client models (current + up to ``max_staleness`` rounds old), the
aggregator computes

    w_{T+1} = sum_i s(t_i, T) * (n_i / n) * w^i   /   sum_i s(t_i, T) * (n_i / n)

where ``s`` is Eq. 2 (1/sqrt(T - t_i + 1)) for Apodotiko or Eq. 1 (t_i/T)
for FedLesScan. The denominator normalization matches the FedLess reference
implementation (the raw paper formula shrinks the model norm whenever any
update is stale).

The hot loop — a K-way weighted reduction over every parameter — is exactly
the paper's serverless aggregation function. Three execution paths:
  * ``weighted_aggregate``: jit'd XLA path (default, used by the controller);
  * ``kernels.ops.staleness_agg``: Pallas TPU kernel (VMEM-tiled fused
    multiply-accumulate; validated in interpret mode);
  * sharded path: on a mesh, stacked updates [K, ...] are sharded over the
    ``pod``/``data`` axes and the reduce lowers to a weighted psum — this is
    how the FaaS aggregation pattern maps onto TPU collectives (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.staleness import STALENESS_FNS

Pytree = Any


def staleness_weights(rounds: Sequence[int], cardinalities: Sequence[int],
                      current_round: int, fn: str = "eq2") -> np.ndarray:
    s = STALENESS_FNS[fn]
    n = float(sum(cardinalities)) or 1.0
    w = np.array([s(t_i, current_round) * (n_i / n)
                  for t_i, n_i in zip(rounds, cardinalities)], np.float64)
    total = w.sum()
    if total <= 0:
        w = np.full(len(w), 1.0 / max(len(w), 1))
        total = 1.0
    return (w / total).astype(np.float32)


@functools.partial(jax.jit, donate_argnums=(0,))
def _weighted_sum_stacked(stacked: Pytree, weights: jax.Array) -> Pytree:
    def one(x):
        wf = weights.astype(jnp.float32)
        shape = (x.shape[0],) + (1,) * (x.ndim - 1)
        return jnp.sum(x.astype(jnp.float32) * wf.reshape(shape), axis=0)

    return jax.tree.map(one, stacked)


def weighted_aggregate(updates: Sequence[Pytree], weights: np.ndarray,
                       out_dtype=None) -> Pytree:
    """updates: list of K pytrees -> weighted average pytree.

    Stacks on a leading K axis then runs one fused jit reduction (the
    benchmarked aggregation path)."""
    assert len(updates) == len(weights) and len(updates) > 0
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *updates)
    out = _weighted_sum_stacked(stacked, jnp.asarray(weights))
    if out_dtype is not None:
        out = jax.tree.map(lambda x: x.astype(out_dtype), out)
    return out


def incremental_aggregate(acc: Optional[Pytree], update: Pytree,
                          weight: float) -> Pytree:
    """Streaming form: acc += w * update (callers normalize at the end).
    Used when K is large and stacking would blow host memory."""
    if acc is None:
        return jax.tree.map(lambda x: x.astype(jnp.float32) * weight, update)
    return jax.tree.map(lambda a, x: a + x.astype(jnp.float32) * weight,
                        acc, update)
