"""Staleness-weighted asynchronous aggregation (paper §III-B).

Given K client models (current + up to ``max_staleness`` rounds old), the
aggregator computes

    w_{T+1} = sum_i s(t_i, T) * (n_i / n) * w^i   /   sum_i s(t_i, T) * (n_i / n)

where ``s`` is Eq. 2 (1/sqrt(T - t_i + 1)) for Apodotiko or Eq. 1 (t_i/T)
for FedLesScan. The denominator normalization matches the FedLess reference
implementation (the raw paper formula shrinks the model norm whenever any
update is stale).

The hot loop — a K-way weighted reduction over every parameter — is exactly
the paper's serverless aggregation function. Three execution paths
(DESIGN.md §2):

  * **Pallas** (default): the K update pytrees are raveled and concatenated
    into one ``[K, N]`` fp32 buffer (K padded to the fp32 sublane multiple,
    N padded to the kernel block), then reduced by the fused
    ``kernels/staleness_agg.py`` multiply-accumulate kernel — interpret mode
    on CPU/GPU, compiled Mosaic on TPU. A one-time numerical-equivalence
    self-check against the XLA path gates the dispatch; any mismatch or
    kernel failure falls back to XLA for the rest of the process.
  * **XLA** (``_weighted_sum_stacked``): jit'd per-leaf stacked reduction.
    Fallback path, and forced via ``path="xla"`` or ``REPRO_AGG_PATH=xla``.
  * **Sharded**: on a mesh, stacked updates [K, ...] are sharded over the
    ``pod``/``data`` axes and the reduce lowers to a weighted psum — this is
    how the FaaS aggregation pattern maps onto TPU collectives (DESIGN.md §4).

Dispatch policy: ``path`` argument > ``REPRO_AGG_PATH`` env var > ``auto``
(Pallas when the self-check passes, XLA otherwise). ``last_path()`` reports
which path produced the most recent result (observability + tests).

``weighted_aggregate`` consumes a *list of pytrees* (the legacy blob path).
``weighted_aggregate_rows`` is the device-resident update-plane fast path:
it reads K rows straight out of an ``UpdateStore`` buffer by index and
skips the ravel/stack work entirely (DESIGN.md §2, "update plane").
"""
from __future__ import annotations

import functools
import os
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.staleness import STALENESS_FNS
from repro.kernels import ops as kernel_ops
from repro.kernels.staleness_agg import BLOCK_N

Pytree = Any

_PALLAS_OK: Optional[bool] = None   # equivalence self-check; False = disabled
_LAST_PATH = "none"
# In interpret mode (no TPU) the kernel is a correctness path, ~100x slower
# than XLA at large N; ``auto`` only takes it below this parameter count.
# Compiled TPU dispatch ignores the cap. Env-tunable for experiments.
_INTERP_MAX_N = int(os.environ.get("REPRO_AGG_PALLAS_MAX_INTERP_N",
                                   str(1 << 18)))


def last_path() -> str:
    """Which execution path ('pallas' | 'xla' | 'psum') produced the last
    aggregate."""
    return _LAST_PATH


def staleness_weights(rounds: Sequence[int], cardinalities: Sequence[int],
                      current_round: int, fn: str = "eq2") -> np.ndarray:
    s = STALENESS_FNS[fn]
    n = float(sum(cardinalities)) or 1.0
    w = np.array([s(t_i, current_round) * (n_i / n)
                  for t_i, n_i in zip(rounds, cardinalities)], np.float64)
    total = w.sum()
    if total <= 0:
        w = np.full(len(w), 1.0 / max(len(w), 1))
        total = 1.0
    return (w / total).astype(np.float32)


# --------------------------------------------------------------- XLA path
@functools.partial(jax.jit, donate_argnums=(0,))
def _weighted_sum_stacked(stacked: Pytree, weights: jax.Array) -> Pytree:
    def one(x):
        wf = weights.astype(jnp.float32)
        shape = (x.shape[0],) + (1,) * (x.ndim - 1)
        return jnp.sum(x.astype(jnp.float32) * wf.reshape(shape), axis=0)

    return jax.tree.map(one, stacked)


# ------------------------------------------------------------ Pallas path
# The ravel -> [K, N] buffer -> kernel -> unravel plumbing (including the
# sublane/block padding) lives in kernels/ops.aggregate_pytree; this module
# only owns the dispatch policy around it.
def _pallas_validated() -> bool:
    """One-time numerical-equivalence check of the kernel path vs. XLA.

    Runs a deterministic ragged pytree that exercises both pad paths (K not
    a sublane multiple, N not a block multiple). On mismatch or any kernel
    error the process permanently falls back to XLA."""
    global _PALLAS_OK
    if _PALLAS_OK is None:
        try:
            rng = np.random.default_rng(0)
            ups = [{"a": jnp.asarray(rng.normal(size=(BLOCK_N,)), jnp.float32),
                    "b": jnp.asarray(rng.normal(size=(17,)), jnp.float32)}
                   for _ in range(3)]
            w = staleness_weights([2, 1, 0], [5, 3, 2], 2)
            got = kernel_ops.aggregate_pytree(ups, w, restore_dtype=False)
            stack = {k: np.stack([np.asarray(u[k], np.float64) for u in ups])
                     for k in ("a", "b")}
            w64 = np.asarray(w, np.float64)
            _PALLAS_OK = all(
                np.allclose(np.asarray(got[k]),
                            np.einsum("k,kn->n", w64, stack[k]),
                            rtol=1e-5, atol=1e-6)
                for k in ("a", "b"))
        except Exception:  # noqa: BLE001 — any kernel failure disables path
            _PALLAS_OK = False
    return _PALLAS_OK


# --------------------------------------------------------------- dispatch
def weighted_aggregate(updates: Sequence[Pytree], weights: np.ndarray,
                       out_dtype=None, path: Optional[str] = None) -> Pytree:
    """updates: list of K pytrees -> weighted average pytree (fp32 leaves
    unless ``out_dtype`` is given).

    ``path``: "auto" (default — Pallas kernel when its equivalence
    self-check passes; off-TPU the interpreter is only taken up to
    ``REPRO_AGG_PALLAS_MAX_INTERP_N`` params), "pallas" (force kernel;
    raises on failure), or "xla". ``REPRO_AGG_PATH`` overrides the
    default."""
    global _LAST_PATH
    assert len(updates) == len(weights) and len(updates) > 0
    path = path or os.environ.get("REPRO_AGG_PATH", "auto")
    if path not in ("auto", "pallas", "xla"):
        raise ValueError(f"unknown aggregation path {path!r}")

    global _PALLAS_OK
    n_params = sum(int(np.prod(l.shape)) if l.shape else 1
                   for l in jax.tree.leaves(updates[0]))
    auto_pallas = (_pallas_validated()
                   and (kernel_ops.on_tpu() or n_params <= _INTERP_MAX_N))
    out = None
    if path == "pallas" or (path == "auto" and auto_pallas):
        try:
            out = kernel_ops.aggregate_pytree(updates, weights,
                                              restore_dtype=False)
            _LAST_PATH = "pallas"
        except Exception:  # noqa: BLE001 — fall back unless forced
            if path == "pallas":
                raise
            _PALLAS_OK = False  # runtime failure: disable for the process
            out = None
    if out is None:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *updates)
        out = _weighted_sum_stacked(stacked, jnp.asarray(weights))
        _LAST_PATH = "xla"
    if out_dtype is not None:
        out = jax.tree.map(lambda x: x.astype(out_dtype), out)
    return out


def weighted_aggregate_rows(buffer, row_idx, weights,
                            spec: "kernel_ops.RavelSpec", out_dtype=None,
                            path: Optional[str] = None, mesh=None) -> Pytree:
    """Row-index fast path over the device-resident update plane.

    ``buffer`` is an ``UpdateStore``'s persistent [capacity, N] fp32 device
    buffer; ``row_idx`` selects the K pending updates; ``spec`` is the
    ``RavelSpec`` of the global model. One device gather feeds
    ``kernels/staleness_agg`` (or the XLA einsum fallback) directly — no
    ravel, no stack, no per-leaf work — and the flat result unravels exactly
    once to produce the new global pytree. Dispatch policy (``path`` arg,
    ``REPRO_AGG_PATH``, self-check, interpret-mode size cap) is identical to
    ``weighted_aggregate``.

    With ``mesh`` set (the buffer sharded P("data", "model")), the
    reduction routes to ``kernels/ops.aggregate_rows_psum``: a weighted
    ``lax.psum`` of per-shard partial matvecs over the ``data`` axis, so
    aggregation bytes move over ICI instead of through one device. Same
    weight-0 stale-row contract, same finiteness-guard recompute."""
    global _LAST_PATH
    assert len(row_idx) == len(weights) and len(row_idx) > 0
    path = path or os.environ.get("REPRO_AGG_PATH", "auto")
    if path not in ("auto", "pallas", "xla"):
        raise ValueError(f"unknown aggregation path {path!r}")

    if mesh is not None:
        flat = kernel_ops.aggregate_rows_psum(buffer, row_idx, weights, mesh)
        _LAST_PATH = "psum"
        if not bool(jnp.all(jnp.isfinite(flat))):
            flat = kernel_ops.aggregate_rows_gather(buffer, row_idx, weights)
        out = spec.unravel(flat[:spec.n_params], restore_dtype=False)
        if out_dtype is not None:
            out = jax.tree.map(lambda x: x.astype(out_dtype), out)
        return out

    global _PALLAS_OK
    auto_pallas = (_pallas_validated()
                   and (kernel_ops.on_tpu()
                        or spec.n_params <= _INTERP_MAX_N))
    # The full-buffer sweep reads every row; once the reference set is a
    # small fraction of a grown buffer (capacity only doubles, never
    # shrinks), gathering just the K referenced rows is cheaper — and
    # needs no finiteness guard, since it never touches freed rows.
    sparse = (path != "pallas"
              and buffer.shape[0] >= 4 * max(len(row_idx), kernel_ops.SUBLANE))
    flat = None
    if sparse:
        flat = kernel_ops.aggregate_rows_gather(buffer, row_idx, weights)
        _LAST_PATH = "xla"
    elif path == "pallas" or (path == "auto" and auto_pallas):
        try:
            flat = kernel_ops.aggregate_rows(buffer, row_idx, weights)
            _LAST_PATH = "pallas"
        except Exception:  # noqa: BLE001 — fall back unless forced
            if path == "pallas":
                raise
            _PALLAS_OK = False  # runtime failure: disable for the process
            flat = None
    if flat is None:
        flat = kernel_ops.aggregate_rows_xla(buffer, row_idx, weights)
        _LAST_PATH = "xla"
    # Finiteness guard: the full-buffer sweep multiplies freed rows by
    # weight 0, which is only exact for finite stale values (0 * inf = nan).
    # A non-finite result triggers one exact recompute over just the
    # referenced rows, so a diverged-then-pruned client can never poison a
    # later aggregate. The check reads the [W] result, not the buffer.
    if not sparse and not bool(jnp.all(jnp.isfinite(flat))):
        flat = kernel_ops.aggregate_rows_gather(buffer, row_idx, weights)
    # buffer rows are block-padded (W >= N); unravel exactly once per round
    out = spec.unravel(flat[:spec.n_params], restore_dtype=False)
    if out_dtype is not None:
        out = jax.tree.map(lambda x: x.astype(out_dtype), out)
    return out


def rows_dispatch(buffer_rows: int, k: int, n_params: int,
                  path: Optional[str] = None) -> tuple[bool, bool, bool]:
    """Resolve the ``weighted_aggregate_rows`` dispatch predicates
    *statically* -> ``(sparse, use_pallas, interpret)``.

    The fused-round megastep must bake the aggregation route into its
    jitted scan at trace time, so the route has to be decided from static
    facts only (buffer capacity, K, model size, env/path policy). The
    expressions here are verbatim from ``weighted_aggregate_rows`` —
    keeping them in this module means a policy change cannot silently
    fork the two paths. The one dynamic behavior that cannot be
    replicated in-trace is the Pallas runtime-raise fallback
    (``_PALLAS_OK`` flipping False mid-process); a trace-time raise
    simply aborts megastep entry and the round runs stepwise."""
    path = path or os.environ.get("REPRO_AGG_PATH", "auto")
    if path not in ("auto", "pallas", "xla"):
        raise ValueError(f"unknown aggregation path {path!r}")
    sparse = (path != "pallas"
              and buffer_rows >= 4 * max(k, kernel_ops.SUBLANE))
    use_pallas = (path == "pallas"
                  or (path == "auto" and _pallas_validated()
                      and (kernel_ops.on_tpu()
                           or n_params <= _INTERP_MAX_N)))
    return sparse, use_pallas, kernel_ops.default_interpret()


def incremental_aggregate(acc: Optional[Pytree], update: Pytree,
                          weight: float) -> Pytree:
    """Streaming form: acc += w * update (callers normalize at the end).
    Used when K is large and stacking would blow host memory."""
    if acc is None:
        return jax.tree.map(lambda x: x.astype(jnp.float32) * weight, update)
    return jax.tree.map(lambda a, x: a + x.astype(jnp.float32) * weight,
                        acc, update)
