"""Fused round megastep: whole quiescent rounds as one jitted ``lax.scan``.

After PRs 2-5 every *plane* is device-resident, but the scheduler still
hops through Python between them each round: select (1 jit dispatch) ->
train (1) -> aggregate (1) -> EMA/booster bookkeeping, plus the event-loop
choreography around them. For rounds that are provably *quiescent* — no
hedge timer can fire, no churn or failure can land, no eval/checkpoint
boundary, every completion of the round lands before anything else could
happen — that Python traffic is pure overhead. This module lowers a run of
R such rounds into ONE jitted program:

    scan over R rounds of:
        scored_topk            (kernels.ops — the same op select_topk jits)
        cohort train           (the same compiled indexed-flat fn the
                                stepwise path dispatches; jit-in-jit inlines)
        aggregate_rows_traced  (kernels.ops — traceable twin of the
                                weighted_aggregate_rows dispatch)
        f32 EMA + booster scatter-update (the FleetStore mirror algebra)

so the steady state is zero Python dispatches per round.

**Bit-identity contract.** The event-driven engine stays the oracle; the
fused path must be bitwise indistinguishable from it. The anchors:

  * selection: the scan carries the FleetStore device score state
    (f32 twin columns, ``_flush_device``) and calls the single
    ``scored_topk`` definition ``select_topk`` jits;
  * training: the scan body calls the *same compiled fn object* out of the
    trainer's compile cache, with identically padded operands and the
    identical ``_cohort_keys`` key-split schedule (the key is a carry);
  * update rows: the scan carries the UpdateStore free-stack and replays
    its LIFO pop/push algebra, so row ids equal what ``alloc`` produces;
  * aggregation: all-current-round Eq.2 weights are integer-valued
    (``s(T,T) = 1``), so the f32 cast-then-normalize in
    ``services.aggregate_round`` is reduction-order independent and the
    in-scan ``jnp.sum`` normalization is bitwise the host one; the kernel
    dispatch predicates are pre-resolved by ``aggregation.rows_dispatch``;
  * landing order: durations are deterministic in the eligible regime
    (variability 0, warm instances), so per-slot completion ranks are
    precomputed and a stable argsort reproduces the event heap's
    (time, schedule-seq) pop order.

After the scan, a **host replay** walks the same R rounds through the REAL
bookkeeping code (``platform.invoke``, ``_launch``, the event loop,
``db.mark_complete``, result records, free-lists) with protocol emission
suppressed and zero device dispatches — the platform RNG draws are
state-advancing but value-deterministic here, so every host structure ends
bit-identical to stepwise execution. Scan-vs-replay cross-checks (row ids,
landing order) raise rather than diverge silently.

``plan_megastep`` is the eligibility check: it admits a round run only
when every condition above is statically provable and otherwise reports
why (``Scheduler.metrics()['megastep_fallback_reason']``). Anything it
cannot prove — a timer armed, pending results, a cold or noisy client,
K exceeding the idle pool — falls through to the stepwise engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.core.fleet_store import IDLE

Pytree = Any

#: compiled fused-scan programs, keyed by their static closure values
#: (the trainer fn identity pins model/optimizer/Kp/max_steps; jax.jit
#: adds its own shape/dtype specialization on top)
_SCAN_CACHE: dict[tuple, Any] = {}


@dataclass
class MegastepPlan:
    """Everything the fused scan + host replay need, resolved statically."""

    R: int                  # rounds to fuse
    K: int                  # cohort size (= cfg.clients_per_round)
    Kp: int                 # padded cohort bucket
    top: int                # free-stack height at entry
    fn: Any                 # compiled indexed-flat cohort fn
    max_steps: int          # static step bucket baked into ``fn``
    sparse: bool            # aggregation dispatch (rows_dispatch)
    use_pallas: bool
    interpret: bool
    out_dtype: Any          # model leaf dtype (post-aggregate astype)
    beta32: np.float32      # booster promotion rate (1 + rho)
    dec32: np.float32       # EMA decay (1 - rho)
    # [capacity] per-slot columns (host); device copies are made at launch
    ids_col: np.ndarray     # client id (= dataset index), int32
    n_col: np.ndarray       # data.n[id] (trainer arg dtype)
    n32_col: np.ndarray     # f32 cast of n (aggregation weights)
    steps_col: np.ndarray   # step budget, int64 (trainer arg dtype)
    card32_col: np.ndarray  # f32 cardinality (EMA operand)
    upd32_col: np.ndarray   # FleetStore.upd32 (EMA operand)
    d64_col: np.ndarray     # deterministic invocation duration, f64
    d32_col: np.ndarray     # f32 cast (the mark_complete EMA operand)
    rank_col: np.ndarray    # dense duration rank (landing-order key), int32
    mesh: Any = None        # device mesh (None = single-device): routes the
    #                         in-scan aggregation through the weighted psum


def _plan(sched) -> tuple[Optional[MegastepPlan], str]:
    """Prove a run of rounds quiescent, or say why not (side-effect free
    apart from reading — and thereby purging — the stale-timer heap)."""
    import jax

    from repro.core.strategies.reactive import LegacyStrategyAdapter

    cfg = sched.cfg
    db = sched.db
    # config-level refusals first: they name the *user-set* knob even when
    # a knob also changes the policy object (RecoveryPolicy wrapping)
    if getattr(sched, "durability", None) is not None:
        # fused rounds dispatch no per-event Python, so the write-ahead
        # journal would record nothing at their boundaries — crash points
        # inside a fused horizon would be unresumable
        return None, "durability journal active"
    if cfg.invocation_timeout or cfg.retry_budget or cfg.quarantine_threshold:
        return None, "retry/timeout recovery enabled"
    if cfg.quorum_fraction < 1.0:
        return None, "partial-cohort quorum enabled"
    if type(sched.policy) is not LegacyStrategyAdapter \
            or sched.policy.strategy.name != "apodotiko-topk":
        return None, "strategy is not adapter-wrapped apodotiko-topk"
    if not db.columnar:
        return None, "object control plane"
    if sched.update_plane != "device" or sched.store is None:
        return None, "blob update plane"
    if sched.data_plane != "device" or sched.dataset is None:
        return None, "host data plane"
    if cfg.eval_every:
        return None, "per-round evaluation enabled"
    if cfg.checkpoint_every:
        return None, "checkpointing enabled"
    if cfg.target_accuracy:
        return None, "target-accuracy early stop enabled"
    if cfg.failure_rate != 0.0:
        return None, "nonzero failure rate"
    faults = sched.platform.faults
    if faults is not None and faults.active and faults.stochastic:
        # stochastic faults perturb any round; outage windows are handled
        # below by shrinking the horizon to stop short of the window
        return None, "stochastic fault schedule active"
    if sched.strategy.needs_scaffold:
        return None, "scaffold variates"
    K = int(cfg.clients_per_round)
    if K <= 0:
        return None, "empty cohort"
    if sched.strategy.results_needed() < K:
        return None, "CR gate closes rounds before all K land"
    if any(not r.aggregated for r in db.results):
        return None, "un-aggregated results pending"
    if sched.inflight:
        return None, "invocations in flight"
    if sched._peek_timer() is not None:
        return None, "timer armed"
    if sched._progress is not None:
        return None, "progress callback installed (may mutate mid-run)"
    if sched.loop.peek() is not None:
        return None, "event loop not quiescent"

    fleet = db.fleet
    slots = np.flatnonzero(fleet.active)
    if slots.size == 0:
        return None, "no active clients"
    if np.any(fleet.status[slots] != IDLE):
        return None, "clients not idle"
    if np.any(fleet.n_invocations[slots] <= 0):
        return None, "bootstrap rounds remain (uninvoked clients)"
    if np.any(fleet.quarantined_until[slots] > db.round):
        return None, "clients quarantined"
    if slots.size < K:
        return None, "K exceeds idle-client count"
    ids = fleet.ids[slots].astype(np.int64)
    if int(ids.max()) >= sched.dataset.n_clients:
        return None, "client id outside resident dataset"
    for cid in ids:
        hw = sched.hw.get(int(cid))
        if hw is None or hw.variability != 0.0:
            return None, "client hardware has nonzero variability"
        if int(cid) not in sched.platform._instances:
            return None, "client has no platform instance"

    stack = sched.store.free_stack()
    leaves = jax.tree.leaves(sched.params)
    if len({l.dtype for l in leaves}) != 1:
        return None, "mixed model leaf dtypes (scan carry instability)"
    out_dtype = leaves[0].dtype

    # deterministic per-slot durations: warm startup (0.15, no uniform
    # draw), speed = hw.speed * exp(N(0, 0)) = hw.speed exactly, no
    # failure — the exact f64 expression platform.invoke evaluates
    platform = sched.platform
    n_all = np.asarray(sched.data.n)
    cap = fleet.capacity
    ids_col = np.zeros(cap, np.int32)
    n_col = np.ones(cap, n_all.dtype)
    steps_col = np.ones(cap, np.int64)
    d64_col = np.zeros(cap, np.float64)
    ids_col[slots] = ids
    n_col[slots] = n_all[ids]
    steps_col[slots] = np.maximum(
        np.ceil(n_col[slots] / cfg.batch_size).astype(np.int64)
        * cfg.local_epochs, 1)
    for s in slots:
        hw = sched.hw[int(ids_col[s])]
        d64_col[s] = ((0.15 + platform.model_load_s)
                      + float(steps_col[s]) * cfg.base_step_time / hw.speed
                      ) + platform.upload_s
    if float(np.sum(n_col[slots].astype(np.float64))) >= float(2 ** 24):
        return None, "sample counts too large for exact f32 weights"

    # horizon: every invocation must hit a warm instance and every round
    # must close inside the sim budget, under the conservative per-round
    # advance bound D = max duration over active clients
    t0 = float(sched.loop.now)
    D = float(d64_col[slots].max())
    warm_min = min(platform._instances[int(c)].warm_until for c in ids)
    R = int(cfg.rounds) - int(db.round)
    if D > 0:
        if warm_min < t0:
            R = 0
        else:
            R = min(R, int(np.floor((warm_min - t0) / D)) + 1)
        R = min(R, max(int(np.ceil((cfg.max_sim_time - t0) / D)) - 1, 0))
    while R > 0 and (t0 + (R - 1) * D > warm_min
                     or t0 + R * D >= cfg.max_sim_time):
        R -= 1
    if R < 1:
        return None, "no quiescent horizon (keep-warm or sim budget)"
    if faults is not None and faults.active:
        # deterministic outage windows: fused launches happen at t0 + r*D,
        # so shrink the horizon to stop strictly before any window that
        # overlaps it. A window already behind us (end <= t0) is ignored —
        # megastep re-engages once simulated time passes the outage.
        for w in faults.outage_windows():
            if w.end <= t0 or w.start >= t0 + R * D:
                continue
            if w.start > t0 and D > 0:
                R = min(R, int(np.floor((w.start - t0) / D + 1e-12)))
            else:
                R = 0
        if R < 1:
            return None, "fault window overlaps horizon"
    traffic = getattr(sched, "traffic", None)
    if traffic is not None:
        if traffic.stochastic:
            # the schedule is pre-compiled, but whether a fused horizon
            # stays membership-quiescent under a Poisson/diurnal source
            # is not provable from static facts — stepwise is the oracle
            return None, "stochastic traffic profile active"
        nb = sched._traffic_boundary()
        if nb is not None:
            # deterministic segment boundaries work like outage windows:
            # stepwise applies a segment at the first round *open* with
            # t >= start, and fused round r opens at t0 + r*D — so the
            # horizon must stop before the next unapplied boundary and
            # re-engage after _open_round applies it.
            if nb <= t0:
                return None, "traffic boundary overlaps horizon"
            if D > 0:
                R = min(R, int(np.ceil((nb - t0) / D - 1e-12)))
            if R < 1 or t0 + (R - 1) * D >= nb:
                return None, "traffic boundary overlaps horizon"

    from repro.core.aggregation import rows_dispatch
    from repro.core.scoring import promotion_rate

    try:
        fn, Kp, max_steps = sched.trainer.cohort_fn_indexed(
            sched.dataset, K, int(steps_col[slots].max()))
    except Exception:  # noqa: BLE001 — e.g. forced-pallas trace failure
        return None, "cohort fn compilation failed"
    if stack.size < Kp:
        return None, "update-store free list too small (would grow)"
    try:
        sparse, use_pallas, interpret = rows_dispatch(
            sched.store.capacity, K, sched.spec.n_params)
    except ValueError:
        return None, "unknown aggregation path"
    # mesh-compatibility obligation (DESIGN.md §15): the in-scan cohort fn
    # shard_maps its batch over "data" and the buffer is row-sharded, so
    # both geometries must split evenly — guaranteed by the trainer's
    # lcm'd cohort floor and the store's mesh-aware capacity alignment,
    # but proved here so a future geometry change degrades to stepwise
    # instead of tracing a shard_map error inside the scan
    mesh = getattr(sched, "mesh", None)
    if mesh is not None:
        from repro.sharding import flmesh
        d_ax = flmesh.mesh_axes(mesh)[0]
        if Kp % d_ax != 0:
            return None, "cohort bucket not divisible by mesh data axis"
        if sched.store.capacity % d_ax != 0:
            return None, "store capacity not divisible by mesh data axis"

    _, rank_col = np.unique(d64_col, return_inverse=True)
    return MegastepPlan(
        R=R, K=K, Kp=Kp, top=int(stack.size), fn=fn, max_steps=max_steps,
        sparse=sparse, use_pallas=use_pallas, interpret=interpret,
        out_dtype=out_dtype,
        beta32=np.float32(promotion_rate(cfg.adjustment_rate)),
        dec32=np.float32(fleet.decay),
        ids_col=ids_col, n_col=n_col,
        n32_col=n_col.astype(np.float32), steps_col=steps_col,
        card32_col=fleet.cardinality[:cap].astype(np.float32),
        upd32_col=fleet.upd32[:cap].copy(),
        d64_col=d64_col, d32_col=d64_col.astype(np.float32),
        rank_col=rank_col.astype(np.int32), mesh=mesh), "eligible"


def _build_scan(plan: MegastepPlan, spec):
    """The jitted R-round program. Cached on the static closure values —
    jax.jit's own cache layers shape/dtype specialization on top."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import aggregate_rows_traced, scored_topk

    from repro.sharding import flmesh

    key_ = (id(plan.fn), id(spec), plan.R, plan.K, plan.Kp, plan.top,
            plan.sparse, plan.use_pallas, plan.interpret,
            str(plan.out_dtype), *flmesh.mesh_token(plan.mesh))
    cached = _SCAN_CACHE.get(key_)
    if cached is not None:
        return cached

    R, K, Kp, top = plan.R, plan.K, plan.Kp, plan.top
    sparse, use_pallas, interpret = \
        plan.sparse, plan.use_pallas, plan.interpret
    out_dtype = plan.out_dtype
    fn = plan.fn
    mesh = plan.mesh

    @jax.jit
    def fused(params, buffer, stack, num, den, booster, key,
              eligible, ever, X, y,
              ids_col, n_col, n32_col, steps_col,
              card32_col, upd32_col, d32_col, rank_col,
              beta32, dec32):

        # Cohort-bucket pad maps, resolved at trace time: lane k >= K
        # repeats lane K-1's client and runs 0 steps — the values
        # _cohort_pad/train_cohort_indexed produce. Under a mesh these are
        # applied as constant-map GATHERS rather than the stepwise path's
        # concatenate-of-repeated-slice: that concatenate pattern is
        # miscompiled by the 0.4.x SPMD partitioner when a shard_map
        # coexists in the program (a spurious model-axis all-reduce scales
        # the values; see kernels.ops.aggregate_rows_traced). The gather
        # form produces bitwise the same integers on any mesh.
        pad_map = np.concatenate([np.arange(K), np.full(Kp - K, K - 1)]
                                 ).astype(np.int32)
        step_mask = np.concatenate([np.ones(K, bool), np.zeros(Kp - K, bool)])

        def body(carry, _):
            params, buffer, stack, num, den, booster, key = carry
            # -- selection: the exact select_topk program ------------------
            sel, valid, booster = scored_topk(
                num, den, booster, eligible, ever, beta32, K)
            # -- update rows: the UpdateStore LIFO pop sequence ------------
            ids = stack[top - Kp:top][::-1]
            # -- cohort train: same compiled fn, same padding, same keys ---
            if Kp > K and mesh is not None:
                sel_p = sel[jnp.asarray(pad_map)]
                steps_p = jnp.where(jnp.asarray(step_mask),
                                    steps_col[sel_p], 0)
            elif Kp > K:
                sel_p = jnp.concatenate([sel, jnp.repeat(sel[-1:], Kp - K)])
                steps_sel = steps_col[sel]
                steps_p = jnp.concatenate(
                    [steps_sel, jnp.zeros((Kp - K,), steps_sel.dtype)])
            else:
                sel_p = sel
                steps_p = steps_col[sel]
            cidx = ids_col[sel_p]
            n_p = n_col[sel_p]
            ks = jax.random.split(key)          # the _cohort_keys schedule
            key = ks[0]
            keys = jax.random.split(ks[1], Kp)
            cg = jax.tree.map(lambda p: jnp.zeros((), p.dtype), params)
            ci = jax.tree.map(
                lambda p: jnp.zeros((Kp,) + (1,) * p.ndim, p.dtype), params)
            buffer, _, losses = fn(params, cidx, n_p, steps_p, keys,
                                   cg, ci, X, y, buffer, ids)
            # -- f32 EMA fold per landing (the mark_complete twin) ---------
            s32 = card32_col[sel] * (
                upd32_col[sel]
                / jnp.maximum(d32_col[sel], jnp.float32(1e-9)))
            num = num.at[sel].set(s32 + dec32 * num[sel])
            den = den.at[sel].set(jnp.float32(1.0) + dec32 * den[sel])
            # -- aggregation in landing order ------------------------------
            perm = jnp.argsort(rank_col[sel], stable=True)
            rows_land = ids[:K][perm]
            w = n32_col[sel][perm]
            w = w / jnp.sum(w)
            flat = aggregate_rows_traced(
                buffer, rows_land, w, sparse=sparse,
                use_pallas=use_pallas, interpret=interpret, mesh=mesh)
            out = spec.unravel(flat[:spec.n_params], restore_dtype=False)
            params = jax.tree.map(lambda x: x.astype(out_dtype), out)
            # -- free-stack push algebra (pad frees, then landing frees) ---
            if mesh is not None:
                # two static-slice writes instead of a concatenate (same
                # SPMD-partitioner hazard as the pad maps above)
                stack = stack.at[top - Kp:top - K].set(ids[K:])
                stack = stack.at[top - K:top].set(rows_land)
            else:
                stack = stack.at[top - Kp:top].set(
                    jnp.concatenate([ids[K:], rows_land]))
            return ((params, buffer, stack, num, den, booster, key),
                    (sel, ids, losses[:K]))

        carry = (params, buffer, stack, num, den, booster, key)
        carry, ys = jax.lax.scan(body, carry, None, length=R)
        return carry, ys

    _SCAN_CACHE[key_] = fused
    return fused


def run_megastep(sched, plan: MegastepPlan) -> None:
    """Launch the fused scan, then replay the R rounds through the REAL
    host bookkeeping (platform, event loop, database, free-lists) with
    protocol emission suppressed — zero device dispatches, bit-identical
    end state. Cross-checks against the scan outputs raise on mismatch."""
    import jax.numpy as jnp

    cfg = sched.cfg
    db = sched.db
    fleet = db.fleet
    store = sched.store
    R, K, Kp = plan.R, plan.K, plan.Kp

    fleet._flush_device()               # fold pre-scan dirt into the carry
    dev = fleet._device()
    fused = _build_scan(plan, sched.spec)
    X, y = sched.dataset.arrays()
    carry, ys = fused(
        sched.params, store.buffer, jnp.asarray(store.free_stack()),
        dev.num, dev.den, dev.booster, sched.trainer._key,
        dev.eligible, dev.ever, X, y,
        jnp.asarray(plan.ids_col), jnp.asarray(plan.n_col),
        jnp.asarray(plan.n32_col), jnp.asarray(plan.steps_col),
        jnp.asarray(plan.card32_col), jnp.asarray(plan.upd32_col),
        jnp.asarray(plan.d32_col), jnp.asarray(plan.rank_col),
        jnp.float32(plan.beta32), jnp.float32(plan.dec32))
    params_f, buffer_f, _, _, _, booster_f, key_f = carry
    sel_np = np.asarray(ys[0])          # [R, K] selected slots
    ids_np = np.asarray(ys[1])          # [R, Kp] update rows
    losses_np = np.asarray(ys[2])       # [R, K]

    # ---- host replay: the real code paths, no device work ----------------
    from repro.core.services import RoundLog, _Payload

    strat = sched.strategy
    sched._emit = lambda ev: None       # instance attr shadows the method
    try:
        for r in range(R):
            round_ = db.round
            sched._t0 = sched.loop.now
            sched._invoked_this_round = True
            sched._completed_this_round = set()
            sel = sel_np[r]
            ids = store.alloc(Kp)
            if not np.array_equal(ids, ids_np[r]):
                raise RuntimeError("megastep: scan/alloc row-id mismatch")
            if Kp > K:
                store.free(ids[K:])
            for k in range(K):
                slot = int(sel[k])
                cid = int(plan.ids_col[slot])
                payload = _Payload(row=int(ids[k]))
                inv = sched._launch(cid, round_, float(plan.steps_col[slot]),
                                    payload, int(plan.n_col[slot]),
                                    float(losses_np[r, k]))
                if inv.rec.cold or inv.rec.failed \
                        or inv.rec.duration != plan.d64_col[slot]:
                    raise RuntimeError(
                        "megastep: replayed invocation diverged from plan")
            for _ in range(K):          # drain exactly this round's landings
                sched.loop.step()
            pending = [p for p in db.pending_results(cfg.max_staleness,
                                                     round_)
                       if strat.usable(p, round_)]
            perm = np.argsort(plan.rank_col[sel], kind="stable")
            rows_land = ids[:K][perm]
            if [p.update_row for p in pending] != rows_land.tolist():
                raise RuntimeError("megastep: landing-order mismatch")
            # aggregate_round's exact close sequence (params came from the
            # scan): free landing rows, then mark aggregated
            store.free(rows_land.tolist())
            db.mark_aggregated(pending)
            log = RoundLog(round=round_, t_start=sched._t0,
                           t_end=sched.loop.now, accuracy=sched._acc,
                           n_aggregated=K, n_stale=0, mean_loss=0.0)
            sched.history.append(log)   # _plan refused if _progress was set
            db.round = round_ + 1
    finally:
        vars(sched).pop("_emit", None)  # restore the class method

    # ---- device-state handoff -------------------------------------------
    sched.params = params_f
    store.buffer = buffer_f
    dev.booster = booster_f
    sched.trainer._key = key_f
    # num/den are NOT written back: the replayed mark_complete calls marked
    # every touched slot dirty, and the next _flush_device rebuilds them
    # from the f32 mirror columns — which the scan evolved with the exact
    # same algebra, so the rebuilt values equal the final carry bitwise.
    sched.megastep_scans += 1
    sched.megastep_rounds += R


def try_megastep(sched) -> bool:
    """Scheduler hook: plan, and if eligible run, one fused scan. Returns
    True when rounds were executed (the caller re-checks termination and
    may re-enter — completions extend keep-warm windows)."""
    plan, reason = _plan(sched)
    sched.megastep_fallback_reason = reason
    if plan is None:
        return False
    run_megastep(sched, plan)
    return True
