"""Shared FL execution substrate: config, state, and round services.

``controller.py``'s 515-line monolith is decomposed here (DESIGN.md §7):
:class:`FLRuntime` owns the execution state (model params, database,
platform, event loop, update store, SCAFFOLD variates) and exposes the
three round services both drivers share —

  * **invocation** (``invoke_round`` / ``hedge_invocations`` /
    ``cancel_client``): cohort-vectorized Client_Update, simulated FaaS
    invocation, completion/failure callbacks, and the in-flight registry
    with refcounted update payloads (hedge siblings share one trained
    update; the row/blob is freed exactly once, by whichever invocation
    ends last without landing it);
  * **aggregation** (``aggregate_round``): staleness x cardinality
    weighting (Eq. 2), device-row or blob transport, stale pruning;
  * **evaluation** (``evaluate``): the jitted masked-scan eval.

Drivers differ only in *when* they call the services: ``Controller``
keeps the legacy poll loop (Algorithm 1 verbatim); ``Scheduler``
dispatches typed protocol events to a reactive policy. Completions and
membership changes flow through the ``_emit`` hook — a no-op for the
legacy loop, the protocol dispatch for the scheduler.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows
from repro.core.client import CohortTrainer
from repro.core.data_plane import DatasetStore, dataset_store, resolve_data_plane
from repro.core.database import ClientRecord, Database, ResultRecord
from repro.core.protocol import (ClientJoined, ClientLeft, ClientsJoined,
                                 ClientsLeft, Event, InvocationFailed,
                                 InvocationTimedOut, ResultLanded)
from repro.core.scoring import decay_rate
from repro.core.strategies.base import Strategy, StrategyConfig, build_strategy
from repro.core.update_store import (UpdateStore, gather_stacked,
                                     grow_stacked, scatter_stacked_tree)
from repro.faas.cost import CostModel
from repro.faas.events import EventLoop
from repro.faas.faults import build_fault_model, resolve_fault_profile
from repro.traffic import (build_traffic_schedule, resolve_traffic_profile,
                           slo_summary)
from repro.faas.hardware import HardwareProfile
from repro.faas.platform import FaaSPlatform, InvocationRecord
from repro.kernels.ops import RavelSpec
from repro.sharding import flmesh

Pytree = Any

UPDATE_STORE_DIRNAME = "update_store"


def resolve_update_plane(mode: str) -> str:
    """'device' (default) | 'blob' (legacy pytree-blob path).
    Resolution: explicit config value > ``REPRO_UPDATE_PLANE`` > 'device'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_UPDATE_PLANE", "device")
    if mode not in ("device", "blob"):
        raise ValueError(f"unknown update plane {mode!r} "
                         "(expected 'device', 'blob', or 'auto')")
    return mode


def resolve_control_plane(mode: str) -> str:
    """'columnar' (default: struct-of-arrays FleetStore, vectorized
    scoring/selection) | 'object' (legacy per-client ClientRecord dict,
    kept as the equivalence oracle).
    Resolution: explicit config value > ``REPRO_CONTROL_PLANE`` > 'columnar'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_CONTROL_PLANE", "columnar")
    if mode not in ("columnar", "object"):
        raise ValueError(f"unknown control plane {mode!r} "
                         "(expected 'columnar', 'object', or 'auto')")
    return mode


def resolve_engine(mode: str) -> str:
    """'scheduler' (default: event-driven reactive protocol) | 'legacy'
    (the pre-redesign poll loop, kept as the equivalence oracle).
    Resolution: explicit config value > ``REPRO_ENGINE`` > 'scheduler'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_ENGINE", "scheduler")
    if mode not in ("scheduler", "legacy"):
        raise ValueError(f"unknown engine {mode!r} "
                         "(expected 'scheduler', 'legacy', or 'auto')")
    return mode


def resolve_megastep(mode: str) -> str:
    """'fused' (default: the scheduler opportunistically lowers runs of
    quiescent rounds into one jitted ``lax.scan`` megastep — see
    ``core.megastep``) | 'stepwise' (always drive rounds through the
    event-driven engine, the bit-exact oracle).
    Resolution: explicit config value > ``REPRO_MEGASTEP`` > 'fused'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_MEGASTEP", "fused")
    if mode not in ("fused", "stepwise"):
        raise ValueError(f"unknown megastep mode {mode!r} "
                         "(expected 'fused', 'stepwise', or 'auto')")
    return mode


def resolve_durability(mode: str) -> str:
    """'off' (default: no journal, no snapshots, zero extra work — every
    pre-existing trace bit-identical) | 'journal' (write-ahead event
    journal + coordinated round-boundary snapshots, DESIGN.md §14).
    Resolution: explicit config value > ``REPRO_DURABILITY`` > 'off'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_DURABILITY", "off")
    if mode not in ("off", "journal"):
        raise ValueError(f"unknown durability mode {mode!r} "
                         "(expected 'off', 'journal', or 'auto')")
    return mode


def resolve_durability_sync(mode: str) -> str:
    """'round' (default: fsync the journal at round boundaries only) |
    'event' (fsync every record — strongest, slowest).
    Resolution: explicit config value > ``REPRO_DURABILITY_SYNC`` >
    'round'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_DURABILITY_SYNC", "round")
    if mode not in ("event", "round"):
        raise ValueError(f"unknown durability sync policy {mode!r} "
                         "(expected 'event', 'round', or 'auto')")
    return mode


@dataclass
class FLConfig:
    """Experiment configuration. Each field maps to a paper quantity
    (symbol / section noted inline) or a simulator knob.

    Paper defaults (IV-A): 200 clients, 100 per round, E=5 local epochs,
    batch 10 (MNIST), Adam 1e-3, CR=0.3, rho=0.2, staleness cap 5."""

    # -- population & schedule -------------------------------------------------
    n_clients: int = 200           # total registered clients (paper IV-A3: 200)
    clients_per_round: int = 100   # |clients| invoked per round ("100/round")
    rounds: int = 50               # max global rounds T
    target_accuracy: Optional[float] = None  # early stop (Alg. 1 line 3)
    # -- Client_Update (Alg. 2) ------------------------------------------------
    local_epochs: int = 5          # E, local epochs per invocation
    batch_size: int = 10           # B, local minibatch size
    optimizer: str = "adam"        # client-side optimizer (paper: Adam/SGD)
    lr: float = 1e-3               # client learning rate eta
    # -- strategy (Alg. 1 / Alg. 3) --------------------------------------------
    strategy: str = "apodotiko"    # STRATEGIES key or a reactive policy name
    #                                 (repro.core.strategies.reactive)
    concurrency_ratio: float = 0.3  # CR: aggregate at ceil(CR x clientsPerRound)
    #                                 results (Alg. 1 line 9; Fig. 6 sweeps it)
    adjustment_rate: float = 0.2   # rho: booster step for the CEF score
    #                                 (Alg. 3; score = booster x CEF, §III-A)
    max_staleness: int = 5         # staleness cap: results from at most this
    #                                 many previous rounds aggregate (§III-B)
    round_timeout: float = 300.0   # sync-strategy round deadline, sim-seconds
    hedge_fraction: float = 0.5    # apodotiko-hedge: fraction of outstanding
    #                                 invocations speculatively re-invoked at
    #                                 the CR gate (slowest first)
    # -- FaaS platform simulation (§IV-A) --------------------------------------
    keep_warm: float = 600.0       # provider keep-warm window before
    #                                 scale-to-zero, sim-seconds
    cold_start_s: float = 8.0      # container cold-start penalty, sim-seconds
    base_step_time: float = 0.05   # 1vCPU-seconds per optimizer step
    #                                 (hardware profiles scale this, Fig. 1/3)
    failure_rate: float = 0.0      # P(invocation crash) — fault tolerance
    fault_profile: str = "auto"    # fault injection (DESIGN.md §12): a
    #                                 FAULT_PROFILES name ("crash-heavy",
    #                                 "outage-window", "lossy-network") or a
    #                                 raw faults.parse_faults spec string;
    #                                 "auto" defers to REPRO_FAULTS (default
    #                                 off — no extra RNG draws, every
    #                                 pre-existing trace bit-identical)
    traffic_profile: str = "auto"  # open-loop traffic (DESIGN.md §13): a
    #                                 TRAFFIC_PROFILES name ("steady-churn",
    #                                 "diurnal", "flash-crowd", "trace-demo")
    #                                 or a raw traffic.parse_traffic spec;
    #                                 "auto" defers to REPRO_TRAFFIC (default
    #                                 off — fixed fleet, no extra RNG draws,
    #                                 every pre-existing trace bit-identical)
    # -- recovery layer (DESIGN.md §12; scheduler engine only) -----------------
    invocation_timeout: float = 0.0  # per-invocation kill timer, sim-seconds
    #                                 (distinct from round_timeout; 0 = off)
    retry_budget: int = 0          # max retries per round (0 = no retries)
    retry_base_delay: float = 2.0  # backoff: delay = base * backoff^(k-1)
    retry_backoff: float = 2.0     #   * (1 + jitter * U[0,1)) for the k-th
    retry_jitter: float = 0.1      #   retry of a client within a round
    quarantine_threshold: int = 0  # circuit breaker: quarantine a client
    #                                 after this many consecutive failures
    #                                 (0 = off)
    quarantine_rounds: int = 3     # rounds a quarantined client sits out
    quorum_fraction: float = 1.0   # sync rounds aggregate once this cohort
    #                                 fraction completed (graceful
    #                                 degradation; 1.0 = legacy full gate)
    # -- aggregation (§III-B) --------------------------------------------------
    prox_mu: float = 0.01          # mu, FedProx proximal coefficient
    staleness_fn: str = "eq2"      # "eq2" = 1/sqrt(T - t_i + 1) (Eq. 2,
    #                                 Apodotiko) | "eq1" = t_i/T (FedLesScan)
    update_plane: str = "auto"     # client-update transport: "device" keeps
    #                                 updates as rows of one device-resident
    #                                 [capacity, N] buffer (zero host
    #                                 round-trips per round); "blob" is the
    #                                 legacy host-pytree path; "auto" defers
    #                                 to REPRO_UPDATE_PLANE (default device)
    engine: str = "auto"           # round driver: "scheduler" (event-driven
    #                                 reactive protocol, the default) |
    #                                 "legacy" (pre-redesign poll loop);
    #                                 "auto" defers to REPRO_ENGINE
    control_plane: str = "auto"    # per-client fleet state: "columnar"
    #                                 (default) keeps status/scores/duration
    #                                 rings in struct-of-arrays columns with
    #                                 vectorized scoring + selection (scales
    #                                 to 1e6 clients); "object" is the
    #                                 legacy per-client ClientRecord dict,
    #                                 kept as the bit-exact oracle; "auto"
    #                                 defers to REPRO_CONTROL_PLANE
    data_plane: str = "auto"       # training-input transport: "device"
    #                                 keeps the federated dataset resident
    #                                 on device and the jitted cohort fn
    #                                 gathers minibatches by client index
    #                                 (zero H2D training-input bytes per
    #                                 round); "host" is the legacy
    #                                 fancy-index + per-dispatch upload;
    #                                 "auto" defers to REPRO_DATA_PLANE
    #                                 (default device)
    megastep: str = "auto"         # fused-round execution: "fused"
    #                                 (default) lets the scheduler lower
    #                                 runs of quiescent rounds into one
    #                                 jitted lax.scan (zero Python
    #                                 dispatches per round) with automatic
    #                                 fallback to the event-driven engine;
    #                                 "stepwise" disables the fast path;
    #                                 "auto" defers to REPRO_MEGASTEP
    durability: str = "auto"       # durable runs (DESIGN.md §14): "journal"
    #                                 write-ahead-journals every protocol
    #                                 event and snapshots all planes at
    #                                 round boundaries so a killed run
    #                                 resumes bit-identically
    #                                 (durability.resume_durable); "off"
    #                                 does nothing; "auto" defers to
    #                                 REPRO_DURABILITY (default off)
    durability_sync: str = "auto"  # journal fsync policy: "event" (every
    #                                 record) | "round" (round boundaries
    #                                 only, the default); "auto" defers to
    #                                 REPRO_DURABILITY_SYNC
    durability_snap_every: int = 1  # coordinated snapshot every k closed
    #                                 rounds (journal validation covers the
    #                                 re-executed gap on resume)
    mesh: str = "auto"             # device mesh (DESIGN.md §15): "1x1"
    #                                 (default — the single-device path,
    #                                 bit-exact oracle) or "<data>x<model>"
    #                                 to shard the update-store rows, the
    #                                 cohort batch, and the weighted-psum
    #                                 aggregation over a (data, model)
    #                                 mesh; "auto" defers to REPRO_MESH
    #                                 (default 1x1). Meshes > 1x1 require
    #                                 the device update AND data planes.
    # -- harness ---------------------------------------------------------------
    eval_every: int = 1            # evaluate global model every k rounds
    seed: int = 0                  # RNG seed: selection, init, platform noise
    max_sim_time: float = 1e8      # simulated wall-clock budget, seconds
    checkpoint_dir: Optional[str] = None  # database checkpoint location
    checkpoint_every: int = 0      # checkpoint every k rounds (0 = off)


def strategy_config(cfg: FLConfig) -> StrategyConfig:
    """The strategy-facing slice of ``FLConfig``."""
    return StrategyConfig(
        clients_per_round=cfg.clients_per_round,
        concurrency_ratio=cfg.concurrency_ratio,
        adjustment_rate=cfg.adjustment_rate,
        max_staleness=cfg.max_staleness,
        round_timeout=cfg.round_timeout,
        prox_mu=cfg.prox_mu,
        staleness_fn=cfg.staleness_fn,
        hedge_fraction=cfg.hedge_fraction,
        quorum_fraction=cfg.quorum_fraction,
        seed=cfg.seed)


@dataclass
class RoundLog:
    round: int
    t_start: float
    t_end: float
    accuracy: float
    n_aggregated: int
    n_stale: int
    mean_loss: float


@dataclass
class _Payload:
    """One trained client update, shared by an invocation and its hedge
    siblings. Freed exactly once: either ownership passes to the landed
    ``ResultRecord`` (``landed``) or the last reference releases it."""

    row: int = -1          # UpdateStore row handle (device plane)
    blob: Any = None       # host pytree (blob plane)
    refs: int = 1
    landed: bool = False


@dataclass
class Inflight:
    """Registry entry for one live invocation (the satellite fix for
    ``remove_clients`` and the substrate for Hedge/CancelInvocation)."""

    client_id: int
    round: int
    steps: float
    t_invoked: float
    rec: InvocationRecord
    payload: _Payload
    n_samples: int
    loss: float
    is_hedge: bool = False
    done: bool = False
    event: Any = None      # the loop completion event (cancellable)


class FLRuntime:
    """State + round services shared by the legacy ``Controller`` loop and
    the event-driven ``Scheduler`` (see module docstring)."""

    engine_name = "runtime"

    def __init__(self, cfg: FLConfig, model, data, fleet: list[HardwareProfile],
                 *, db: Optional[Database] = None,
                 init_params: Optional[Pytree] = None,
                 strategy: Optional[Strategy] = None):
        self.cfg = cfg
        self.model = model
        self.data = data        # FederatedDataset (repro.data)
        self.fleet = fleet
        self.loop = EventLoop()
        # fault injection (faas.faults): off by default — the model owns a
        # separate RNG stream, so the platform's legacy draw order (the
        # golden-trace bit-identity anchor) is untouched either way
        self.fault_profile = resolve_fault_profile(cfg.fault_profile)
        self.platform = FaaSPlatform(
            keep_warm=cfg.keep_warm, cold_start_s=cfg.cold_start_s,
            seed=cfg.seed, failure_rate=cfg.failure_rate,
            faults=build_fault_model(self.fault_profile, cfg.seed))
        self.cost_model = CostModel()
        # open-loop traffic (repro.traffic, DESIGN.md §13): off by
        # default. The whole arrival process is compiled once, ahead of
        # the run, from its own numpy RNG stream — platform/trainer draw
        # order is untouched either way, and the off path compiles
        # nothing, so every pre-existing trace is bit-identical
        self.traffic_profile = resolve_traffic_profile(cfg.traffic_profile)
        self.traffic = build_traffic_schedule(
            self.traffic_profile, cfg.n_clients, seed=cfg.seed,
            horizon_cap=cfg.max_sim_time)
        self._traffic_pos = 0       # next unapplied schedule segment
        self.n_traffic_joins = 0
        self.n_traffic_leaves = 0
        self.strategy: Strategy = (
            strategy if strategy is not None
            else build_strategy(cfg.strategy, strategy_config(cfg)))
        # mesh plane (DESIGN.md §15): "1x1" resolves to mesh=None — the
        # unchanged single-device path, nothing constructed or re-placed
        self.mesh_spec = flmesh.resolve_mesh(cfg.mesh)
        self.mesh = flmesh.build_fl_mesh(self.mesh_spec)
        self.trainer = CohortTrainer(
            model, optimizer=cfg.optimizer, lr=cfg.lr,
            batch_size=cfg.batch_size, prox_mu=self.strategy.prox_mu,
            scaffold=self.strategy.needs_scaffold, seed=cfg.seed,
            mesh=self.mesh)

        # control plane: a restored checkpoint's plane is authoritative
        # (its client state is stored in that representation)
        self.control_plane = (db.control_plane if db is not None
                              else resolve_control_plane(cfg.control_plane))
        self.db = db or Database(control_plane=self.control_plane)
        if self.db.columnar:
            # incremental-EMA decay (lambda = 1 - rho) for the device
            # score state; the bit-exact windowed path re-derives it from
            # the strategy config at each selection
            self.db.fleet.decay = decay_rate(cfg.adjustment_rate)
        if db is None:
            if self.traffic is not None:
                # open-loop: only the schedule's initial membership exists
                # at t=0; later arrivals land via bulk traffic segments
                init = self.traffic.initial
                self.db.register_clients_bulk(
                    init, data.n[init], cfg.batch_size, cfg.local_epochs,
                    hardware=[fleet[int(c)].name for c in init])
            else:
                for cid in range(cfg.n_clients):
                    self.db.register_client(ClientRecord(
                        client_id=cid, hardware=fleet[cid].name,
                        data_cardinality=int(data.n[cid]),
                        batch_size=cfg.batch_size,
                        local_epochs=cfg.local_epochs))
        self.hw = {cid: fleet[cid] for cid in range(len(fleet))}
        # never pruned: cost/metrics must resolve hardware for historical
        # invocations of since-removed clients
        self._hw_history = dict(self.hw)
        # client id -> position in ``fleet``: removal must drop the entry
        # the id owns, not the first list entry that compares equal (two
        # clients may share one HardwareProfile object)
        self._fleet_pos = {cid: cid for cid in range(len(fleet))}

        rng = jax.random.PRNGKey(cfg.seed)
        if init_params is not None:
            self.params = init_params
        elif self.db.global_models:
            self.params = jax.tree.map(jnp.asarray, self.db.latest_global())
        else:
            self.params = model.init(rng)[0]
        # SCAFFOLD state: c_global plus a persistent device-resident
        # stacked buffer of per-client control variates, indexed by client
        # id — cohort gathers/scatters are device ops, replacing the old
        # per-round host dict + jnp.stack
        self.c_global = None
        self.c_buf: Optional[Pytree] = None
        self._c_cap = 0
        if self.strategy.needs_scaffold:
            self.c_global = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                         self.params)
            self._ensure_c_capacity(max(cfg.n_clients, 1))
        self.history: list[RoundLog] = []
        self._acc = 0.0             # last evaluated accuracy (carried across
        #                             rounds when eval_every > 1; lives on the
        #                             runtime so a durable resume restores it)
        self._eval_fn = jax.jit(model.accuracy)
        self._eval_scan = None      # (jitted fn, padded arrays) built lazily
        self._completed_this_round: set[int] = set()
        self.inflight: dict[int, list[Inflight]] = {}
        self.n_hedges = 0           # speculative re-invocations issued
        self.n_hedge_wins = 0       # hedges that beat their original
        self.n_cancelled = 0        # invocations cancelled (race/explicit)
        # recovery-layer observability (DESIGN.md §12)
        self.n_retries = 0          # backoff re-invocations fired
        self.n_timeouts = 0         # invocations killed by the timeout
        self.n_quarantined = 0      # circuit-breaker quarantines issued
        self.retry_latency_s = 0.0  # total failure->retry delay, sim-seconds

        # -- update plane: device-resident flat-buffer client updates ------
        self.update_plane = resolve_update_plane(cfg.update_plane)
        self.spec = RavelSpec(self.params)
        self.store: Optional[UpdateStore] = None
        self.update_host_bytes = 0  # bytes moved host<->device for updates
        if db is not None:
            self._check_plane_compatible(db)
        if self.update_plane == "device":
            self.store = UpdateStore(
                self.spec.n_params,
                capacity=max(cfg.clients_per_round, 1),
                mesh=self.mesh)
            if db is not None and cfg.checkpoint_dir:
                self._rehydrate_store()

        # -- data plane: device-resident training inputs -------------------
        self.data_plane = resolve_data_plane(cfg.data_plane)
        self.dataset: Optional[DatasetStore] = None
        if self.data_plane == "device":
            # one resident upload per dataset object (cached across runs)
            self.dataset = dataset_store(data, mesh=self.mesh)
        if self.mesh is not None and (self.update_plane != "device"
                                      or self.data_plane != "device"):
            raise ValueError(
                f"mesh {self.mesh_spec!r} requires the device update and "
                f"data planes (got update_plane={self.update_plane!r}, "
                f"data_plane={self.data_plane!r}): the blob/host paths "
                "move every row through the host and cannot shard")

        # -- durability plane (DESIGN.md §14): off by default — no journal,
        # no snapshots, no RNG draws, every pre-existing trace bit-identical
        self.durability = None
        if resolve_durability(cfg.durability) == "journal":
            from repro.durability.manager import DurabilityManager
            self.durability = DurabilityManager(self)

    # -- driver view contract (protocol.DatabaseView reads these) ------------
    @property
    def current_round(self) -> int:
        return self.db.round

    @property
    def round_start(self) -> float:
        return getattr(self, "_t0", 0.0)

    def _check_plane_compatible(self, db: Database) -> None:
        """A checkpoint written under one update plane cannot feed pending
        results to the other: blob records carry update_row=-1 (which would
        silently index the last buffer row) and device records carry no
        blob. Switching planes across a resume is fine once nothing is
        in flight."""
        saved = db.meta.get("update_plane")
        if saved is None or saved == self.update_plane:
            return
        if any(not r.aggregated for r in db.results):
            raise ValueError(
                f"checkpoint was written with update_plane={saved!r} and "
                f"has un-aggregated results; resuming with "
                f"update_plane={self.update_plane!r} would corrupt them — "
                f"set REPRO_UPDATE_PLANE={saved} (or cfg.update_plane) to "
                f"resume, or aggregate before switching planes")

    def _rehydrate_store(self) -> None:
        """Resume path: reload the live un-aggregated update rows saved at
        checkpoint time, at their original ids so ResultRecord handles in
        the restored database stay valid."""
        from repro.checkpoint import restore_update_store
        d = os.path.join(self.cfg.checkpoint_dir, UPDATE_STORE_DIRNAME)
        if not os.path.isdir(d):
            return
        ids, rows, n_params = restore_update_store(d)
        if n_params != self.spec.n_params:
            raise ValueError(
                f"update-store checkpoint has N={n_params} params but the "
                f"model has N={self.spec.n_params}")
        self.store.write_at(ids, rows)

    # ------------------------------------------------------- SCAFFOLD buffer
    def _ensure_c_capacity(self, n: int) -> None:
        """Grow the control-variate buffer to hold client ids < ``n``
        (amortized doubling, zero-initialized new rows)."""
        if n <= self._c_cap:
            return
        cap = max(n, 2 * self._c_cap)
        if self.c_buf is None:
            self.c_buf = jax.tree.map(
                lambda p: jnp.zeros((cap,) + p.shape, jnp.float32),
                self.params)
        else:
            self.c_buf = grow_stacked(self.c_buf, self._c_cap, cap)
        self._c_cap = cap

    # ---------------------------------------------------------------- elastic
    def add_clients(self, records: list[ClientRecord],
                    profiles: list[HardwareProfile]) -> None:
        for rec, hw in zip(records, profiles):
            self.db.register_client(rec)
            self.hw[rec.client_id] = hw
            self._hw_history[rec.client_id] = hw
            self._fleet_pos[rec.client_id] = len(self.fleet)
            self.fleet.append(hw)
            if self.c_buf is not None:
                self._ensure_c_capacity(rec.client_id + 1)
            self._emit(ClientJoined(t=self.loop.now, client_id=rec.client_id))

    def remove_clients(self, client_ids: list[int]) -> None:
        """Deregister clients mid-run: cancel their in-flight invocations
        (releasing update rows/blobs), drop their hardware profile from
        ``hw`` and ``fleet`` (by the id's recorded fleet position — a
        ``list.remove`` identity scan would evict the wrong entry when two
        clients share one HardwareProfile object), and emit ``ClientLeft``
        through the protocol."""
        for cid in client_ids:
            for inv in list(self.inflight.get(cid, ())):
                self._cancel_inflight(inv)
            self.inflight.pop(cid, None)
            if not self.db.unregister_client(cid):
                continue
            if self.c_buf is not None and cid < self._c_cap:
                # a rejoining id must start from zero variates, like any
                # fresh client
                self.c_buf = jax.tree.map(
                    lambda b: b.at[cid].set(0.0), self.c_buf)
            self.hw.pop(cid, None)
            pos = self._fleet_pos.pop(cid, None)
            if pos is not None:
                del self.fleet[pos]
                for c, p in self._fleet_pos.items():
                    if p > pos:
                        self._fleet_pos[c] = p - 1
            self._emit(ClientLeft(t=self.loop.now, client_id=cid))

    # ------------------------------------------------------------- traffic
    def _traffic_boundary(self) -> Optional[float]:
        """Start time of the next unapplied traffic segment (None when
        traffic is off or the schedule is exhausted)."""
        if (self.traffic is None
                or self._traffic_pos >= len(self.traffic.segments)):
            return None
        return self.traffic.segments[self._traffic_pos].start

    def _apply_due_traffic(self) -> bool:
        """Apply every compiled traffic segment with start <= now (both
        engines call this at fresh-round open). Returns True if fleet
        membership changed."""
        applied = False
        while True:
            nb = self._traffic_boundary()
            if nb is None or nb > self.loop.now:
                return applied
            seg = self.traffic.segments[self._traffic_pos]
            self._traffic_pos += 1
            self._apply_traffic_segment(seg)
            applied = True

    def _apply_traffic_segment(self, seg) -> None:
        """One bulk membership delta: leaves first (cancelling their
        in-flight work and reclaiming their platform instances), then
        joins — one columnar scatter + one append instead of per-event
        Python. The hardware universe (``fleet``/``hw``/``_fleet_pos``)
        is untouched: traffic ids live in the fixed [0, n_clients)
        universe, so a departed id keeps its profile for its eventual
        re-join (unlike ``remove_clients``, which retires an id for
        good)."""
        now = self.loop.now
        leaves = [int(c) for c in seg.leaves if self.db.has_client(int(c))]
        if leaves:
            for cid in leaves:
                for inv in list(self.inflight.get(cid, ())):
                    self._cancel_inflight(inv)
                self.inflight.pop(cid, None)
            self.db.unregister_clients_bulk(leaves)
            # departed containers scale to zero: a re-join under the same
            # id pays a fresh cold start (cold-start-rate SLO accounting)
            self.platform.scale_down(leaves)
            if self.c_buf is not None:
                idx = jnp.asarray([c for c in leaves if c < self._c_cap],
                                  jnp.int32)
                if idx.size:
                    self.c_buf = jax.tree.map(
                        lambda b: b.at[idx].set(0.0), self.c_buf)
            self.n_traffic_leaves += len(leaves)
            self._emit(ClientsLeft(t=now, client_ids=tuple(leaves)))
        joins = [int(c) for c in seg.joins
                 if not self.db.has_client(int(c))]
        if joins:
            self.db.register_clients_bulk(
                joins, self.data.n[joins], self.cfg.batch_size,
                self.cfg.local_epochs,
                hardware=[self.fleet[c].name for c in joins])
            if self.c_buf is not None:
                self._ensure_c_capacity(max(joins) + 1)
            self.n_traffic_joins += len(joins)
            self._emit(ClientsJoined(t=now, client_ids=tuple(joins)))

    def _traffic_fast_forward(self) -> bool:
        """The run is stalled — no pending events and no idle client.
        Under closed-loop scenarios that ends the run; under open-loop
        traffic the clock jumps to the next arrival boundary instead and
        applies it. Returns True when the jump changed membership (so the
        caller re-opens selection)."""
        nb = self._traffic_boundary()
        if nb is None or nb >= self.cfg.max_sim_time:
            return False
        if self.loop.peek() is not None:
            return False
        self.loop.now = max(self.loop.now, nb)
        return self._apply_due_traffic()

    # -------------------------------------------------- protocol emit hook
    def _emit(self, event: Event) -> None:
        """Protocol dispatch hook: journal-only for the legacy loop; the
        ``Scheduler`` overrides this to hand the event to its policy
        (which journals at the top of ``_dispatch`` instead)."""
        if self.durability is not None:
            self.durability.record_event(event)

    def _durability_round_closed(self) -> None:
        """Both engines call this immediately after ``db.round``
        advances: the round-close journal marker plus, on cadence, the
        coordinated snapshot (repro.durability)."""
        if self.durability is not None:
            self.durability.on_round_closed()

    # -------------------------------------------------- invocation service
    def invoke_round(self, round_: int, selection: list[int],
                     *, reset_completed: bool = True) -> None:
        """Train the selected cohort against the current global model and
        start their simulated invocations. ``reset_completed`` clears the
        sync gating set — the first invocation of a round does, follow-up
        reinforcements must not."""
        cfg = self.cfg
        if reset_completed:
            self._completed_this_round = set()
        n_i = self.data.n[selection]
        steps = np.ceil(n_i / cfg.batch_size).astype(np.int64) * cfg.local_epochs
        steps = np.maximum(steps, 1)

        # real local training, cohort-vectorized (global model of *this* round)
        cg = self.c_global
        ci = None
        if self.strategy.needs_scaffold:
            # device gather out of the persistent variate buffer (replaces
            # the old per-round host dict lookup + jnp.stack)
            self._ensure_c_capacity(max(selection) + 1)
            sel_idx = jnp.asarray(np.asarray(selection, np.int32))
            ci = gather_stacked(self.c_buf, sel_idx)
        device = self.update_plane == "device"
        sink = self.store if device else None
        if self.data_plane == "device":
            # out-of-range selections already raised at the data.n[...]
            # fancy-index above — the resident device gather (which would
            # clamp silently) can never see one
            out, ci_new, losses = self.trainer.train_cohort_indexed(
                self.params, self.dataset, selection, n_i, steps, cg, ci,
                update_sink=sink)
        else:
            out, ci_new, losses = self.trainer.train_cohort(
                self.params, self.data.X[selection], self.data.y[selection],
                n_i, steps, cg, ci, update_sink=sink)
        if device:
            # trained models never left the device: the jitted cohort fn
            # scattered them into the store's persistent row buffer; only
            # the [K] row handles come back
            row_ids = out
        else:
            out = jax.tree.map(np.asarray, out)  # host copies
            self.update_host_bytes += sum(
                l.nbytes for l in jax.tree.leaves(out))
        if self.strategy.needs_scaffold:
            self._apply_scaffold_updates(selection, ci_new)

        for k, cid in enumerate(selection):
            payload = (_Payload(row=int(row_ids[k])) if device
                       else _Payload(blob=jax.tree.map(lambda x: x[k], out)))
            self._launch(cid, round_, float(steps[k]), payload,
                         int(n_i[k]), float(losses[k]))

    def _launch(self, cid: int, round_: int, steps: float, payload: _Payload,
                n_samples: int, loss: float, *, is_hedge: bool = False
                ) -> Inflight:
        rec = self.platform.invoke(cid, round_, self.loop.now, steps,
                                   self.hw[cid], self.cfg.base_step_time)
        self.db.mark_running(cid, round_)
        inv = Inflight(client_id=cid, round=round_, steps=steps,
                       t_invoked=self.loop.now, rec=rec, payload=payload,
                       n_samples=n_samples, loss=loss, is_hedge=is_hedge)
        inv.event = self.loop.schedule(rec.duration,
                                       lambda: self._complete(inv))
        self.inflight.setdefault(cid, []).append(inv)
        return inv

    def _complete(self, inv: Inflight) -> None:
        """Completion callback: land the result (or record the failure),
        settle the payload, and cancel any losing hedge siblings."""
        inv.done = True
        self._drop_inflight(inv)
        pay = inv.payload
        siblings = [o for o in self.inflight.get(inv.client_id, ())
                    if o.round == inv.round and not o.done]
        if inv.rec.failed:
            if siblings:
                # a hedge is still racing: count the failure but keep the
                # client marked running for the surviving invocation
                self.db.incr_failures(inv.client_id)
            else:
                self.db.mark_failed(inv.client_id)
            pay.refs -= 1
            if pay.refs <= 0 and not pay.landed:
                self._free_payload(pay)
            self._emit(InvocationFailed(t=self.loop.now, round=inv.round,
                                        client_id=inv.client_id))
            return
        train_dur = inv.rec.duration  # includes startup/load/upload
        self.db.mark_complete(inv.client_id, train_dur)
        result = ResultRecord(client_id=inv.client_id, round=inv.round,
                              n_samples=inv.n_samples,
                              train_duration=train_dur,
                              t_available=self.loop.now)
        if self.update_plane == "device":
            self.db.put_update_row(result, pay.row)
        else:
            self.db.put_update(result, pay.blob)
        pay.landed = True
        pay.refs -= 1
        self._completed_this_round.add(inv.client_id)
        if inv.is_hedge:
            self.n_hedge_wins += 1
        for sib in siblings:        # losers of the hedge race
            self._cancel_inflight(sib)
        self._emit(ResultLanded(t=self.loop.now, round=inv.round,
                                result=result))

    def _drop_inflight(self, inv: Inflight) -> None:
        invs = self.inflight.get(inv.client_id)
        if invs and inv in invs:
            invs.remove(inv)
            if not invs:
                self.inflight.pop(inv.client_id, None)

    def _cancel_inflight(self, inv: Inflight) -> None:
        if inv.done:
            return
        inv.done = True
        self.loop.cancel(inv.event)
        self._drop_inflight(inv)
        # bill only the elapsed fraction and stop the container clocks —
        # unless a sibling invocation still runs on the instance (its own
        # completion then bounds the busy/keep-warm horizon)
        live = [i.rec.t_completed
                for i in self.inflight.get(inv.client_id, ()) if not i.done]
        self.platform.cancel(inv.rec, self.loop.now,
                             live_until=max(live) if live else None)
        self.n_cancelled += 1
        pay = inv.payload
        pay.refs -= 1
        if pay.refs <= 0 and not pay.landed:
            self._free_payload(pay)

    def timeout_invocation(self, inv: Inflight) -> None:
        """Kill an in-flight invocation that outlived the per-invocation
        timeout (the recovery layer's ``FLConfig.invocation_timeout``):
        the container is cancelled at ``now``, the payload released, the
        failure counted against the client, and ``InvocationTimedOut``
        emitted so the recovery policy can retry or quarantine."""
        if inv.done:
            return
        inv.done = True
        self.loop.cancel(inv.event)
        self._drop_inflight(inv)
        live = [i.rec.t_completed
                for i in self.inflight.get(inv.client_id, ()) if not i.done]
        self.platform.cancel(inv.rec, self.loop.now,
                             live_until=max(live) if live else None)
        inv.rec.failed = True
        inv.rec.timed_out = True
        inv.rec.failed_phase = "timeout"
        pay = inv.payload
        pay.refs -= 1
        if pay.refs <= 0 and not pay.landed:
            self._free_payload(pay)
        if live:
            self.db.incr_failures(inv.client_id)    # a sibling still races
        else:
            self.db.mark_failed(inv.client_id)
        self.n_timeouts += 1
        self._emit(InvocationTimedOut(t=self.loop.now, round=inv.round,
                                      client_id=inv.client_id))

    def _free_payload(self, pay: _Payload) -> None:
        if self.update_plane == "device" and pay.row >= 0:
            self.store.free([pay.row])
        pay.blob = None

    def cancel_client(self, cid: int) -> None:
        """Cancel every live invocation of ``cid`` and return the client
        to the idle pool (the ``CancelInvocation`` action)."""
        for inv in list(self.inflight.get(cid, ())):
            self._cancel_inflight(inv)
        self.db.release_client(cid)

    def hedge_invocations(self, cids: list[int]) -> list[int]:
        """Speculatively re-invoke the outstanding invocation of each
        client on its (still-warm, per the keep-warm window the original
        opened) container. The hedge reuses the original's trained update
        — same data, same global model — and races its simulated duration;
        ``_complete`` settles the race. Returns the clients hedged."""
        launched = []
        for cid in cids:
            if not self.db.has_client(cid) or cid not in self.hw:
                continue
            invs = self.inflight.get(cid, ())
            if any(i.is_hedge and not i.done for i in invs):
                continue            # already hedged
            live = [i for i in invs if not i.done and not i.is_hedge]
            if not live:
                continue
            orig = live[0]
            orig.payload.refs += 1
            self._launch(cid, orig.round, orig.steps, orig.payload,
                         orig.n_samples, orig.loss, is_hedge=True)
            self.n_hedges += 1
            launched.append(cid)
        return launched

    def _apply_scaffold_updates(self, selection, ci_new) -> None:
        """c <- c + sum(c_i' - c_i) / N_total, entirely on device: the old
        variates are gathered out of the persistent buffer, the delta is a
        stacked-axis reduction, and the new variates scatter back in
        place — no per-client host pytrees."""
        sel_idx = jnp.asarray(np.asarray(selection, np.int32))
        old = gather_stacked(self.c_buf, sel_idx)
        n_total = max(self.db.n_clients, 1)
        self.c_global = jax.tree.map(
            lambda c, nw, o: c + jnp.sum(nw - o, axis=0) / n_total,
            self.c_global, ci_new, old)
        self.c_buf = scatter_stacked_tree(self.c_buf, sel_idx, ci_new)

    # ------------------------------------------------- aggregation service
    def aggregate_round(self, round_: int) -> tuple[int, int, float]:
        strat = self.strategy
        pending = [r for r in self.db.pending_results(self.cfg.max_staleness, round_)
                   if strat.usable(r, round_)]
        if not pending:
            return 0, 0, float("nan")
        weights = np.array([strat.result_weight(r, round_) for r in pending],
                           np.float64)
        total = weights.sum()
        if not np.isfinite(total) or total <= 0:
            # e.g. Eq. 1 zeroes round-0 updates at T=1: fall back to
            # cardinality weighting so the aggregation stays well-defined
            weights = np.array([r.n_samples for r in pending], np.float64)
            total = weights.sum() or 1.0
        # cast THEN normalize in f32: when the weights are integer-valued
        # (the all-current-round case — eq2(T,T)=1 exactly, so the weight
        # is n_samples) both operands are exactly representable and the
        # quotient is a single correctly-rounded f32 division, making the
        # result independent of host-vs-device summation order — the
        # anchor that lets the fused megastep's in-scan normalization be
        # bitwise identical to this line
        weights = weights.astype(np.float32)
        weights = weights / weights.sum()
        out_dtype = jax.tree.leaves(self.params)[0].dtype
        if self.update_plane == "device":
            # row-index fast path: gather rows out of the persistent device
            # buffer, one kernel dispatch, one unravel — no host traffic
            rows = [r.update_row for r in pending]
            assert all(r >= 0 for r in rows), \
                "pending result without a row handle on the device plane"
            self.params = weighted_aggregate_rows(
                self.store.buffer, rows, weights, self.spec,
                out_dtype=out_dtype, mesh=self.mesh)
            self.store.free(rows)
        else:
            updates = [jax.tree.map(jnp.asarray, self.db.blobs[r.update_key])
                       for r in pending]
            self.update_host_bytes += sum(
                l.nbytes for u in updates for l in jax.tree.leaves(u))
            self.params = weighted_aggregate(updates, weights,
                                             out_dtype=out_dtype)
        n_stale = sum(1 for r in pending if r.round < round_)
        mean_dur = float(np.mean([r.train_duration for r in pending]))
        self.db.mark_aggregated(pending)
        # prune: results too stale to ever be usable again
        drop = [r for r in self.db.results
                if not r.aggregated and round_ - r.round >= self.cfg.max_staleness]
        if self.update_plane == "device":
            self.store.free([r.update_row for r in drop if r.update_row >= 0])
        self.db.mark_aggregated(drop)
        return len(pending), n_stale, mean_dur

    # -------------------------------------------------- evaluation service
    def _build_eval_scan(self):
        """One jitted masked scan over the padded eval set: a single device
        dispatch and a single scalar host transfer per evaluation, instead
        of a Python loop of per-256-batch jit calls each synchronizing."""
        xs = np.asarray(self.data.eval_x)
        ys = np.asarray(self.data.eval_y)
        n, bs = len(xs), 256
        nb = max(1, math.ceil(n / bs))
        pad = nb * bs - n
        if pad:
            xs = np.concatenate([xs, np.repeat(xs[:1], pad, axis=0)])
            ys = np.concatenate([ys, np.repeat(ys[:1], pad, axis=0)])
        mask = (np.arange(nb * bs) < n).reshape(nb, bs)
        batches = (jnp.asarray(xs.reshape((nb, bs) + xs.shape[1:])),
                   jnp.asarray(ys.reshape((nb, bs) + ys.shape[1:])),
                   jnp.asarray(mask))
        model = self.model

        @jax.jit
        def run(params, X, y, m):
            def body(correct, inp):
                xb, yb, mb = inp
                pred = jnp.argmax(model.predict(params, xb), axis=-1)
                return correct + jnp.sum((pred == yb) & mb), None
            correct, _ = jax.lax.scan(body, jnp.zeros((), jnp.int32),
                                      (X, y, m))
            return correct.astype(jnp.float32) / n

        return run, batches

    def evaluate(self) -> float:
        if not hasattr(self.model, "predict"):
            # models exposing only ``accuracy`` (e.g. LM adapters with
            # internal target masking) keep the legacy per-batch loop;
            # batches are weighted by size so both paths report the same
            # statistic (exact sample mean) on ragged tails
            xs, ys = self.data.eval_x, self.data.eval_y
            total, bs = 0.0, 256
            for i in range(0, len(xs), bs):
                xb, yb = xs[i:i + bs], ys[i:i + bs]
                total += float(self._eval_fn(
                    self.params, {"x": jnp.asarray(xb),
                                  "y": jnp.asarray(yb)})) * len(xb)
            return total / max(len(xs), 1)
        if self._eval_scan is None:
            self._eval_scan = self._build_eval_scan()
        run, batches = self._eval_scan
        return float(run(self.params, *batches))

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        inv = self.platform.invocations
        # _hw_history, not hw: invocation records outlive removed clients
        cost = self.cost_model.total(inv, lambda cid: self._hw_history[cid])
        counts = self.platform.invocation_counts()
        count_arr = [counts.get(cid, 0) for cid in self.db.client_ids()]
        return {
            "strategy": self.strategy.name,
            "engine": self.engine_name,
            "control_plane": self.control_plane,
            "mesh": self.mesh_spec,
            "update_plane": self.update_plane,
            "update_host_bytes": int(self.update_host_bytes),
            "data_plane": self.data_plane,
            # per-dispatch H2D training-input traffic (0 on the device
            # plane: the dataset is resident — see data_resident_bytes)
            "data_host_bytes": int(self.trainer.data_h2d_bytes),
            "data_resident_bytes": (self.dataset.resident_bytes
                                    if self.dataset is not None else 0),
            "rounds": len(self.history),
            "final_accuracy": self.history[-1].accuracy if self.history else 0.0,
            "total_time": self.loop.now,
            "total_cost_usd": cost,
            "cold_start_ratio": self.platform.cold_start_ratio(),
            "n_invocations": len(inv),
            "n_hedges": self.n_hedges,
            "n_hedge_wins": self.n_hedge_wins,
            "n_cancelled": self.n_cancelled,
            # failure / recovery observability (DESIGN.md §12)
            "fault_profile": self.fault_profile,
            # open-loop traffic + SLO layer (DESIGN.md §13)
            "traffic_profile": self.traffic_profile,
            "n_traffic_joins": self.n_traffic_joins,
            "n_traffic_leaves": self.n_traffic_leaves,
            "n_traffic_dropped": (self.traffic.n_dropped
                                  if self.traffic is not None else 0),
            "traffic_segments_applied": self._traffic_pos,
            **slo_summary(
                self.history, self.platform.cold_start_ratio(), cost,
                time_to_accuracy=(
                    self.time_to_accuracy(self.cfg.target_accuracy)
                    if self.cfg.target_accuracy else None)),
            "n_failures": sum(1 for r in inv if r.failed),
            "n_timeouts": self.n_timeouts,
            "n_retries": self.n_retries,
            "n_quarantined": self.n_quarantined,
            "retry_latency_s": self.retry_latency_s,
            "failures_by_phase": self._failures_by_phase(inv),
            # durability plane (DESIGN.md §14)
            **(self.durability.metrics() if self.durability is not None
               else {"durability": "off"}),
            "selection_bias": (max(count_arr) - min(count_arr)) if count_arr else 0,
            "invocation_counts": count_arr,
            "history": [(l.t_end, l.round, l.accuracy) for l in self.history],
        }

    @staticmethod
    def _failures_by_phase(inv) -> dict:
        """Count failed invocations by attributed phase. Legacy Bernoulli
        failures carry phase "train"; records predating the fault model
        (empty phase) land under "unattributed"."""
        by: dict[str, int] = {}
        for r in inv:
            if not r.failed:
                continue
            phase = r.failed_phase or "unattributed"
            by[phase] = by.get(phase, 0) + 1
        return by

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for l in self.history:
            if l.accuracy >= target:
                return l.t_end
        return None

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self) -> None:
        if not self.cfg.checkpoint_dir:
            return
        self.db.meta["update_plane"] = self.update_plane
        self.db.put_global_model(self.db.round,
                                 jax.tree.map(np.asarray, self.params))
        self.db.save(self.cfg.checkpoint_dir)
        if self.update_plane == "device":
            # persist the live un-aggregated rows so the async in-flight
            # state survives a crash bit-exactly (handles stay valid)
            from repro.checkpoint import save_update_store
            ids = [r.update_row for r in self.db.results
                   if not r.aggregated and r.update_row >= 0]
            save_update_store(
                self.store, ids,
                os.path.join(self.cfg.checkpoint_dir, UPDATE_STORE_DIRNAME))

    @classmethod
    def resume(cls, cfg: FLConfig, model, data, fleet):
        db = Database.load(cfg.checkpoint_dir)
        return cls(cfg, model, data, fleet, db=db)
