"""Device-resident data plane: the federated dataset lives on device.

PR 2's update plane removed the host round-trips for client *updates*; this
module removes the symmetric input cost. The legacy ("host") path pays, on
every cohort dispatch, a host-side fancy-index of ``data.X[selection]``, a
pad-concatenation to the cohort bucket, and a full host→device upload of
the padded cohort dataset — at K=100 the dominant per-round transfer. The
``DatasetStore`` instead uploads the padded per-client training arrays
``X [M, N_max, ...] / y [M, N_max]`` to persistent device buffers **once**
at runtime construction; thereafter the jitted cohort-train function
(``core.client``) receives only a ``[Kp] int32`` client-index vector and
gathers each minibatch directly out of the resident buffers *inside the
jit* — zero host→device training-input bytes per round, and the
compile-cache key loses its per-selection data shapes (they are fixed for
the store's lifetime).

Selection: ``FLConfig.data_plane`` > ``REPRO_DATA_PLANE`` > ``"device"``
(mirroring ``REPRO_UPDATE_PLANE``). The host path is kept as the
equivalence oracle: both planes produce bit-identical round traces
(tests/test_data_plane.py) because the device gather yields exactly the
batch values the host fancy-index would have uploaded, and every
downstream op sees identical shapes.

Stores are cached per ``FederatedDataset`` object (id-keyed with a
``weakref.finalize`` eviction), so sweep cells and golden-trace test
pairs sharing one dataset share one resident copy instead of
re-uploading per run.
"""
from __future__ import annotations

import os
import weakref
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.update_store import gather_stacked
from repro.sharding import flmesh


def resolve_data_plane(mode: str) -> str:
    """'device' (default: resident buffers, on-jit gather) | 'host'
    (legacy per-dispatch fancy-index + upload, the equivalence oracle).
    Resolution: explicit config value > ``REPRO_DATA_PLANE`` > 'device'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_DATA_PLANE", "device")
    if mode not in ("device", "host"):
        raise ValueError(f"unknown data plane {mode!r} "
                         "(expected 'device', 'host', or 'auto')")
    return mode


class DatasetStore:
    """Persistent device residence of one ``FederatedDataset``.

    Holds ``X [M, N_max, ...]`` and ``y [M, N_max]`` as device arrays,
    uploaded exactly once. The arrays are passed (not closed over)
    into the jitted cohort fn, so every trainer sharing the store hits the
    same compiled entry and no program embeds the dataset as a constant.

    The store mirrors the dataset at construction; clients registered
    later (``add_clients``) must already have rows in the underlying
    dataset — ``FLRuntime.invoke_round`` bounds-checks selections against
    ``n_clients`` because an out-of-range device gather clamps silently
    where the host fancy-index would raise.
    """

    def __init__(self, data: Any, mesh=None):
        self.X = jnp.asarray(data.X)
        self.y = jnp.asarray(data.y)
        if mesh is not None:
            # replicate across the mesh so each cohort shard's minibatch
            # gathers are device-local (no cross-device index traffic);
            # un-meshed this branch never runs and placement is untouched
            from jax.sharding import PartitionSpec as P
            self.X = flmesh.shard_put(self.X, mesh, P())
            self.y = flmesh.shard_put(self.y, mesh, P())
        self.mesh = mesh
        # sample counts stay host-side: the runtime needs them on host
        # anyway (step budgets, result cardinalities), and the jitted
        # cohort fn receives the [Kp] slice as a per-dispatch arg
        self.n_clients = int(self.X.shape[0])
        # one-time H2D cost of residence (NOT per-round traffic; the
        # per-round counter is CohortTrainer.data_h2d_bytes)
        self.resident_bytes = int(self.X.nbytes + self.y.nbytes)

    def arrays(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """The resident ``(X, y)`` device buffers — the exact operands the
        jitted cohort fn receives per dispatch. Shared by the per-round
        trainer path (``core.client``) and the fused-round megastep, so
        both feed the identical arrays into the identical compiled fn."""
        return self.X, self.y

    def gather(self, selection) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Device gather of a cohort's (X, y) — debug/oracle convenience;
        the hot path gathers per-minibatch inside the jitted cohort fn."""
        idx = jnp.asarray(np.asarray(selection, np.int32))
        gx, gy = gather_stacked((self.X, self.y), idx)
        return gx, gy


# One resident copy per (dataset object, mesh): sweep cells and test pairs
# reuse it. FederatedDataset is an unhashable dataclass, so the cache keys
# by id(); a weakref.finalize evicts the entries when the dataset is
# collected, BEFORE its id can be recycled — a new dataset at a reused
# address can never be served the old store. The mesh component of the key
# uses id(mesh) too, safe because flmesh.build_fl_mesh caches one Mesh per
# spec for the process lifetime.
_STORE_CACHE: dict[tuple, DatasetStore] = {}


def dataset_store(data: Any, mesh=None) -> DatasetStore:
    """The cached ``DatasetStore`` for ``data`` (built on first use)."""
    key = (id(data),) + flmesh.mesh_token(mesh)
    store = _STORE_CACHE.get(key)
    if store is None:
        store = DatasetStore(data, mesh=mesh)
        _STORE_CACHE[key] = store
        weakref.finalize(data, _STORE_CACHE.pop, key, None)
    return store
