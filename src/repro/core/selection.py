"""Probabilistic client selection (paper §III-D, Algorithm 3).

1. Split clients into uninvoked vs invoked; drop busy clients.
2. While uninvoked clients remain, sample the round uniformly from them
   (bootstraps the scoring data).
3. Otherwise compute every available client's weighted score (Algorithm 2),
   normalize to probabilities, and sample without replacement.
4. Booster bookkeeping: reset to 1 for selected clients; multiply by the
   promotion rate (1 + rho) for available-but-unselected clients.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.database import ClientRecord, Database
from repro.core.scoring import calculate_score, decay_rate, promotion_rate


def select_clients(
    db: Database,
    clients_per_round: int,
    rng: np.random.Generator,
    adjustment_rate: float = 0.2,
    history_window: int = 10,
) -> list[int]:
    clients = list(db.clients.values())
    uninvoked = [c for c in clients if not c.ever_invoked and c.status == "idle"]
    invoked = [c for c in clients if c.ever_invoked and c.status == "idle"]

    # Lines 4-6: prioritize uninvoked clients to gather scoring data.
    if len(uninvoked) >= clients_per_round:
        picks = rng.choice(len(uninvoked), size=clients_per_round, replace=False)
        selection = [uninvoked[i].client_id for i in picks]
        _update_boosters(db, selection, adjustment_rate)
        return selection

    selection = [c.client_id for c in uninvoked]
    need = clients_per_round - len(selection)
    need = min(need, len(invoked))
    if need > 0:
        lam = decay_rate(adjustment_rate)
        scores = np.array([
            calculate_score(
                c.booster,
                list(reversed(c.durations[-history_window:])),  # newest first
                c.data_cardinality, c.local_epochs, c.batch_size, lam)
            for c in invoked
        ], dtype=np.float64)
        # Line 12: normalize scores into probabilities.
        smax = scores.max() if len(scores) else 0.0
        if smax <= 0:
            probs = np.full(len(invoked), 1.0 / len(invoked))
        else:
            norm = scores / smax                    # scale to (0, 1]
            probs = norm / norm.sum()
        picks = rng.choice(len(invoked), size=need, replace=False, p=probs)
        selection += [invoked[i].client_id for i in picks]

    _update_boosters(db, selection, adjustment_rate)
    return selection


def _update_boosters(db: Database, selection: Sequence[int],
                     adjustment_rate: float) -> None:
    """Lines 14-15: reset selected boosters, promote available-unselected."""
    beta = promotion_rate(adjustment_rate)
    chosen = set(selection)
    for c in db.clients.values():
        if c.client_id in chosen:
            c.booster = 1.0
        elif c.status == "idle":
            c.booster *= beta
