"""Probabilistic client selection (paper §III-D, Algorithm 3).

1. Split clients into uninvoked vs invoked; drop busy clients.
2. While uninvoked clients remain, sample the round uniformly from them
   (bootstraps the scoring data).
3. Otherwise compute every available client's weighted score (Algorithm 2),
   normalize to probabilities, and sample without replacement.
4. Booster bookkeeping: reset to 1 for selected clients; multiply by the
   promotion rate (1 + rho) for available-but-unselected clients.

Two implementations, dispatched by the database's control plane
(DESIGN.md §10): the original per-``ClientRecord`` Python loop (the
object-plane oracle, kept verbatim below) and a vectorized columnar twin
over ``FleetStore`` arrays. Both are **bit-identical**: the columnar path
builds the same candidate lists in the same (registration) order, computes
the same f64 scores (``scoring.calculate_scores`` replays the scalar
loop's operation order), and feeds the identical probability vector to the
identical ``rng.choice`` calls — so the two planes consume the same RNG
stream and return the same selections (tests/test_control_plane.py).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.database import ClientRecord, Database
from repro.core.scoring import calculate_score, decay_rate, promotion_rate


def select_clients(
    db: Database,
    clients_per_round: int,
    rng: np.random.Generator,
    adjustment_rate: float = 0.2,
    history_window: int = 10,
) -> list[int]:
    if db.columnar:
        return _select_clients_columnar(db, clients_per_round, rng,
                                        adjustment_rate, history_window)
    clients = list(db.clients.values())
    # "available" = idle and not quarantined by the recovery layer's
    # circuit breaker (quarantined_until defaults to 0, so the mask is
    # the plain idle mask whenever recovery is off)
    avail = [c for c in clients
             if c.status == "idle" and c.quarantined_until <= db.round]
    uninvoked = [c for c in avail if not c.ever_invoked]
    invoked = [c for c in avail if c.ever_invoked]

    # Lines 4-6: prioritize uninvoked clients to gather scoring data.
    if len(uninvoked) >= clients_per_round:
        picks = rng.choice(len(uninvoked), size=clients_per_round, replace=False)
        selection = [uninvoked[i].client_id for i in picks]
        _update_boosters(db, selection, adjustment_rate)
        return selection

    selection = [c.client_id for c in uninvoked]
    need = clients_per_round - len(selection)
    need = min(need, len(invoked))
    if need > 0:
        lam = decay_rate(adjustment_rate)
        scores = np.array([
            calculate_score(
                c.booster,
                list(reversed(c.durations[-history_window:])),  # newest first
                c.data_cardinality, c.local_epochs, c.batch_size, lam)
            for c in invoked
        ], dtype=np.float64)
        # Line 12: normalize scores into probabilities.
        smax = scores.max() if len(scores) else 0.0
        if smax <= 0:
            probs = np.full(len(invoked), 1.0 / len(invoked))
        else:
            norm = scores / smax                    # scale to (0, 1]
            probs = norm / norm.sum()
        # zero-score clients (every invocation failed, so no duration
        # history) carry probability 0 — sampling without replacement
        # cannot draw more than the nonzero-probability count
        need = min(need, int(np.count_nonzero(probs)))
        if need > 0:
            picks = rng.choice(len(invoked), size=need, replace=False,
                               p=probs)
            selection += [invoked[i].client_id for i in picks]

    _update_boosters(db, selection, adjustment_rate)
    return selection


def _update_boosters(db: Database, selection: Sequence[int],
                     adjustment_rate: float) -> None:
    """Lines 14-15: reset selected boosters, promote available-unselected."""
    beta = promotion_rate(adjustment_rate)
    chosen = set(selection)
    for c in db.clients.values():
        if c.client_id in chosen:
            c.booster = 1.0
        elif c.status == "idle" and c.quarantined_until <= db.round:
            c.booster *= beta


# --------------------------------------------------------- columnar twin


def _select_clients_columnar(
    db: Database,
    clients_per_round: int,
    rng: np.random.Generator,
    adjustment_rate: float = 0.2,
    history_window: int = 10,
) -> list[int]:
    """Algorithm 3 over FleetStore columns — one vectorized scoring pass
    instead of an O(M) Python loop, bit-identical draws (module docstring)."""
    fleet = db.fleet
    order = fleet.ordered_slots()
    idle = ((fleet.status[order] == 0)
            & (fleet.quarantined_until[order] <= db.round))
    ever = fleet.n_invocations[order] > 0
    unv = order[idle & ~ever]
    inv = order[idle & ever]

    # Lines 4-6: prioritize uninvoked clients to gather scoring data.
    if len(unv) >= clients_per_round:
        picks = rng.choice(len(unv), size=clients_per_round, replace=False)
        selection = fleet.ids[unv[picks]].tolist()
        _update_boosters_columnar(db, selection, adjustment_rate)
        return selection

    selection = fleet.ids[unv].tolist()
    need = clients_per_round - len(selection)
    need = min(need, len(inv))
    if need > 0:
        lam = decay_rate(adjustment_rate)
        scores = fleet.window_scores(inv, history_window, lam)
        # Line 12: normalize scores into probabilities.
        smax = scores.max() if len(scores) else 0.0
        if smax <= 0:
            probs = np.full(len(inv), 1.0 / len(inv))
        else:
            norm = scores / smax                    # scale to (0, 1]
            probs = norm / norm.sum()
        # zero-score clients cap the draw, mirroring the object plane
        need = min(need, int(np.count_nonzero(probs)))
        if need > 0:
            picks = rng.choice(len(inv), size=need, replace=False, p=probs)
            selection += fleet.ids[inv[picks]].tolist()

    _update_boosters_columnar(db, selection, adjustment_rate)
    return selection


def _update_boosters_columnar(db: Database, selection: Sequence[int],
                              adjustment_rate: float) -> None:
    """Vectorized booster bookkeeping: same per-element f64 ops as the
    object-plane loop (set 1.0 / one multiply), so boosters stay bit-equal
    across planes round after round."""
    fleet = db.fleet
    beta = promotion_rate(adjustment_rate)
    chosen = np.array([fleet.slot_of(c) for c in selection], np.int64)
    idle = (fleet.active & (fleet.status == 0)
            & (fleet.quarantined_until <= db.round))
    if len(chosen):
        idle[chosen] = False
        fleet.booster[chosen] = 1.0
    fleet.booster[idle] *= beta
