"""Typed event -> action protocol between the scheduler and reactive policies.

The poll-based ``Controller.run`` loop asked a passive ``Strategy`` three
questions (``select`` / ``results_needed`` / ``usable``) and blocked in
``EventLoop.run_until`` — a shape that cannot express mid-round reactions
(straggler hedging, adaptive CR, per-tier timeouts). This module is the new
boundary (DESIGN.md §7): the ``Scheduler`` translates every simulation
occurrence into a typed :class:`Event`, hands it to a
:class:`ReactivePolicy` together with a read-only :class:`DatabaseView`,
and executes the returned :class:`Action` list against the FaaS platform,
update store, and aggregation service.

Events (what happened)            Actions (what the policy wants)
--------------------------------  -------------------------------------
``RoundStarted``                  ``Invoke`` — run clients this round
``ResultLanded``                  ``Aggregate`` — close the round now
``InvocationFailed``              ``SetTimer`` — wake me at now+delay
``InvocationTimedOut``            ``CancelInvocation`` — kill in-flight
``TimerFired``                    ``Hedge`` — re-invoke outstanding
``ClientJoined`` / ``ClientLeft`` ``Retry`` — re-invoke after a delay
``LoopDrained``                   ``Quarantine`` — bench a repeat offender
                                  ``EndRun`` — terminate the run

Policies must treat the view as read-only; the one sanctioned exception is
``DatabaseView.db``, the mutable database handle the legacy strategies'
``select`` needs for Algorithm 3 booster bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass
from types import MappingProxyType
from typing import TYPE_CHECKING, Mapping, Optional, Sequence, Tuple

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.database import ClientRecord, Database, ResultRecord
    from repro.core.strategies.base import Strategy


# ---------------------------------------------------------------------- events


@dataclass(frozen=True)
class Event:
    """Base simulation event; ``t`` is the simulated time it occurred."""

    t: float


@dataclass(frozen=True)
class RoundStarted(Event):
    """A new scheduling round opened (``round`` is its index)."""

    round: int


@dataclass(frozen=True)
class ResultLanded(Event):
    """A client update landed in the database. ``round`` is the round the
    client *trained against* (may trail the current round for stragglers);
    ``result`` is the database record, including its update handle."""

    round: int
    result: "ResultRecord"


@dataclass(frozen=True)
class InvocationFailed(Event):
    """An invocation crashed (or was preempted) and will never produce a
    result. Hedge siblings, if any, keep racing."""

    round: int
    client_id: int


@dataclass(frozen=True)
class InvocationTimedOut(Event):
    """The scheduler's per-invocation timeout (``FLConfig.
    invocation_timeout``, distinct from the sync round deadline) killed an
    in-flight invocation: the container was cancelled, the payload
    released, and the failure counted. Only emitted when the recovery
    layer is enabled (DESIGN.md §12)."""

    round: int
    client_id: int


@dataclass(frozen=True)
class TimerFired(Event):
    """A ``SetTimer`` armed in round ``round`` elapsed. Timers armed in
    earlier rounds are dropped by the scheduler, never dispatched."""

    round: int
    tag: str


@dataclass(frozen=True)
class ClientJoined(Event):
    client_id: int


@dataclass(frozen=True)
class ClientLeft(Event):
    client_id: int


@dataclass(frozen=True)
class ClientsJoined(Event):
    """A traffic segment registered ``client_ids`` in bulk — one event
    per windowed segment, not per client (the open-loop arrival path,
    DESIGN.md §13). Policies that don't care may ignore it."""

    client_ids: Tuple[int, ...]


@dataclass(frozen=True)
class ClientsLeft(Event):
    """Bulk counterpart of ``ClientLeft`` for traffic segments; the
    runtime has already cancelled the departees' in-flight work."""

    client_ids: Tuple[int, ...]


@dataclass(frozen=True)
class LoopDrained(Event):
    """No future events exist (and, for policies with
    ``fire_timers_on_drain=False``, pending timers will not fire). The
    policy must either make progress (``Aggregate`` / ``Invoke``) or
    ``EndRun``; if its answer schedules nothing, the run ends — this is
    the last event such a policy receives."""


# --------------------------------------------------------------------- actions


@dataclass(frozen=True)
class Action:
    pass


@dataclass(frozen=True)
class Invoke(Action):
    """Invoke ``clients`` (in order) for the current round: train the
    cohort against the current global model and start their simulated
    FaaS invocations."""

    clients: Tuple[int, ...]


@dataclass(frozen=True)
class Aggregate(Action):
    """Close the current round: aggregate every usable pending result
    (weights from the underlying strategy), evaluate, log, advance the
    round, and — unless the run is over — dispatch the next
    ``RoundStarted``. Put this last in an action list: actions after it
    execute in the next round's context."""


@dataclass(frozen=True)
class SetTimer(Action):
    """Wake the policy with ``TimerFired(tag)`` at ``now + delay``. The
    timer is tagged with the current round and silently dropped once the
    round closes. A negative delay fires immediately with the simulated
    clock set to the target time (the legacy budget-barrier semantics of
    ``run_until(max_time=...)``); native policies should arm only future
    timers."""

    delay: float
    tag: str


@dataclass(frozen=True)
class CancelInvocation(Action):
    """Cancel every in-flight invocation of ``client_id``: the completion
    event is dropped, the update row/blob is released, and the client
    returns to ``idle``."""

    client_id: int


@dataclass(frozen=True)
class Hedge(Action):
    """Speculatively re-invoke the outstanding invocations of ``clients``
    on their (still-warm) containers. The hedge shares the original's
    trained update and races it: the first completion lands the result and
    cancels the sibling; a failed original leaves the hedge racing."""

    clients: Tuple[int, ...]


@dataclass(frozen=True)
class Retry(Action):
    """Re-invoke ``client_id`` after ``delay`` sim-seconds (the recovery
    layer's backoff step). The scheduler arms a runtime timer scoped to
    the current round: it is dropped if the round closes first, skipped
    if the client left, was quarantined, or is busy again when it fires;
    otherwise the client is re-trained against the *current* global model
    and re-invoked without resetting the sync gating set."""

    client_id: int
    delay: float


@dataclass(frozen=True)
class Quarantine(Action):
    """Circuit-break ``client_id``: mark it quarantined until round
    ``until_round`` (exclusive). Quarantined clients are dropped from the
    idle pool and every strategy's selection mask until the round counter
    passes ``until_round``."""

    client_id: int
    until_round: int


@dataclass(frozen=True)
class EndRun(Action):
    """Terminate the run (the legacy loop's ``break``)."""


# ----------------------------------------------------------------------- views


@dataclass(frozen=True)
class InflightView:
    """Read-only snapshot of one outstanding invocation."""

    client_id: int
    round: int
    t_invoked: float
    is_hedge: bool     # this invocation is itself a speculative re-invoke
    hedged: bool       # a live hedge sibling is racing this invocation


class DatabaseView:
    """Read-only window onto the scheduler's state for policies.

    Everything here is a cheap view over live state — no copies beyond the
    tuples handed out — valid only for the duration of one ``on_event``
    call. ``db`` is the legacy escape hatch (see module docstring).
    """

    def __init__(self, runtime):
        self._rt = runtime

    # -- time & round ------------------------------------------------------
    @property
    def now(self) -> float:
        return self._rt.loop.now

    @property
    def round(self) -> int:
        return self._rt.current_round

    @property
    def round_start(self) -> float:
        """Simulated time the current round opened."""
        return self._rt.round_start

    @property
    def max_sim_time(self) -> float:
        return self._rt.cfg.max_sim_time

    # -- database ----------------------------------------------------------
    @property
    def db(self) -> "Database":
        """Mutable database handle — sanctioned ONLY for legacy
        ``Strategy.select`` calls (booster bookkeeping)."""
        return self._rt.db

    @property
    def clients(self) -> Mapping[int, "ClientRecord"]:
        """Record view — O(fleet) materialization on the columnar plane;
        policies should prefer the plane-agnostic accessors below."""
        return MappingProxyType(self._rt.db.clients)

    @property
    def control_plane(self) -> str:
        return self._rt.db.control_plane

    @property
    def n_clients(self) -> int:
        return self._rt.db.n_clients

    def has_client(self, client_id: int) -> bool:
        return self._rt.db.has_client(client_id)

    def any_idle(self) -> bool:
        """Any registered client currently idle (both planes, O(columns))."""
        return self._rt.db.any_idle()

    def recent_durations(self, client_id: int, k: int):
        """The client's last <=k training durations, oldest first (empty
        list for unknown clients) — the hedge-ranking accessor."""
        return self._rt.db.recent_durations(client_id, k)

    @property
    def results(self) -> Tuple["ResultRecord", ...]:
        return tuple(self._rt.db.results)

    def pending_results(self, max_staleness: Optional[int] = None,
                        round_: Optional[int] = None):
        """Un-aggregated results inside the staleness window (defaults:
        the configured cap, the current round)."""
        if max_staleness is None:
            max_staleness = self._rt.cfg.max_staleness
        if round_ is None:
            round_ = self._rt.current_round
        return self._rt.db.pending_results(max_staleness, round_)

    @property
    def completed_this_round(self) -> frozenset:
        """Client ids whose invocations completed since this round's first
        ``Invoke`` (the sync gating set)."""
        return frozenset(self._rt._completed_this_round)

    # -- in-flight invocations --------------------------------------------
    def outstanding(self, round_: Optional[int] = None
                    ) -> Tuple[InflightView, ...]:
        """Live (not completed, not cancelled) invocations, optionally
        restricted to one round."""
        out = []
        for invs in self._rt.inflight.values():
            for inv in invs:
                if inv.done or (round_ is not None and inv.round != round_):
                    continue
                out.append(InflightView(
                    client_id=inv.client_id, round=inv.round,
                    t_invoked=inv.t_invoked, is_hedge=inv.is_hedge,
                    hedged=inv.payload.refs > 1))
        return tuple(out)


# ---------------------------------------------------------------------- policy


class ReactivePolicy:
    """Event-driven strategy: ``on_event(event, view) -> [Action, ...]``.

    ``strategy`` is the underlying :class:`Strategy` whose aggregation
    contract (``usable`` / ``result_weight`` / ``prox_mu`` /
    ``needs_scaffold``) the runtime services keep consulting — reactive
    policies redesign the *scheduling*, not the paper's weighting math.

    ``fire_timers_on_drain``: whether armed timers still fire once the
    platform has no future events. The legacy adapter sets this False to
    reproduce the poll loop's drain semantics (a drained ``run_until``
    returns at the last event's time, never advancing to its deadline).
    """

    name: str = "reactive"
    fire_timers_on_drain: bool = True
    strategy: "Strategy"

    def on_event(self, event: Event, view: DatabaseView) -> Sequence[Action]:
        raise NotImplementedError

    def metrics(self) -> dict:
        """Policy-specific numbers merged into ``Scheduler.metrics()``."""
        return {}

    # -- durability (coordinated snapshots, DESIGN.md §14) -------------
    def state_dict(self) -> dict:
        """Mutable policy state for a durable resume. The base captures
        the wrapped strategy; stateful policies extend this."""
        return {"strategy": self.strategy.state_dict()}

    def load_state(self, state: dict) -> None:
        self.strategy.load_state(state["strategy"])
