"""Columnar control plane: struct-of-arrays fleet state + device score state.

The object control plane keeps one Python ``ClientRecord`` per client and
evaluates Apodotiko's scoring with a per-client Python loop — fine at the
paper's 200 clients, a hard wall at the ROADMAP's millions. ``FleetStore``
is the columnar replacement (DESIGN.md §10), mirroring the conventions of
the device-resident update plane (``update_store.py``): all per-client
control state lives in parallel ``[capacity]`` numpy columns —

    ids / seq         id per slot (-1 free) + registration sequence number
    active / status   membership mask, 0 = idle | 1 = running
    cardinality, batch_size, local_epochs   Client_Update config (Alg. 2)
    booster           Algorithm 3 booster (f64, bit-exact vs the oracle)
    n_invocations / n_failures / last_round   invocation bookkeeping
    durations         [capacity, W] f64 window of the last W training
                      durations, newest FIRST (W = scoring.HISTORY_WINDOW);
                      each result shifts its row right by one — O(W)
                      contiguous — so the scoring read is a plain row
                      gather with no ring-index arithmetic
    ema_num / ema_den O(1) incremental CEF EMA state (scoring.ema_push)
    win_num / win_den cached *windowed* CEF terms, refreshed with an O(W)
                      scalar replay when a result lands — selection-time
                      scoring collapses to three [M] vector ops while
                      staying bit-identical to the oracle's full walk

— with an id->slot map and a LIFO free-list; capacity doubles amortized.
Slot *iteration order* is registration order (``ordered_slots`` sorts by
``seq`` lazily), which reproduces the object plane's dict-iteration order
exactly — the property the bit-identical selection traces rest on: both
planes hand ``np.random.Generator.choice`` identical candidate arrays and
identical probability vectors (see ``scoring.calculate_scores``).

Scoring is vectorized (one ``[M, W]`` window pass, bit-identical to the
Python loop) and the duration ring is updated incrementally on every
``ResultLanded`` instead of growing an unbounded per-client list.

**Device score state / top-k selection.** For fleet-scale cohorts the
store additionally maintains a device-resident score state (f32 jax
arrays: EMA num/den, booster, eligibility masks) updated by O(dirty)
scatters, and ``select_topk`` runs one jitted vectorized kernel over the
whole ``[capacity]`` state: score -> mask busy/uninvoked -> ``masked_topk``
(XLA ``lax.top_k`` fast path, Pallas block kernel on TPU —
``kernels/topk.py``) -> in-kernel booster update. This path is
deterministic (no sampling) and f32 — it is the *scale* selector behind
the ``apodotiko-topk`` strategy and the ``fleet_scale`` bench path, not
the bit-exact oracle twin.
"""
from __future__ import annotations

import functools
from itertools import repeat
from typing import Optional

import numpy as np

from repro.core.scoring import (HISTORY_WINDOW, calculate_scores, ema_push,
                                per_round_score, scores_from_terms,
                                window_accumulate, window_terms)

IDLE, RUNNING = 0, 1


def _grow(arr: np.ndarray, new_cap: int) -> np.ndarray:
    out = np.zeros((new_cap,) + arr.shape[1:], arr.dtype)
    out[:len(arr)] = arr
    return out


class FleetStore:
    """Free-listed columnar store of per-client control-plane state."""

    #: column name -> dtype; every 1-D [capacity] column (rings are separate)
    COLUMNS = {
        "ids": np.int64, "seq": np.int64, "status": np.int8,
        "active": np.bool_, "cardinality": np.int64, "batch_size": np.int64,
        "local_epochs": np.int64, "booster": np.float64,
        "n_invocations": np.int64, "n_failures": np.int64,
        "last_round": np.int64, "dur_len": np.int32,
        # recovery-layer circuit breaker (DESIGN.md §12): consecutive
        # failures since the last completed result, and the round until
        # which the client is benched (0 = never quarantined — always
        # eligible, so zero-filled legacy checkpoints behave identically)
        "consec_failures": np.int64, "quarantined_until": np.int64,
        "ema_num": np.float64, "ema_den": np.float64,
        "win_num": np.float64, "win_den": np.float64,
        # f32 twins of the EMA terms, folded *in f32 from the start* so the
        # device score state (and the megastep's in-scan score evolution)
        # is reproducible from host state without a f64->f32 cast of an
        # f64 fold — the cast of a fold and a fold of casts differ in ulps,
        # and the fused-round scan carries these exact f32 values
        "ema_num32": np.float32, "ema_den32": np.float32,
        "upd32": np.float32,   # f32(card * E / max(B, 1)), set at add time
    }

    def __init__(self, capacity: int = 0, history: int = HISTORY_WINDOW,
                 decay: float = 0.8):
        self.history = int(history)
        self._decay = float(decay)    # EMA decay (1 - rho); runtime sets it
        self.capacity = 0
        for name, dt in self.COLUMNS.items():
            setattr(self, name, np.zeros((0,), dt))
        self.durations = np.zeros((0, self.history), np.float64)
        self._slot: dict[int, int] = {}
        self._free: list[int] = []
        self._next_seq = 0
        self._order: Optional[np.ndarray] = None   # slots sorted by seq
        self._dev = None                           # device score state
        self._dev_dirty: set[int] = set()
        if capacity:
            self._ensure(capacity)

    @property
    def decay(self) -> float:
        return self._decay

    @decay.setter
    def decay(self, value: float) -> None:
        """Changing the decay invalidates every cached score term — both
        the windowed cache and the infinite-horizon EMA are decay-weighted
        sums, so they are rebuilt (window terms exactly; the EMA restarts
        from the retained window, its only recoverable history)."""
        value = float(value)
        if value == self._decay:
            return
        self._decay = value
        slots = self._registered_slots()
        if not len(slots):
            return
        self._rebuild_window_terms(slots)
        self.ema_num[slots] = self.win_num[slots]
        self.ema_den[slots] = self.win_den[slots]
        self._rebuild_mirror32(slots)
        self._dev_dirty.update(slots.tolist())

    # ------------------------------------------------------------ capacity
    def _ensure(self, capacity: int) -> None:
        if capacity <= self.capacity:
            return
        cap = max(int(capacity), 2 * self.capacity, 8)
        for name in self.COLUMNS:
            setattr(self, name, _grow(getattr(self, name), cap))
        self.ids[self.capacity:cap] = -1
        self.durations = _grow(self.durations, cap)
        self._free.extend(range(cap - 1, self.capacity - 1, -1))
        if self._dev is not None:
            self._dev.grow(cap)
        self.capacity = cap

    # ---------------------------------------------------------- membership
    def add(self, client_id: int, cardinality: int, batch_size: int,
            local_epochs: int, *, booster: float = 1.0,
            status: int = IDLE) -> int:
        """Register one client (or overwrite an existing id in place — like
        the object plane's dict assignment, which keeps insertion order)."""
        cid = int(client_id)
        slot = self._slot.get(cid)
        fresh = slot is None
        if fresh:
            if not self._free:
                self._ensure(self.capacity + 1)
            slot = self._free.pop()
            self._slot[cid] = slot
            self.seq[slot] = self._next_seq
            self._next_seq += 1
            self._order = None
        self.ids[slot] = cid
        self.active[slot] = True
        self.status[slot] = status
        self.cardinality[slot] = int(cardinality)
        self.batch_size[slot] = int(batch_size)
        self.local_epochs[slot] = int(local_epochs)
        self.booster[slot] = float(booster)
        self.n_invocations[slot] = 0
        self.n_failures[slot] = 0
        self.consec_failures[slot] = 0
        self.quarantined_until[slot] = 0
        self.last_round[slot] = -1
        self.dur_len[slot] = 0
        self.durations[slot, :] = 0.0
        self.ema_num[slot] = 0.0
        self.ema_den[slot] = 0.0
        self.win_num[slot] = 0.0
        self.win_den[slot] = 0.0
        self.ema_num32[slot] = 0.0
        self.ema_den32[slot] = 0.0
        self.upd32[slot] = np.float32(
            int(cardinality) * int(local_epochs) / max(int(batch_size), 1))
        self._touch(slot, reset_booster=True)
        return slot

    def add_batch(self, client_ids, cardinality, batch_size,
                  local_epochs) -> np.ndarray:
        """Bulk registration without per-client Python objects (the
        fleet-scale entry point). All ids must be fresh."""
        cids = np.asarray(client_ids, np.int64)
        n = len(cids)
        if n == 0:
            return np.empty(0, np.int64)
        if not self._slot.keys().isdisjoint(cids.tolist()):
            raise ValueError("add_batch requires fresh client ids")
        if len(self._free) < n:
            self._ensure(self.capacity + (n - len(self._free)))
        # vectorized LIFO pop: identical slot order to n sequential pops
        slots = np.asarray(self._free[-n:][::-1], np.int64)
        del self._free[len(self._free) - n:]
        self._slot.update(zip(cids.tolist(), slots.tolist()))
        self.seq[slots] = self._next_seq + np.arange(n)
        self._next_seq += n
        self.ids[slots] = cids
        self.active[slots] = True
        self.status[slots] = IDLE
        self.cardinality[slots] = np.asarray(cardinality, np.int64)
        self.batch_size[slots] = np.asarray(batch_size, np.int64)
        self.local_epochs[slots] = np.asarray(local_epochs, np.int64)
        self.booster[slots] = 1.0
        for name in ("n_invocations", "n_failures", "consec_failures",
                     "quarantined_until", "dur_len",
                     "ema_num", "ema_den", "win_num", "win_den",
                     "ema_num32", "ema_den32"):
            getattr(self, name)[slots] = 0
        self.upd32[slots] = (
            (self.cardinality[slots] * self.local_epochs[slots])
            / np.maximum(self.batch_size[slots], 1)).astype(np.float32)
        self.durations[slots, :] = 0.0
        self.last_round[slots] = -1
        self._order = None
        self._dev_dirty.update(slots.tolist())
        if self._dev is not None:
            self._dev.reset_booster(slots)
        return slots

    def remove_batch(self, client_ids) -> list[int]:
        """Bulk removal: one column scatter + one free-list extend,
        free-list-order-identical to sequential ``remove`` calls. Unknown
        ids are skipped; returns the ids actually removed."""
        cids = np.asarray(client_ids, np.int64).tolist()
        # C-speed pop loop: dict.pop is a C method, so map() never enters
        # a Python frame per id
        raw = list(map(self._slot.pop, cids, repeat(None)))
        if None in raw:
            removed = [c for c, s in zip(cids, raw) if s is not None]
            slots = [s for s in raw if s is not None]
        else:
            removed, slots = cids, raw
        if not slots:
            return []
        sl = np.asarray(slots, np.int64)
        self.active[sl] = False
        self.ids[sl] = -1
        self._free.extend(slots)
        self._order = None
        self._dev_dirty.update(slots)
        return removed

    def remove(self, client_id: int) -> bool:
        slot = self._slot.pop(int(client_id), None)
        if slot is None:
            return False
        self.active[slot] = False
        self.ids[slot] = -1
        self._free.append(slot)
        self._order = None
        self._touch(slot)
        return True

    def slot_of(self, client_id: int) -> int:
        return self._slot[int(client_id)]

    def has(self, client_id: int) -> bool:
        return int(client_id) in self._slot

    def __len__(self) -> int:
        return len(self._slot)

    def ordered_slots(self) -> np.ndarray:
        """Active slots in registration order (== object-plane dict order)."""
        if self._order is None:
            act = np.flatnonzero(self.active)
            self._order = act[np.argsort(self.seq[act], kind="stable")]
        return self._order

    def client_ids(self) -> list[int]:
        return self.ids[self.ordered_slots()].tolist()

    # ------------------------------------------------------------- updates
    def _touch(self, slot: int, *, reset_booster: bool = False) -> None:
        self._dev_dirty.add(int(slot))
        if reset_booster and self._dev is not None:
            self._dev.reset_booster(np.array([slot], np.int64))

    def mark_running(self, client_id: int, round_: int) -> None:
        slot = self._slot[int(client_id)]
        self.status[slot] = RUNNING
        self.n_invocations[slot] += 1
        self.last_round[slot] = int(round_)
        self._touch(slot)

    def mark_complete(self, client_id: int, duration: float) -> None:
        """Result landed: shift the duration window (newest first), push
        the O(1) incremental EMA, and replay the O(W) windowed terms for
        THIS client only — selection never walks histories again
        (DESIGN.md §10)."""
        slot = self._slot[int(client_id)]
        self.status[slot] = IDLE
        self.consec_failures[slot] = 0      # a landed result heals the streak
        row = self.durations[slot]
        row[1:] = row[:-1]          # numpy buffers overlapping base-slices
        row[0] = float(duration)
        m = min(int(self.dur_len[slot]) + 1, self.history)
        self.dur_len[slot] = m
        card = int(self.cardinality[slot])
        epochs = int(self.local_epochs[slot])
        batch = int(self.batch_size[slot])
        s = per_round_score(float(duration), card, epochs, batch)
        self.ema_num[slot], self.ema_den[slot] = ema_push(
            float(self.ema_num[slot]), float(self.ema_den[slot]),
            s, self._decay)
        self.win_num[slot], self.win_den[slot] = window_accumulate(
            row[:m].tolist(), card, epochs, batch, self._decay)
        # f32 twin fold (the device-score / megastep-scan evolution): same
        # ema_push structure, every operand and intermediate f32
        dec32 = np.float32(self._decay)
        s32 = np.float32(card) * (
            self.upd32[slot]
            / np.maximum(np.float32(duration), np.float32(1e-9)))
        self.ema_num32[slot] = s32 + dec32 * self.ema_num32[slot]
        self.ema_den32[slot] = np.float32(1.0) + dec32 * self.ema_den32[slot]
        self._touch(slot)

    def mark_failed(self, client_id: int) -> None:
        slot = self._slot[int(client_id)]
        self.status[slot] = IDLE
        self.n_failures[slot] += 1
        self.consec_failures[slot] += 1
        self._touch(slot)

    def incr_failures(self, client_id: int) -> None:
        slot = self._slot[int(client_id)]
        self.n_failures[slot] += 1
        self.consec_failures[slot] += 1

    def quarantine(self, client_id: int, until_round: int) -> None:
        """Bench the client until ``until_round`` (exclusive) — it drops
        out of the idle pool and every selection mask meanwhile."""
        slot = self._slot[int(client_id)]
        self.quarantined_until[slot] = int(until_round)
        self._touch(slot)

    def set_idle(self, client_id: int) -> bool:
        """Return a running client to idle (cancellation path)."""
        slot = self._slot.get(int(client_id))
        if slot is None or self.status[slot] != RUNNING:
            return False
        self.status[slot] = IDLE
        self._touch(slot)
        return True

    # ------------------------------------------------------------- queries
    def any_idle(self, now_round: Optional[int] = None) -> bool:
        """Any active idle client; with ``now_round``, quarantined clients
        (``quarantined_until > now_round``) don't count."""
        mask = self.active & (self.status == IDLE)
        if now_round is not None:
            mask &= self.quarantined_until <= now_round
        return bool(np.any(mask))

    def idle_slots(self, now_round: Optional[int] = None) -> np.ndarray:
        order = self.ordered_slots()
        mask = self.status[order] == IDLE
        if now_round is not None:
            mask &= self.quarantined_until[order] <= now_round
        return order[mask]

    def idle_ids(self, now_round: Optional[int] = None) -> list[int]:
        return self.ids[self.idle_slots(now_round)].tolist()

    def recent_durations(self, client_id: int, k: int) -> list[float]:
        """The last <=k training durations, oldest first — exactly the
        object plane's ``record.durations[-k:]`` (for k <= history)."""
        slot = self._slot.get(int(client_id))
        if slot is None:
            return []
        m = min(int(self.dur_len[slot]), int(k), self.history)
        return self.durations[slot, :m][::-1].tolist()

    def duration_window(self, slots: np.ndarray,
                        window: int) -> tuple[np.ndarray, np.ndarray]:
        """``[len(slots), window]`` durations most-recent-FIRST plus the
        per-row valid lengths (the ``calculate_scores`` input layout) —
        a plain row gather thanks to the newest-first storage."""
        window = min(int(window), self.history)
        durs = self.durations[slots, :window]
        lens = np.minimum(self.dur_len[slots], window)
        return durs, lens

    def window_scores(self, slots: np.ndarray, window: int,
                      decay: float) -> np.ndarray:
        """Bit-exact windowed CEF scores for ``slots`` (oracle twin).

        Fast path: when the request matches the cached configuration (the
        full retained window, the store's decay — the Algorithm 3 defaults)
        the incrementally maintained ``win_num/win_den`` terms answer in
        three vector ops. Any other window/decay recomputes vectorized."""
        if window >= self.history and decay == self._decay:
            return scores_from_terms(self.booster[slots],
                                     self.win_num[slots],
                                     self.win_den[slots],
                                     self.dur_len[slots])
        durs, lens = self.duration_window(slots, window)
        return calculate_scores(self.booster[slots], durs, lens,
                                self.cardinality[slots],
                                self.local_epochs[slots],
                                self.batch_size[slots], decay)

    def _registered_slots(self) -> np.ndarray:
        return np.fromiter(self._slot.values(), np.int64,
                           count=len(self._slot))

    def _rebuild_window_terms(self, slots: np.ndarray) -> None:
        """Vectorized refresh of the cached windowed terms (bulk install /
        decay change) — same math, same bit patterns as the per-result
        scalar replay."""
        durs, lens = self.duration_window(slots, self.history)
        ws, nm = window_terms(durs, lens, self.cardinality[slots],
                              self.local_epochs[slots],
                              self.batch_size[slots], self._decay)
        self.win_num[slots] = ws
        self.win_den[slots] = nm

    def _rebuild_mirror32(self, slots: np.ndarray) -> None:
        """Restart the f32 EMA twins from the retained window (the only
        recoverable history — the same compromise the f64 path makes on a
        decay change), folding oldest -> newest entirely in f32."""
        m = np.minimum(self.dur_len[slots], self.history)
        num32 = np.zeros(len(slots), np.float32)
        den32 = np.zeros(len(slots), np.float32)
        dec32 = np.float32(self._decay)
        card32 = self.cardinality[slots].astype(np.float32)
        u32 = self.upd32[slots]
        for j in range(self.history - 1, -1, -1):   # oldest -> newest
            valid = j < m
            d32 = self.durations[slots, j].astype(np.float32)
            s32 = card32 * (u32 / np.maximum(d32, np.float32(1e-9)))
            num32 = np.where(valid, s32 + dec32 * num32, num32)
            den32 = np.where(valid, np.float32(1.0) + dec32 * den32, den32)
        self.ema_num32[slots] = num32
        self.ema_den32[slots] = den32

    def recent_mean(self, slots: np.ndarray, k: int) -> np.ndarray:
        """Mean of the last <=k durations per slot (0.0 when empty) —
        bit-identical to ``np.mean(record.durations[-k:])``: the masked
        accumulation below is sequential oldest-to-newest, numpy's own
        summation order for these short windows."""
        k = min(int(k), self.history)
        rows = self.durations[slots, :k]            # newest first
        m = np.minimum(self.dur_len[slots], k)
        n = len(slots)
        total = np.zeros(n, np.float64)
        arange = np.arange(n)
        for j in range(k):                          # oldest -> newest
            idx = m - 1 - j
            valid = idx >= 0
            total = total + np.where(
                valid, rows[arange, np.clip(idx, 0, k - 1)], 0.0)
        return np.where(m > 0, total / np.where(m > 0, m, 1), 0.0)

    # ----------------------------------------------------- bulk test/bench
    def bulk_history(self, durations: np.ndarray) -> None:
        """Install a ``[M, h]`` duration history (oldest first) for the
        first M registered clients in one vectorized pass — the bench/test
        seeding path; equivalent to h ``mark_complete`` calls per client
        but without 2*M*h Python scalar ops."""
        durations = np.asarray(durations, np.float64)
        M, h = durations.shape
        slots = self.ordered_slots()[:M]
        keep = min(h, self.history)
        self.durations[slots, :] = 0.0
        # newest-first storage: column j <- the (j+1)-th most recent
        self.durations[slots, :keep] = durations[:, ::-1][:, :keep]
        self.dur_len[slots] = keep
        upd = (self.cardinality[slots] * self.local_epochs[slots]) \
            / np.maximum(self.batch_size[slots], 1)
        num = np.zeros(M, np.float64)
        den = np.zeros(M, np.float64)
        num32 = np.zeros(M, np.float32)
        den32 = np.zeros(M, np.float32)
        dec32 = np.float32(self._decay)
        card32 = self.cardinality[slots].astype(np.float32)
        u32 = self.upd32[slots]
        for i in range(h):          # oldest -> newest, the ema_push order
            s = self.cardinality[slots] * (upd / np.maximum(durations[:, i],
                                                            1e-9))
            num, den = ema_push(num, den, s, self._decay)  # array-safe
            s32 = card32 * (u32 / np.maximum(
                durations[:, i].astype(np.float32), np.float32(1e-9)))
            num32 = s32 + dec32 * num32
            den32 = np.float32(1.0) + dec32 * den32
        self.ema_num[slots] = num
        self.ema_den[slots] = den
        self.ema_num32[slots] = num32
        self.ema_den32[slots] = den32
        self._rebuild_window_terms(slots)
        self.n_invocations[slots] = np.maximum(self.n_invocations[slots], h)
        self._dev_dirty.update(slots.tolist())

    def install_history(self, client_id: int, durations,
                        n_invocations: int = 0, n_failures: int = 0,
                        last_round: int = -1) -> None:
        """Install a pre-existing client history (oldest-first durations,
        counters) — the columnar equivalent of registering a populated
        ``ClientRecord``: the retained window, cached window terms, and
        EMA state are rebuilt so scoring matches the object plane's view
        of the same record."""
        slot = self._slot[int(client_id)]
        durations = [float(d) for d in durations]
        keep = durations[-self.history:]
        m = len(keep)
        self.durations[slot, :] = 0.0
        self.durations[slot, :m] = keep[::-1]          # newest first
        self.dur_len[slot] = m
        card = int(self.cardinality[slot])
        epochs = int(self.local_epochs[slot])
        batch = int(self.batch_size[slot])
        num = den = 0.0
        num32 = den32 = np.float32(0.0)
        dec32 = np.float32(self._decay)
        u32 = self.upd32[slot]
        for d in durations:                            # full history EMA
            num, den = ema_push(num, den,
                                per_round_score(d, card, epochs, batch),
                                self._decay)
            s32 = np.float32(card) * (
                u32 / np.maximum(np.float32(d), np.float32(1e-9)))
            num32 = s32 + dec32 * num32
            den32 = np.float32(1.0) + dec32 * den32
        self.ema_num[slot], self.ema_den[slot] = num, den
        self.ema_num32[slot], self.ema_den32[slot] = num32, den32
        self.win_num[slot], self.win_den[slot] = window_accumulate(
            keep[::-1], card, epochs, batch, self._decay)
        self.n_invocations[slot] = max(int(n_invocations), 0)
        self.n_failures[slot] = max(int(n_failures), 0)
        self.last_round[slot] = int(last_round)
        self._touch(slot)

    # ------------------------------------------------- device score state
    def _device(self):
        if self._dev is None:
            self._dev = _DeviceScores(self.capacity)
            self._dev_dirty.update(self._slot.values())
        return self._dev

    def _flush_device(self) -> None:
        dev = self._device()
        if not self._dev_dirty:
            return
        idx = np.fromiter((i for i in self._dev_dirty if i < self.capacity),
                          np.int64)
        self._dev_dirty.clear()
        if idx.size == 0:
            return
        # the f32 twin columns ARE the device values (no cast of an f64
        # fold): the megastep scan carries and evolves these exact numbers,
        # so its in-scan selection is bitwise the stepwise selection
        dev.scatter(idx,
                    self.ema_num32[idx], self.ema_den32[idx],
                    self.active[idx] & (self.status[idx] == IDLE),
                    self.active[idx] & (self.n_invocations[idx] > 0))

    def select_topk(self, k: int, beta: float,
                    now_round: Optional[int] = None) -> list[int]:
        """Fleet-scale cohort selection: one jitted vectorized kernel over
        the device-resident score state. Idle uninvoked clients rank first
        (score +inf, the Algorithm 3 bootstrap), then the masked top-k of
        ``booster * ema_num/ema_den``; the booster update (selected -> 1,
        idle-unselected -> * beta) happens in the same kernel. Returns at
        most k client ids (fewer when fewer clients are eligible).
        ``now_round`` applies the quarantine mask host-side: benched
        clients are filtered from the returned cohort (their device score
        state is untouched, so they rank normally once released)."""
        if not self._slot:
            return []
        self._flush_device()
        dev = self._dev
        k_eff = int(min(int(k), self.capacity))
        if k_eff <= 0:
            return []
        idx, valid, boost = _score_topk(
            dev.num, dev.den, dev.booster, dev.eligible, dev.ever,
            np.float32(beta), k=k_eff)
        dev.booster = boost
        idx = np.asarray(idx)
        valid = np.asarray(valid)
        return [int(self.ids[s]) for s, v in zip(idx, valid)
                if v and (now_round is None
                          or self.quarantined_until[s] <= now_round)]

    # --------------------------------------------------------- persistence
    def state_dict(self) -> dict:
        """Numpy snapshot of every column + allocator state (checkpoint
        contract: ``FleetStore.from_state(state_dict())`` is identity,
        including live EMA/ring buffers and slot assignments)."""
        out = {name: getattr(self, name)[:self.capacity].copy()
               for name in self.COLUMNS}
        out["durations"] = self.durations[:self.capacity].copy()
        out["free"] = np.asarray(self._free, np.int64)
        out["next_seq"] = np.asarray([self._next_seq], np.int64)
        out["decay"] = np.asarray([self.decay], np.float64)
        out["history"] = np.asarray([self.history], np.int64)
        if self._dev is not None:
            # the top-k booster is device-owned state (never mirrored to
            # the host columns) — without it a resumed apodotiko-topk run
            # would restart every booster at 1.0
            out["dev_booster"] = np.asarray(self._dev.booster, np.float32)
        return out

    @classmethod
    def from_state(cls, state: dict) -> "FleetStore":
        fs = cls(history=int(state["history"][0]),
                 decay=float(state["decay"][0]))
        cap = len(state["ids"])
        fs.capacity = cap
        for name, dt in cls.COLUMNS.items():
            if name in state:
                setattr(fs, name, np.asarray(state[name]).copy())
            else:
                setattr(fs, name, np.zeros((cap,), dt))
        if "ema_num32" not in state:
            # checkpoint from before the f32 twin columns: rebuild from
            # the retained duration window (the only recoverable history)
            fs.upd32 = ((fs.cardinality * fs.local_epochs)
                        / np.maximum(fs.batch_size, 1)).astype(np.float32)
            fs.durations = np.asarray(state["durations"]).copy()
            live = np.flatnonzero(fs.active)
            if live.size:
                fs._rebuild_mirror32(live)
        fs.durations = np.asarray(state["durations"]).copy()
        fs._free = [int(i) for i in state["free"]]
        fs._next_seq = int(state["next_seq"][0])
        fs._slot = {int(c): int(s) for s, c in enumerate(fs.ids) if c >= 0}
        if "dev_booster" in state:
            import jax.numpy as jnp
            dev = fs._device()              # marks every slot dirty
            dev.booster = jnp.asarray(np.asarray(state["dev_booster"],
                                                 np.float32))
        return fs


class _DeviceScores:
    """Device-resident f32 score state (lazy; see FleetStore docstring).

    ``booster`` is *device-owned*: it evolves inside the top-k kernel and
    is never overwritten from the host columns — the f64 host booster
    belongs to the bit-exact probabilistic path, this one to the top-k
    path. Everything else mirrors the host columns via dirty scatters."""

    def __init__(self, capacity: int):
        import jax.numpy as jnp
        self.num = jnp.zeros((capacity,), jnp.float32)
        self.den = jnp.zeros((capacity,), jnp.float32)
        self.booster = jnp.ones((capacity,), jnp.float32)
        self.eligible = jnp.zeros((capacity,), bool)
        self.ever = jnp.zeros((capacity,), bool)

    def grow(self, capacity: int) -> None:
        import jax.numpy as jnp
        pad = capacity - self.num.shape[0]
        if pad <= 0:
            return
        cat = jnp.concatenate
        self.num = cat([self.num, jnp.zeros((pad,), jnp.float32)])
        self.den = cat([self.den, jnp.zeros((pad,), jnp.float32)])
        self.booster = cat([self.booster, jnp.ones((pad,), jnp.float32)])
        self.eligible = cat([self.eligible, jnp.zeros((pad,), bool)])
        self.ever = cat([self.ever, jnp.zeros((pad,), bool)])

    def scatter(self, idx, num, den, eligible, ever) -> None:
        import jax.numpy as jnp
        i = jnp.asarray(idx, jnp.int32)
        self.num = self.num.at[i].set(jnp.asarray(num, jnp.float32))
        self.den = self.den.at[i].set(jnp.asarray(den, jnp.float32))
        self.eligible = self.eligible.at[i].set(jnp.asarray(eligible))
        self.ever = self.ever.at[i].set(jnp.asarray(ever))

    def reset_booster(self, idx) -> None:
        import jax.numpy as jnp
        self.booster = self.booster.at[jnp.asarray(idx, jnp.int32)].set(1.0)


@functools.lru_cache(maxsize=None)
def _score_topk_fn():
    """Build the jitted score+topk+booster kernel lazily so importing the
    store never pays jax startup. The body is ``kernels.ops.scored_topk``
    — the single selection definition shared with the fused-round
    megastep's scan, which is what keeps the two paths bitwise equal."""
    import jax

    from repro.kernels.ops import scored_topk

    @functools.partial(jax.jit, static_argnames=("k",))
    def fn(num, den, booster, eligible, ever, beta, *, k):
        return scored_topk(num, den, booster, eligible, ever, beta, k)

    return fn


def _score_topk(num, den, booster, eligible, ever, beta, *, k):
    return _score_topk_fn()(num, den, booster, eligible, ever, beta, k=k)
