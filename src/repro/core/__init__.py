from repro.core.staleness import eq1_fedlesscan, eq2_apodotiko  # noqa: F401
from repro.core.scoring import (  # noqa: F401
    calculate_score, calculate_scores, ema_push, ema_score)
from repro.core.selection import select_clients  # noqa: F401
from repro.core.database import Database, ClientRecord, ResultRecord  # noqa: F401
from repro.core.fleet_store import FleetStore  # noqa: F401
from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows  # noqa: F401
from repro.core.update_store import UpdateStore  # noqa: F401
from repro.core.data_plane import (  # noqa: F401
    DatasetStore, dataset_store, resolve_data_plane)
from repro.core.services import (  # noqa: F401
    FLConfig, FLRuntime, RoundLog, resolve_control_plane)
from repro.core.controller import Controller  # noqa: F401
from repro.core.scheduler import Scheduler, build_engine  # noqa: F401
from repro.core.protocol import (  # noqa: F401
    Action, Aggregate, CancelInvocation, ClientJoined, ClientLeft,
    DatabaseView, EndRun, Event, Hedge, Invoke, InvocationFailed,
    LoopDrained, ReactivePolicy, ResultLanded, RoundStarted, SetTimer,
    TimerFired)
from repro.core.strategies.reactive import (  # noqa: F401
    LegacyStrategyAdapter, REACTIVE_POLICIES, is_reactive, make_policy)
