from repro.core.staleness import eq1_fedlesscan, eq2_apodotiko  # noqa: F401
from repro.core.scoring import calculate_score  # noqa: F401
from repro.core.selection import select_clients  # noqa: F401
from repro.core.database import Database, ClientRecord, ResultRecord  # noqa: F401
from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows  # noqa: F401
from repro.core.update_store import UpdateStore  # noqa: F401
from repro.core.controller import Controller, FLConfig  # noqa: F401
