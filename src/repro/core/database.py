"""FedLess-style database (the paper's external state store).

The real system keeps invocation records, client attributes and model
updates in MongoDB; clients and the controller communicate exclusively
through it (Algorithm 1 lines 6-7, 20-22). Here it is an in-process store
with the same record semantics plus optional persistence (JSON metadata +
NPZ parameter blobs) so the controller can crash and resume — the
fault-tolerance path exercised in tests/test_checkpoint.py.

Two **control planes** back the per-client state (DESIGN.md §10):

* ``object`` — the original dict of :class:`ClientRecord` Python objects.
  Kept verbatim as the equivalence oracle and for direct construction
  (``Database()`` defaults to it, so tests poking records keep working).
* ``columnar`` — a struct-of-arrays :class:`~repro.core.fleet_store.FleetStore`
  (the runtime default via ``REPRO_CONTROL_PLANE``): status/cardinality/
  booster/EMA columns, duration ring buffers, id->slot map. Selection and
  scoring run vectorized over the columns with **bit-identical** results
  to the object plane (tests/test_control_plane.py).

Both planes expose one uniform accessor API (``mark_*``, ``has_client``,
``idle_client_ids``, ``any_idle``, ``recent_durations``, ...) — the
runtime, scheduler, and strategies speak only that API, never the record
objects, so the plane is swappable per run. ``db.clients`` remains as a
dict view: the live dict on the object plane, a materialized *snapshot*
of ClientRecords on the columnar plane (read-only by construction — for
tests and debugging, never on a hot path).

Results, update blobs, and global models are plane-independent: they are
O(clients_per_round) per round, not O(fleet).
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.fleet_store import IDLE, RUNNING, FleetStore

FLEET_NPZ = "fleet.npz"


@dataclass
class ClientRecord:
    client_id: int
    hardware: str                      # profile name
    data_cardinality: int
    batch_size: int
    local_epochs: int
    booster: float = 1.0
    status: str = "idle"               # idle | running
    invoked_rounds: list = field(default_factory=list)
    durations: list = field(default_factory=list)   # most-recent-LAST
    n_invocations: int = 0
    n_failures: int = 0
    consec_failures: int = 0       # failures since the last landed result
    quarantined_until: int = 0     # benched until this round (exclusive;
    #                                0 = never quarantined)

    @property
    def ever_invoked(self) -> bool:
        return self.n_invocations > 0


@dataclass
class ResultRecord:
    client_id: int
    round: int                         # round the client trained against
    n_samples: int
    train_duration: float
    t_available: float                 # sim time the update landed in the DB
    aggregated: bool = False
    update_key: str = ""               # key into the parameter blob store
    update_row: int = -1               # row handle into the device-resident
    #                                    UpdateStore (update-plane path); -1
    #                                    when the update lives in a blob


class Database:
    """Transactional-enough store: every mutation goes through a method so a
    snapshot/restore pair gives a consistent view (used for FT tests)."""

    def __init__(self, control_plane: str = "object"):
        if control_plane not in ("object", "columnar"):
            raise ValueError(f"unknown control plane {control_plane!r}")
        self.control_plane = control_plane
        self._clients: dict[int, ClientRecord] = {}
        self.fleet: Optional[FleetStore] = (
            FleetStore() if control_plane == "columnar" else None)
        self.results: list[ResultRecord] = []
        self.blobs: dict[str, Any] = {}          # update pytrees (host numpy)
        self.global_models: dict[int, str] = {}  # round -> blob key
        self.round: int = 0
        self.meta: dict[str, Any] = {}

    @property
    def columnar(self) -> bool:
        return self.control_plane == "columnar"

    # ------------------------------------------------------------- clients
    @property
    def clients(self) -> dict:
        """Object plane: the live record dict. Columnar plane: a
        materialized ClientRecord snapshot (reads reflect the columns at
        call time; mutations do NOT write back — use the accessor API)."""
        if not self.columnar:
            return self._clients
        return {cid: self.materialize_client(cid)
                for cid in self.fleet.client_ids()}

    def materialize_client(self, client_id: int) -> ClientRecord:
        fs = self.fleet
        s = fs.slot_of(client_id)
        last = int(fs.last_round[s])
        return ClientRecord(
            client_id=int(client_id), hardware="",
            data_cardinality=int(fs.cardinality[s]),
            batch_size=int(fs.batch_size[s]),
            local_epochs=int(fs.local_epochs[s]),
            booster=float(fs.booster[s]),
            status="running" if fs.status[s] == RUNNING else "idle",
            invoked_rounds=[last] if last >= 0 else [],
            durations=fs.recent_durations(client_id, fs.history),
            n_invocations=int(fs.n_invocations[s]),
            n_failures=int(fs.n_failures[s]),
            consec_failures=int(fs.consec_failures[s]),
            quarantined_until=int(fs.quarantined_until[s]))

    def register_client(self, rec: ClientRecord) -> None:
        if self.columnar:
            self.fleet.add(rec.client_id, rec.data_cardinality,
                           rec.batch_size, rec.local_epochs,
                           booster=rec.booster,
                           status=RUNNING if rec.status == "running"
                           else IDLE)
            if rec.durations or rec.n_invocations or rec.n_failures:
                # pre-populated record (tests/benches seed history this
                # way): replay it into the columns so both planes score
                # the client identically
                self.fleet.install_history(
                    rec.client_id, rec.durations,
                    n_invocations=rec.n_invocations,
                    n_failures=rec.n_failures,
                    last_round=(rec.invoked_rounds[-1]
                                if rec.invoked_rounds else -1))
            if rec.consec_failures or rec.quarantined_until:
                slot = self.fleet.slot_of(rec.client_id)
                self.fleet.consec_failures[slot] = rec.consec_failures
                self.fleet.quarantined_until[slot] = rec.quarantined_until
        else:
            self._clients[rec.client_id] = rec

    def unregister_client(self, client_id: int) -> bool:
        if self.columnar:
            return self.fleet.remove(client_id)
        return self._clients.pop(client_id, None) is not None

    # ------------------------------------------------------ bulk membership
    def register_clients_bulk(self, client_ids, cardinalities, batch_size,
                              local_epochs, hardware=None) -> None:
        """Register fresh clients in one columnar append (the traffic
        plane's entry point, DESIGN.md §13). On the object plane this
        degrades to per-record dict assignment with identical insertion
        order (ids are applied in the given order on both planes)."""
        if self.columnar:
            self.fleet.add_batch(client_ids, cardinalities, batch_size,
                                 local_epochs)
            return
        hw = hardware if hardware is not None else [""] * len(client_ids)
        for cid, card, name in zip(client_ids, cardinalities, hw):
            self._clients[int(cid)] = ClientRecord(
                client_id=int(cid), hardware=name,
                data_cardinality=int(card), batch_size=int(batch_size),
                local_epochs=int(local_epochs))

    def unregister_clients_bulk(self, client_ids) -> list[int]:
        """Remove clients in one columnar scatter; returns the ids that
        were actually registered (unknown ids are skipped)."""
        if self.columnar:
            return self.fleet.remove_batch(client_ids)
        return [int(cid) for cid in client_ids
                if self._clients.pop(int(cid), None) is not None]

    def mark_running(self, client_id: int, round_: int) -> None:
        if self.columnar:
            self.fleet.mark_running(client_id, round_)
            return
        c = self._clients[client_id]
        c.status = "running"
        c.invoked_rounds.append(round_)
        c.n_invocations += 1

    def mark_complete(self, client_id: int, duration: float) -> None:
        if self.columnar:
            self.fleet.mark_complete(client_id, duration)
            return
        c = self._clients[client_id]
        c.status = "idle"
        c.durations.append(duration)
        c.consec_failures = 0           # a landed result heals the streak

    def mark_failed(self, client_id: int) -> None:
        if self.columnar:
            self.fleet.mark_failed(client_id)
            return
        c = self._clients[client_id]
        c.status = "idle"
        c.n_failures += 1
        c.consec_failures += 1

    def incr_failures(self, client_id: int) -> None:
        """Count a failure without touching status (a hedge sibling is
        still racing for this client)."""
        if self.columnar:
            self.fleet.incr_failures(client_id)
        else:
            c = self._clients[client_id]
            c.n_failures += 1
            c.consec_failures += 1

    # ------------------------------------------- recovery / circuit breaker
    def quarantine(self, client_id: int, until_round: int) -> None:
        """Bench the client until ``until_round`` (exclusive): it drops
        out of ``idle_client_ids``/``any_idle`` and every strategy's
        selection mask while ``round < until_round`` (DESIGN.md §12)."""
        if self.columnar:
            self.fleet.quarantine(client_id, until_round)
        else:
            self._clients[client_id].quarantined_until = int(until_round)

    def consecutive_failures(self, client_id: int) -> int:
        if self.columnar:
            return int(self.fleet.consec_failures[
                self.fleet.slot_of(client_id)])
        return self._clients[client_id].consec_failures

    def is_quarantined(self, client_id: int) -> bool:
        if self.columnar:
            return bool(self.fleet.quarantined_until[
                self.fleet.slot_of(client_id)] > self.round)
        return self._clients[client_id].quarantined_until > self.round

    def release_client(self, client_id: int) -> None:
        """Return a running client to idle without recording a duration
        (cancellation path)."""
        if self.columnar:
            self.fleet.set_idle(client_id)
            return
        rec = self._clients.get(client_id)
        if rec is not None and rec.status == "running":
            rec.status = "idle"

    # ------------------------------------------------ uniform fleet queries
    @property
    def n_clients(self) -> int:
        return len(self.fleet) if self.columnar else len(self._clients)

    def has_client(self, client_id: int) -> bool:
        if self.columnar:
            return self.fleet.has(client_id)
        return client_id in self._clients

    def client_ids(self) -> list[int]:
        """Registered client ids in registration order (dict order on the
        object plane, seq order on the columnar one — identical)."""
        if self.columnar:
            return self.fleet.client_ids()
        return list(self._clients)

    def idle_client_ids(self) -> list[int]:
        """Idle, non-quarantined client ids in registration order — the
        shared selection candidate list (both planes produce the identical
        list, so shared downstream ``rng.choice`` draws stay
        bit-identical). Quarantine defaults keep this exactly the old
        idle list when the recovery layer is off."""
        if self.columnar:
            return self.fleet.idle_ids(self.round)
        return [c.client_id for c in self._clients.values()
                if c.status == "idle" and c.quarantined_until <= self.round]

    def any_idle(self) -> bool:
        if self.columnar:
            return self.fleet.any_idle(self.round)
        return any(c.status == "idle" and c.quarantined_until <= self.round
                   for c in self._clients.values())

    def recent_durations(self, client_id: int, k: int) -> list[float]:
        """The client's last <=k training durations, oldest first (empty
        for unknown clients) — ``record.durations[-k:]`` on both planes."""
        if self.columnar:
            return self.fleet.recent_durations(client_id, k)
        rec = self._clients.get(client_id)
        return list(rec.durations[-k:]) if rec is not None else []

    # ------------------------------------------------------------- results
    def put_update(self, rec: ResultRecord, update: Any) -> None:
        key = f"u{rec.client_id}r{rec.round}n{len(self.results)}"
        rec.update_key = key
        self.blobs[key] = update
        self.results.append(rec)

    def put_update_row(self, rec: ResultRecord, row: int) -> None:
        """Update-plane result: the parameters stay on device as a row of
        the controller's UpdateStore; the database records only the handle."""
        rec.update_row = int(row)
        self.results.append(rec)

    def pending_results(self, max_staleness: int, current_round: int):
        """Un-aggregated updates no older than max_staleness rounds."""
        return [r for r in self.results
                if not r.aggregated
                and current_round - r.round <= max_staleness]

    def mark_aggregated(self, recs) -> None:
        for r in recs:
            r.aggregated = True
            # free the blob: aggregated updates are never re-read
            self.blobs.pop(r.update_key, None)

    def put_global_model(self, round_: int, params: Any) -> None:
        key = f"g{round_}"
        self.blobs[key] = params
        self.global_models[round_] = key
        # retain only a short history of globals
        for r in sorted(self.global_models)[:-3]:
            self.blobs.pop(self.global_models.pop(r), None)

    def latest_global(self) -> Any:
        r = max(self.global_models)
        return self.blobs[self.global_models[r]]

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        meta = {
            "round": self.round,
            "meta": self.meta,
            "control_plane": self.control_plane,
            # object plane: full records; columnar plane: the columns live
            # in fleet.npz (no O(fleet) JSON materialization)
            "clients": ({} if self.columnar else
                        {str(k): asdict(v)
                         for k, v in self._clients.items()}),
            "results": [asdict(r) for r in self.results],
            "global_models": {str(k): v for k, v in self.global_models.items()},
        }
        tmp = os.path.join(path, ".db.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, "db.json"))
        if self.columnar:
            tmp = os.path.join(path, ".fleet.npz.tmp")
            with open(tmp, "wb") as f:
                np.savez(f, **self.fleet.state_dict())
            os.replace(tmp, os.path.join(path, FLEET_NPZ))
        flat = {}
        for key, tree in self.blobs.items():
            leaves, _ = _flatten(tree)
            for i, leaf in enumerate(leaves):
                flat[f"{key}|{i}"] = np.asarray(leaf)
            flat[f"{key}|treedef"] = np.array(json.dumps(_treedef(tree)))
        # atomic like db.json/fleet.npz: a crash mid-write must never
        # leave a truncated blobs.npz shadowing the previous good one
        tmp = os.path.join(path, ".blobs.npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, os.path.join(path, "blobs.npz"))

    @classmethod
    def load(cls, path: str) -> "Database":
        with open(os.path.join(path, "db.json")) as f:
            meta = json.load(f)
        db = cls(control_plane=meta.get("control_plane", "object"))
        db.round = meta["round"]
        db.meta = meta["meta"]
        if db.columnar:
            with np.load(os.path.join(path, FLEET_NPZ)) as data:
                db.fleet = FleetStore.from_state(dict(data))
        else:
            for k, v in meta["clients"].items():
                db._clients[int(k)] = ClientRecord(**v)
        db.results = [ResultRecord(**r) for r in meta["results"]]
        db.global_models = {int(k): v for k, v in meta["global_models"].items()}
        data = np.load(os.path.join(path, "blobs.npz"), allow_pickle=False)
        groups: dict[str, dict] = {}
        for name in data.files:
            key, idx = name.rsplit("|", 1)
            groups.setdefault(key, {})[idx] = data[name]
        for key, parts in groups.items():
            tdef = json.loads(str(parts.pop("treedef")))
            leaves = [parts[str(i)] for i in range(len(parts))]
            db.blobs[key] = _unflatten(tdef, leaves)
        return db


# -- tiny pytree (nested-dict) flatten helpers, no jax dependency ------------


def _flatten(tree):
    leaves = []

    def rec(node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k])
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)
        else:
            leaves.append(node)

    rec(tree)
    return leaves, None


def _treedef(tree):
    if isinstance(tree, dict):
        return {k: _treedef(tree[k]) for k in sorted(tree)}
    if isinstance(tree, (list, tuple)):
        return [_treedef(v) for v in tree]
    return None


def _unflatten(tdef, leaves):
    it = iter(leaves)

    def rec(node):
        if isinstance(node, dict):
            return {k: rec(node[k]) for k in sorted(node)}
        if isinstance(node, list):
            return [rec(v) for v in node]
        return next(it)

    return rec(tdef)
