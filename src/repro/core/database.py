"""FedLess-style database (the paper's external state store).

The real system keeps invocation records, client attributes and model
updates in MongoDB; clients and the controller communicate exclusively
through it (Algorithm 1 lines 6-7, 20-22). Here it is an in-process store
with the same record semantics plus optional persistence (JSON metadata +
NPZ parameter blobs) so the controller can crash and resume — the
fault-tolerance path exercised in tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class ClientRecord:
    client_id: int
    hardware: str                      # profile name
    data_cardinality: int
    batch_size: int
    local_epochs: int
    booster: float = 1.0
    status: str = "idle"               # idle | running
    invoked_rounds: list = field(default_factory=list)
    durations: list = field(default_factory=list)   # most-recent-LAST
    n_invocations: int = 0
    n_failures: int = 0

    @property
    def ever_invoked(self) -> bool:
        return self.n_invocations > 0


@dataclass
class ResultRecord:
    client_id: int
    round: int                         # round the client trained against
    n_samples: int
    train_duration: float
    t_available: float                 # sim time the update landed in the DB
    aggregated: bool = False
    update_key: str = ""               # key into the parameter blob store
    update_row: int = -1               # row handle into the device-resident
    #                                    UpdateStore (update-plane path); -1
    #                                    when the update lives in a blob


class Database:
    """Transactional-enough store: every mutation goes through a method so a
    snapshot/restore pair gives a consistent view (used for FT tests)."""

    def __init__(self):
        self.clients: dict[int, ClientRecord] = {}
        self.results: list[ResultRecord] = []
        self.blobs: dict[str, Any] = {}          # update pytrees (host numpy)
        self.global_models: dict[int, str] = {}  # round -> blob key
        self.round: int = 0
        self.meta: dict[str, Any] = {}

    # ------------------------------------------------------------- clients
    def register_client(self, rec: ClientRecord) -> None:
        self.clients[rec.client_id] = rec

    def mark_running(self, client_id: int, round_: int) -> None:
        c = self.clients[client_id]
        c.status = "running"
        c.invoked_rounds.append(round_)
        c.n_invocations += 1

    def mark_complete(self, client_id: int, duration: float) -> None:
        c = self.clients[client_id]
        c.status = "idle"
        c.durations.append(duration)

    def mark_failed(self, client_id: int) -> None:
        c = self.clients[client_id]
        c.status = "idle"
        c.n_failures += 1

    # ------------------------------------------------------------- results
    def put_update(self, rec: ResultRecord, update: Any) -> None:
        key = f"u{rec.client_id}r{rec.round}n{len(self.results)}"
        rec.update_key = key
        self.blobs[key] = update
        self.results.append(rec)

    def put_update_row(self, rec: ResultRecord, row: int) -> None:
        """Update-plane result: the parameters stay on device as a row of
        the controller's UpdateStore; the database records only the handle."""
        rec.update_row = int(row)
        self.results.append(rec)

    def pending_results(self, max_staleness: int, current_round: int):
        """Un-aggregated updates no older than max_staleness rounds."""
        return [r for r in self.results
                if not r.aggregated
                and current_round - r.round <= max_staleness]

    def mark_aggregated(self, recs) -> None:
        for r in recs:
            r.aggregated = True
            # free the blob: aggregated updates are never re-read
            self.blobs.pop(r.update_key, None)

    def put_global_model(self, round_: int, params: Any) -> None:
        key = f"g{round_}"
        self.blobs[key] = params
        self.global_models[round_] = key
        # retain only a short history of globals
        for r in sorted(self.global_models)[:-3]:
            self.blobs.pop(self.global_models.pop(r), None)

    def latest_global(self) -> Any:
        r = max(self.global_models)
        return self.blobs[self.global_models[r]]

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        meta = {
            "round": self.round,
            "meta": self.meta,
            "clients": {str(k): asdict(v) for k, v in self.clients.items()},
            "results": [asdict(r) for r in self.results],
            "global_models": {str(k): v for k, v in self.global_models.items()},
        }
        tmp = os.path.join(path, ".db.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, "db.json"))
        flat = {}
        for key, tree in self.blobs.items():
            leaves, _ = _flatten(tree)
            for i, leaf in enumerate(leaves):
                flat[f"{key}|{i}"] = np.asarray(leaf)
            flat[f"{key}|treedef"] = np.array(json.dumps(_treedef(tree)))
        np.savez(os.path.join(path, "blobs.npz"), **flat)

    @classmethod
    def load(cls, path: str) -> "Database":
        db = cls()
        with open(os.path.join(path, "db.json")) as f:
            meta = json.load(f)
        db.round = meta["round"]
        db.meta = meta["meta"]
        for k, v in meta["clients"].items():
            db.clients[int(k)] = ClientRecord(**v)
        db.results = [ResultRecord(**r) for r in meta["results"]]
        db.global_models = {int(k): v for k, v in meta["global_models"].items()}
        data = np.load(os.path.join(path, "blobs.npz"), allow_pickle=False)
        groups: dict[str, dict] = {}
        for name in data.files:
            key, idx = name.rsplit("|", 1)
            groups.setdefault(key, {})[idx] = data[name]
        for key, parts in groups.items():
            tdef = json.loads(str(parts.pop("treedef")))
            leaves = [parts[str(i)] for i in range(len(parts))]
            db.blobs[key] = _unflatten(tdef, leaves)
        return db


# -- tiny pytree (nested-dict) flatten helpers, no jax dependency ------------


def _flatten(tree):
    leaves = []

    def rec(node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k])
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)
        else:
            leaves.append(node)

    rec(tree)
    return leaves, None


def _treedef(tree):
    if isinstance(tree, dict):
        return {k: _treedef(tree[k]) for k in sorted(tree)}
    if isinstance(tree, (list, tuple)):
        return [_treedef(v) for v in tree]
    return None


def _unflatten(tdef, leaves):
    it = iter(leaves)

    def rec(node):
        if isinstance(node, dict):
            return {k: rec(node[k]) for k in sorted(node)}
        if isinstance(node, list):
            return [rec(v) for v in node]
        return next(it)

    return rec(tdef)
