"""Append-only write-ahead journal of protocol events (DESIGN.md §14).

The durability layer (``repro.durability``) records every protocol
occurrence — events dispatched through ``_emit``/``_dispatch`` plus
round-boundary markers — as one CRC-framed JSON line *before* its
side effects become externally visible. Because the simulator is fully
deterministic given its seeds, the journal is not replayed to mutate
state; it is the **oracle** a resumed run re-validates itself against:
after restoring the last coordinated snapshot, re-execution must re-emit
the exact journal tail byte for byte, or the resume aborts with a
divergence error instead of silently forking the trace.

Framing: each record is ``<compact-json>|<crc32 hex8>\n``. A torn tail
(the process died mid-``write``) fails the CRC or the newline scan and
defines the *last consistent prefix*; ``read`` reports both the parsed
records and the byte offset of that prefix so the resume path can
truncate the file back to a clean state. Sequence numbers (``q``) are
dense from 0 — a gap means a corrupt middle, which also ends the prefix.

Sync policy: ``append`` always issues the ``os.write`` immediately (an
in-process SIGKILL loses nothing already appended); ``fsync`` is per
record ("event" policy) or only at round boundaries ("round" policy) —
the caller decides per append.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, List, Optional, Tuple

JOURNAL_NAME = "journal.wal"

#: journal record kinds that are markers, not protocol events
MARKER_KINDS = ("genesis", "round_open", "round_close", "run_end")


def encode_line(record: dict) -> bytes:
    body = json.dumps(record, separators=(",", ":"), sort_keys=True)
    return f"{body}|{zlib.crc32(body.encode()):08x}\n".encode()


def decode_line(line: bytes) -> Optional[dict]:
    """Parse one framed line; None if the frame or CRC is bad."""
    body, sep, crc = line.rpartition(b"|")
    if not sep or len(crc) != 8:
        return None
    try:
        if zlib.crc32(body) != int(crc, 16):
            return None
        return json.loads(body)
    except (ValueError, UnicodeDecodeError):
        return None


def encode_event(event: Any) -> Tuple[str, dict]:
    """A protocol event as (kind, JSON payload). Nested dataclasses
    (``ResultRecord`` inside ``ResultLanded``) flatten via asdict; the
    event's own ``t`` is carried at the record top level, not here."""
    payload = {}
    for f in dataclasses.fields(event):
        if f.name == "t":
            continue
        v = getattr(event, f.name)
        if dataclasses.is_dataclass(v) and not isinstance(v, type):
            v = dataclasses.asdict(v)
        elif isinstance(v, tuple):
            v = list(v)
        payload[f.name] = v
    return type(event).__name__, payload


class Journal:
    """Lazy-open append handle over one journal file. Uses raw
    ``os.write`` so bytes reach the kernel the moment ``append``
    returns — a simulated SIGKILL immediately after cannot tear a
    record that the in-process reader already considers written."""

    def __init__(self, path: str):
        self.path = path
        self._fd: Optional[int] = None
        self.bytes_written = 0
        self.n_fsyncs = 0

    def _open(self) -> int:
        if self._fd is None:
            parent = os.path.dirname(self.path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._fd = os.open(self.path,
                               os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        return self._fd

    def append(self, record: dict, *, fsync: bool) -> None:
        line = encode_line(record)
        fd = self._open()
        os.write(fd, line)
        self.bytes_written += len(line)
        if fsync:
            os.fsync(fd)
            self.n_fsyncs += 1

    def flush(self) -> None:
        if self._fd is not None:
            os.fsync(self._fd)
            self.n_fsyncs += 1

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    # ----------------------------------------------------------- reading
    @staticmethod
    def read(path: str) -> Tuple[List[dict], int]:
        """Parse the journal into (records, consistent_prefix_bytes).

        Scanning stops at the first torn/corrupt line or sequence gap;
        everything before it is the last consistent prefix. A resume
        truncates the file to that offset before appending anything."""
        with open(path, "rb") as f:
            data = f.read()
        records: List[dict] = []
        off = 0
        while True:
            nl = data.find(b"\n", off)
            if nl < 0:
                break                       # torn tail: no newline
            rec = decode_line(data[off:nl])
            if rec is None or rec.get("q") != len(records):
                break                       # bad CRC / frame / seq gap
            records.append(rec)
            off = nl + 1
        return records, off

    @staticmethod
    def truncate_to_consistent(path: str) -> Tuple[List[dict], bool]:
        """Read + repair: drop any torn tail in place. Returns the
        consistent records and whether bytes were discarded."""
        records, good = Journal.read(path)
        size = os.path.getsize(path)
        if good < size:
            os.truncate(path, good)
            return records, True
        return records, False
