"""Client Efficiency Scoring (paper §III-C, Algorithm 2).

Five attributes are collected per training round: training duration, local
data cardinality N_c, batch size B, local epochs E, and the booster value
beta. The Client Efficiency Score (CEF) uses measured training throughput as
an implicit hardware benchmark:

    #updates            = N_c * E / B                    (optimizer steps)
    per-round score_i   = N_c * (#updates / T_i)         (data-weighted throughput)
    weighted_sum        = sum_i lambda^i * score_i       (i=0 most recent)
    score               = beta * weighted_sum / sum_i lambda^i

with decay rate lambda = 1 - rho and promotion rate 1 + rho (rho = 0.2 by
default, paper §III-C).

Three evaluation strategies of the same formula live here (DESIGN.md §10):

* ``calculate_score`` — the original per-client Python loop over a duration
  history. Kept verbatim as the *object-plane oracle*: the columnar control
  plane must reproduce its scores bit-for-bit.
* ``calculate_scores`` — the columnar twin: one vectorized pass over
  ``[M, W]`` duration windows that replays the oracle's exact operation
  order (same associativity, same scalar decay-weight sequence), so every
  element is bit-identical to the per-client loop. This is what
  ``FleetStore``-backed selection dispatches.
* ``ema_push`` / ``ema_score`` — O(1) *incremental* EMA state. The loop
  recomputes the weighted sum from the full history on every selection
  (O(history) per client per round); pushing each new duration into
  ``(num, den)`` instead keeps scoring O(1) per result. Mathematically
  identical to the full recompute over the complete history (Horner vs
  direct evaluation — property-tested in tests/test_properties.py), it is
  the score state behind the device-resident top-k selection path.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

# Duration history window: how many most-recent training durations feed the
# windowed score (Algorithm 3 uses the last 10) and therefore how many the
# columnar plane's ring buffers retain per client. Every consumer of
# per-client duration history (selection window 10, FedLesScan clustering
# and hedge ranking over the last 5) fits inside it.
HISTORY_WINDOW = 10


def n_updates(data_cardinality: int, epochs: int, batch_size: int) -> float:
    """Algorithm 2 line 2: number of local optimizer updates."""
    return data_cardinality * epochs / max(batch_size, 1)


def calculate_score(
    booster: float,
    durations: Sequence[float],
    data_cardinality: int,
    epochs: int,
    batch_size: int,
    decay: float,
) -> float:
    """Algorithm 2. ``durations`` is ordered most-recent-first (i=0 newest).

    Returns beta * (sum_i decay^i * N_c * #updates / T_i) / (sum_i decay^i).
    """
    if not durations:
        return 0.0
    upd = n_updates(data_cardinality, epochs, batch_size)
    weighted_sum = 0.0
    norm = 0.0
    w = 1.0
    for t in durations:
        weighted_sum += w * data_cardinality * (upd / max(t, 1e-9))
        norm += w
        w *= decay
    return booster * weighted_sum / norm


def window_accumulate(durations: Sequence[float], data_cardinality: int,
                      epochs: int, batch_size: int,
                      decay: float) -> Tuple[float, float]:
    """One client's windowed CEF terms ``(weighted_sum, norm)`` — the
    exact accumulation loop of ``calculate_score`` without the final
    booster scaling. ``durations`` is most-recent-first. This is the O(W)
    per-result refresh behind the columnar plane's cached window terms:
    selection then reads ``booster * weighted_sum / norm`` with three
    vector ops instead of re-walking every client's history."""
    upd = n_updates(data_cardinality, epochs, batch_size)
    weighted_sum = 0.0
    norm = 0.0
    w = 1.0
    for t in durations:
        weighted_sum += w * data_cardinality * (upd / max(t, 1e-9))
        norm += w
        w *= decay
    return weighted_sum, norm


def calculate_scores(booster, durations, lengths, cardinality, epochs,
                     batch_size, decay: float) -> np.ndarray:
    """Vectorized Algorithm 2 over ``M`` clients at once.

    ``durations`` is ``[M, W]`` float64 ordered most-recent-FIRST along the
    window axis, with ``lengths[m]`` valid entries per row; ``booster``,
    ``cardinality``, ``epochs``, ``batch_size`` are ``[M]`` columns.

    Bit-identical to ``calculate_score`` applied per client: the window
    loop below replays the scalar loop's exact operation order — the decay
    weight ``w`` is the same Python-float sequence, every elementwise f64
    op is the same IEEE-rounded op, and the associativity
    ``(w * N_c) * (upd / max(t, eps))`` / ``(beta * sum) / norm`` matches
    the scalar expression. Clients with empty histories score 0.0, like
    the scalar early-return.
    """
    lengths = np.asarray(lengths)
    weighted_sum, norm = window_terms(durations, lengths, cardinality,
                                      epochs, batch_size, decay)
    return scores_from_terms(booster, weighted_sum, norm, lengths)


def window_terms(durations, lengths, cardinality, epochs, batch_size,
                 decay: float) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized ``window_accumulate`` over ``[M, W]`` newest-first
    duration windows: ``(weighted_sum [M], norm [M])``, bit-identical per
    element to the scalar loop (see ``calculate_scores``)."""
    # [W, M] contiguous so each window step streams one cache-friendly row
    durs = np.ascontiguousarray(np.asarray(durations, np.float64).T)
    lengths = np.asarray(lengths)
    W, M = durs.shape
    card = np.asarray(cardinality, np.int64)
    upd = (card * np.asarray(epochs, np.int64)) \
        / np.maximum(np.asarray(batch_size, np.int64), 1)
    cardf = card.astype(np.float64)
    weighted_sum = np.zeros(M, np.float64)
    norm = np.zeros(M, np.float64)
    # preallocated scratch: the loop below runs allocation-free in-place
    # ufuncs replaying the scalar loop's op order exactly. Masking is a
    # multiply by the valid bool — exact for these terms (positive finite:
    # x*1.0 == x, x*0.0 == 0.0), unlike the general np.where contract.
    term = np.empty(M, np.float64)
    wc = np.empty(M, np.float64)
    valid = np.empty(M, np.float64)
    w = 1.0
    for i in range(W):
        np.multiply(lengths > i, 1.0, out=valid)
        np.maximum(durs[i], 1e-9, out=term)
        np.divide(upd, term, out=term)              # upd / max(t, 1e-9)
        np.multiply(cardf, w, out=wc)               # w * N_c
        np.multiply(wc, term, out=term)             # (w*N_c) * (upd/max)
        np.multiply(term, valid, out=term)
        weighted_sum += term
        np.multiply(valid, w, out=valid)
        norm += valid
        w = w * decay
    return weighted_sum, norm


def scores_from_terms(booster, weighted_sum, norm, lengths) -> np.ndarray:
    """``beta * weighted_sum / norm`` with the empty-history guard — the
    final step shared by the recompute path and the cached-terms path."""
    return np.where(
        np.asarray(lengths) > 0,
        (np.asarray(booster, np.float64) * np.asarray(weighted_sum))
        / np.where(np.asarray(norm) > 0, norm, 1.0),
        0.0)


def per_round_score(duration: float, data_cardinality: int, epochs: int,
                    batch_size: int) -> float:
    """One round's contribution to the CEF sum: N_c * #updates / T."""
    upd = n_updates(data_cardinality, epochs, batch_size)
    return data_cardinality * (upd / max(duration, 1e-9))


def ema_push(num: float, den: float, score: float,
             decay: float) -> Tuple[float, float]:
    """O(1) incremental EMA update on a new per-round ``score``.

    Maintains ``num = sum_i decay^i * s_i`` and ``den = sum_i decay^i``
    (i = 0 newest) without revisiting the history: the newest round enters
    with weight 1 and every older round's weight decays by one step."""
    return score + decay * num, 1.0 + decay * den


def ema_score(booster: float, num: float, den: float) -> float:
    """Score from incremental EMA state (0.0 before any result lands)."""
    if den <= 0:
        return 0.0
    return booster * num / den


def decay_rate(adjustment_rate: float) -> float:
    """lambda = 1 - rho."""
    return 1.0 - adjustment_rate


def promotion_rate(adjustment_rate: float) -> float:
    """beta multiplier = 1 + rho."""
    return 1.0 + adjustment_rate
