"""Client Efficiency Scoring (paper §III-C, Algorithm 2).

Five attributes are collected per training round: training duration, local
data cardinality N_c, batch size B, local epochs E, and the booster value
beta. The Client Efficiency Score (CEF) uses measured training throughput as
an implicit hardware benchmark:

    #updates            = N_c * E / B                    (optimizer steps)
    per-round score_i   = N_c * (#updates / T_i)         (data-weighted throughput)
    weighted_sum        = sum_i lambda^i * score_i       (i=0 most recent)
    score               = beta * weighted_sum / sum_i lambda^i

with decay rate lambda = 1 - rho and promotion rate 1 + rho (rho = 0.2 by
default, paper §III-C).
"""
from __future__ import annotations

from typing import Sequence


def n_updates(data_cardinality: int, epochs: int, batch_size: int) -> float:
    """Algorithm 2 line 2: number of local optimizer updates."""
    return data_cardinality * epochs / max(batch_size, 1)


def calculate_score(
    booster: float,
    durations: Sequence[float],
    data_cardinality: int,
    epochs: int,
    batch_size: int,
    decay: float,
) -> float:
    """Algorithm 2. ``durations`` is ordered most-recent-first (i=0 newest).

    Returns beta * (sum_i decay^i * N_c * #updates / T_i) / (sum_i decay^i).
    """
    if not durations:
        return 0.0
    upd = n_updates(data_cardinality, epochs, batch_size)
    weighted_sum = 0.0
    norm = 0.0
    w = 1.0
    for t in durations:
        weighted_sum += w * data_cardinality * (upd / max(t, 1e-9))
        norm += w
        w *= decay
    return booster * weighted_sum / norm


def decay_rate(adjustment_rate: float) -> float:
    """lambda = 1 - rho."""
    return 1.0 - adjustment_rate


def promotion_rate(adjustment_rate: float) -> float:
    """beta multiplier = 1 + rho."""
    return 1.0 + adjustment_rate
