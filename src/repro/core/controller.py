"""The FedLess controller with Apodotiko's modifications (Algorithm 1).

Train_Global_Model loop:
  1. ``Select_Clients`` via the active strategy (Algorithm 3 for Apodotiko).
  2. Invoke the selected client functions on the (simulated) FaaS platform;
     save invocation records; mark clients busy.
  3. Clients run Client_Update (real JAX training, cohort-vectorized) and
     land results in the database at their simulated completion times.
  4. The controller polls the database until the strategy's gating condition
     holds — all current-round results or timeout (sync), or
     ``ceil(CR x clientsPerRound)`` un-aggregated results from the current or
     up to five previous rounds (async, Algorithm 1 line 9).
  5. Aggregate with cardinality x staleness weights (Eq. 2), write the new
     global model, evaluate, and start the next round immediately.

Fault tolerance: failed invocations (crash/preemption) simply never produce
results — sync strategies absorb them via the round timeout, async ones are
oblivious; the controller checkpoints {global model, client records, scores,
boosters, round} and can resume from the database (tests/test_controller.py).
Elasticity: clients may join/leave between rounds (add_clients/remove_clients).
"""
from __future__ import annotations

import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_aggregate, weighted_aggregate_rows
from repro.core.client import CohortTrainer
from repro.core.database import ClientRecord, Database, ResultRecord
from repro.core.strategies.base import Strategy, StrategyConfig, build_strategy
from repro.core.update_store import UpdateStore
from repro.faas.cost import CostModel
from repro.faas.events import EventLoop
from repro.faas.hardware import HardwareProfile
from repro.faas.platform import FaaSPlatform
from repro.kernels.ops import RavelSpec

Pytree = Any

UPDATE_STORE_DIRNAME = "update_store"


def resolve_update_plane(mode: str) -> str:
    """'device' (default) | 'blob' (legacy pytree-blob path).
    Resolution: explicit config value > ``REPRO_UPDATE_PLANE`` > 'device'."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_UPDATE_PLANE", "device")
    if mode not in ("device", "blob"):
        raise ValueError(f"unknown update plane {mode!r} "
                         "(expected 'device', 'blob', or 'auto')")
    return mode


@dataclass
class FLConfig:
    """Experiment configuration. Each field maps to a paper quantity
    (symbol / section noted inline) or a simulator knob.

    Paper defaults (IV-A): 200 clients, 100 per round, E=5 local epochs,
    batch 10 (MNIST), Adam 1e-3, CR=0.3, rho=0.2, staleness cap 5."""

    # -- population & schedule -------------------------------------------------
    n_clients: int = 200           # total registered clients (paper IV-A3: 200)
    clients_per_round: int = 100   # |clients| invoked per round ("100/round")
    rounds: int = 50               # max global rounds T
    target_accuracy: Optional[float] = None  # early stop (Alg. 1 line 3)
    # -- Client_Update (Alg. 2) ------------------------------------------------
    local_epochs: int = 5          # E, local epochs per invocation
    batch_size: int = 10           # B, local minibatch size
    optimizer: str = "adam"        # client-side optimizer (paper: Adam/SGD)
    lr: float = 1e-3               # client learning rate eta
    # -- strategy (Alg. 1 / Alg. 3) --------------------------------------------
    strategy: str = "apodotiko"    # repro.core.strategies.STRATEGIES key
    concurrency_ratio: float = 0.3  # CR: aggregate at ceil(CR x clientsPerRound)
    #                                 results (Alg. 1 line 9; Fig. 6 sweeps it)
    adjustment_rate: float = 0.2   # rho: booster step for the CEF score
    #                                 (Alg. 3; score = booster x CEF, §III-A)
    max_staleness: int = 5         # staleness cap: results from at most this
    #                                 many previous rounds aggregate (§III-B)
    round_timeout: float = 300.0   # sync-strategy round deadline, sim-seconds
    # -- FaaS platform simulation (§IV-A) --------------------------------------
    keep_warm: float = 600.0       # provider keep-warm window before
    #                                 scale-to-zero, sim-seconds
    cold_start_s: float = 8.0      # container cold-start penalty, sim-seconds
    base_step_time: float = 0.05   # 1vCPU-seconds per optimizer step
    #                                 (hardware profiles scale this, Fig. 1/3)
    failure_rate: float = 0.0      # P(invocation crash) — fault tolerance
    # -- aggregation (§III-B) --------------------------------------------------
    prox_mu: float = 0.01          # mu, FedProx proximal coefficient
    staleness_fn: str = "eq2"      # "eq2" = 1/sqrt(T - t_i + 1) (Eq. 2,
    #                                 Apodotiko) | "eq1" = t_i/T (FedLesScan)
    update_plane: str = "auto"     # client-update transport: "device" keeps
    #                                 updates as rows of one device-resident
    #                                 [capacity, N] buffer (zero host
    #                                 round-trips per round); "blob" is the
    #                                 legacy host-pytree path; "auto" defers
    #                                 to REPRO_UPDATE_PLANE (default device)
    # -- harness ---------------------------------------------------------------
    eval_every: int = 1            # evaluate global model every k rounds
    seed: int = 0                  # RNG seed: selection, init, platform noise
    max_sim_time: float = 1e8      # simulated wall-clock budget, seconds
    checkpoint_dir: Optional[str] = None  # database checkpoint location
    checkpoint_every: int = 0      # checkpoint every k rounds (0 = off)


@dataclass
class RoundLog:
    round: int
    t_start: float
    t_end: float
    accuracy: float
    n_aggregated: int
    n_stale: int
    mean_loss: float


class Controller:
    def __init__(self, cfg: FLConfig, model, data, fleet: list[HardwareProfile],
                 *, db: Optional[Database] = None, init_params: Optional[Pytree] = None):
        self.cfg = cfg
        self.model = model
        self.data = data        # FederatedDataset (repro.data)
        self.fleet = fleet
        self.loop = EventLoop()
        self.platform = FaaSPlatform(
            keep_warm=cfg.keep_warm, cold_start_s=cfg.cold_start_s,
            seed=cfg.seed, failure_rate=cfg.failure_rate)
        self.cost_model = CostModel()
        scfg = StrategyConfig(
            clients_per_round=cfg.clients_per_round,
            concurrency_ratio=cfg.concurrency_ratio,
            adjustment_rate=cfg.adjustment_rate,
            max_staleness=cfg.max_staleness,
            round_timeout=cfg.round_timeout,
            prox_mu=cfg.prox_mu,
            staleness_fn=cfg.staleness_fn,
            seed=cfg.seed)
        self.strategy: Strategy = build_strategy(cfg.strategy, scfg)
        self.trainer = CohortTrainer(
            model, optimizer=cfg.optimizer, lr=cfg.lr,
            batch_size=cfg.batch_size, prox_mu=self.strategy.prox_mu,
            scaffold=self.strategy.needs_scaffold, seed=cfg.seed)

        self.db = db or Database()
        if db is None:
            for cid in range(cfg.n_clients):
                self.db.register_client(ClientRecord(
                    client_id=cid, hardware=fleet[cid].name,
                    data_cardinality=int(data.n[cid]),
                    batch_size=cfg.batch_size, local_epochs=cfg.local_epochs))
        self.hw = {cid: fleet[cid] for cid in range(len(fleet))}

        rng = jax.random.PRNGKey(cfg.seed)
        if init_params is not None:
            self.params = init_params
        elif self.db.global_models:
            self.params = jax.tree.map(jnp.asarray, self.db.latest_global())
        else:
            self.params = model.init(rng)[0]
        # SCAFFOLD state
        self.c_global = None
        self.c_clients: dict[int, Pytree] = {}
        if self.strategy.needs_scaffold:
            self.c_global = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                         self.params)
        self.history: list[RoundLog] = []
        self._eval_fn = jax.jit(model.accuracy)
        self._eval_scan = None      # (jitted fn, padded arrays) built lazily
        self._completed_this_round: set[int] = set()

        # -- update plane: device-resident flat-buffer client updates ------
        self.update_plane = resolve_update_plane(cfg.update_plane)
        self.spec = RavelSpec(self.params)
        self.store: Optional[UpdateStore] = None
        self.update_host_bytes = 0  # bytes moved host<->device for updates
        if db is not None:
            self._check_plane_compatible(db)
        if self.update_plane == "device":
            self.store = UpdateStore(
                self.spec.n_params,
                capacity=max(cfg.clients_per_round, 1))
            if db is not None and cfg.checkpoint_dir:
                self._rehydrate_store()

    def _check_plane_compatible(self, db: Database) -> None:
        """A checkpoint written under one update plane cannot feed pending
        results to the other: blob records carry update_row=-1 (which would
        silently index the last buffer row) and device records carry no
        blob. Switching planes across a resume is fine once nothing is
        in flight."""
        saved = db.meta.get("update_plane")
        if saved is None or saved == self.update_plane:
            return
        if any(not r.aggregated for r in db.results):
            raise ValueError(
                f"checkpoint was written with update_plane={saved!r} and "
                f"has un-aggregated results; resuming with "
                f"update_plane={self.update_plane!r} would corrupt them — "
                f"set REPRO_UPDATE_PLANE={saved} (or cfg.update_plane) to "
                f"resume, or aggregate before switching planes")

    def _rehydrate_store(self) -> None:
        """Resume path: reload the live un-aggregated update rows saved at
        checkpoint time, at their original ids so ResultRecord handles in
        the restored database stay valid."""
        from repro.checkpoint import restore_update_store
        d = os.path.join(self.cfg.checkpoint_dir, UPDATE_STORE_DIRNAME)
        if not os.path.isdir(d):
            return
        ids, rows, n_params = restore_update_store(d)
        if n_params != self.spec.n_params:
            raise ValueError(
                f"update-store checkpoint has N={n_params} params but the "
                f"model has N={self.spec.n_params}")
        self.store.write_at(ids, rows)

    # ---------------------------------------------------------------- elastic
    def add_clients(self, records: list[ClientRecord],
                    profiles: list[HardwareProfile]) -> None:
        for rec, hw in zip(records, profiles):
            self.db.register_client(rec)
            self.hw[rec.client_id] = hw
            self.fleet.append(hw)

    def remove_clients(self, client_ids: list[int]) -> None:
        for cid in client_ids:
            self.db.clients.pop(cid, None)

    # ------------------------------------------------------------------ round
    def _invoke_round(self, round_: int, selection: list[int]) -> None:
        cfg = self.cfg
        n_i = self.data.n[selection]
        steps = np.ceil(n_i / cfg.batch_size).astype(np.int64) * cfg.local_epochs
        steps = np.maximum(steps, 1)

        # real local training, cohort-vectorized (global model of *this* round)
        cg = self.c_global
        ci = None
        if self.strategy.needs_scaffold:
            zeros = lambda p: jnp.zeros_like(p, jnp.float32)
            ci_list = [self.c_clients.get(cid) or jax.tree.map(zeros, self.params)
                       for cid in selection]
            ci = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ci_list)
        device = self.update_plane == "device"
        out, ci_new, losses = self.trainer.train_cohort(
            self.params, self.data.X[selection], self.data.y[selection],
            n_i, steps, cg, ci,
            update_sink=self.store if device else None)
        if device:
            # trained models never left the device: the jitted cohort fn
            # scattered them into the store's persistent row buffer; only
            # the [K] row handles come back
            row_ids = out
        else:
            out = jax.tree.map(np.asarray, out)  # host copies
            self.update_host_bytes += sum(
                l.nbytes for l in jax.tree.leaves(out))
        if self.strategy.needs_scaffold:
            self._apply_scaffold_updates(selection, ci_new)

        for k, cid in enumerate(selection):
            rec = self.platform.invoke(cid, round_, self.loop.now,
                                       float(steps[k]), self.hw[cid],
                                       cfg.base_step_time)
            self.db.mark_running(cid, round_)
            update_k = (int(row_ids[k]) if device
                        else jax.tree.map(lambda x: x[k], out))
            self.loop.schedule(rec.duration, self._completion_cb(
                cid, round_, rec, update_k, int(n_i[k]), float(losses[k])))

    def _completion_cb(self, cid, round_, rec, update, n_samples, loss):
        device = self.update_plane == "device"

        def cb():
            if rec.failed:
                self.db.mark_failed(cid)
                if device:
                    self.store.free([update])  # recycle the orphaned row
                return
            train_dur = rec.duration  # includes startup/load/upload
            self.db.mark_complete(cid, train_dur)
            result = ResultRecord(client_id=cid, round=round_,
                                  n_samples=n_samples,
                                  train_duration=train_dur,
                                  t_available=self.loop.now)
            if device:
                self.db.put_update_row(result, update)
            else:
                self.db.put_update(result, update)
            self._completed_this_round.add(cid)
        return cb

    def _apply_scaffold_updates(self, selection, ci_new) -> None:
        old = [self.c_clients.get(cid) for cid in selection]
        new_list = [jax.tree.map(lambda x: x[k], ci_new)
                    for k in range(len(selection))]
        # c <- c + sum(c_i' - c_i) / N_total
        n_total = max(len(self.db.clients), 1)
        delta = None
        for cid, n, o in zip(selection, new_list, old):
            if o is None:
                o = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), n)
            d = jax.tree.map(lambda a, b: a - b, n, o)
            delta = d if delta is None else jax.tree.map(jnp.add, delta, d)
            self.c_clients[cid] = n
        if delta is not None:
            self.c_global = jax.tree.map(
                lambda c, d: c + d / n_total, self.c_global, delta)

    def _aggregate(self, round_: int) -> tuple[int, int, float]:
        strat = self.strategy
        pending = [r for r in self.db.pending_results(self.cfg.max_staleness, round_)
                   if strat.usable(r, round_)]
        if not pending:
            return 0, 0, float("nan")
        weights = np.array([strat.result_weight(r, round_) for r in pending],
                           np.float64)
        total = weights.sum()
        if not np.isfinite(total) or total <= 0:
            # e.g. Eq. 1 zeroes round-0 updates at T=1: fall back to
            # cardinality weighting so the aggregation stays well-defined
            weights = np.array([r.n_samples for r in pending], np.float64)
            total = weights.sum() or 1.0
        weights = (weights / total).astype(np.float32)
        out_dtype = jax.tree.leaves(self.params)[0].dtype
        if self.update_plane == "device":
            # row-index fast path: gather rows out of the persistent device
            # buffer, one kernel dispatch, one unravel — no host traffic
            rows = [r.update_row for r in pending]
            assert all(r >= 0 for r in rows), \
                "pending result without a row handle on the device plane"
            self.params = weighted_aggregate_rows(
                self.store.buffer, rows, weights, self.spec,
                out_dtype=out_dtype)
            self.store.free(rows)
        else:
            updates = [jax.tree.map(jnp.asarray, self.db.blobs[r.update_key])
                       for r in pending]
            self.update_host_bytes += sum(
                l.nbytes for u in updates for l in jax.tree.leaves(u))
            self.params = weighted_aggregate(updates, weights,
                                             out_dtype=out_dtype)
        n_stale = sum(1 for r in pending if r.round < round_)
        mean_dur = float(np.mean([r.train_duration for r in pending]))
        self.db.mark_aggregated(pending)
        # prune: results too stale to ever be usable again
        drop = [r for r in self.db.results
                if not r.aggregated and round_ - r.round >= self.cfg.max_staleness]
        if self.update_plane == "device":
            self.store.free([r.update_row for r in drop if r.update_row >= 0])
        self.db.mark_aggregated(drop)
        return len(pending), n_stale, mean_dur

    def _build_eval_scan(self):
        """One jitted masked scan over the padded eval set: a single device
        dispatch and a single scalar host transfer per evaluation, instead
        of a Python loop of per-256-batch jit calls each synchronizing."""
        xs = np.asarray(self.data.eval_x)
        ys = np.asarray(self.data.eval_y)
        n, bs = len(xs), 256
        nb = max(1, math.ceil(n / bs))
        pad = nb * bs - n
        if pad:
            xs = np.concatenate([xs, np.repeat(xs[:1], pad, axis=0)])
            ys = np.concatenate([ys, np.repeat(ys[:1], pad, axis=0)])
        mask = (np.arange(nb * bs) < n).reshape(nb, bs)
        batches = (jnp.asarray(xs.reshape((nb, bs) + xs.shape[1:])),
                   jnp.asarray(ys.reshape((nb, bs) + ys.shape[1:])),
                   jnp.asarray(mask))
        model = self.model

        @jax.jit
        def run(params, X, y, m):
            def body(correct, inp):
                xb, yb, mb = inp
                pred = jnp.argmax(model.predict(params, xb), axis=-1)
                return correct + jnp.sum((pred == yb) & mb), None
            correct, _ = jax.lax.scan(body, jnp.zeros((), jnp.int32),
                                      (X, y, m))
            return correct.astype(jnp.float32) / n

        return run, batches

    def _evaluate(self) -> float:
        if not hasattr(self.model, "predict"):
            # models exposing only ``accuracy`` (e.g. LM adapters with
            # internal target masking) keep the legacy per-batch loop;
            # batches are weighted by size so both paths report the same
            # statistic (exact sample mean) on ragged tails
            xs, ys = self.data.eval_x, self.data.eval_y
            total, bs = 0.0, 256
            for i in range(0, len(xs), bs):
                xb, yb = xs[i:i + bs], ys[i:i + bs]
                total += float(self._eval_fn(
                    self.params, {"x": jnp.asarray(xb),
                                  "y": jnp.asarray(yb)})) * len(xb)
            return total / max(len(xs), 1)
        if self._eval_scan is None:
            self._eval_scan = self._build_eval_scan()
        run, batches = self._eval_scan
        return float(run(self.params, *batches))

    # -------------------------------------------------------------------- run
    def run(self, progress: Optional[Callable[[RoundLog], None]] = None):
        cfg, strat = self.cfg, self.strategy
        round_ = self.db.round
        acc = 0.0
        while round_ < cfg.rounds and self.loop.now < cfg.max_sim_time:
            t0 = self.loop.now
            selection = strat.select(self.db, round_)
            if not selection:
                # every client busy: advance until something completes
                if not self.loop.run_until(
                        lambda: any(c.status == "idle"
                                    for c in self.db.clients.values())):
                    break
                continue
            self._completed_this_round = set()
            self._invoke_round(round_, selection)

            if strat.is_async:
                need = strat.results_needed()
                ok = self.loop.run_until(
                    lambda: len(self.db.pending_results(cfg.max_staleness, round_))
                    >= need, max_time=cfg.max_sim_time)
                if not ok and not self.db.pending_results(cfg.max_staleness, round_):
                    break
            else:
                deadline = t0 + cfg.round_timeout
                self.loop.run_until(
                    lambda: self._completed_this_round >= set(selection),
                    max_time=deadline)
                # guarantee progress: at least one usable result
                self.loop.run_until(
                    lambda: any(strat.usable(r, round_) for r in
                                self.db.pending_results(cfg.max_staleness, round_)),
                    max_time=cfg.max_sim_time)

            n_agg, n_stale, _ = self._aggregate(round_)
            if n_agg == 0:
                round_ += 1
                self.db.round = round_
                continue
            if cfg.eval_every and round_ % cfg.eval_every == 0:
                acc = self._evaluate()
            log = RoundLog(round=round_, t_start=t0, t_end=self.loop.now,
                           accuracy=acc, n_aggregated=n_agg, n_stale=n_stale,
                           mean_loss=0.0)
            self.history.append(log)
            if progress:
                progress(log)
            round_ += 1
            self.db.round = round_
            if cfg.checkpoint_every and round_ % cfg.checkpoint_every == 0:
                self.checkpoint()
            if cfg.target_accuracy and acc >= cfg.target_accuracy:
                break
        return self.metrics()

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        inv = self.platform.invocations
        cost = self.cost_model.total(inv, lambda cid: self.hw[cid])
        counts = self.platform.invocation_counts()
        count_arr = [counts.get(cid, 0) for cid in self.db.clients]
        return {
            "strategy": self.strategy.name,
            "update_plane": self.update_plane,
            "update_host_bytes": int(self.update_host_bytes),
            "rounds": len(self.history),
            "final_accuracy": self.history[-1].accuracy if self.history else 0.0,
            "total_time": self.loop.now,
            "total_cost_usd": cost,
            "cold_start_ratio": self.platform.cold_start_ratio(),
            "n_invocations": len(inv),
            "selection_bias": (max(count_arr) - min(count_arr)) if count_arr else 0,
            "invocation_counts": count_arr,
            "history": [(l.t_end, l.round, l.accuracy) for l in self.history],
        }

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for l in self.history:
            if l.accuracy >= target:
                return l.t_end
        return None

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self) -> None:
        if not self.cfg.checkpoint_dir:
            return
        self.db.meta["update_plane"] = self.update_plane
        self.db.put_global_model(self.db.round,
                                 jax.tree.map(np.asarray, self.params))
        self.db.save(self.cfg.checkpoint_dir)
        if self.update_plane == "device":
            # persist the live un-aggregated rows so the async in-flight
            # state survives a crash bit-exactly (handles stay valid)
            from repro.checkpoint import save_update_store
            ids = [r.update_row for r in self.db.results
                   if not r.aggregated and r.update_row >= 0]
            save_update_store(
                self.store, ids,
                os.path.join(self.cfg.checkpoint_dir, UPDATE_STORE_DIRNAME))

    @classmethod
    def resume(cls, cfg: FLConfig, model, data, fleet) -> "Controller":
        db = Database.load(cfg.checkpoint_dir)
        return cls(cfg, model, data, fleet, db=db)
