"""The legacy poll-loop driver (FedLess controller, Algorithm 1).

Train_Global_Model loop:
  1. ``Select_Clients`` via the active strategy (Algorithm 3 for Apodotiko).
  2. Invoke the selected client functions on the (simulated) FaaS platform;
     save invocation records; mark clients busy.
  3. Clients run Client_Update (real JAX training, cohort-vectorized) and
     land results in the database at their simulated completion times.
  4. The controller polls the database until the strategy's gating condition
     holds — all current-round results or timeout (sync), or
     ``ceil(CR x clientsPerRound)`` un-aggregated results from the current or
     up to five previous rounds (async, Algorithm 1 line 9).
  5. Aggregate with cardinality x staleness weights (Eq. 2), write the new
     global model, evaluate, and start the next round immediately.

Fault tolerance: failed invocations (crash/preemption — the Bernoulli
``failure_rate`` coin or any seeded ``fault_profile`` schedule,
faas/faults.py) simply never produce results — sync strategies absorb them
via the round timeout, async ones are oblivious. This engine is purely
*passive*: the active recovery layer (retry/backoff, timeouts, circuit
breaker, quorum degradation — DESIGN.md §12) is scheduler-only, so
recovery knobs must stay off for cross-engine differential runs. The
controller checkpoints {global model, client records, scores,
boosters, round} and can resume from the database (tests/test_controller.py).
Elasticity: clients may join/leave between rounds (add_clients/remove_clients).

The execution state and round services (invocation, aggregation,
evaluation) live in :class:`repro.core.services.FLRuntime`; this class
only contributes the poll loop. The event-driven replacement —
``repro.core.scheduler.Scheduler`` dispatching typed protocol events to a
reactive policy — is the default engine (DESIGN.md §7); this loop is kept
as the golden-trace equivalence oracle (tests/test_golden_trace.py) and
for ``REPRO_ENGINE=legacy``.
"""
from __future__ import annotations

from typing import Callable, Optional

# Re-exported for backwards compatibility: these lived here before the
# scheduler redesign split the services out (PR 3).
from repro.core.services import (FLConfig, FLRuntime, RoundLog,  # noqa: F401
                                 UPDATE_STORE_DIRNAME, resolve_control_plane,
                                 resolve_engine, resolve_update_plane,
                                 strategy_config)


class Controller(FLRuntime):
    """Poll-based round driver: blocks in ``EventLoop.run_until`` on the
    strategy's gating predicate (see module docstring)."""

    engine_name = "controller"

    # -------------------------------------------------------------------- run
    def run(self, progress: Optional[Callable[[RoundLog], None]] = None):
        cfg, strat = self.cfg, self.strategy
        round_ = self.db.round
        traffic_round = -1
        while round_ < cfg.rounds and self.loop.now < cfg.max_sim_time:
            t0 = self.loop.now
            self._t0 = t0
            if round_ != traffic_round:
                # fresh-round open only — mid-round re-polls must not
                # shift membership, mirroring the scheduler (which applies
                # traffic in _open_round, never on adapter re-selects)
                self._apply_due_traffic()
                traffic_round = round_
                if self.durability is not None:
                    # the poll loop has no RoundStarted event; the marker
                    # gives its journal the same open boundary
                    self.durability.record_marker("round_open", round_)
            selection = strat.select(self.db, round_)
            if not selection:
                # every client busy: advance until something completes —
                # or, when the fleet is empty under open-loop traffic,
                # jump to the next arrival boundary
                if not self.loop.run_until(self.db.any_idle):
                    if not self._traffic_fast_forward():
                        break
                continue
            self.invoke_round(round_, selection)

            if strat.is_async:
                need = strat.results_needed()
                ok = self.loop.run_until(
                    lambda: len(self.db.pending_results(cfg.max_staleness, round_))
                    >= need, max_time=cfg.max_sim_time)
                if not ok and not self.db.pending_results(cfg.max_staleness, round_):
                    break
            else:
                deadline = t0 + cfg.round_timeout
                self.loop.run_until(
                    lambda: self._completed_this_round >= set(selection),
                    max_time=deadline)
                # guarantee progress: at least one usable result
                self.loop.run_until(
                    lambda: any(strat.usable(r, round_) for r in
                                self.db.pending_results(cfg.max_staleness, round_)),
                    max_time=cfg.max_sim_time)

            n_agg, n_stale, _ = self.aggregate_round(round_)
            if n_agg == 0:
                round_ += 1
                self.db.round = round_
                self._durability_round_closed()
                continue
            if cfg.eval_every and round_ % cfg.eval_every == 0:
                self._acc = self.evaluate()
            log = RoundLog(round=round_, t_start=t0, t_end=self.loop.now,
                           accuracy=self._acc, n_aggregated=n_agg,
                           n_stale=n_stale, mean_loss=0.0)
            self.history.append(log)
            if progress:
                progress(log)
            round_ += 1
            self.db.round = round_
            self._durability_round_closed()
            if cfg.checkpoint_every and round_ % cfg.checkpoint_every == 0:
                self.checkpoint()
            if cfg.target_accuracy and self._acc >= cfg.target_accuracy:
                break
        if self.durability is not None:
            self.durability.finish()
        return self.metrics()
