"""Retry/backoff + circuit-breaker recovery layer (DESIGN.md §12).

``RecoveryPolicy`` wraps any ``ReactivePolicy`` and intercepts failure
events before the inner policy sees them:

* ``InvocationTimedOut`` (emitted by ``FLRuntime.timeout_invocation``
  when an invocation outlives ``FLConfig.invocation_timeout``) is
  translated into a plain ``InvocationFailed`` for the inner policy —
  strategies never need to learn the new event type.
* Repeat offenders trip the circuit breaker: once a client's
  consecutive-failure streak (``FleetStore.consec_failures``, healed by
  any landed result) reaches ``quarantine_threshold``, a ``Quarantine``
  action removes it from the selection mask for ``quarantine_rounds``
  rounds via the ``quarantined_until`` column.
* Otherwise, while the per-round ``retry_budget`` lasts, the failure is
  answered with a ``Retry`` action: exponential backoff
  (``retry_base_delay * retry_backoff**(attempt-1)``) with multiplicative
  jitter drawn from the policy's own seeded RNG — deterministic and
  replayable, and isolated from every other RNG stream in the run.

The wrapper is only installed when ``recovery_enabled(cfg)`` — with all
three knobs at their zero defaults the scheduler runs the inner policy
directly and stays bit-identical to the legacy engine.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.protocol import (Action, DatabaseView, Event,
                                 InvocationFailed, InvocationTimedOut,
                                 Quarantine, ReactivePolicy, Retry,
                                 RoundStarted)

# RNG-stream offset so recovery jitter never collides with the selection
# RNG (cfg.seed) or the platform RNG (also cfg.seed, separate Generator)
_JITTER_SALT = 0x5EC0


def recovery_enabled(cfg) -> bool:
    """True when any recovery knob is on (FLConfig or StrategyConfig-like
    object with the three fields)."""
    return bool(getattr(cfg, "invocation_timeout", 0.0) > 0
                or getattr(cfg, "retry_budget", 0) > 0
                or getattr(cfg, "quarantine_threshold", 0) > 0)


class RecoveryPolicy(ReactivePolicy):
    """Failure-handling decorator around an inner reactive policy."""

    def __init__(self, inner: ReactivePolicy, cfg):
        self.inner = inner
        self.cfg = cfg
        self.strategy = getattr(inner, "strategy", None)
        self.name = getattr(inner, "name", "recovery")
        self._rng = np.random.default_rng(cfg.seed + _JITTER_SALT)
        self._attempts: dict[int, int] = {}   # client -> retries this round
        self._budget = cfg.retry_budget

    @property
    def fire_timers_on_drain(self) -> bool:
        return self.inner.fire_timers_on_drain

    # -- durability (coordinated snapshots, DESIGN.md §14) -------------
    def state_dict(self) -> dict:
        return {"rng": self._rng.bit_generator.state,
                "attempts": [[cid, n] for cid, n in self._attempts.items()],
                "budget": self._budget,
                "inner": self.inner.state_dict()}

    def load_state(self, state: dict) -> None:
        self._rng.bit_generator.state = state["rng"]
        self._attempts = {int(c): int(n) for c, n in state["attempts"]}
        self._budget = int(state["budget"])
        self.inner.load_state(state["inner"])

    def on_event(self, ev: Event, view: DatabaseView) -> Sequence[Action]:
        if isinstance(ev, RoundStarted):
            self._attempts.clear()
            self._budget = self.cfg.retry_budget
            return self.inner.on_event(ev, view)
        if isinstance(ev, (InvocationFailed, InvocationTimedOut)):
            pre = self._recover(ev, view)
            if isinstance(ev, InvocationTimedOut):
                ev = InvocationFailed(t=ev.t, round=ev.round,
                                      client_id=ev.client_id)
            return list(pre) + list(self.inner.on_event(ev, view))
        return self.inner.on_event(ev, view)

    def _recover(self, ev, view: DatabaseView) -> list[Action]:
        cfg, cid = self.cfg, ev.client_id
        if (cfg.quarantine_threshold
                and view.db.consecutive_failures(cid)
                >= cfg.quarantine_threshold):
            if view.db.is_quarantined(cid):
                return []           # breaker already open
            return [Quarantine(client_id=cid,
                               until_round=view.round + cfg.quarantine_rounds)]
        if cfg.retry_budget > 0 and self._budget > 0 and ev.round == view.round:
            attempt = self._attempts.get(cid, 0) + 1
            self._attempts[cid] = attempt
            self._budget -= 1
            delay = (cfg.retry_base_delay
                     * cfg.retry_backoff ** (attempt - 1)
                     * (1.0 + cfg.retry_jitter * float(self._rng.random())))
            return [Retry(client_id=cid, delay=delay)]
        return []

    def metrics(self) -> dict:
        m = getattr(self.inner, "metrics", None)
        return m() if m is not None else {}
