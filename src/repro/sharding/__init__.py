from repro.sharding.rules import (  # noqa: F401
    DEFAULT_RULES,
    axis_rules,
    current_mesh,
    logical_spec,
    make_param_sharding,
    param_specs,
    shard_act,
    zero1_extend,
)
