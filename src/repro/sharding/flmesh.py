"""FL mesh plane: resolve and construct the (data, model) device mesh the
FL core shards over (DESIGN.md §15).

The seed shipped a sharding rule engine (``sharding.rules``) and mesh
construction (``launch.mesh``) that nothing in the FL core used; this
module is the bridge. One mesh spec — ``"<data>x<model>"`` — is resolved
through the same flag-oracle pattern as every other plane
(``FLConfig.mesh`` > ``REPRO_MESH`` > ``"1x1"``) and governs three layouts:

  * the ``UpdateStore`` ``[capacity, W]`` row buffer is sharded
    ``P("data", "model")`` — rows split over the ``data`` axis, the row
    width ``W`` split over ``model`` — so ``K*W`` update bytes stop being
    bounded by one device's HBM;
  * the jitted cohort fn's batch dimension is ``shard_map``-ed over
    ``data`` (``core.client``): each device trains ``Kp/data`` lanes
    against a replicated ``DatasetStore``, so per-lane train work and the
    minibatch gathers are shard-local;
  * aggregation becomes a weighted ``psum`` over ``data``
    (``kernels.ops.aggregate_rows_psum``): each shard reduces its local
    ``[C/d, W/m]`` tile and the partials meet over ICI instead of
    converging through one device.

``"1x1"`` (the default) is the bit-exact oracle: :func:`build_fl_mesh`
returns ``None``, no mesh object is constructed, no array is re-placed,
and every pre-existing single-device trace is byte-identical. Meshes with
more than one device are numerically equivalent, not bitwise (batch
splitting and the psum reassociate float reductions); the golden-trace
contract for them is identical selections/timing + allclose params
(tests/test_mesh_plane.py).
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: the update-row buffer layout: [capacity over "data", W over "model"]
ROW_SPEC = P("data", "model")


def parse_mesh(spec: str) -> tuple[int, int]:
    """``"<data>x<model>"`` -> ``(data, model)`` with validation."""
    parts = str(spec).lower().split("x")
    try:
        if len(parts) != 2:
            raise ValueError(spec)
        d, m = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"unknown mesh spec {spec!r} (expected '<data>x<model>', "
            "e.g. '1x1', '2x4', or 'auto')") from None
    if d < 1 or m < 1:
        raise ValueError(f"mesh spec {spec!r} has a non-positive axis")
    return d, m


def resolve_mesh(spec: str) -> str:
    """'1x1' (default: no mesh — the single-device path, bit-exact) |
    '<data>x<model>' (shard the FL core over a (data, model) device mesh).
    Resolution: explicit config value > ``REPRO_MESH`` > '1x1'."""
    if spec in (None, "", "auto"):
        spec = os.environ.get("REPRO_MESH", "1x1")
    parse_mesh(spec)            # validate eagerly: bad specs fail loudly
    return spec


@functools.lru_cache(maxsize=None)
def build_fl_mesh(spec: str) -> Optional[Mesh]:
    """The ("data", "model") mesh for ``spec``, or ``None`` for 1x1.

    The 1x1 oracle path constructs nothing and touches no jax device
    state, so resolution alone can never perturb a single-device trace.
    Cached per spec: every plane sharing a spec shares ONE mesh object,
    which keeps ``id(mesh)``-keyed compile caches stable for the process
    lifetime."""
    d, m = parse_mesh(resolve_mesh(spec))
    if (d, m) == (1, 1):
        return None
    n = d * m
    if jax.device_count() < n:
        raise ValueError(
            f"mesh {spec!r} needs {n} devices but only "
            f"{jax.device_count()} are visible (on CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")
    return jax.make_mesh((d, m), ("data", "model"))


def mesh_axes(mesh: Optional[Mesh]) -> tuple[int, int]:
    """``(data, model)`` axis sizes; ``(1, 1)`` for the no-mesh path."""
    if mesh is None:
        return (1, 1)
    return int(mesh.shape["data"]), int(mesh.shape["model"])


def mesh_token(mesh: Optional[Mesh]) -> tuple:
    """Compile-cache key fragment for a mesh. Empty for the no-mesh path
    so pre-existing cache keys are unchanged; ``id()`` is safe because
    :func:`build_fl_mesh` caches meshes for the process lifetime."""
    if mesh is None:
        return ()
    return ("mesh", mesh_axes(mesh), id(mesh))


def row_sharding(mesh: Mesh) -> NamedSharding:
    """The update-row buffer's NamedSharding (``ROW_SPEC``)."""
    return NamedSharding(mesh, ROW_SPEC)


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement (every device holds the whole array) —
    the ``DatasetStore`` layout, so cohort-shard gathers are local."""
    return NamedSharding(mesh, P())


def shard_put(x, mesh: Optional[Mesh], spec: P):
    """Place ``x`` with ``NamedSharding(mesh, spec)``; identity un-meshed."""
    if mesh is None:
        return x
    return jax.device_put(x, NamedSharding(mesh, spec))


def row_align(mesh: Optional[Mesh], base: int) -> int:
    """Row-width alignment: the kernel block, additionally divisible by
    the ``model`` axis so every device owns an equal column stripe."""
    d, m = mesh_axes(mesh)
    return math.lcm(base, m)


def capacity_align(mesh: Optional[Mesh], base: int) -> int:
    """Capacity alignment: the fp32 sublane, additionally divisible by
    the ``data`` axis so every device owns an equal row stripe."""
    d, m = mesh_axes(mesh)
    return math.lcm(base, d)
