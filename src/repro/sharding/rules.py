"""Logical-axis -> mesh-axis sharding rules.

Model code never names mesh axes. It tags parameters and activations with
*logical* axis names ("batch", "ffn", "heads", "experts", ...). A rules table
maps logical names to mesh axes; specs are derived with divisibility checks so
a rule silently degrades to replication when a dim does not divide (e.g. GQA
kv=8 over a 16-way model axis) instead of relying on uneven-shard padding.

The active (mesh, rules) pair is installed with the ``axis_rules`` context
manager; ``shard_act`` is a no-op outside of it, so the same model code runs
un-meshed on one CPU device and fully sharded under the production mesh.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rule = Union[None, str, tuple]

# Logical axis -> preferred mesh axes (tuples try to use all listed axes).
DEFAULT_RULES: dict[str, Rule] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,          # decode KV caches: overridden per shape
    "d_model": None,
    "head_dim": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_cap": None,
    "vocab": "model",
    "state": None,           # SSM state dim
    "ssm_heads": "model",
    "layers": None,
    "lora": None,
    "patches": None,
    "frames": None,
    "stats": None,
}

# Shape-kind specific overrides (see launch/dryrun.py):
#  - long-context decode (global_batch=1): shard the cache sequence instead of batch
#  - decode: shard KV cache sequence over the model axis (kv heads rarely divide)
DECODE_RULES = dict(DEFAULT_RULES, kv_seq="model")
LONGCTX_RULES = dict(DEFAULT_RULES, batch=None, kv_seq=("data", "model"), seq=("data", "model"))

_ctx = threading.local()


@contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, dict(rules or DEFAULT_RULES)) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh() -> Optional[Mesh]:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def _mesh_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve_rule(rule: Rule, mesh: Mesh, dim: int, used: set[str]):
    """Return a tuple of mesh axes for one dim, or None (replicate)."""
    if rule is None:
        return None
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    axes = [a for a in axes if a in mesh.shape and a not in used]
    # Greedy: drop leading axes until the product divides the dim.
    while axes and (dim % _mesh_axis_size(mesh, axes) != 0):
        axes = axes[1:]
    if not axes:
        return None
    used.update(axes)
    return tuple(axes) if len(axes) > 1 else axes[0]


def logical_spec(names: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: dict) -> P:
    """Build a PartitionSpec for one array from logical dim names."""
    used: set[str] = set()
    parts = []
    for name, dim in zip(names, shape):
        rule = rules.get(name) if name else None
        parts.append(_resolve_rule(rule, mesh, dim, used))
    # trim trailing Nones (cosmetic)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard_act(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Apply a with_sharding_constraint from logical names; no-op un-meshed."""
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    spec = logical_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------------
# Parameter / optimizer-state shardings
# ----------------------------------------------------------------------------


def param_specs(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
                rules: Optional[dict] = None) -> Any:
    """axes_tree: tuples-of-names tree (see models.common.ParamFactory).
    shapes_tree: matching tree of arrays or ShapeDtypeStructs."""
    rules = dict(rules or DEFAULT_RULES)
    is_leaf = lambda x: isinstance(x, tuple)
    return jax.tree.map(
        lambda names, arr: logical_spec(names, arr.shape, mesh, rules),
        axes_tree,
        shapes_tree,
        is_leaf=is_leaf,
    )


def make_param_sharding(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
                        rules: Optional[dict] = None) -> Any:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(axes_tree, shapes_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def zero1_extend(spec: P, shape: Sequence[int], mesh: Mesh,
                 axis: str = "data") -> P:
    """ZeRO-1: additionally shard an optimizer-state array over the data axis.

    Picks the largest dim not already sharded whose size divides the data-axis
    extent; replicates (returns spec unchanged) if none qualifies.
    """
    if axis not in mesh.shape:
        return spec
    n = mesh.shape[axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for p in parts if p for a in ((p,) if isinstance(p, str) else p)}
    if axis in used:
        return spec
    best, best_dim = -1, 0
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is None and dim % n == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best < 0:
        return spec
    parts[best] = axis
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)
