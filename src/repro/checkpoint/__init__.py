from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    restore_pytree,
    restore_update_store,
    save_pytree,
    save_update_store,
)
