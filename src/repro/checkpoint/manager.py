"""Fault-tolerant checkpointing: atomic pytree save/restore with retention.

Design (orbax is unavailable offline, so this is self-contained):
  - every leaf is written to one ``.npz`` under a temp dir, then the dir is
    atomically renamed to ``step_<N>`` — a crash mid-save never corrupts the
    latest checkpoint;
  - tree structure is stored as JSON (path-joined keys), dtypes preserved
    (bf16 saved via uint16 view);
  - retention keeps the newest ``keep`` checkpoints;
  - on a multi-host fleet each host saves its local shards under
    ``host_<i>`` (addressable-shard save) and restore re-assembles against
    the current mesh — enabling restarts with a different device count
    (elastic resume). On this single-host container that path degenerates to
    one shard dir, exercised by tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zipfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    out = []

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, path + (str(i),))
        else:
            out.append(("/".join(path), node))

    rec(tree, ())
    return out


def _treedef_json(tree):
    if isinstance(tree, dict):
        return {"__kind": "dict", "items": {k: _treedef_json(v) for k, v in tree.items()}}
    if isinstance(tree, list):
        return {"__kind": "list", "items": [_treedef_json(v) for v in tree]}
    if isinstance(tree, tuple):
        return {"__kind": "tuple", "items": [_treedef_json(v) for v in tree]}
    return {"__kind": "leaf"}


def _rebuild(tdef, leaves_by_path, path=()):
    kind = tdef["__kind"]
    if kind == "dict":
        return {k: _rebuild(v, leaves_by_path, path + (str(k),))
                for k, v in tdef["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, leaves_by_path, path + (str(i),))
               for i, v in enumerate(tdef["items"])]
        return seq if kind == "list" else tuple(seq)
    return leaves_by_path["/".join(path)]


def save_pytree(tree: Pytree, directory: str) -> None:
    os.makedirs(os.path.dirname(directory) or ".", exist_ok=True)
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    arrays, dtypes = {}, {}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        dtypes[str(i)] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[str(i)] = arr
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    meta = {
        "treedef": _treedef_json(tree),
        "paths": [p for p, _ in flat],
        "dtypes": dtypes,
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    # rename-aside swap: the old checkpoint moves aside *before* the new
    # one replaces it, so one valid checkpoint exists at every instant —
    # a kill between rmtree and replace can no longer lose both
    old = directory + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(directory):
        os.replace(directory, old)
    os.replace(tmp, directory)
    if os.path.exists(old):
        shutil.rmtree(old)


def restore_pytree(directory: str) -> Pytree:
    if not os.path.exists(os.path.join(directory, "meta.json")):
        # a crash between the two renames above leaves only the aside
        # copy; fall back to it rather than failing the restore
        old = directory + ".old"
        if os.path.exists(os.path.join(old, "meta.json")):
            directory = old
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(directory, "leaves.npz"))
    leaves_by_path = {}
    for i, path in enumerate(meta["paths"]):
        arr = data[str(i)]
        dt = meta["dtypes"][str(i)]
        if dt == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves_by_path[path] = arr
    return _rebuild(meta["treedef"], leaves_by_path)


# ------------------------------------------------- update-plane checkpoints
def save_update_store(store, row_ids, directory: str) -> None:
    """Serialize the live (un-aggregated) rows of a device-resident
    ``UpdateStore`` so an async run can resume with its in-flight updates
    intact. Only the referenced rows are written — one host transfer per
    checkpoint, not per round — together with their ids so record handles
    (``ResultRecord.update_row``) stay valid after rehydration."""
    ids = np.asarray(row_ids, np.int64)
    rows = (np.asarray(store.gather(ids)) if ids.size
            else np.zeros((0, store.row_width), np.float32))
    save_pytree({"ids": ids, "rows": rows,
                 "n_params": np.int64(store.n_params)}, directory)


def restore_update_store(directory: str) -> tuple[np.ndarray, np.ndarray, int]:
    """Returns (row_ids, rows [L, N], n_params) saved by
    ``save_update_store``; the caller writes them back into a fresh store at
    the original ids (``UpdateStore.write_at``) for a bit-exact resume."""
    tree = restore_pytree(directory)
    return (np.asarray(tree["ids"], np.int64),
            np.asarray(tree["rows"], np.float32),
            int(tree["n_params"]))


class CheckpointManager:
    """step-indexed checkpoints with retention + atomic latest resolution."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree: Pytree, extra: Optional[dict] = None) -> str:
        d = self._step_dir(step)
        save_pytree(tree, d)
        if extra is not None:
            with open(os.path.join(d, "extra.json"), "w") as f:
                json.dump(extra, f)
        self._gc()
        return d

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None) -> tuple[Pytree, dict, int]:
        """Restore ``step`` (explicit steps still raise on corruption) or,
        with ``step=None``, the newest *loadable* retained step: corrupt
        or partial checkpoints — missing meta.json, truncated leaves.npz —
        are skipped in favor of the next older one."""
        if step is not None:
            return self._restore_step(step)
        candidates = self.steps()
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        last_err: Optional[Exception] = None
        for s in reversed(candidates):
            try:
                return self._restore_step(s)
            except (OSError, ValueError, KeyError, json.JSONDecodeError,
                    zipfile.BadZipFile) as e:
                last_err = e
        raise FileNotFoundError(
            f"no loadable checkpoint under {self.root} "
            f"({len(candidates)} corrupt): {last_err}")

    def _restore_step(self, step: int) -> tuple[Pytree, dict, int]:
        d = self._step_dir(step)
        tree = restore_pytree(d)
        extra = {}
        ep = os.path.join(d, "extra.json")
        if os.path.exists(ep):
            with open(ep) as f:
                extra = json.load(f)
        return tree, extra, step

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
