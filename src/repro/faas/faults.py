"""Typed fault injection for the FaaS platform (DESIGN.md §12).

The paper targets real serverless platforms where invocations crash, get
preempted, OOM, return late, or disappear into provider outages — failure
modes a single Bernoulli ``failure_rate`` cannot express (and whose
failures the legacy path silently absorbed). This module is the
composable replacement:

* :class:`FaultSchedule` — a declarative, *seeded* description of what
  goes wrong: phase-attributed crashes (startup / train / upload),
  transient slowdowns, result loss with zombie or late landings,
  per-hardware-tier OOM, and correlated outage windows that take whole
  client groups down. Schedules are plain frozen data, so chaos runs are
  replayable bit-for-bit and comparable across engines.
* :class:`FaultModel` — the runtime evaluator the platform consults once
  per invocation. It owns its **own** RNG stream (never the platform's
  duration/failure stream) and draws a *fixed* number of values per
  invocation regardless of what triggers, so enabling a schedule never
  perturbs the legacy draw order and an empty schedule draws nothing —
  the bit-identity anchor for the pre-existing golden traces.

Phase attribution (``InvocationRecord.failed_phase``):

    ``startup``  crash during container boot (duration = partial startup)
    ``train``    crash mid-training (the legacy Bernoulli failure's phase)
    ``upload``   crash while uploading the update
    ``oom``      memory kill during training on a low-memory tier
    ``outage``   correlated platform outage at invocation time
    ``loss``     zombie: the invocation runs to completion but the result
                 never lands (the container stays warm — it did not crash)
    ``timeout``  killed by the scheduler's per-invocation timeout
                 (stamped by ``FLRuntime.timeout_invocation``, not here)

Compact spec strings (comma-separated, parsed by :func:`parse_faults`)::

    crash:<phase>:<rate>               crash:train:0.2
    slow:<factor>:<rate>               slow:2.5:0.2
    loss:<rate>[:<late_rate>[:<late_s>]]   loss:0.15:0.2:45
    oom:<mem_gib>:<rate>               oom:2.0:0.3   (tiers with mem <= 2)
    outage:<start>-<end>[:mod<m>=<r>]  outage:150-400:mod3=0

``resolve_fault_profile`` follows the repo's flag convention (explicit
config > ``REPRO_FAULTS`` env var > off) and accepts either a named
profile from :data:`FAULT_PROFILES` or a raw spec string.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.faas.hardware import HardwareProfile

#: crash phases a fault spec may name (observability adds oom/outage/loss)
PHASES = ("startup", "train", "upload")


@dataclass(frozen=True)
class FaultOutcome:
    """What the fault model decided for one invocation."""

    failed_phase: str = ""   # "" = no crash ("loss" = zombie, see module doc)
    slowdown: float = 1.0    # multiplier on train time (transient stragglers)
    lost: bool = False       # ran to completion, result never lands
    late_by: float = 0.0     # extra seconds before the result lands
    frac: float = 1.0        # fraction of the failed phase elapsed at crash


@dataclass(frozen=True)
class CrashFault:
    """Bernoulli crash attributed to one lifecycle phase."""

    phase: str               # "startup" | "train" | "upload"
    rate: float

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"unknown crash phase {self.phase!r}")


@dataclass(frozen=True)
class SlowdownFault:
    """Transient slowdown: train time multiplied by ``factor``."""

    rate: float
    factor: float = 2.0


@dataclass(frozen=True)
class ResultLossFault:
    """Result loss: the invocation runs its full duration but the update
    never lands (a zombie — the container survives). With probability
    ``late_rate`` the result instead lands ``late_s`` seconds late."""

    rate: float
    late_rate: float = 0.0
    late_s: float = 60.0


@dataclass(frozen=True)
class OOMFault:
    """Memory kill during training, hitting only hardware tiers with
    ``mem_gib <= mem_below_gib`` (keyed on :class:`HardwareProfile`)."""

    rate: float
    mem_below_gib: float = 2.0


@dataclass(frozen=True)
class OutageWindow:
    """Correlated outage: every invocation *launched* inside
    ``[start, end)`` by an affected client fails at startup. Affected
    clients are ``client_id % group_mod == group_rem`` (the default
    ``mod 1 == 0`` takes the whole fleet down), or the explicit
    ``clients`` tuple when non-empty. Purely deterministic: no RNG."""

    start: float
    end: float
    group_mod: int = 1
    group_rem: int = 0
    clients: Tuple[int, ...] = ()

    def hits(self, client_id: int, t: float) -> bool:
        if not (self.start <= t < self.end):
            return False
        if self.clients:
            return client_id in self.clients
        return client_id % self.group_mod == self.group_rem


@dataclass(frozen=True)
class FaultSchedule:
    """Seeded, declarative fault plan — the replayability unit."""

    seed: int = 0
    faults: Tuple = ()

    @property
    def active(self) -> bool:
        return bool(self.faults)

    @property
    def stochastic(self) -> Tuple:
        """The RNG-consuming specs, in declaration order (the fixed
        per-invocation draw order of :class:`FaultModel`)."""
        return tuple(f for f in self.faults
                     if not isinstance(f, OutageWindow))

    @property
    def outages(self) -> Tuple[OutageWindow, ...]:
        return tuple(f for f in self.faults if isinstance(f, OutageWindow))


def parse_faults(spec: str) -> Tuple:
    """Parse a compact comma-separated fault spec string (module doc)."""
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        kind = fields[0]
        if kind == "crash":
            out.append(CrashFault(phase=fields[1], rate=float(fields[2])))
        elif kind == "slow":
            out.append(SlowdownFault(factor=float(fields[1]),
                                     rate=float(fields[2])))
        elif kind == "loss":
            out.append(ResultLossFault(
                rate=float(fields[1]),
                late_rate=float(fields[2]) if len(fields) > 2 else 0.0,
                late_s=float(fields[3]) if len(fields) > 3 else 60.0))
        elif kind == "oom":
            out.append(OOMFault(mem_below_gib=float(fields[1]),
                                rate=float(fields[2])))
        elif kind == "outage":
            lo, hi = fields[1].split("-")
            mod, rem = 1, 0
            clients: Tuple[int, ...] = ()
            if len(fields) > 2:
                g = fields[2]
                if g.startswith("mod"):
                    m, r = g[3:].split("=")
                    mod, rem = int(m), int(r)
                else:
                    clients = tuple(int(c) for c in g.split("+"))
            out.append(OutageWindow(start=float(lo), end=float(hi),
                                    group_mod=mod, group_rem=rem,
                                    clients=clients))
        else:
            raise ValueError(f"unknown fault spec {part!r}")
    return tuple(out)


#: named chaos profiles (the sweep's ``fault_profile`` axis values)
FAULT_PROFILES: dict[str, str] = {
    # crashes dominate, spread across all three phases
    "crash-heavy": "crash:train:0.25,crash:startup:0.05,crash:upload:0.05",
    # two correlated outages, each taking a third of the fleet down
    "outage-window": "outage:150-400:mod3=0,outage:700-1000:mod3=1",
    # results vanish or land late; transient stragglers
    "lossy-network": "loss:0.15:0.2:45,slow:2.5:0.2",
}


def resolve_fault_profile(mode: str) -> str:
    """Explicit config value > ``REPRO_FAULTS`` > off. Returns the
    normalized profile string: "" means no fault injection (the default —
    the platform draws nothing extra and every pre-existing trace is
    bit-identical); otherwise a :data:`FAULT_PROFILES` name or a raw
    :func:`parse_faults` spec string."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_FAULTS", "")
    if mode in ("none", "off"):
        mode = ""
    if mode and mode not in FAULT_PROFILES:
        parse_faults(mode)      # raise early on a malformed spec
    return mode


def build_fault_schedule(profile: str, seed: int = 0
                         ) -> Optional[FaultSchedule]:
    """Profile name (or raw spec) -> schedule; None when faults are off."""
    if not profile:
        return None
    spec = FAULT_PROFILES.get(profile, profile)
    return FaultSchedule(seed=seed, faults=parse_faults(spec))


def build_fault_model(profile: str, seed: int = 0) -> Optional["FaultModel"]:
    sched = build_fault_schedule(profile, seed)
    return FaultModel(sched) if sched is not None else None


class FaultModel:
    """Runtime fault evaluator (one call per invocation).

    Determinism contract: per ``evaluate`` call the model draws exactly
    ``len(schedule.stochastic) + 1`` values from its private RNG — one
    Bernoulli per stochastic spec in declaration order plus one crash
    fraction — whether or not anything triggers. Outage windows are pure
    predicates (no draws). Identical schedules therefore produce identical
    outcome sequences on every engine/plane, which is what the chaos
    harness's cross-engine bit-identity rests on."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self._rng = np.random.default_rng(schedule.seed)
        self._stoch = schedule.stochastic
        self._outages = schedule.outages

    @property
    def active(self) -> bool:
        return self.schedule.active

    @property
    def stochastic(self) -> Tuple:
        return self._stoch

    def outage_windows(self) -> Tuple[OutageWindow, ...]:
        return self._outages

    def evaluate(self, client_id: int, now: float,
                 hw: HardwareProfile) -> FaultOutcome:
        # fixed unconditional draw block (see class docstring)
        draws = [float(self._rng.random()) for _ in self._stoch]
        frac = float(self._rng.uniform(0.1, 0.9))

        # deterministic correlated outages take precedence over everything
        for w in self._outages:
            if w.hits(client_id, now):
                return FaultOutcome(failed_phase="outage", frac=frac)

        crash: str = ""
        slowdown = 1.0
        lost = False
        late_by = 0.0
        for spec, u in zip(self._stoch, draws):
            triggered = u < spec.rate
            if not triggered:
                continue
            if isinstance(spec, OOMFault):
                if hw.mem_gib <= spec.mem_below_gib:
                    crash = _worse(crash, "oom")
            elif isinstance(spec, CrashFault):
                crash = _worse(crash, spec.phase)
            elif isinstance(spec, ResultLossFault):
                if u < spec.rate * spec.late_rate:
                    late_by = max(late_by, spec.late_s)
                else:
                    lost = True
            elif isinstance(spec, SlowdownFault):
                slowdown = max(slowdown, spec.factor)
        if crash:
            return FaultOutcome(failed_phase=crash, slowdown=slowdown,
                                frac=frac)
        if lost:
            return FaultOutcome(failed_phase="loss", slowdown=slowdown,
                                lost=True, frac=frac)
        return FaultOutcome(slowdown=slowdown, late_by=late_by, frac=frac)


#: crash precedence, earliest-killing first (an OOM or startup crash
#: preempts anything later in the lifecycle)
_SEVERITY = {"oom": 0, "startup": 1, "train": 2, "upload": 3}


def _worse(a: str, b: str) -> str:
    if not a:
        return b
    return a if _SEVERITY[a] <= _SEVERITY[b] else b
