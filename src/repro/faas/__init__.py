from repro.faas.events import EventLoop  # noqa: F401
from repro.faas.hardware import HARDWARE_PROFILES, HardwareProfile  # noqa: F401
from repro.faas.platform import FaaSPlatform, InvocationRecord  # noqa: F401
from repro.faas.cost import CostModel  # noqa: F401
