"""GCP cost model (paper IV-A5, refs [46][47]).

CPU clients are billed like Cloud Functions: vCPU-seconds + GiB-seconds over
the whole invocation duration. GPU clients are billed like Compute Engine
GPUs: the P100 hourly rate scaled by the vGPU fraction (0.4) actually
allocated, plus the host vCPU/memory.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.faas.hardware import HardwareProfile
from repro.faas.platform import InvocationRecord

# Cloud Functions 2nd gen (Tier 1 pricing, 2023)
PRICE_PER_VCPU_SECOND = 0.0000240   # USD
PRICE_PER_GIB_SECOND = 0.0000025    # USD
# Compute Engine accelerator pricing (us-central1, 2023): Nvidia P100
PRICE_P100_PER_HOUR = 1.46          # USD


@dataclass
class CostModel:
    def invocation_cost(self, rec: InvocationRecord, hw: HardwareProfile) -> float:
        d = rec.duration
        cpu_cost = d * hw.vcpus * PRICE_PER_VCPU_SECOND
        mem_cost = d * hw.mem_gib * PRICE_PER_GIB_SECOND
        gpu_cost = 0.0
        if hw.is_gpu:
            gpu_cost = (d / 3600.0) * PRICE_P100_PER_HOUR * hw.gpu_fraction
        return cpu_cost + mem_cost + gpu_cost

    def total(self, invocations, hw_of) -> float:
        return float(sum(self.invocation_cost(r, hw_of(r.client_id))
                         for r in invocations))
