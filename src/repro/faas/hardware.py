"""Client hardware resource profiles (paper IV-A3).

The paper's heterogeneous fleet: 130 clients at 1vCPU/2048MiB, 50 clients at
2vCPU/4096MiB, 20 clients on Nvidia P100s at 0.4 vGPU each. Training speed is
modeled as optimizer steps/second relative to the 1vCPU baseline, with
lognormal per-invocation noise (FaaS performance variability).

Speed ratios are calibrated from the paper's Fig. 3 (Shakespeare non-IID
client durations): GPU clients train roughly an order of magnitude faster
than 1vCPU clients; 2vCPU roughly 1.9x (sub-linear scaling).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    speed: float          # steps/sec multiplier vs 1vCPU baseline
    vcpus: float
    mem_gib: float
    is_gpu: bool = False
    gpu_fraction: float = 0.0
    variability: float = 0.10  # lognormal sigma of per-invocation speed noise


HARDWARE_PROFILES: dict[str, HardwareProfile] = {
    "cpu1": HardwareProfile("cpu1", speed=1.0, vcpus=1.0, mem_gib=2.0),
    "cpu2": HardwareProfile("cpu2", speed=1.9, vcpus=2.0, mem_gib=4.0),
    "gpu": HardwareProfile("gpu", speed=12.0, vcpus=2.0, mem_gib=4.0,
                           is_gpu=True, gpu_fraction=0.4, variability=0.05),
}


def paper_fleet(n_clients: int = 200, rng: np.random.Generator | None = None,
                mix: tuple[tuple[str, float], ...] = (("cpu1", 0.65),
                                                      ("cpu2", 0.25),
                                                      ("gpu", 0.10))):
    """The paper's 130/50/20 split (fractions of n_clients), shuffled."""
    rng = rng or np.random.default_rng(0)
    profiles: list[HardwareProfile] = []
    for name, frac in mix:
        profiles += [HARDWARE_PROFILES[name]] * round(n_clients * frac)
    while len(profiles) < n_clients:
        profiles.append(HARDWARE_PROFILES[mix[0][0]])
    profiles = profiles[:n_clients]
    rng.shuffle(profiles)
    return profiles


def homogeneous_fleet(n_clients: int, profile: str = "cpu2"):
    return [HARDWARE_PROFILES[profile]] * n_clients
