"""Discrete-event engine for the serverless simulation.

Simulated wall-clock is fully decoupled from real compute: client training
runs eagerly in JAX while durations come from the hardware model, so the
event loop reproduces the paper's timing behaviour (cold starts, stragglers,
round timeouts) deterministically and fast.

Cancellation is tombstone-based (``cancel`` just flags the entry), but the
heap compacts itself lazily: once more than half the entries are dead —
the steady state under heavy hedging/cancellation (DESIGN.md §7) — the
live entries are re-heapified in one O(n) pass, so the heap stays bounded
by the live event count and ``pending`` is O(1).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    callback: Callable[[], None] = field(compare=False)
    cancelled: bool = field(compare=False, default=False)
    popped: bool = field(compare=False, default=False)


class EventLoop:
    def __init__(self):
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self._n_cancelled = 0       # tombstones currently in the heap
        self.now: float = 0.0

    def schedule(self, delay: float, callback: Callable[[], None]) -> _Event:
        assert delay >= 0, delay
        ev = _Event(self.now + delay, next(self._seq), callback)
        heapq.heappush(self._heap, ev)
        return ev

    def cancel(self, ev: _Event) -> None:
        if ev.cancelled or ev.popped:
            return  # idempotent; popped events are no longer in the heap
        ev.cancelled = True
        self._n_cancelled += 1
        if self._n_cancelled * 2 > len(self._heap):
            self._compact()

    def _compact(self) -> None:
        """Drop every tombstone in one pass (O(live) re-heapify)."""
        self._heap = [e for e in self._heap if not e.cancelled]
        heapq.heapify(self._heap)
        self._n_cancelled = 0

    def _pop_live(self) -> Optional[_Event]:
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            return ev
        return None

    def peek(self) -> Optional[float]:
        """Time of the next live event, without running it (None if empty)."""
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
            self._n_cancelled -= 1
        return self._heap[0].time if self._heap else None

    def step(self) -> bool:
        """Pop and run exactly one live event (the scheduler's pump).
        Returns False if the heap is empty."""
        ev = self._pop_live()
        if ev is None:
            return False
        ev.popped = True
        self.now = ev.time
        ev.callback()
        return True

    def run_until(self, predicate: Callable[[], bool],
                  max_time: float = float("inf")) -> bool:
        """Pop events until predicate() holds. Returns False if the loop
        drained or max_time passed first."""
        while not predicate():
            ev = self._pop_live()
            if ev is None:
                return False
            if ev.time > max_time:
                heapq.heappush(self._heap, ev)  # put back; caller hit deadline
                self.now = max_time
                return False
            ev.popped = True
            self.now = ev.time
            ev.callback()
        return True

    def run_all(self, max_time: float = float("inf")) -> None:
        self.run_until(lambda: False, max_time)

    @property
    def pending(self) -> int:
        return len(self._heap) - self._n_cancelled
