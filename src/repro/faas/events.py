"""Discrete-event engine for the serverless simulation.

Simulated wall-clock is fully decoupled from real compute: client training
runs eagerly in JAX while durations come from the hardware model, so the
event loop reproduces the paper's timing behaviour (cold starts, stragglers,
round timeouts) deterministically and fast.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    callback: Callable[[], None] = field(compare=False)
    cancelled: bool = field(compare=False, default=False)


class EventLoop:
    def __init__(self):
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.now: float = 0.0

    def schedule(self, delay: float, callback: Callable[[], None]) -> _Event:
        assert delay >= 0, delay
        ev = _Event(self.now + delay, next(self._seq), callback)
        heapq.heappush(self._heap, ev)
        return ev

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def run_until(self, predicate: Callable[[], bool],
                  max_time: float = float("inf")) -> bool:
        """Pop events until predicate() holds. Returns False if the loop
        drained or max_time passed first."""
        while not predicate():
            if not self._heap:
                return False
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if ev.time > max_time:
                heapq.heappush(self._heap, ev)  # put back; caller hit deadline
                self.now = max_time
                return False
            self.now = ev.time
            ev.callback()
        return True

    def run_all(self, max_time: float = float("inf")) -> None:
        self.run_until(lambda: False, max_time)

    @property
    def pending(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)
