"""FaaS platform simulation: function instances, cold starts, scale-to-zero.

Models the serverless client lifecycle the paper measures (IV-A5):
  - a client function instance is *warm* if it served an invocation within
    ``keep_warm`` seconds (paper: instances scale down after 10 idle minutes);
  - a cold invocation pays ``cold_start_s`` (container pull + runtime boot +
    model/dataset load is accounted separately by the duration model);
  - the platform records every invocation for the cold-start-ratio metric.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.faas.hardware import HardwareProfile

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.faas.faults import FaultModel


@dataclass
class InvocationRecord:
    client_id: int
    round: int
    t_invoked: float
    cold: bool
    duration: float = 0.0
    t_completed: float = 0.0
    failed: bool = False
    cancelled: bool = False    # killed mid-flight (hedge loser / explicit
    #                            cancel); duration is truncated at the kill
    failed_phase: str = ""     # fault attribution: startup | train | upload
    #                            | oom | outage | loss | timeout ("" = ok)
    lost: bool = False         # zombie: ran to completion, result never
    #                            landed (container survives — stays warm)
    timed_out: bool = False    # killed by the scheduler's per-invocation
    #                            timeout (recovery layer)


@dataclass
class _Instance:
    warm_until: float = -1.0
    busy_until: float = -1.0


class FaaSPlatform:
    def __init__(self, *, keep_warm: float = 600.0, cold_start_s: float = 8.0,
                 model_load_s: float = 2.0, upload_s: float = 1.0,
                 seed: int = 0, failure_rate: float = 0.0,
                 faults: Optional["FaultModel"] = None):
        self.keep_warm = keep_warm
        self.cold_start_s = cold_start_s
        self.model_load_s = model_load_s
        self.upload_s = upload_s
        self.failure_rate = failure_rate
        self.faults = faults
        self._instances: dict[int, _Instance] = {}
        self._rng = np.random.default_rng(seed)
        self.invocations: list[InvocationRecord] = []

    # ------------------------------------------------------------------ API
    def invoke(self, client_id: int, round_: int, now: float,
               train_steps: float, hw: HardwareProfile,
               base_step_time: float) -> InvocationRecord:
        """Returns the invocation record with ``duration`` filled in
        (invocation latency + load + train + upload)."""
        inst = self._instances.setdefault(client_id, _Instance())
        cold = now > inst.warm_until
        startup = self.cold_start_s * self._rng.uniform(0.8, 1.3) if cold else 0.15
        speed = hw.speed * float(np.exp(self._rng.normal(0.0, hw.variability)))
        train_time = train_steps * base_step_time / speed
        failed = bool(self._rng.random() < self.failure_rate)
        duration = startup + self.model_load_s + train_time + self.upload_s
        if failed:
            # fail partway through (crash / preemption)
            duration = startup + self.model_load_s + train_time * self._rng.uniform(0.1, 0.9)
        phase = "train" if failed else ""
        lost = False
        # fault injection rides on TOP of the legacy draws above (which are
        # consumed verbatim, keeping pre-existing traces bit-identical);
        # the FaultModel owns a separate RNG stream and draws a fixed
        # number of values per invocation — nothing when faults are off
        if self.faults is not None and self.faults.active and not failed:
            out = self.faults.evaluate(client_id, now, hw)
            if out.slowdown != 1.0:
                train_time *= out.slowdown
                duration = (startup + self.model_load_s + train_time
                            + self.upload_s)
            if out.failed_phase:
                failed = True
                phase = out.failed_phase
                if phase in ("startup", "outage"):
                    duration = startup * out.frac
                elif phase in ("train", "oom"):
                    duration = (startup + self.model_load_s
                                + train_time * out.frac)
                elif phase == "upload":
                    duration = (startup + self.model_load_s + train_time
                                + self.upload_s * out.frac)
                elif phase == "loss":
                    # zombie: full duration, the result just never lands
                    lost = True
            elif out.late_by:
                duration += out.late_by
        rec = InvocationRecord(client_id, round_, now, cold,
                               duration=duration, t_completed=now + duration,
                               failed=failed, failed_phase=phase, lost=lost)
        inst.busy_until = rec.t_completed
        if failed and not lost:
            # a crashed container is gone — the platform reclaims it, so
            # the next invocation pays a cold start (a keep-warm window
            # here undercounted cold starts); zombies survive their loss
            inst.warm_until = rec.t_completed
        else:
            inst.warm_until = rec.t_completed + self.keep_warm
        self.invocations.append(rec)
        return rec

    def cancel(self, rec: InvocationRecord, now: float,
               live_until: Optional[float] = None) -> None:
        """Kill an in-flight invocation at sim-time ``now``: the record is
        billed only for its elapsed fraction, and the instance's busy /
        keep-warm clocks stop at the cancellation — or at ``live_until``,
        the completion time of a sibling invocation (a hedge race winner)
        still running on the instance."""
        if rec.t_completed <= now:
            return  # already finished; nothing to roll back
        rec.duration = max(0.0, now - rec.t_invoked)
        rec.t_completed = now
        rec.cancelled = True
        inst = self._instances.get(rec.client_id)
        if inst is not None:
            horizon = max(now, live_until if live_until is not None else now)
            inst.busy_until = min(inst.busy_until, horizon)
            inst.warm_until = min(inst.warm_until, horizon + self.keep_warm)

    def scale_down(self, client_ids) -> None:
        """Reclaim the function instances of departed clients. Without
        this, a client that leaves and later re-joins under the same id
        would inherit the dead instance's keep-warm horizon and dodge its
        cold start — undercounting the cold-start-rate SLO (traffic
        plane, DESIGN.md §13)."""
        for cid in client_ids:
            self._instances.pop(int(cid), None)

    # ---------------------------------------------------------- durability
    def state_dict(self) -> dict:
        """JSON-serializable platform state for coordinated snapshots
        (repro.durability): instance clocks in insertion order, the
        legacy-noise PCG64 position, the fault model's RNG, and the full
        invocation log (records round-trip through ``asdict``)."""
        from dataclasses import asdict
        s = {
            "instances": [[cid, inst.warm_until, inst.busy_until]
                          for cid, inst in self._instances.items()],
            "rng": self._rng.bit_generator.state,
            "invocations": [asdict(r) for r in self.invocations],
        }
        if self.faults is not None:
            s["faults_rng"] = self.faults._rng.bit_generator.state
        return s

    def load_state(self, s: dict) -> None:
        self._instances = {int(c): _Instance(w, b)
                           for c, w, b in s["instances"]}
        self._rng.bit_generator.state = s["rng"]
        self.invocations = [InvocationRecord(**r) for r in s["invocations"]]
        if self.faults is not None and "faults_rng" in s:
            self.faults._rng.bit_generator.state = s["faults_rng"]

    # -------------------------------------------------------------- metrics
    def cold_start_ratio(self) -> float:
        if not self.invocations:
            return 0.0
        return sum(r.cold for r in self.invocations) / len(self.invocations)

    def invocation_counts(self) -> dict[int, int]:
        counts: dict[int, int] = {}
        for r in self.invocations:
            counts[r.client_id] = counts.get(r.client_id, 0) + 1
        return counts
