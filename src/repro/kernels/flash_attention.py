"""Pallas TPU kernel: causal flash attention (forward).

TPU adaptation (not a CUDA port): the online-softmax accumulation is kept in
fp32 VREGs; tiles are MXU-shaped (q block 128 x head_dim, kv block 128);
per-(batch*head) K/V panels are VMEM-resident (HBM->VMEM once per panel) and
the q grid walks over them — the HBM->VMEM->MXU hierarchy replaces the
SRAM/warp structure of the GPU algorithm. For causal attention the kv loop
is bounded by the query block index, halving work (the XLA fallback
materializes the full S x T score matrix; this kernel never does).

Scope: forward pass, used on the serving path (prefill); training uses the
XLA attention (see DESIGN.md — kernels stay off the CPU dry-run path since
Mosaic requires a real TPU; correctness is validated in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -2.0**30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, block_k, causal):
    # q_ref [BQ, D]; k_ref/v_ref [T, D] (VMEM-resident panel); o_ref [BQ, D]
    bq = q_ref.shape[0]
    T = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale
    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    nkb = T // block_k
    if causal:
        # only kv blocks whose start <= last query position
        nkb = jnp.minimum(nkb, (qi + 1) * bq // block_k + (bq % block_k != 0))

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [BQ, BK]
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot(p.astype(v.dtype), v)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, q_ref.shape[1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "block_q",
                                              "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale=None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q [B,H,S,D], k/v [B,H,T,D] -> [B,H,S,D]. S % block_q == T % block_k == 0."""
    B, H, S, D = q.shape
    T = k.shape[2]
    if sm_scale is None:
        sm_scale = D ** -0.5
    assert S % block_q == 0 and T % block_k == 0, (S, T)
    qr = q.reshape(B * H, S, D)
    kr = k.reshape(B * H, T, D)
    vr = v.reshape(B * H, T, D)
    kernel = functools.partial(_fa_kernel, sm_scale=sm_scale,
                               block_k=block_k, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, T, D), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((None, T, D), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, D)
