"""Pallas TPU kernel: fused Adam update.

The unfused optimizer step reads p/m/v/g and writes p/m/v as six separate
HBM-bound elementwise ops; fusing them into one kernel moves each tensor
exactly once (4 reads + 3 writes per element vs ~12 accesses unfused). The
bias-correction scalars are precomputed on the host side of the trace and
passed via scalar prefetch-free closure (static per step under jit).

Tiling: [8, 1024] fp32 tiles (sublane x lane aligned), 1-D grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, c_ref,
                 po_ref, mo_ref, vo_ref, *, lr, b1, b2, eps):
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    bc1 = c_ref[0, 0]   # 1 / (1 - b1^t)
    bc2 = c_ref[0, 1]   # 1 / (1 - b2^t)
    upd = (m * bc1) / (jnp.sqrt(v * bc2) + eps)
    po_ref[...] = (p_ref[...].astype(jnp.float32) - lr * upd).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit,
                   static_argnames=("lr", "b1", "b2", "eps", "interpret"))
def fused_adam(p: jax.Array, m: jax.Array, v: jax.Array, g: jax.Array,
               t: jax.Array, *, lr: float, b1: float = 0.9, b2: float = 0.999,
               eps: float = 1e-8, interpret: bool = True):
    """Flat arrays [N], N % BLOCK == 0; t: scalar int32 step (1-based).
    Returns (p', m', v')."""
    N = p.shape[0]
    assert N % BLOCK == 0, N
    rows = N // 1024
    shape2 = (rows, 1024)
    tf = t.astype(jnp.float32)
    consts = jnp.stack([1.0 / (1.0 - b1 ** tf), 1.0 / (1.0 - b2 ** tf)])
    consts = consts.reshape(1, 2)
    kernel = functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps)
    grid = (rows // 8,)
    tile = pl.BlockSpec((8, 1024), lambda i: (i, 0))
    po, mo, vo = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile,
                  pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[tile, tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct(shape2, p.dtype),
            jax.ShapeDtypeStruct(shape2, jnp.float32),
            jax.ShapeDtypeStruct(shape2, jnp.float32),
        ],
        interpret=interpret,
    )(p.reshape(shape2), m.reshape(shape2), v.reshape(shape2),
      g.reshape(shape2), consts)
    return po.reshape(N), mo.reshape(N), vo.reshape(N)
