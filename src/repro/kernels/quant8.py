"""Pallas TPU kernels: block-scaled int8 (de)quantization for gradient /
client-update compression.

Serverless FL ships every client update over the WAN (and the TPU mapping
ships it over ICI during the weighted psum); 4x compression with per-256
block scales keeps aggregation quality while quartering collective bytes
(used by the beyond-paper hillclimb in EXPERIMENTS.md §Perf). Layout: values
reshaped [N/256, 256] so each scale block is one aligned VMEM row; tiles of
8 rows (8x256) match the fp32 sublane x lane register shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256     # elements per scale
ROWS = 8         # scale-blocks per kernel tile


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # [ROWS, QBLOCK]
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)                   # [ROWS, QBLOCK]
    x_ref[...] = (q * s_ref[...]).astype(x_ref.dtype)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_q8(x: jax.Array, *, interpret: bool = True):
    """x [N] -> (int8 [N], scales [ceil(N/QBLOCK)]).

    N need not be block-aligned: the input is zero-padded up to the
    ROWS*QBLOCK kernel tile internally and the outputs trimmed back.
    Zero padding cannot perturb a block's max-abs scale, so values in a
    partial tail block quantize exactly as they would in an aligned
    buffer (round-trip test: tests/test_kernels.py).
    """
    N = x.shape[0]
    tile = ROWS * QBLOCK
    Np = _ceil_div(N, tile) * tile
    if Np != N:
        x = jnp.pad(x, (0, Np - N))
    nb = Np // QBLOCK
    x2 = x.reshape(nb, QBLOCK)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q.reshape(Np)[:N], s.reshape(nb)[:_ceil_div(N, QBLOCK)]


@functools.partial(jax.jit, static_argnames=("interpret", "dtype"))
def dequantize_q8(q: jax.Array, scales: jax.Array, *,
                  dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    """Inverse of :func:`quantize_q8`; accepts the same arbitrary N
    (zero/one padding of q/scales is trimmed after the kernel)."""
    N = q.shape[0]
    tile = ROWS * QBLOCK
    Np = _ceil_div(N, tile) * tile
    nb = Np // QBLOCK
    if Np != N:
        q = jnp.pad(q, (0, Np - N))
    if scales.shape[0] != nb:
        scales = jnp.pad(scales, (0, nb - scales.shape[0]),
                         constant_values=1.0)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, QBLOCK), dtype),
        interpret=interpret,
    )(q.reshape(nb, QBLOCK), scales.reshape(nb, 1))
    return out.reshape(Np)[:N]
