"""Pallas TPU kernels: block-scaled int8 (de)quantization for gradient /
client-update compression.

Serverless FL ships every client update over the WAN (and the TPU mapping
ships it over ICI during the weighted psum); 4x compression with per-256
block scales keeps aggregation quality while quartering collective bytes
(used by the beyond-paper hillclimb in EXPERIMENTS.md §Perf). Layout: values
reshaped [N/256, 256] so each scale block is one aligned VMEM row; tiles of
8 rows (8x256) match the fp32 sublane x lane register shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256     # elements per scale
ROWS = 8         # scale-blocks per kernel tile


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # [ROWS, QBLOCK]
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)                   # [ROWS, QBLOCK]
    x_ref[...] = (q * s_ref[...]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_q8(x: jax.Array, *, interpret: bool = True):
    """x [N] with N % (ROWS*QBLOCK) == 0 -> (int8 [N], scales [N/QBLOCK])."""
    N = x.shape[0]
    assert N % (ROWS * QBLOCK) == 0, N
    nb = N // QBLOCK
    x2 = x.reshape(nb, QBLOCK)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q.reshape(N), s.reshape(nb)


@functools.partial(jax.jit, static_argnames=("interpret", "dtype"))
def dequantize_q8(q: jax.Array, scales: jax.Array, *,
                  dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    N = q.shape[0]
    nb = N // QBLOCK
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, QBLOCK), dtype),
        interpret=interpret,
    )(q.reshape(nb, QBLOCK), scales.reshape(nb, 1))
    return out.reshape(N)
