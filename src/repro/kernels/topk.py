"""Pallas TPU kernel: blockwise masked top-k over a score vector.

The columnar control plane's fleet-scale cohort selection reduces to
"top-k of an ``[M]`` score vector under an eligibility mask" (the mask is
applied upstream as ``-inf`` scores — DESIGN.md §10). ``lax.top_k`` is the
XLA fast path; this kernel is the TPU variant that keeps the whole sweep
in one pass over VMEM-resident tiles:

grid over ``[G, B]`` score blocks; each program runs k rounds of
(max, first-argmax, mask-out) over its VMEM tile — k is tiny (a cohort,
<= a few hundred) against B — and writes its local top-k (values + GLOBAL
indices) to a ``[G, k]`` candidate table. The caller then reduces the
``G*k`` candidates with one small ``lax.top_k``. Ties break toward the
lowest index at both levels (first-argmax in-block, block-major candidate
order across blocks), matching ``lax.top_k``'s tie order, so the two paths
agree exactly on distinct-score inputs and on tie *order* as well.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_TOPK = 1024   # scores per grid program (lane-aligned: 8 x 128)


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int, block: int):
    x = x_ref[...].astype(jnp.float32)                       # [1, B]
    base = pl.program_id(0) * block
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)    # 2D iota (TPU)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(j, carry):
        xv, vals, idx = carry
        m = jnp.max(xv)
        # first index attaining the max (ties -> lowest, lax.top_k order)
        a = jnp.min(jnp.where(xv == m, col, block))
        vals = jnp.where(kcol == j, m, vals)
        idx = jnp.where(kcol == j, base + a, idx)
        xv = jnp.where(col == a, -jnp.inf, xv)               # extract
        return xv, vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0, k, body,
        (x, jnp.full((1, k), -jnp.inf, jnp.float32),
         jnp.zeros((1, k), jnp.int32)))
    vals_ref[...] = vals
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk(scores: jax.Array, k: int, *, block: int = BLOCK_TOPK,
               interpret: bool = True):
    """Per-block top-k candidates of ``scores [M]`` (M % block == 0):
    returns ``(vals [G, k], global_idx [G, k])`` with G = M // block."""
    M = scores.shape[0]
    assert M % block == 0 and k <= block, (M, block, k)
    G = M // block
    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, block=block),
        grid=(G,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((G, k), jnp.float32),
                   jax.ShapeDtypeStruct((G, k), jnp.int32)],
        interpret=interpret,
    )(scores.reshape(G, block).astype(jnp.float32))
    return vals, idx


def chosen_mask(idx: jax.Array, valid: jax.Array, n: int) -> jax.Array:
    """Scatter a top-k result back to an ``[n]`` bool membership mask
    (invalid slots — ``-inf`` scores that padded the k — stay False).
    Traceable; shared by the selection kernel wrapper (``kernels.ops``)
    and the fused-round megastep's in-scan booster update."""
    return jnp.zeros((n,), bool).at[idx].set(valid)
