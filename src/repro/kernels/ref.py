"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def staleness_agg(updates: jax.Array, weights: jax.Array) -> jax.Array:
    """updates [K, N], weights [K] -> weighted sum [N] (fp32 accumulate)."""
    return jnp.einsum("kn,k->n", updates.astype(jnp.float32),
                      weights.astype(jnp.float32)).astype(updates.dtype)


def quantize_q8(x: jax.Array, block: int = 256):
    """x [N] (N % block == 0) -> (int8 values [N], fp32 scales [N/block])."""
    xb = x.astype(jnp.float32).reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_q8(q: jax.Array, scale: jax.Array, block: int = 256) -> jax.Array:
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scale[:, None]).reshape(-1)


def fused_adam(p, m, v, g, *, lr, b1=0.9, b2=0.999, eps=1e-8, t=1):
    """Single fused Adam step on flat arrays (fp32 math)."""
    g = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g
    v_new = b2 * v + (1 - b2) * g * g
    mhat = m_new / (1 - b1 ** t)
    vhat = v_new / (1 - b2 ** t)
    p_new = p.astype(jnp.float32) - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p_new.astype(p.dtype), m_new, v_new


def flash_attention(q, k, v, *, causal: bool = True, sm_scale=None):
    """q [B,H,S,D], k/v [B,H,T,D] -> [B,H,S,D]. Naive softmax oracle."""
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        S, T = s.shape[-2], s.shape[-1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask, s, -2.0**30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)
