"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend — the
kernels target TPU (Mosaic); on this CPU container they execute through the
Pallas interpreter, validated against ``repro.kernels.ref`` oracles.

Higher-level conveniences:
  - ``RavelSpec``: the flattening contract (leaf order, shapes, dtypes,
    offsets) shared by every pytree<->flat-buffer boundary: the aggregation
    kernel path, the device-resident update plane, and checkpointing of
    live update rows;
  - ``aggregate_pytree``: staleness-weighted aggregation over a list of
    parameter pytrees (ravel -> kernel -> unravel), the drop-in kernel path
    for ``repro.core.aggregation``;
  - ``aggregate_rows``: index-gather entry point over a persistent [C, N]
    row buffer (the update-plane hot path — no ravel, no stack);
  - ``masked_topk``: top-k of a score vector (the control plane's cohort
    selection) — XLA ``lax.top_k`` fast path, blockwise Pallas kernel on
    TPU (``REPRO_TOPK_PATH=pallas|xla|auto`` forcing);
  - ``compress_update`` / ``decompress_update``: int8 client-update
    compression with error feedback.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.fused_adam import fused_adam  # noqa: F401
from repro.kernels.quant8 import QBLOCK, ROWS, dequantize_q8, quantize_q8  # noqa: F401
from repro.kernels.staleness_agg import BLOCK_N, staleness_agg  # noqa: F401
from repro.kernels.topk import BLOCK_TOPK, block_topk, chosen_mask  # noqa: F401

Pytree = Any


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


class RavelSpec:
    """Stable pytree <-> flat fp32 buffer contract.

    Built once from a template pytree; thereafter any structurally identical
    tree ravels into an ``[N]`` vector (or ``[K, N]`` rows for trees with a
    leading stacked axis) in canonical ``jax.tree.leaves`` order, and any
    ``[N]`` vector unravels back. All methods are jit-traceable; the spec
    itself is static (shapes/dtypes/offsets captured at build time)."""

    def __init__(self, template: Pytree):
        leaves = jax.tree.leaves(template)
        self.treedef = jax.tree.structure(template)
        self.shapes = tuple(tuple(l.shape) for l in leaves)
        self.dtypes = tuple(np.dtype(l.dtype) for l in leaves)
        self.sizes = tuple(int(np.prod(s)) if s else 1 for s in self.shapes)
        self.n_params = int(sum(self.sizes))

    def ravel(self, tree: Pytree) -> jax.Array:
        """tree (template structure) -> flat [N] fp32."""
        leaves = jax.tree.leaves(tree)
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])

    def ravel_stacked(self, tree: Pytree) -> jax.Array:
        """tree with [K, ...]-stacked leaves -> [K, N] fp32 rows."""
        leaves = jax.tree.leaves(tree)
        K = leaves[0].shape[0]
        return jnp.concatenate(
            [l.reshape(K, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unravel(self, flat: jax.Array, restore_dtype: bool = True) -> Pytree:
        out, off = [], 0
        for shape, dtype, n in zip(self.shapes, self.dtypes, self.sizes):
            x = flat[off:off + n].reshape(shape)
            out.append(x.astype(dtype) if restore_dtype else x)
            off += n
        return jax.tree.unflatten(self.treedef, out)


SUBLANE = 8  # fp32 TPU sublane; aggregate_pytree pads K to a multiple


# ----------------------------------------------------- row-buffer entry point
@functools.partial(jax.jit, static_argnames=("interpret",))
def _scatter_w_agg(buffer: jax.Array, idx: jax.Array, w: jax.Array,
                   interpret: bool) -> jax.Array:
    """Scatter the K weights to per-row weights over the FULL buffer and
    reduce with the kernel — no row gather, no materialized [K, N] copy.
    Free rows carry weight 0, an exact no-op for finite stale values; the
    NaN/Inf case (0 * inf = nan) is handled by the caller's finiteness
    guard, which falls back to ``aggregate_rows_gather``."""
    C, N = buffer.shape
    full_w = jnp.zeros((C,), jnp.float32).at[idx].add(w)
    pad_c = (-C) % SUBLANE
    pad_n = (-N) % BLOCK_N
    if pad_c or pad_n:   # non-conforming caller buffer: pad (copies)
        buffer = jnp.pad(buffer, ((0, pad_c), (0, pad_n)))
        full_w = jnp.pad(full_w, (0, pad_c))
    return staleness_agg(buffer, full_w, interpret=interpret)[:N]


@jax.jit
def _scatter_w_matvec(buffer: jax.Array, idx: jax.Array,
                      w: jax.Array) -> jax.Array:
    """XLA oracle/fallback for ``aggregate_rows`` (same scattered weights,
    one matvec over the buffer)."""
    full_w = jnp.zeros((buffer.shape[0],), jnp.float32).at[idx].add(w)
    return full_w @ buffer.astype(jnp.float32)


def _pad_rows(row_idx, weights) -> tuple[np.ndarray, np.ndarray]:
    """Pad (idx, weights) to the sublane multiple with zero-weight repeats of
    row 0 (exact no-ops under scatter-add) so round-to-round K jitter reuses
    compiled shapes."""
    idx = np.asarray(row_idx, np.int32)
    w = np.asarray(weights, np.float32)
    pad_k = (-len(idx)) % SUBLANE
    if pad_k:
        idx = np.concatenate([idx, np.repeat(idx[:1], pad_k)])
        w = np.concatenate([w, np.zeros(pad_k, np.float32)])
    return idx, w


def aggregate_rows(buffer: jax.Array, row_idx, weights,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Kernel aggregation straight off a persistent row buffer:
    ``sum_k weights[k] * buffer[row_idx[k], :]`` -> flat [W] fp32.

    The update-plane hot path: the K weights scatter-add into a [capacity]
    per-row weight vector and ``staleness_agg`` streams the whole buffer —
    no per-leaf ravel, no row gather, no host round-trip. ``UpdateStore``
    geometry (capacity % 8 == 0, width % 1024 == 0) makes this pad-free.
    Unreferenced rows ride along with weight 0 — exact for finite values;
    callers must guard the NaN/Inf case (0 * inf = nan) and recompute via
    ``aggregate_rows_gather``, as ``weighted_aggregate_rows`` does."""
    interpret = default_interpret() if interpret is None else interpret
    idx, w = _pad_rows(row_idx, weights)
    return _scatter_w_agg(buffer, jnp.asarray(idx), jnp.asarray(w), interpret)


def aggregate_rows_xla(buffer: jax.Array, row_idx, weights) -> jax.Array:
    """XLA fallback with identical semantics to ``aggregate_rows``."""
    idx, w = _pad_rows(row_idx, weights)
    return _scatter_w_matvec(buffer, jnp.asarray(idx), jnp.asarray(w))


@jax.jit
def _gather_weighted_sum(buffer: jax.Array, idx: jax.Array,
                         w: jax.Array) -> jax.Array:
    return jnp.einsum("k,kn->n", w.astype(jnp.float32),
                      buffer[idx].astype(jnp.float32))


def aggregate_rows_gather(buffer: jax.Array, row_idx, weights) -> jax.Array:
    """Exact-rows fallback: reduces ONLY the referenced rows (device
    gather + einsum, fused). Slower than the full-buffer sweep but immune
    to non-finite garbage in freed rows — the aggregation layer recomputes
    through this when its finiteness guard trips."""
    idx, w = _pad_rows(row_idx, weights)
    return _gather_weighted_sum(buffer, jnp.asarray(idx), jnp.asarray(w))


# ------------------------------------------------- sharded-mesh aggregation
# keyed by id(mesh); safe because repro.sharding.flmesh caches one Mesh
# object per spec for the process lifetime
_PSUM_AGG_CACHE: dict[int, Any] = {}


def _psum_agg(mesh):
    """Per-mesh jitted weighted psum over a [capacity, W] row buffer
    sharded P("data", "model"): each shard reduces its local
    [C/d, W/m] tile against its slice of the scattered per-row weight
    vector, then the d partial sums meet in one ``lax.psum`` over the
    ``data`` axis — aggregation bytes move over ICI instead of
    converging through a single device. Output: [W] sharded over
    ``model``."""
    fn = _PSUM_AGG_CACHE.get(id(mesh))
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def _local(buf, full_w):
        part = full_w.astype(jnp.float32) @ buf.astype(jnp.float32)
        return jax.lax.psum(part, "data")

    sharded = shard_map(
        _local, mesh=mesh,
        in_specs=(P("data", "model"), P("data")),
        out_specs=P("model"), check_rep=False)

    @jax.jit
    def fn(buffer, idx, w):
        full_w = jnp.zeros((buffer.shape[0],), jnp.float32).at[idx].add(w)
        return sharded(buffer, full_w)

    _PSUM_AGG_CACHE[id(mesh)] = fn
    return fn


def aggregate_rows_psum(buffer: jax.Array, row_idx, weights,
                        mesh) -> jax.Array:
    """``aggregate_rows`` semantics over a mesh-sharded buffer via a
    weighted ``lax.psum`` (see ``_psum_agg``). Same weight-0 stale-row
    contract; callers guard NaN/Inf via ``aggregate_rows_gather``."""
    idx, w = _pad_rows(row_idx, weights)
    return _psum_agg(mesh)(buffer, jnp.asarray(idx), jnp.asarray(w))


def aggregate_rows_traced(buffer: jax.Array, row_idx: jax.Array,
                          weights: jax.Array, *, sparse: bool,
                          use_pallas: bool, interpret: bool,
                          mesh=None) -> jax.Array:
    """Fully traceable twin of the ``aggregate_rows*`` dispatch for use
    INSIDE a jit (the fused-round megastep's scan body): ``row_idx`` /
    ``weights`` may be tracers, the dispatch predicates are static
    (pre-resolved by ``core.aggregation.rows_dispatch``), and the
    aggregation layer's host-sync finiteness guard becomes a ``lax.cond``
    whose true branch is the identity — bitwise equal to the stepwise
    path whenever the data is finite, and the same exact-rows recompute
    when it is not. Runs the same inner jitted kernels (jit-in-jit
    inlines); single-device branches see identically padded operands."""
    idx = jnp.asarray(row_idx, jnp.int32)
    w = jnp.asarray(weights, jnp.float32)
    # the mesh route mirrors weighted_aggregate_rows: with a mesh the psum
    # path is unconditional (the sparse heuristic and pallas/xla dispatch
    # only arbitrate single-device execution). It takes the UNPADDED
    # (idx, w): the scatter-add needs no sublane shape, the megastep
    # regime is statically shaped anyway — and, decisively, the
    # concatenate-of-repeated-slice pad pattern below is miscompiled by
    # the 0.4.x SPMD partitioner whenever a shard_map coexists in the
    # program: the partitioner books the padded vector as a partial sum
    # over the "model" axis and inserts a spurious all-reduce that
    # scales idx and w by the model-axis size (tests/test_mesh_plane.py
    # guards the end-to-end fused/stepwise contract this broke).
    if mesh is not None:
        flat = _psum_agg(mesh)(buffer, idx, w)
        return jax.lax.cond(
            jnp.all(jnp.isfinite(flat)),
            lambda f, b, i, ww: f,
            lambda f, b, i, ww: _gather_weighted_sum(b, i, ww),
            flat, buffer, idx, w)
    pad_k = (-idx.shape[0]) % SUBLANE
    if pad_k:       # zero-weight repeats of row 0, as _pad_rows does
        idx = jnp.concatenate([idx, jnp.repeat(idx[:1], pad_k)])
        w = jnp.concatenate([w, jnp.zeros((pad_k,), jnp.float32)])
    if sparse:
        return _gather_weighted_sum(buffer, idx, w)
    else:
        flat = (_scatter_w_agg(buffer, idx, w, interpret) if use_pallas
                else _scatter_w_matvec(buffer, idx, w))
    return jax.lax.cond(
        jnp.all(jnp.isfinite(flat)),
        lambda f, b, i, ww: f,
        lambda f, b, i, ww: _gather_weighted_sum(b, i, ww),
        flat, buffer, idx, w)


# --------------------------------------------------------- top-k selection
def resolve_topk_path(path: Optional[str] = None) -> str:
    """'xla' (lax.top_k — the fast path everywhere off-TPU) | 'pallas'
    (blockwise kernel) | 'auto' (pallas on a real TPU backend, xla
    otherwise). Resolution: explicit arg > ``REPRO_TOPK_PATH`` > 'auto'."""
    if path in (None, "", "auto"):
        path = os.environ.get("REPRO_TOPK_PATH", "auto")
    if path == "auto":
        return "pallas" if on_tpu() else "xla"
    if path not in ("pallas", "xla"):
        raise ValueError(f"unknown topk path {path!r} "
                         "(expected 'pallas', 'xla', or 'auto')")
    return path


def masked_topk(scores: jax.Array, k: int, *,
                path: Optional[str] = None,
                interpret: Optional[bool] = None,
                block: int = BLOCK_TOPK) -> tuple[jax.Array, jax.Array]:
    """Top-k of ``scores [M]`` -> ``(vals [k], idx [k])``, descending;
    masked entries are ``-inf`` scores (the caller filters them by value).
    Traceable (usable inside jit). The Pallas path computes per-block
    candidates (``kernels/topk.py``) and reduces them with one small
    ``lax.top_k``; both paths break ties toward the lowest index."""
    M = scores.shape[0]
    assert k <= M, (k, M)
    path = resolve_topk_path(path)
    if path == "xla" or M <= block or k > block:
        return jax.lax.top_k(scores.astype(jnp.float32), k)
    interpret = default_interpret() if interpret is None else interpret
    pad = (-M) % block
    if pad:
        scores = jnp.pad(scores.astype(jnp.float32), (0, pad),
                         constant_values=-jnp.inf)
    vals, idx = block_topk(scores, k, block=block, interpret=interpret)
    cand_v, cand_i = vals.reshape(-1), idx.reshape(-1)
    top_v, pos = jax.lax.top_k(cand_v, k)
    return top_v, cand_i[pos]


def scored_topk(num: jax.Array, den: jax.Array, booster: jax.Array,
                eligible: jax.Array, ever: jax.Array, beta,
                k: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The full Algorithm-3 top-k selection step as one traceable
    composition: CEF score (``booster * num/den``), bootstrap (+inf for
    never-invoked), eligibility masking (-inf), ``masked_topk``, and the
    in-kernel booster update (selected -> 1, idle-unselected -> * beta).
    Returns ``(idx [k], valid [k], new_booster [M])``.

    This is THE selection op: ``FleetStore.select_topk`` jits it per
    round and the fused-round megastep (``core.megastep``) inlines it in
    its ``lax.scan`` body — one definition, so both paths are bitwise the
    same program. ``k`` must be static under jit."""
    score = booster * (num / jnp.maximum(den, 1e-12))
    score = jnp.where(ever, score, jnp.inf)       # bootstrap: uninvoked
    score = jnp.where(eligible, score, -jnp.inf)  # mask busy/removed
    vals, idx = masked_topk(score, k)
    valid = vals > -jnp.inf
    chosen = chosen_mask(idx, valid, score.shape[0])
    boost = jnp.where(chosen, 1.0,
                      jnp.where(eligible, booster * beta, booster))
    return idx, valid, boost


def aggregate_pytree(updates: Sequence[Pytree], weights,
                     interpret: Optional[bool] = None, *,
                     restore_dtype: bool = True) -> Pytree:
    """Kernel-path aggregation over K parameter pytrees: ravel ->
    [K, N] buffer -> staleness_agg -> unravel. The default-dispatch
    target of ``core.aggregation.weighted_aggregate``.

    K pads to the fp32 sublane multiple with zero-weight rows (exact
    no-ops) so round-to-round K jitter reuses compiled shapes; N pads to
    the kernel block. ``restore_dtype=False`` keeps fp32 leaves
    (``weighted_aggregate``'s contract)."""
    interpret = default_interpret() if interpret is None else interpret
    spec = RavelSpec(updates[0])
    stacked = jnp.stack([spec.ravel(u) for u in updates], 0)
    w = jnp.asarray(weights, jnp.float32)
    K, N = stacked.shape
    pad_k = (-K) % SUBLANE
    pad_n = (-N) % BLOCK_N
    if pad_k or pad_n:
        stacked = jnp.pad(stacked, ((0, pad_k), (0, pad_n)))
        w = jnp.pad(w, (0, pad_k))
    agg = staleness_agg(stacked, w, interpret=interpret)
    return spec.unravel(agg[:N], restore_dtype=restore_dtype)


def compress_update(update: Pytree, error_feedback: Optional[Pytree] = None,
                    interpret: Optional[bool] = None):
    """int8-compress a client update with residual error feedback.

    Returns ((q, scales, meta), new_error_feedback)."""
    interpret = default_interpret() if interpret is None else interpret
    spec = RavelSpec(update)
    flat = spec.ravel(update)
    if error_feedback is not None:
        flat = flat + error_feedback
    N = spec.n_params
    pad = (-N) % (ROWS * QBLOCK)
    flat_p = jnp.pad(flat, (0, pad)) if pad else flat
    q, s = quantize_q8(flat_p, interpret=interpret)
    deq = dequantize_q8(q, s, interpret=interpret)[:N]
    err = flat - deq
    return (q, s, spec), err


def decompress_update(q, s, meta: "RavelSpec",
                      interpret: Optional[bool] = None) -> Pytree:
    interpret = default_interpret() if interpret is None else interpret
    flat = dequantize_q8(q, s, interpret=interpret)[:meta.n_params]
    return meta.unravel(flat)
