"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend — the
kernels target TPU (Mosaic); on this CPU container they execute through the
Pallas interpreter, validated against ``repro.kernels.ref`` oracles.

Higher-level conveniences:
  - ``aggregate_pytree``: staleness-weighted aggregation over a list of
    parameter pytrees (ravel -> kernel -> unravel), the drop-in kernel path
    for ``repro.core.aggregation``;
  - ``compress_update`` / ``decompress_update``: int8 client-update
    compression with error feedback.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.fused_adam import fused_adam  # noqa: F401
from repro.kernels.quant8 import QBLOCK, ROWS, dequantize_q8, quantize_q8  # noqa: F401
from repro.kernels.staleness_agg import staleness_agg  # noqa: F401

Pytree = Any


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


def _ravel(tree: Pytree):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, leaves


def _unravel(flat: jax.Array, like_leaves, treedef) -> Pytree:
    out, off = [], 0
    for l in like_leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def aggregate_pytree(updates: Sequence[Pytree], weights,
                     interpret: Optional[bool] = None) -> Pytree:
    """Kernel-path equivalent of core.aggregation.weighted_aggregate."""
    interpret = default_interpret() if interpret is None else interpret
    treedef = jax.tree.structure(updates[0])
    flats = []
    leaves0 = None
    for u in updates:
        f, leaves = _ravel(u)
        leaves0 = leaves0 or leaves
        flats.append(f)
    stacked = jnp.stack(flats, 0)
    N = stacked.shape[1]
    pad = (-N) % 1024
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    agg = staleness_agg(stacked, jnp.asarray(weights), interpret=interpret)
    return _unravel(agg[:N], leaves0, treedef)


def compress_update(update: Pytree, error_feedback: Optional[Pytree] = None,
                    interpret: Optional[bool] = None):
    """int8-compress a client update with residual error feedback.

    Returns ((q, scales, meta), new_error_feedback)."""
    interpret = default_interpret() if interpret is None else interpret
    treedef = jax.tree.structure(update)
    flat, leaves = _ravel(update)
    if error_feedback is not None:
        flat = flat + error_feedback
    N = flat.shape[0]
    pad = (-N) % (ROWS * QBLOCK)
    flat_p = jnp.pad(flat, (0, pad)) if pad else flat
    q, s = quantize_q8(flat_p, interpret=interpret)
    deq = dequantize_q8(q, s, interpret=interpret)[:N]
    err = flat - deq
    meta = (treedef, [(l.shape, l.dtype) for l in leaves], N)
    return (q, s, meta), err


def decompress_update(q, s, meta, interpret: Optional[bool] = None) -> Pytree:
    interpret = default_interpret() if interpret is None else interpret
    treedef, shapes, N = meta
    flat = dequantize_q8(q, s, interpret=interpret)[:N]
    out, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
