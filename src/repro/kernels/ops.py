"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend — the
kernels target TPU (Mosaic); on this CPU container they execute through the
Pallas interpreter, validated against ``repro.kernels.ref`` oracles.

Higher-level conveniences:
  - ``aggregate_pytree``: staleness-weighted aggregation over a list of
    parameter pytrees (ravel -> kernel -> unravel), the drop-in kernel path
    for ``repro.core.aggregation``;
  - ``compress_update`` / ``decompress_update``: int8 client-update
    compression with error feedback.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.fused_adam import fused_adam  # noqa: F401
from repro.kernels.quant8 import QBLOCK, ROWS, dequantize_q8, quantize_q8  # noqa: F401
from repro.kernels.staleness_agg import staleness_agg  # noqa: F401

Pytree = Any


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


def _ravel(tree: Pytree):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, leaves


def _unravel(flat: jax.Array, like_leaves, treedef,
             restore_dtype: bool = True) -> Pytree:
    out, off = [], 0
    for l in like_leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        x = flat[off:off + n].reshape(l.shape)
        out.append(x.astype(l.dtype) if restore_dtype else x)
        off += n
    return jax.tree.unflatten(treedef, out)


SUBLANE = 8  # fp32 TPU sublane; aggregate_pytree pads K to a multiple


def aggregate_pytree(updates: Sequence[Pytree], weights,
                     interpret: Optional[bool] = None, *,
                     restore_dtype: bool = True) -> Pytree:
    """Kernel-path aggregation over K parameter pytrees: ravel ->
    [K, N] buffer -> staleness_agg -> unravel. The default-dispatch
    target of ``core.aggregation.weighted_aggregate``.

    K pads to the fp32 sublane multiple with zero-weight rows (exact
    no-ops) so round-to-round K jitter reuses compiled shapes; N pads to
    the kernel block. ``restore_dtype=False`` keeps fp32 leaves
    (``weighted_aggregate``'s contract)."""
    interpret = default_interpret() if interpret is None else interpret
    treedef = jax.tree.structure(updates[0])
    flats = []
    leaves0 = None
    for u in updates:
        f, leaves = _ravel(u)
        leaves0 = leaves0 or leaves
        flats.append(f)
    stacked = jnp.stack(flats, 0)
    w = jnp.asarray(weights, jnp.float32)
    K, N = stacked.shape
    pad_k = (-K) % SUBLANE
    pad_n = (-N) % 1024
    if pad_k or pad_n:
        stacked = jnp.pad(stacked, ((0, pad_k), (0, pad_n)))
        w = jnp.pad(w, (0, pad_k))
    agg = staleness_agg(stacked, w, interpret=interpret)
    return _unravel(agg[:N], leaves0, treedef, restore_dtype=restore_dtype)


def compress_update(update: Pytree, error_feedback: Optional[Pytree] = None,
                    interpret: Optional[bool] = None):
    """int8-compress a client update with residual error feedback.

    Returns ((q, scales, meta), new_error_feedback)."""
    interpret = default_interpret() if interpret is None else interpret
    treedef = jax.tree.structure(update)
    flat, leaves = _ravel(update)
    if error_feedback is not None:
        flat = flat + error_feedback
    N = flat.shape[0]
    pad = (-N) % (ROWS * QBLOCK)
    flat_p = jnp.pad(flat, (0, pad)) if pad else flat
    q, s = quantize_q8(flat_p, interpret=interpret)
    deq = dequantize_q8(q, s, interpret=interpret)[:N]
    err = flat - deq
    meta = (treedef, [(l.shape, l.dtype) for l in leaves], N)
    return (q, s, meta), err


def decompress_update(q, s, meta, interpret: Optional[bool] = None) -> Pytree:
    interpret = default_interpret() if interpret is None else interpret
    treedef, shapes, N = meta
    flat = dequantize_q8(q, s, interpret=interpret)[:N]
    out, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
