"""Pallas TPU kernel: K-way staleness-weighted parameter aggregation.

The paper's aggregation hot loop: ``out = sum_k w[k] * updates[k, :]``
over every model parameter. Memory-bound (arithmetic intensity ~= 1 FLOP /
2 bytes), so the kernel streams [K, BN] tiles HBM->VMEM once, accumulates in
fp32 VREGs, and writes each output tile once — the roofline optimum of
(K+1)/K x N x itemsize bytes moved.

Tiling: grid over the parameter axis; block (K, 1024) — 1024 = 8x128 keeps
the lane dimension aligned with the VPU; K (<= few hundred clients) rides the
sublane dimension. Weights are a [K, 1] VMEM-resident operand broadcast
against the tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _agg_kernel(w_ref, u_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # [K, BN]
    w = w_ref[...].astype(jnp.float32)          # [K, 1]
    o_ref[...] = jnp.sum(u * w, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def staleness_agg(updates: jax.Array, weights: jax.Array, *,
                  interpret: bool = True, block_n: int = BLOCK_N) -> jax.Array:
    """updates [K, N] (N % block_n == 0), weights [K] -> [N]."""
    K, N = updates.shape
    assert N % block_n == 0, (N, block_n)
    w2 = weights.reshape(K, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _agg_kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),        # weights (resident)
            pl.BlockSpec((K, block_n), lambda i: (0, i)),  # update tile
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), updates.dtype),
        interpret=interpret,
    )(w2, updates)
    return out.reshape(N)
