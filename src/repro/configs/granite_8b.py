"""granite-8b [dense] 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
— llama-arch, code [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49_152,
    rope_theta=1e4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adam",
    learning_rate=3e-4,
    remat=True,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
    param_dtype="float32", compute_dtype="float32",
)
