"""llama-3.2-vision-11b [vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

40 total decoder layers are interpreted as 32 self-attn + 8 gated cross-attn
(one per 4 self layers), matching the HF layout. The vision frontend is a
STUB per the assignment: input_specs() provides precomputed patch embeddings
[B, 1601, d_model] (560px / 14px patches + CLS).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=32,
    cross_attn_period=4,   # 32/4 = 8 cross-attn blocks -> 40 blocks total
    n_patches=1601,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    rope_theta=5e5,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adam",
    learning_rate=3e-4,
    remat=True,
)

SMOKE = CONFIG.with_(
    n_layers=4, cross_attn_period=2, n_patches=16, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128,
    param_dtype="float32", compute_dtype="float32",
)
