"""seamless-m4t-large-v2 [audio] 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Interpreted as 24 encoder + 24 decoder layers (speech encoder and text
decoder are both 24L in SeamlessM4T-large). The audio frontend is a STUB per
the assignment: input_specs() provides precomputed frame embeddings
[B, S, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    rope_theta=1e4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adam",
    learning_rate=3e-4,
    remat=True,
)

SMOKE = CONFIG.with_(
    n_layers=4, enc_layers=2, dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=128,
    param_dtype="float32", compute_dtype="float32",
)
