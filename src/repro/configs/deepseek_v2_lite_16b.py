"""deepseek-v2-lite-16b [moe] 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64e top-6 — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf].

Note: the assignment line says "2 shared+160 routed"; 160 routed belongs to
full DeepSeek-V2. The HF config for V2-Lite is 64 routed + 2 shared, top-6,
which we implement (see DESIGN.md §5). Layer 0 is a dense-FFN MLA layer
(first_dense_layers=1) with d_ff=10944; experts use moe_d_ff=1408.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,
    moe_d_ff=1408,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    vocab_size=102_400,
    rope_theta=1e4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adam",
    learning_rate=3e-4,
    remat=True,
)

SMOKE = CONFIG.with_(
    capacity_factor=8.0,
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, moe_d_ff=32,
    n_experts=8, n_shared_experts=2, top_k=2, first_dense_layers=1,
    kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
    vocab_size=128, param_dtype="float32", compute_dtype="float32",
)
