"""The paper's Google Speech client model (67,267 params): 2 conv blocks."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(name="paper-speech", family="paper-cnn", vocab_size=35,
                     optimizer="adam", learning_rate=1e-3)
SMOKE = CONFIG
LOCAL_EPOCHS = 5
BATCH_SIZE = 5
TARGET_ACCURACY = 0.75
