"""arctic-480b [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base; hf].

Dense-MoE hybrid: every layer runs a dense FFN residual (d_ff=4864) in
parallel with a 128-expert top-2 MoE (expert d_ff=4864). Adam's fp32 moments
for 468B expert params exceed 16 GB/chip even fully sharded on 256 chips, so
training cells default to Adafactor (recorded in EXPERIMENTS.md §Roofline).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    moe_d_ff=4864,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    vocab_size=32_000,
    rope_theta=1e4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adafactor",
    learning_rate=1e-2,
    remat=True,
)

SMOKE = CONFIG.with_(
    capacity_factor=8.0,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, moe_d_ff=96,
    n_experts=8, top_k=2, vocab_size=128, remat=False,
    param_dtype="float32", compute_dtype="float32",
)
