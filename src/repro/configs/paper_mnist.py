"""The paper's MNIST client model (582,026 params): 2-layer CNN, fc 512."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(name="paper-mnist", family="paper-cnn", vocab_size=10,
                     optimizer="adam", learning_rate=1e-3)
SMOKE = CONFIG
# paper hyperparameters: 5 local epochs, batch size 10, Adam(1e-3)
LOCAL_EPOCHS = 5
BATCH_SIZE = 10
TARGET_ACCURACY = 0.98
