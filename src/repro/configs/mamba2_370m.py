"""mamba2-370m [ssm] 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128
— SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,          # unused (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adam",
    learning_rate=6e-4,
    remat=True,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, vocab_size=128, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16, param_dtype="float32", compute_dtype="float32",
)
