"""Architecture / run configuration dataclasses and the config registry.

Every assigned architecture lives in ``repro/configs/<id>.py`` exposing:

  CONFIG  -- the exact published configuration (full scale)
  SMOKE   -- a reduced configuration of the same family for CPU smoke tests

Configs are looked up by id via :func:`get_config` (used by ``--arch`` in the
launchers).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class ModelConfig:
    """Superset config covering all supported model families."""

    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm | paper-*

    # -- transformer core ---------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 256
    vocab_size: int = 256
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                  # expert hidden size (d_ff used for dense parts)
    dense_residual: bool = False       # arctic-style parallel dense MLP
    first_dense_layers: int = 0        # deepseek: leading dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- MLA (deepseek) -----------------------------------------------------
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # -- SSM (mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # -- hybrid (zamba2) ------------------------------------------------------
    attn_period: int = 0               # shared attn block every N mamba layers

    # -- enc-dec (seamless) ---------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0

    # -- vlm (llama-3.2 vision) ----------------------------------------------
    cross_attn_period: int = 0         # one cross-attn block per N self-attn layers
    n_patches: int = 0                 # stub frontend: precomputed patch embeddings

    # -- numerics -------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # -- training defaults ----------------------------------------------------
    optimizer: str = "adam"            # adam | sgd | momentum | adafactor
    learning_rate: float = 1e-3
    remat: bool = False                # activation checkpointing over layer scan
    zero1: bool = True                 # shard optimizer state over the data axis
    # roofline-exact lowering: XLA's cost_analysis counts while-loop bodies
    # once, so the dry-run lowers a fully-unrolled variant for FLOP/collective
    # extraction (production programs keep the scan).
    unroll_layers: bool = False

    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The four assigned LM shapes (identical across all ten architectures),
# plus the paper-technique cell: one asynchronous aggregation round over a
# cohort of K=32 client updates (global_batch carries K).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
    "fl_round": ShapeConfig("fl_round", 0, 32, "flround"),
}

# Architectures capable of long_500k decode (sub-quadratic sequence mixing).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")

ARCH_IDS: Sequence[str] = (
    "qwen3-1.7b",
    "granite-8b",
    "yi-6b",
    "qwen3-4b",
    "llama-3.2-vision-11b",
    "zamba2-2.7b",
    "deepseek-v2-lite-16b",
    "arctic-480b",
    "mamba2-370m",
    "seamless-m4t-large-v2",
)

_MODULE_FOR: dict[str, str] = {
    "qwen3-1.7b": "qwen3_1p7b",
    "granite-8b": "granite_8b",
    "yi-6b": "yi_6b",
    "qwen3-4b": "qwen3_4b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "zamba2-2.7b": "zamba2_2p7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "arctic-480b": "arctic_480b",
    "mamba2-370m": "mamba2_370m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    # the paper's own four models
    "paper-mnist": "paper_mnist",
    "paper-femnist": "paper_femnist",
    "paper-shakespeare": "paper_shakespeare",
    "paper-speech": "paper_speech",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) a runnable cell? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "pure full-attention arch: O(S^2) at 524k; skipped per assignment"
    return True, ""
