"""The paper's FEMNIST client model (6,603,710 params): 2-layer CNN, fc 2048."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(name="paper-femnist", family="paper-cnn", vocab_size=62,
                     optimizer="adam", learning_rate=1e-3)
SMOKE = CONFIG
LOCAL_EPOCHS = 5
BATCH_SIZE = 10
TARGET_ACCURACY = 0.70
