"""zamba2-2.7b [hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242].

54 Mamba2 layers; one *weight-shared* full-attention transformer block is
applied every 6 mamba layers (9 applications), consuming
concat(hidden, initial_embedding) per the Zamba trick.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    attn_period=6,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adam",
    learning_rate=3e-4,
    remat=True,
)

SMOKE = CONFIG.with_(
    n_layers=4, attn_period=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=128, ssm_state=16, ssm_headdim=16, ssm_chunk=16,
    param_dtype="float32", compute_dtype="float32",
)
