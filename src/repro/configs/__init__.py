from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    shape_supported,
)
