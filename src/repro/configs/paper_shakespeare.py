"""The paper's Shakespeare client model (818,402 params): embed8 + 2xLSTM256."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(name="paper-shakespeare", family="paper-lstm",
                     vocab_size=82, optimizer="sgd", learning_rate=0.8)
SMOKE = CONFIG
LOCAL_EPOCHS = 1
BATCH_SIZE = 32
TARGET_ACCURACY = 0.40
