from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adam,
    adam_fused,
    apply_updates,
    build_optimizer,
    momentum,
    sgd,
)
