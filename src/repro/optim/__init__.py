from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adam,
    apply_updates,
    build_optimizer,
    momentum,
    sgd,
)
