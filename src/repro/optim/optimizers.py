"""Minimal optax-style optimizers over pytrees: SGD, momentum, Adam,
Adafactor (factored second moment — used for the 480B MoE where Adam's fp32
moments do not fit HBM even fully sharded).

All states are pytrees mirroring the parameter tree so the sharding rule
engine (``repro.sharding``) can derive optimizer-state shardings (ZeRO-1)
from the parameter logical axes.

The Adam path can dispatch to the fused ``kernels/fused_adam`` Pallas
kernel (one HBM pass over p/m/v/g instead of ~12 unfused accesses),
mirroring the aggregation dispatch pattern: a one-time ref-equivalence
self-check gates ``auto`` dispatch, any failure falls back to the XLA
implementation, and ``REPRO_ADAM_PATH=fused|xla|auto`` forces a path.
``auto`` only takes the kernel on a real TPU backend — off-TPU the Pallas
interpreter inside the per-step training loop would be a slowdown, unlike
the once-per-round aggregation kernel. Fused state is flat ([Np] m/v
vectors) rather than tree-shaped, so it is excluded from the sharding-rule
derivation (single-host FL clients only).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]
    name: str


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        return jax.tree.map(lambda m_: -lr * m_, m), {"m": m}

    return Optimizer(init, update, "momentum")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        upd = jax.tree.map(
            lambda m_, v_: -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), m, v)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adam")


# ------------------------------------------------------- fused Adam kernel
_FUSED_ADAM_OK: Optional[bool] = None   # one-time self-check result


def _fused_adam_validated() -> bool:
    """Ref-equivalence self-check of the fused Pallas Adam step against the
    XLA implementation on a deterministic input (mirrors the aggregation
    kernel's gating). Any mismatch or kernel error disables ``auto``
    dispatch for the process."""
    global _FUSED_ADAM_OK
    if _FUSED_ADAM_OK is None:
        try:
            import numpy as np

            from repro.kernels import ref
            from repro.kernels.fused_adam import BLOCK, fused_adam
            from repro.kernels.ops import default_interpret

            rng = np.random.default_rng(0)
            N, t, lr = BLOCK, 3, 1e-3
            p, g = rng.normal(size=(2, N)).astype(np.float32)
            m = rng.normal(size=N).astype(np.float32) * 0.1
            v = np.abs(rng.normal(size=N)).astype(np.float32) * 0.01
            got = fused_adam(
                jnp.asarray(p), jnp.asarray(m), jnp.asarray(v),
                jnp.asarray(g), jnp.int32(t), lr=lr,
                interpret=default_interpret())
            want = ref.fused_adam(jnp.asarray(p), jnp.asarray(m),
                                  jnp.asarray(v), jnp.asarray(g),
                                  lr=lr, t=t)
            _FUSED_ADAM_OK = all(
                np.allclose(np.asarray(a), np.asarray(b),
                            rtol=1e-5, atol=1e-6)
                for a, b in zip(got, want))
        except Exception:  # noqa: BLE001 — any kernel failure disables path
            _FUSED_ADAM_OK = False
    return _FUSED_ADAM_OK


def adam_fused(lr: float, b1: float = 0.9, b2: float = 0.999,
               eps: float = 1e-8) -> Optimizer:
    """Adam via the fused ``kernels/fused_adam`` Pallas kernel. Params and
    grads are raveled through the shared ``RavelSpec`` contract into one
    flat fp32 vector padded to the kernel block (pad lanes carry zero
    grads -> exact no-ops); m/v state is kept flat."""
    from repro.kernels.fused_adam import BLOCK, fused_adam
    from repro.kernels.ops import RavelSpec, default_interpret

    def _flat(spec, tree):
        flat = spec.ravel(tree)
        pad = (-spec.n_params) % BLOCK
        return jnp.pad(flat, (0, pad)) if pad else flat

    def init(params):
        spec = RavelSpec(params)
        n = spec.n_params + (-spec.n_params) % BLOCK
        return {"m": jnp.zeros(n, jnp.float32),
                "v": jnp.zeros(n, jnp.float32),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        spec = RavelSpec(grads)
        p_flat = _flat(spec, params)
        t = state["t"] + 1
        po, mo, vo = fused_adam(p_flat, state["m"], state["v"],
                                _flat(spec, grads), t, lr=lr, b1=b1, b2=b2,
                                eps=eps, interpret=default_interpret())
        upd_flat = po - p_flat
        upd = spec.unravel(upd_flat[:spec.n_params], restore_dtype=False)
        return upd, {"m": mo, "v": vo, "t": t}

    return Optimizer(init, update, "adam-fused")


def adafactor(lr: float = 1e-2, eps: float = 1e-30, clip: float = 1.0) -> Optimizer:
    """Factored second-moment (Shazeer & Stern). Rank>=2 leaves keep only
    row/col statistics -> O(n+m) state instead of O(n*m); no first moment."""

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"s": jax.tree.map(one, params), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** -0.8

        def one(g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if g.ndim >= 2:
                row = beta * s["row"] + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * s["col"] + (1 - beta) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(row, axis=-1, keepdims=True)
                r = (row / jnp.maximum(row_mean, eps))[..., None]
                c = col[..., None, :]
                vhat = r * c
                upd = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
                new_s = {"row": row, "col": col}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS <= clip)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip)
            return -lr * upd, new_s

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        outs = [one(g, s) for g, s in zip(flat_g, flat_s)]
        upd = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        return upd, {"s": new_s, "t": t}

    return Optimizer(init, update, "adafactor")


def build_optimizer(name: str, lr: float) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adam":
        path = os.environ.get("REPRO_ADAM_PATH", "auto")
        if path not in ("auto", "fused", "xla"):
            raise ValueError(f"unknown adam path {path!r}")
        if path == "fused":
            return adam_fused(lr)   # forced: kernel errors propagate
        if path == "auto":
            from repro.kernels.ops import on_tpu
            if on_tpu() and _fused_adam_validated():
                return adam_fused(lr)
        return adam(lr)
    if name == "adafactor":
        return adafactor(lr)
    raise ValueError(f"unknown optimizer {name}")
