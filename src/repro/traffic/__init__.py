"""Open-loop traffic plane: arrival processes compiled into vectorized
availability schedules, plus the SLO metrics layer (DESIGN.md §13).

The fifth plane alongside control/data/update/schedule: fleet membership
is driven by seeded arrival processes (Poisson, diurnal, flash-crowd,
trace replay) instead of fixed scenario lists, applied to the
``FleetStore`` in bulk windowed segments rather than per-event Python.
``REPRO_TRAFFIC`` / ``FLConfig.traffic_profile`` select a canned profile
or a raw spec string; off (the default) is bit-identical to every
pre-existing trace.
"""
from repro.traffic.model import (DiurnalTraffic, FlashCrowd,  # noqa: F401
                                 PoissonTraffic, TraceTraffic,
                                 TRAFFIC_PROFILES, TrafficSpec,
                                 parse_traffic, resolve_traffic_profile)
from repro.traffic.schedule import (TrafficSchedule,  # noqa: F401
                                    TrafficSegment,
                                    build_traffic_schedule,
                                    compile_traffic_schedule)
from repro.traffic.slo import round_latencies, slo_summary  # noqa: F401
