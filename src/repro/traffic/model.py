"""Open-loop traffic model: arrival-process specs and profiles.

Closed-loop scenarios pull rounds from a fixed fleet; the serverless
setting the paper targets is open-loop — clients arrive, disappear, and
surge on their own clock. This module defines the *declarative* side of
the traffic plane (DESIGN.md §13): compact spec strings describing
arrival sources, mirrored on `faas/faults.py`:

    REPRO_TRAFFIC=init:0.5,poisson:0.02:600
    REPRO_TRAFFIC=diurnal                      # a canned profile name

Spec grammar (comma-separated clauses, colon-separated fields):

    init:<frac>                 fraction of the id universe present at t=0
                                (ids 0..k-1; default 1.0)
    window:<s>                  schedule quantum: every join/leave lands on
                                a multiple of this (default 30 s)
    horizon:<s>                 compiled schedule length (default 20000 s,
                                capped at the run's sim budget)
    poisson:<rate>[:<dwell>]    Poisson arrivals at `rate` clients/s; each
                                stays Exp(dwell) seconds (0 = forever)
    diurnal:<rate>:<depth>:<period>[:<dwell>]
                                sinusoid-modulated Poisson: instantaneous
                                rate = rate*(1 + depth*sin(2*pi*t/period)),
                                realized by thinning at rate*(1+depth)
    flash:<t>:<n>[:<dwell>]     flash crowd: n simultaneous arrivals at t
    trace:<t>=<+n|-n>[;...]     replayed membership deltas (`;`-separated
                                since `,` splits clauses); +n joins n
                                clients, -n removes the n earliest-joined

Everything is resolved through the same oracle as every other plane
flag: explicit config > ``REPRO_TRAFFIC`` env > default, with ""/"none"/
"off" meaning no traffic — and the off path constructs nothing and draws
no RNG, so every pre-existing trace is bit-identical.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Tuple

__all__ = ["PoissonTraffic", "DiurnalTraffic", "FlashCrowd", "TraceTraffic",
           "TrafficSpec", "parse_traffic", "resolve_traffic_profile",
           "TRAFFIC_PROFILES"]


@dataclass(frozen=True)
class PoissonTraffic:
    """Homogeneous Poisson arrivals; dwell 0 means clients never leave."""
    rate: float                 # arrivals per second
    dwell: float = 0.0          # mean Exp dwell time, seconds


@dataclass(frozen=True)
class DiurnalTraffic:
    """Sinusoid-modulated Poisson arrivals (diurnal load)."""
    rate: float                 # mean arrivals per second
    depth: float                # modulation depth in [0, 1]
    period: float               # seconds per cycle
    dwell: float = 0.0


@dataclass(frozen=True)
class FlashCrowd:
    """`n` simultaneous arrivals at time `t` (a surge)."""
    t: float
    n: int
    dwell: float = 0.0


@dataclass(frozen=True)
class TraceTraffic:
    """Replayed membership deltas: (time, +joins / -leaves) pairs."""
    events: Tuple[Tuple[float, int], ...]


@dataclass(frozen=True)
class TrafficSpec:
    """A parsed ``REPRO_TRAFFIC`` string (declarative; compile with
    `repro.traffic.schedule.compile_traffic_schedule`)."""
    sources: Tuple = field(default_factory=tuple)
    init_frac: float = 1.0
    window: float = 30.0
    horizon: float = 20_000.0

    @property
    def active(self) -> bool:
        # "init:1.0" alone is the closed-loop default: not traffic
        return bool(self.sources) or self.init_frac != 1.0

    @property
    def stochastic(self) -> bool:
        """True when compiling consumes RNG (Poisson/diurnal sources) —
        the megastep refuses fusion under these by name."""
        return any(isinstance(s, (PoissonTraffic, DiurnalTraffic))
                   for s in self.sources)


def _floats(fields: list, n_req: int, n_opt: int, clause: str) -> list:
    if not (1 + n_req <= len(fields) <= 1 + n_req + n_opt):
        raise ValueError(f"traffic clause {clause!r}: expected "
                         f"{n_req}-{n_req + n_opt} fields")
    try:
        return [float(f) for f in fields[1:]]
    except ValueError:
        raise ValueError(f"traffic clause {clause!r}: non-numeric field") \
            from None


def parse_traffic(spec: str) -> TrafficSpec:
    """Parse a compact traffic spec string (see module docstring)."""
    spec = (spec or "").strip()
    if not spec or spec.lower() in ("none", "off"):
        return TrafficSpec()
    sources: list = []
    init_frac, window, horizon = 1.0, 30.0, 20_000.0
    for clause in spec.split(","):
        clause = clause.strip()
        if not clause:
            continue
        fields = clause.split(":")
        kind = fields[0].lower()
        if kind == "init":
            (init_frac,) = _floats(fields, 1, 0, clause)
            if not 0.0 <= init_frac <= 1.0:
                raise ValueError(f"traffic init fraction {init_frac} "
                                 f"outside [0, 1]")
        elif kind == "window":
            (window,) = _floats(fields, 1, 0, clause)
            if window <= 0:
                raise ValueError("traffic window must be > 0")
        elif kind == "horizon":
            (horizon,) = _floats(fields, 1, 0, clause)
            if horizon <= 0:
                raise ValueError("traffic horizon must be > 0")
        elif kind == "poisson":
            vals = _floats(fields, 1, 1, clause)
            rate, dwell = vals[0], (vals[1] if len(vals) > 1 else 0.0)
            if rate < 0 or dwell < 0:
                raise ValueError(f"traffic clause {clause!r}: negative field")
            sources.append(PoissonTraffic(rate=rate, dwell=dwell))
        elif kind == "diurnal":
            vals = _floats(fields, 3, 1, clause)
            rate, depth, period = vals[0], vals[1], vals[2]
            dwell = vals[3] if len(vals) > 3 else 0.0
            if rate < 0 or dwell < 0 or period <= 0 or not 0 <= depth <= 1:
                raise ValueError(f"traffic clause {clause!r}: bad field "
                                 f"(need rate,dwell>=0, period>0, "
                                 f"depth in [0,1])")
            sources.append(DiurnalTraffic(rate=rate, depth=depth,
                                          period=period, dwell=dwell))
        elif kind == "flash":
            vals = _floats(fields, 2, 1, clause)
            t, n = vals[0], int(vals[1])
            dwell = vals[2] if len(vals) > 2 else 0.0
            if t < 0 or n < 0 or dwell < 0:
                raise ValueError(f"traffic clause {clause!r}: negative field")
            sources.append(FlashCrowd(t=t, n=n, dwell=dwell))
        elif kind == "trace":
            body = clause.split(":", 1)[1] if ":" in clause else ""
            events = []
            for ev in body.split(";"):
                ev = ev.strip()
                if not ev:
                    continue
                try:
                    t_s, delta_s = ev.split("=")
                    t, delta = float(t_s), int(delta_s)
                except ValueError:
                    raise ValueError(f"traffic trace event {ev!r}: expected "
                                     f"<t>=<+n|-n>") from None
                if t < 0:
                    raise ValueError(f"traffic trace event {ev!r}: t < 0")
                events.append((t, delta))
            if not events:
                raise ValueError(f"traffic clause {clause!r}: empty trace")
            sources.append(TraceTraffic(events=tuple(events)))
        else:
            raise ValueError(f"unknown traffic clause {clause!r} (want "
                             f"init/window/horizon/poisson/diurnal/flash/"
                             f"trace)")
    return TrafficSpec(sources=tuple(sources), init_frac=init_frac,
                       window=window, horizon=horizon)


# Canned profiles, sized so they bite at sweep scale (M~8-256, sim
# budgets of hundreds of seconds) and stress the bulk path at bench
# scale. Raw spec strings work anywhere a profile name does.
TRAFFIC_PROFILES = {
    # half the fleet at t=0, slow Poisson trickle with ~10-minute dwells
    "steady-churn": "init:0.5,window:30,poisson:0.02:600",
    # sinusoidal day/night load over a 10-minute "day"
    "diurnal": "init:0.5,window:30,diurnal:0.05:0.9:600:300",
    # a quarter-fleet baseline hit by a 1000-client surge at t=60
    # (arrivals beyond capacity are dropped and counted)
    "flash-crowd": "init:0.25,window:30,flash:60:1000:300",
    # deterministic replayed deltas (megastep-fusable)
    "trace-demo": "init:0.5,window:30,trace:90=+2;210=-2;300=+3",
}


def resolve_traffic_profile(mode) -> str:
    """Resolution oracle shared with every other plane flag: explicit
    config beats ``REPRO_TRAFFIC`` beats default-off. Returns the profile
    string ("" = traffic off); raises on an unparseable spec."""
    if mode in (None, "", "auto"):
        mode = os.environ.get("REPRO_TRAFFIC", "")
    if not isinstance(mode, str):
        raise ValueError(f"traffic profile must be a string, got {mode!r}")
    if mode.lower() in ("none", "off"):
        return ""
    if mode:
        parse_traffic(TRAFFIC_PROFILES.get(mode, mode))    # validate early
    return mode
