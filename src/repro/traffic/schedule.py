"""Traffic schedule compiler: arrival processes -> vectorized segments.

The naive open-loop simulation emits one Python ``ClientJoined`` /
``ClientLeft`` per arrival — untenable at M=1e6. Instead the whole
arrival process is compiled *once*, ahead of the run, into a short list
of :class:`TrafficSegment` windows: ``(start, end, joins, leaves)`` with
the member deltas as int64 id arrays. The runtime applies each segment
in bulk (one columnar ``FleetStore.add_batch`` + one ``remove_batch``)
when the clock crosses its start, and the megastep treats segment
boundaries exactly like PR 7's outage windows — fuse up to the next
boundary, re-engage after it.

Compilation contract (the replay anchor, property-tested):

* One ``np.random.default_rng(seed)`` generator; sources consume draws
  in declaration order with a fixed draw count per source, so the same
  (spec, seed, capacity) compiles bit-identically forever.
* Poisson arrivals via order statistics (N ~ Poisson(rate*horizon),
  then N sorted uniforms); diurnal via thinning at the peak rate.
* Event times quantize UP to the spec's window; window-0 events fold
  into the initial membership.
* Ids are the *smallest free* ids in [0, capacity): arrivals beyond
  capacity are dropped and counted (``n_dropped``); ids freed by a leave
  are reused. Within a window: leaves first (dwell expiries, then trace
  removals of the earliest-joined), then joins — the i-th earliest
  arrival in the window takes the i-th smallest free id.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.traffic.model import (DiurnalTraffic, FlashCrowd, PoissonTraffic,
                                 TraceTraffic, TrafficSpec, parse_traffic,
                                 TRAFFIC_PROFILES)

__all__ = ["TrafficSegment", "TrafficSchedule", "compile_traffic_schedule",
           "build_traffic_schedule"]


@dataclass(frozen=True, eq=False)
class TrafficSegment:
    """One schedule window: at ``start``, remove ``leaves`` then register
    ``joins`` (both sorted int64 id arrays); membership then holds until
    ``end`` (the next segment's start)."""
    start: float
    end: float
    joins: np.ndarray
    leaves: np.ndarray


@dataclass(frozen=True, eq=False)
class TrafficSchedule:
    """A compiled, replayable availability schedule over a fixed id
    universe [0, capacity)."""
    spec: TrafficSpec
    seed: int
    capacity: int
    horizon: float
    initial: np.ndarray                      # sorted ids present at t=0
    segments: Tuple[TrafficSegment, ...]
    n_dropped: int = 0                       # arrivals beyond capacity

    @property
    def stochastic(self) -> bool:
        return self.spec.stochastic

    def presence_at(self, t: float) -> np.ndarray:
        """Availability mask after every segment with start <= t."""
        present = np.zeros(self.capacity, bool)
        present[self.initial] = True
        for seg in self.segments:
            if seg.start > t:
                break
            present[seg.leaves] = False
            present[seg.joins] = True
        return present

    def events(self) -> Iterator[Tuple[float, str, int]]:
        """Per-client event stream — the slow oracle the bulk path is
        tested against: (t, "leave"|"join", client_id) in apply order."""
        for seg in self.segments:
            for cid in seg.leaves:
                yield seg.start, "leave", int(cid)
            for cid in seg.joins:
                yield seg.start, "join", int(cid)


def _quantize_up(t: float, window: float) -> float:
    if t <= 0.0:
        return 0.0
    return window * math.ceil(t / window - 1e-9)


def compile_traffic_schedule(spec: TrafficSpec, capacity: int, seed: int,
                             horizon_cap: Optional[float] = None
                             ) -> TrafficSchedule:
    """Draw every source once and fold the event stream into windowed
    bulk segments (see module docstring for the contract)."""
    horizon = spec.horizon
    if horizon_cap is not None:
        horizon = min(horizon, float(horizon_cap))
    window = spec.window
    rng = np.random.default_rng(seed)

    # ---- draw arrivals (t, dwell) per source, in declaration order
    ts_parts, dwell_parts = [], []
    trace_leaves: dict[float, int] = {}      # boundary -> count
    for src in spec.sources:
        if isinstance(src, PoissonTraffic):
            n = int(rng.poisson(src.rate * horizon))
            ts = np.sort(rng.uniform(0.0, horizon, n))
            dw = (rng.exponential(src.dwell, n) if src.dwell > 0
                  else np.full(n, np.inf))
        elif isinstance(src, DiurnalTraffic):
            lam_max = src.rate * (1.0 + src.depth)
            n = int(rng.poisson(lam_max * horizon))
            ts = np.sort(rng.uniform(0.0, horizon, n))
            u = rng.uniform(0.0, lam_max, n)
            lam_t = src.rate * (1.0 + src.depth
                                * np.sin(2.0 * np.pi * ts / src.period))
            ts = ts[u < lam_t]
            dw = (rng.exponential(src.dwell, len(ts)) if src.dwell > 0
                  else np.full(len(ts), np.inf))
        elif isinstance(src, FlashCrowd):
            ts = np.full(src.n, float(src.t))
            dw = np.full(src.n, src.dwell if src.dwell > 0 else np.inf)
        elif isinstance(src, TraceTraffic):
            joins = [t for t, d in src.events for _ in range(max(d, 0))]
            ts = np.asarray(joins, float)
            dw = np.full(len(joins), np.inf)
            for t, d in src.events:
                if d < 0:
                    b = _quantize_up(t, window)
                    trace_leaves[b] = trace_leaves.get(b, 0) - d
        else:
            raise TypeError(f"unknown traffic source {src!r}")
        ts_parts.append(ts)
        dwell_parts.append(dw)

    ts_all = (np.concatenate(ts_parts) if ts_parts
              else np.empty(0, float))
    dw_all = (np.concatenate(dwell_parts) if dwell_parts
              else np.empty(0, float))
    order = np.argsort(ts_all, kind="stable")
    ts_all, dw_all = ts_all[order], dw_all[order]

    bounds = np.array([_quantize_up(t, window) for t in ts_all])
    keep = bounds <= horizon
    ts_all, dw_all, bounds = ts_all[keep], dw_all[keep], bounds[keep]
    # leave boundary per arrival: strictly after its join window
    leave_bounds = np.array(
        [max(_quantize_up(t + d, window), b + window)
         if np.isfinite(d) else np.inf
         for t, d, b in zip(ts_all, dw_all, bounds)])

    # group arrivals by (sorted, nondecreasing) boundary
    arrivals: dict[float, np.ndarray] = {}   # boundary -> arrival indices
    if len(bounds):
        uniq, starts = np.unique(bounds, return_index=True)
        splits = np.split(np.arange(len(bounds)), starts[1:])
        arrivals = {float(b): idx for b, idx in zip(uniq, splits)}

    boundaries = sorted(set(arrivals)
                        | set(trace_leaves)
                        | {float(lb) for lb in leave_bounds
                           if np.isfinite(lb) and lb <= horizon})

    # ---- replay boundaries, allocating smallest-free ids
    M = int(capacity)
    present = np.zeros(M, bool)
    join_seq = np.full(M, -1, np.int64)      # join-instance token per id
    seq = 0
    n_dropped = 0
    # leave boundary -> list of (ids, seqs); a token mismatch means the
    # id left earlier (trace removal) and was reassigned — skip it
    dwell_bucket: dict[float, list] = {}

    k0 = min(M, int(round(spec.init_frac * M)))
    present[:k0] = True
    join_seq[:k0] = np.arange(k0)
    seq = k0

    def _process(b: float):
        nonlocal seq, n_dropped
        leave_ids = []
        for ids, seqs in dwell_bucket.pop(b, ()):
            ok = present[ids] & (join_seq[ids] == seqs)
            leave_ids.append(ids[ok])
        n_trace = trace_leaves.get(b, 0)
        if n_trace:
            for part in leave_ids:           # dwell departures leave first,
                present[part] = False        # so they can't be trace victims
            live = np.flatnonzero(present)
            victims = live[np.argsort(join_seq[live],
                                      kind="stable")[:n_trace]]
            leave_ids.append(victims)
        leaves = (np.sort(np.concatenate(leave_ids)).astype(np.int64)
                  if leave_ids else np.empty(0, np.int64))
        present[leaves] = False

        idx = arrivals.get(b)
        if idx is None:
            joins = np.empty(0, np.int64)
        else:
            k = len(idx)
            free = np.flatnonzero(~present)[:k]
            n_dropped += k - len(free)
            present[free] = True
            join_seq[free] = seq + np.arange(len(free))
            seq += len(free)
            lbs = leave_bounds[idx[:len(free)]]
            fin = np.isfinite(lbs) & (lbs <= horizon)
            for lb in np.unique(lbs[fin]):
                m = fin & (lbs == lb)
                dwell_bucket.setdefault(float(lb), []).append(
                    (free[m], join_seq[free[m]]))
            joins = free.astype(np.int64)
        return leaves, joins

    if 0.0 in arrivals or 0.0 in trace_leaves:
        _process(0.0)                        # fold window-0 into initial
    initial = np.flatnonzero(present).astype(np.int64)

    raw_segments = []
    for b in boundaries:
        if b <= 0.0:
            continue
        leaves, joins = _process(b)
        if len(leaves) or len(joins):
            raw_segments.append((b, joins, leaves))

    segments = []
    for i, (b, joins, leaves) in enumerate(raw_segments):
        end = (raw_segments[i + 1][0] if i + 1 < len(raw_segments)
               else max(horizon, b))
        segments.append(TrafficSegment(start=b, end=end, joins=joins,
                                       leaves=leaves))
    return TrafficSchedule(spec=spec, seed=seed, capacity=M,
                           horizon=horizon, initial=initial,
                           segments=tuple(segments), n_dropped=n_dropped)


def build_traffic_schedule(profile: str, capacity: int, seed: int,
                           horizon_cap: Optional[float] = None
                           ) -> Optional[TrafficSchedule]:
    """Profile-or-spec string -> compiled schedule, or None when traffic
    is off (the off path allocates nothing and draws no RNG)."""
    spec = parse_traffic(TRAFFIC_PROFILES.get(profile, profile))
    if not spec.active:
        return None
    return compile_traffic_schedule(spec, capacity, seed,
                                    horizon_cap=horizon_cap)
