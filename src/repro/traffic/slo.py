"""Production SLO metrics (DESIGN.md §13).

The paper's headline numbers are speedup ratios on closed-loop runs;
under open-loop traffic the operative questions are the ones a service
owner asks: tail round latency, cold-start rate, dollars per round, and
time-to-accuracy *under load*. These are pure functions over the
round history / platform counters already collected by ``FLRuntime``,
surfaced uniformly in ``metrics()`` and the sweep result tables.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["round_latencies", "slo_summary"]


def round_latencies(history: Sequence) -> np.ndarray:
    """Per-round wall latency (simulated seconds) from RoundLog entries."""
    return np.asarray([log.t_end - log.t_start for log in history], float)


def slo_summary(history: Sequence, cold_start_ratio: float,
                total_cost_usd: float,
                time_to_accuracy: Optional[float] = None) -> dict:
    """The SLO block merged into ``FLRuntime.metrics()``: p50/p99 round
    latency, cold-start rate, cost-per-round, and (when a target accuracy
    is configured) time-to-accuracy under load."""
    lat = round_latencies(history)
    p50 = float(np.percentile(lat, 50)) if len(lat) else 0.0
    p99 = float(np.percentile(lat, 99)) if len(lat) else 0.0
    return {
        "p50_round_latency_s": p50,
        "p99_round_latency_s": p99,
        "cold_start_rate": float(cold_start_ratio),
        "cost_per_round_usd": float(total_cost_usd) / max(len(history), 1),
        "time_to_accuracy_s": time_to_accuracy,
    }
