"""The paper's four client models (IV-A2), reimplemented in pure JAX.

Parameter counts match the paper exactly where the architecture is fully
determined by the text:

  - MNIST 2-layer CNN (valid padding, fc 512, 10 classes)  -> 582,026 params
  - FEMNIST 2-layer CNN (same padding, fc 2048, 62 classes) -> 6,603,710
  - Shakespeare: embed(82->8) + 2x LSTM(256) + dense(82)    -> 818,402
  - Google Speech: 2 conv blocks (32/64 ch) + avgpool + 35  -> 67,267
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory, softmax_cross_entropy

Pytree = Any


def _conv(pf: ParamFactory, name: str, k: int, cin: int, cout: int):
    pf.param(f"{name}_w", (k, k, cin, cout), (None, None, None, "ffn"))
    pf.param(f"{name}_b", (cout,), ("ffn",), init="zeros")


def _apply_conv(p, name, x, padding: str):
    y = jax.lax.conv_general_dilated(
        x, p[f"{name}_w"].astype(x.dtype), window_strides=(1, 1),
        padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p[f"{name}_b"].astype(x.dtype)


def _maxpool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


class _ClassifierBase:
    n_classes: int = 10

    def loss(self, params, batch):
        logits = self.predict(params, batch["x"])
        ce = softmax_cross_entropy(logits, batch["y"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
        return ce, {"ce": ce, "acc": acc}

    def accuracy(self, params, batch):
        logits = self.predict(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


class MnistCNN(_ClassifierBase):
    """28x28x1, conv5x5(32) VALID + pool, conv5x5(64) VALID + pool, fc512, 10."""

    n_classes = 10
    input_shape = (28, 28, 1)

    def init(self, rng):
        pf = ParamFactory(rng, jnp.float32)
        _conv(pf, "c1", 5, 1, 32)
        _conv(pf, "c2", 5, 32, 64)
        pf.param("fc1_w", (4 * 4 * 64, 512), ("d_model", "ffn"))
        pf.param("fc1_b", (512,), ("ffn",), init="zeros")
        pf.param("fc2_w", (512, 10), ("ffn", "vocab"))
        pf.param("fc2_b", (10,), ("vocab",), init="zeros")
        return pf.params, pf.axes

    def predict(self, p, x):
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c1", x, "VALID")))   # 24->12
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c2", x, "VALID")))   # 8->4
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["fc1_w"] + p["fc1_b"])
        return x @ p["fc2_w"] + p["fc2_b"]


class FemnistCNN(_ClassifierBase):
    """28x28x1, conv5x5(32) SAME + pool, conv5x5(64) SAME + pool, fc2048, 62."""

    n_classes = 62
    input_shape = (28, 28, 1)

    def init(self, rng):
        pf = ParamFactory(rng, jnp.float32)
        _conv(pf, "c1", 5, 1, 32)
        _conv(pf, "c2", 5, 32, 64)
        pf.param("fc1_w", (7 * 7 * 64, 2048), ("d_model", "ffn"))
        pf.param("fc1_b", (2048,), ("ffn",), init="zeros")
        pf.param("fc2_w", (2048, 62), ("ffn", "vocab"))
        pf.param("fc2_b", (62,), ("vocab",), init="zeros")
        return pf.params, pf.axes

    def predict(self, p, x):
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c1", x, "SAME")))    # 28->14
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c2", x, "SAME")))    # 14->7
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["fc1_w"] + p["fc1_b"])
        return x @ p["fc2_w"] + p["fc2_b"]


class SpeechCNN(_ClassifierBase):
    """32x32x1 spectrogram, 2 blocks of (conv3x3, conv3x3, pool, dropout),
    global average pool, 35 classes."""

    n_classes = 35
    input_shape = (32, 32, 1)

    def init(self, rng):
        pf = ParamFactory(rng, jnp.float32)
        _conv(pf, "c1", 3, 1, 32)
        _conv(pf, "c2", 3, 32, 32)
        _conv(pf, "c3", 3, 32, 64)
        _conv(pf, "c4", 3, 64, 64)
        pf.param("fc_w", (64, 35), ("ffn", "vocab"))
        pf.param("fc_b", (35,), ("vocab",), init="zeros")
        return pf.params, pf.axes

    def predict(self, p, x):
        x = jax.nn.relu(_apply_conv(p, "c1", x, "SAME"))
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c2", x, "SAME")))    # 32->16
        x = jax.nn.relu(_apply_conv(p, "c3", x, "SAME"))
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c4", x, "SAME")))    # 16->8
        x = jnp.mean(x, axis=(1, 2))                                   # GAP -> 64
        return x @ p["fc_w"] + p["fc_b"]


class ShakespeareLSTM:
    """Next-char model: embed(82->8), 2x LSTM(256), dense(82). Input [B, 80]."""

    n_classes = 82
    vocab = 82
    seq_len = 80

    def init(self, rng):
        pf = ParamFactory(rng, jnp.float32)
        pf.param("embed", (self.vocab, 8), ("vocab", "d_model"), init="embed")
        for name, din in (("lstm1", 8), ("lstm2", 256)):
            pf.param(f"{name}_wx", (din, 4 * 256), ("d_model", "ffn"))
            pf.param(f"{name}_wh", (256, 4 * 256), ("d_model", "ffn"))
            pf.param(f"{name}_b", (4 * 256,), ("ffn",), init="zeros")
        pf.param("out_w", (256, self.vocab), ("d_model", "vocab"))
        pf.param("out_b", (self.vocab,), ("vocab",), init="zeros")
        return pf.params, pf.axes

    @staticmethod
    def _lstm(p, name, xs):
        """xs: [S, B, din] -> hs [S, B, 256]."""
        B = xs.shape[1]
        h0 = jnp.zeros((B, 256), xs.dtype)
        c0 = jnp.zeros((B, 256), xs.dtype)

        def step(carry, x):
            h, c = carry
            gates = x @ p[f"{name}_wx"] + h @ p[f"{name}_wh"] + p[f"{name}_b"]
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
        return hs

    def predict(self, p, x):
        """x: [B, 80] int32 -> logits [B, 82] (next char)."""
        e = jnp.take(p["embed"], x, axis=0).swapaxes(0, 1)   # [S, B, 8]
        h = self._lstm(p, "lstm1", e)
        h = self._lstm(p, "lstm2", h)
        return h[-1] @ p["out_w"] + p["out_b"]

    def loss(self, params, batch):
        logits = self.predict(params, batch["x"])
        ce = softmax_cross_entropy(logits, batch["y"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
        return ce, {"ce": ce, "acc": acc}

    def accuracy(self, params, batch):
        logits = self.predict(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


PAPER_MODELS = {
    "paper-mnist": MnistCNN,
    "paper-femnist": FemnistCNN,
    "paper-shakespeare": ShakespeareLSTM,
    "paper-speech": SpeechCNN,
}


def build_paper_model(name: str):
    return PAPER_MODELS[name]()
