"""Decoder-only language model covering the dense / moe / ssm / hybrid / vlm
families. Layers are stacked and executed with ``lax.scan`` so HLO size (and
compile time) is O(1) in depth; interleaved structures (zamba2 hybrid chunks,
vision cross-attention) scan over homogeneous *chunks*.

API (functional):
    lm = DecoderLM(cfg)
    params, axes = lm.init(rng)
    logits, aux = lm.apply(params, batch)                  # train/prefill
    loss, metrics = lm.loss(params, batch)
    cache, cache_axes = lm.cache_struct(batch, cache_len)  # ShapeDtypeStructs
    logits, cache = lm.decode_step(params, cache, tokens, pos, ...)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import blocks as blk
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamFactory,
    init_stacked,
    map_axes,
    rms_norm,
    softmax_cross_entropy,
)
from repro.sharding import shard_act

Pytree = Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def block_kind(cfg: ModelConfig) -> str:
    mla = "mla_" if cfg.kv_lora_rank else ""
    return f"{mla}moe" if cfg.n_experts else f"{mla}dense" if mla else "dense"


def _scan(cfg: ModelConfig, body, carry, xs):
    """Layer scan; fully unrolled when cfg.unroll_layers (the roofline-exact
    lowering — XLA cost_analysis counts while bodies once; see launch/dryrun)."""
    return jax.lax.scan(body, carry, xs,
                        unroll=True if cfg.unroll_layers else 1)


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdtype = _dtype(cfg.param_dtype)
        self.cdtype = _dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ init
    def init(self, rng: jax.Array) -> tuple[Pytree, Pytree]:
        cfg = self.cfg
        r_embed, r_layers, r_head, r_shared = jax.random.split(rng, 4)
        pf = ParamFactory(r_embed, self.pdtype)
        pf.param("tok_embed", (cfg.vocab_size, cfg.d_model), ("vocab", "d_model"),
                 init="embed")
        pf.param("ln_f", (cfg.d_model,), ("d_model",), init="ones")
        if not cfg.tie_embeddings:
            pf.param("head", (cfg.d_model, cfg.vocab_size), ("d_model", "vocab"))
        params, axes = pf.params, pf.axes

        fam = cfg.family
        if fam in ("dense", "moe"):
            kind = block_kind(cfg)
            n_stacked = cfg.n_layers - cfg.first_dense_layers
            first = []
            first_axes = []
            rr = r_layers
            dense_kind = kind.replace("moe", "dense")
            for _ in range(cfg.first_dense_layers):
                rr, sub = jax.random.split(rr)
                pf1 = ParamFactory(sub, self.pdtype)
                blk.init_decoder_block(pf1, cfg, kind=dense_kind)
                first.append(pf1.params)
                first_axes.append(pf1.axes)
            stack, stack_axes = init_stacked(
                lambda pf_: blk.init_decoder_block(pf_, cfg, kind=kind),
                rr, n_stacked, self.pdtype)
            params["layers"] = {"first": first, "stack": stack}
            axes["layers"] = {"first": first_axes, "stack": stack_axes}
        elif fam == "ssm":
            stack, stack_axes = init_stacked(
                lambda pf_: blk.init_mamba_block(pf_, cfg),
                r_layers, cfg.n_layers, self.pdtype)
            params["layers"] = {"stack": stack}
            axes["layers"] = {"stack": stack_axes}
        elif fam == "hybrid":
            n_chunks = cfg.n_layers // cfg.attn_period
            stack, stack_axes = init_stacked(
                lambda pf_: blk.init_mamba_block(pf_, cfg),
                r_layers, cfg.n_layers, self.pdtype)
            # reshape [L, ...] -> [n_chunks, period, ...]
            stack = jax.tree.map(
                lambda x: x.reshape(n_chunks, cfg.attn_period, *x.shape[1:]), stack)
            stack_axes = map_axes(stack_axes, lambda a: ("layers",) + tuple(a))
            pf_s = ParamFactory(r_shared, self.pdtype)
            blk.init_zamba_shared(pf_s, cfg)
            params["layers"] = {"stack": stack, "shared": pf_s.params}
            axes["layers"] = {"stack": stack_axes, "shared": pf_s.axes}
        elif fam == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_period
            stack, stack_axes = init_stacked(
                lambda pf_: blk.init_decoder_block(pf_, cfg, kind="dense"),
                r_layers, cfg.n_layers, self.pdtype)
            stack = jax.tree.map(
                lambda x: x.reshape(n_cross, cfg.cross_attn_period, *x.shape[1:]),
                stack)
            stack_axes = map_axes(stack_axes, lambda a: ("layers",) + tuple(a))
            cross, cross_axes = init_stacked(
                lambda pf_: blk.init_cross_block(pf_, cfg, gated=True),
                r_shared, n_cross, self.pdtype)
            params["layers"] = {"stack": stack, "cross": cross}
            axes["layers"] = {"stack": stack_axes, "cross": cross_axes}
        else:
            raise ValueError(f"DecoderLM does not handle family {fam}")
        return params, axes

    # --------------------------------------------------------------- helpers
    def _embed(self, params, tokens):
        emb = params["tok_embed"]
        x = jnp.take(emb, tokens, axis=0).astype(self.cdtype)
        return shard_act(x, ("batch", "seq", "d_model"))

    def _head(self, params, x):
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["tok_embed"].astype(x.dtype))
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
        return shard_act(logits, ("batch", "seq", "vocab"))

    # ---------------------------------------------------- full-sequence pass
    def apply(self, params: Pytree, batch: dict, *, make_cache: bool = False,
              cache_len: Optional[int] = None):
        """batch: {'tokens': [B,S] int32, optional 'patches': [B,P,D]}.
        Returns (logits, caches_or_None, aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.arange(S)
        cache_len = cache_len or S
        aux0 = jnp.zeros((), jnp.float32)

        fam = cfg.family
        caches = None
        if fam in ("dense", "moe"):
            kind = block_kind(cfg)
            dense_kind = kind.replace("moe", "dense")
            aux = aux0
            first_caches = []
            for p_i in params["layers"]["first"]:
                c_i = self._attn_cache_zeros(B, cache_len) if make_cache else None
                x, nc, a = blk.decoder_block(p_i, x, cfg, positions, kind=dense_kind,
                                             cache=c_i, pos=0 if make_cache else None)
                aux += a
                first_caches.append(nc)

            def body(carry, inp):
                x, aux = carry
                p_i, c_i = inp
                y, nc, a = blk.decoder_block(p_i, x, cfg, positions, kind=kind,
                                             cache=c_i, pos=0 if make_cache else None)
                return (y, aux + a), nc

            if cfg.remat:
                body = jax.checkpoint(body)
            n_stacked = cfg.n_layers - cfg.first_dense_layers
            stack_caches = (self._attn_cache_zeros(B, cache_len, n=n_stacked)
                            if make_cache else None)
            (x, aux), new_stack = _scan(cfg, 
                body, (x, aux), (params["layers"]["stack"], stack_caches))
            if make_cache:
                caches = {"first": first_caches, "stack": new_stack}
        elif fam == "ssm":
            def body(x, inp):
                p_i, = inp
                y, nc = blk.mamba_block(p_i, x, cfg,
                                        cache={} if make_cache else None)
                return y, nc

            if cfg.remat:
                body = jax.checkpoint(body)
            x, new_stack = _scan(cfg, body, x, (params["layers"]["stack"],))
            aux = aux0
            if make_cache:
                caches = {"stack": new_stack}
        elif fam == "hybrid":
            x0 = x
            shared_p = params["layers"]["shared"]

            def chunk_body(x, inp):
                p_chunk, = inp

                def inner(x, p_i):
                    y, nc = blk.mamba_block(p_i, x, cfg,
                                            cache={} if make_cache else None)
                    return y, nc

                x, mamba_caches = _scan(cfg, inner, x, p_chunk)
                y, kv = blk.zamba_shared_block(
                    shared_p, x, x0, cfg, positions,
                    cache=self._gqa_cache_zeros(x.shape[0], cache_len) if make_cache else None,
                    pos=0 if make_cache else None)
                return y, (mamba_caches, kv)

            if cfg.remat:
                chunk_body = jax.checkpoint(chunk_body)
            x, (mamba_caches, shared_kv) = _scan(cfg, 
                chunk_body, x, (params["layers"]["stack"],))
            aux = aux0
            if make_cache:
                caches = {"stack": mamba_caches, "shared": shared_kv}
        elif fam == "vlm":
            memory = batch["patches"].astype(self.cdtype)

            def chunk_body(x, inp):
                p_self, p_cross, c_self = inp
                kv = attn.cross_kv(p_cross["xattn"], memory)
                x = blk.cross_block(p_cross, x, kv, cfg, gated=True)

                def inner(carry, inp2):
                    x = carry
                    p_i, c_i = inp2
                    y, nc, _ = blk.decoder_block(p_i, x, cfg, positions,
                                                 kind="dense", cache=c_i,
                                                 pos=0 if make_cache else None)
                    return y, nc

                x, ncs = _scan(cfg, inner, x, (p_self, c_self))
                return x, (ncs, kv)

            if cfg.remat:
                chunk_body = jax.checkpoint(chunk_body)
            n_cross = cfg.n_layers // cfg.cross_attn_period
            c_self = (self._attn_cache_zeros(B, cache_len,
                                             n=(n_cross, cfg.cross_attn_period))
                      if make_cache else None)
            x, (self_caches, cross_kvs) = _scan(cfg, 
                chunk_body, x, (params["layers"]["stack"],
                                params["layers"]["cross"], c_self))
            aux = aux0
            if make_cache:
                caches = {"stack": self_caches, "cross": cross_kvs}
        else:
            raise ValueError(fam)

        logits = self._head(params, x)
        return logits, caches, aux

    # ------------------------------------------------------------------ loss
    def loss(self, params: Pytree, batch: dict):
        logits, _, aux = self.apply(params, batch)
        targets = batch["targets"]
        mask = (targets >= 0)
        ce = softmax_cross_entropy(logits, jnp.maximum(targets, 0), mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # ----------------------------------------------------------- cache utils
    def _attn_cache_zeros(self, B, T, n=None):
        cfg = self.cfg
        if cfg.kv_lora_rank:
            struct = attn.mla_cache_shape(cfg, B, T, self.cdtype)
        else:
            struct = attn.gqa_cache_shape(cfg, B, T, self.cdtype)
        if n is not None:
            ns = n if isinstance(n, tuple) else (n,)
            struct = {k: jax.ShapeDtypeStruct(ns + v.shape, v.dtype)
                      for k, v in struct.items()}
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)

    def _gqa_cache_zeros(self, B, T):
        struct = attn.gqa_cache_shape(self.cfg, B, T, self.cdtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)

    def cache_struct(self, batch: int, cache_len: int):
        """ShapeDtypeStruct cache tree + logical axes tree (for the dry-run)."""
        cfg = self.cfg
        cdt = self.cdtype
        stackdim = lambda s, n: {k: jax.ShapeDtypeStruct((n,) + v.shape, v.dtype)
                                 for k, v in s.items()}
        add_axes = lambda a: {k: ("layers",) + tuple(v) for k, v in a.items()}
        fam = cfg.family
        if fam in ("dense", "moe"):
            if cfg.kv_lora_rank:
                one = attn.mla_cache_shape(cfg, batch, cache_len, cdt)
                ax = attn.mla_cache_axes()
            else:
                one = attn.gqa_cache_shape(cfg, batch, cache_len, cdt)
                ax = attn.gqa_cache_axes()
            n_stacked = cfg.n_layers - cfg.first_dense_layers
            struct = {"first": [one] * cfg.first_dense_layers,
                      "stack": stackdim(one, n_stacked)}
            axes = {"first": [ax] * cfg.first_dense_layers,
                    "stack": add_axes(ax)}
        elif fam == "ssm":
            one = ssm_mod.mamba2_cache_shape(cfg, batch, cdt)
            ax = ssm_mod.mamba2_cache_axes()
            struct = {"stack": stackdim(one, cfg.n_layers)}
            axes = {"stack": add_axes(ax)}
        elif fam == "hybrid":
            n_chunks = cfg.n_layers // cfg.attn_period
            m_one = ssm_mod.mamba2_cache_shape(cfg, batch, cdt)
            m_ax = ssm_mod.mamba2_cache_axes()
            m_struct = {k: jax.ShapeDtypeStruct((n_chunks, cfg.attn_period) + v.shape, v.dtype)
                        for k, v in m_one.items()}
            m_axes = {k: ("layers", "layers") + tuple(v) for k, v in m_ax.items()}
            a_one = attn.gqa_cache_shape(cfg, batch, cache_len, cdt)
            a_ax = attn.gqa_cache_axes()
            struct = {"stack": m_struct, "shared": stackdim(a_one, n_chunks)}
            axes = {"stack": m_axes, "shared": add_axes(a_ax)}
        elif fam == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_period
            one = attn.gqa_cache_shape(cfg, batch, cache_len, cdt)
            ax = attn.gqa_cache_axes()
            s_struct = {k: jax.ShapeDtypeStruct((n_cross, cfg.cross_attn_period) + v.shape, v.dtype)
                        for k, v in one.items()}
            s_axes = {k: ("layers", "layers") + tuple(v) for k, v in ax.items()}
            kv_one = {  # precomputed cross K/V over patches
                "k": jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.n_kv_heads, cfg.hd()), cdt),
                "v": jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.n_kv_heads, cfg.hd()), cdt),
            }
            kv_ax = {"k": ("batch", "patches", "kv_heads", None),
                     "v": ("batch", "patches", "kv_heads", None)}
            struct = {"stack": s_struct, "cross": stackdim(kv_one, n_cross)}
            axes = {"stack": s_axes, "cross": add_axes(kv_ax)}
        else:
            raise ValueError(fam)
        return struct, axes

    # ----------------------------------------------------------- decode step
    def decode_step(self, params: Pytree, caches: Pytree, tokens: jax.Array,
                    pos: jax.Array):
        """tokens [B, 1]; pos scalar int32 (write index). Returns
        (logits [B,1,V], new_caches)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._embed(params, tokens)
        positions = pos + jnp.arange(1)

        fam = cfg.family
        if fam in ("dense", "moe"):
            kind = block_kind(cfg)
            dense_kind = kind.replace("moe", "dense")
            new_first = []
            for p_i, c_i in zip(params["layers"]["first"], caches["first"]):
                x, nc, _ = blk.decoder_block(p_i, x, cfg, positions,
                                             kind=dense_kind, cache=c_i, pos=pos)
                new_first.append(nc)

            def body(x, inp):
                p_i, c_i = inp
                y, nc, _ = blk.decoder_block(p_i, x, cfg, positions, kind=kind,
                                             cache=c_i, pos=pos)
                return y, nc

            x, new_stack = _scan(cfg, 
                body, x, (params["layers"]["stack"], caches["stack"]))
            new_caches = {"first": new_first, "stack": new_stack}
        elif fam == "ssm":
            def body(x, inp):
                p_i, c_i = inp
                y, nc = blk.mamba_block(p_i, x, cfg, cache=c_i, decode=True)
                return y, nc

            x, new_stack = _scan(cfg, 
                body, x, (params["layers"]["stack"], caches["stack"]))
            new_caches = {"stack": new_stack}
        elif fam == "hybrid":
            x0 = x
            shared_p = params["layers"]["shared"]

            def chunk_body(x, inp):
                p_chunk, c_chunk, kv_i = inp

                def inner(x, inp2):
                    p_i, c_i = inp2
                    y, nc = blk.mamba_block(p_i, x, cfg, cache=c_i, decode=True)
                    return y, nc

                x, m_caches = _scan(cfg, inner, x, (p_chunk, c_chunk))
                y, kv = blk.zamba_shared_block(shared_p, x, x0, cfg, positions,
                                               cache=kv_i, pos=pos)
                return y, (m_caches, kv)

            x, (m_caches, kvs) = _scan(cfg, 
                chunk_body, x, (params["layers"]["stack"], caches["stack"],
                                caches["shared"]))
            new_caches = {"stack": m_caches, "shared": kvs}
        elif fam == "vlm":
            def chunk_body(x, inp):
                p_self, p_cross, c_self, kv_i = inp
                x = blk.cross_block(p_cross, x, kv_i, cfg, gated=True)

                def inner(x, inp2):
                    p_i, c_i = inp2
                    y, nc, _ = blk.decoder_block(p_i, x, cfg, positions,
                                                 kind="dense", cache=c_i, pos=pos)
                    return y, nc

                x, ncs = _scan(cfg, inner, x, (p_self, c_self))
                return x, (ncs, kv_i)

            x, (self_caches, kvs) = _scan(cfg, 
                chunk_body, x, (params["layers"]["stack"], params["layers"]["cross"],
                                caches["stack"], caches["cross"]))
            new_caches = {"stack": self_caches, "cross": kvs}
        else:
            raise ValueError(fam)

        logits = self._head(params, x)
        return logits, new_caches
