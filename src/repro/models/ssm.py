"""Mamba2 (state-space duality) layer: chunked SSD for train/prefill, O(1)
recurrent step for decode.

Follows the ssd_minimal discrete formulation of Dao & Gu (arXiv:2405.21060):
within a chunk the dual (attention-like) quadratic form is used; across
chunks the SSM state is carried with ``lax.scan``. ngroups=1 (B/C shared
across heads) as in the published mamba2-370m config.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory, rms_norm
from repro.sharding import shard_act


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm_headdim


def conv_dim(cfg: ModelConfig) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba2(pf: ParamFactory, cfg: ModelConfig) -> None:
    D, di, H = cfg.d_model, d_inner(cfg), n_ssm_heads(cfg)
    cd, W = conv_dim(cfg), cfg.ssm_conv
    d_proj = 2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state + H
    pf.param("in_proj", (D, d_proj), ("d_model", "ffn"))
    pf.param("conv_w", (W, cd), (None, "ffn"))
    pf.param("conv_b", (cd,), ("ffn",), init="zeros")
    pf.param("dt_bias", (H,), ("ssm_heads",), init="ssm_dt")
    pf.param("A_log", (H,), ("ssm_heads",), init="ssm_a")
    pf.param("D_skip", (H,), ("ssm_heads",), init="ones")
    pf.param("norm_w", (di,), ("ffn",), init="ones")
    pf.param("out_proj", (di, D), ("ffn", "d_model"))


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    di, H, gn = d_inner(cfg), n_ssm_heads(cfg), cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array):
    """Depthwise causal conv, window W (unrolled; W=4). xBC [B,S,Cd]."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = b.astype(xBC.dtype)
    acc = jnp.zeros_like(xBC) + out
    for i in range(W):
        acc = acc + pad[:, i:i + S, :] * w[i].astype(xBC.dtype)
    return jax.nn.silu(acc)


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] log-decays -> [..., Q, Q] lower-triangular segment sums,
    L[q, s] = sum_{j=s+1..q} a_j for q >= s, -inf above diagonal."""
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    Q = a.shape[-1]
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xd: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, h0: Optional[jax.Array] = None):
    """xd [B,S,H,P] (already dt-discretized), a [B,S,H] log decay (dt*A),
    Bm/Cm [B,S,N] (ngroups=1). Returns (y [B,S,H,P], final state [B,H,P,N])."""
    Bb, S, H, Pd = xd.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    r = lambda t: t.reshape(Bb, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    xd_c, a_c, B_c, C_c = r(xd), r(a), r(Bm), r(Cm)   # leading chunk axis for scan
    if h0 is None:
        h0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)

    def body(h, inp):
        x_i, a_i, b_i, c_i = inp                       # [B,Q,H,P],[B,Q,H],[B,Q,N],[B,Q,N]
        a_i = a_i.astype(jnp.float32)
        cs = jnp.cumsum(a_i, axis=1)                   # [B,Q,H]
        L = jnp.exp(_segsum(a_i.transpose(0, 2, 1)))   # [B,H,Q,Q]
        xf = x_i.astype(jnp.float32)
        bf, cf = b_i.astype(jnp.float32), c_i.astype(jnp.float32)
        y_diag = jnp.einsum("bqn,bkn,bhqk,bkhp->bqhp", cf, bf, L, xf)
        decay_states = jnp.exp(cs[:, -1:, :] - cs)     # [B,Q,H]
        state_c = jnp.einsum("bkn,bkh,bkhp->bhpn", bf, decay_states, xf)
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", cf, h, jnp.exp(cs))
        h_new = h * jnp.exp(cs[:, -1, :])[:, :, None, None] + state_c
        return h_new, (y_diag + y_off).astype(xd.dtype)

    h_final, ys = jax.lax.scan(body, h0, (xd_c, a_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(Bb, S, H, Pd)
    return y, h_final


def ssd_reference(xd, a, Bm, Cm):
    """O(S^2) dual-form oracle for tests: y_t = sum_{s<=t} C_t.B_s exp(sum a) x_s."""
    Bb, S, H, Pd = xd.shape
    af = a.astype(jnp.float32).transpose(0, 2, 1)           # [B,H,S]
    L = jnp.exp(_segsum(af))                                 # [B,H,S,S]
    return jnp.einsum("bqn,bkn,bhqk,bkhp->bqhp",
                      Cm.astype(jnp.float32), Bm.astype(jnp.float32), L,
                      xd.astype(jnp.float32)).astype(xd.dtype)


def mamba2_forward(p: dict, x: jax.Array, cfg: ModelConfig, *,
                   cache: Optional[dict] = None):
    """Full-sequence path (train/prefill). Returns (y, new_cache or None).

    When ``cache`` is given its final SSM/conv states are produced so decode
    can continue (prefill -> decode handoff).
    """
    B, S, D = x.shape
    di, H, Pd, N = d_inner(cfg), n_ssm_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xBC_raw, dt = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, Pd)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = dt * A                                               # [B,S,H] log decay
    xd = xs * dt.astype(xs.dtype)[..., None]
    xd = shard_act(xd, ("batch", "seq", "ssm_heads", None))
    # largest chunk <= configured that divides S (odd lengths degrade
    # gracefully toward the pure recurrence instead of asserting)
    chunk = min(cfg.ssm_chunk, S)
    while S % chunk:
        chunk -= 1
    y, h_final = ssd_chunked(xd, a, Bm, Cm, chunk)
    y = y + xs * p["D_skip"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    out = shard_act(out, ("batch", "seq", "d_model"))
    new_cache = None
    if cache is not None:
        # conv cache stores the raw (pre-activation) trailing window inputs
        W = cfg.ssm_conv
        conv_tail = xBC_raw[:, max(0, S - (W - 1)):, :]
        if conv_tail.shape[1] < W - 1:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (W - 1 - conv_tail.shape[1], 0), (0, 0)))
        new_cache = {"h": h_final, "conv": conv_tail}
    return out, new_cache


def mamba2_decode_step(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict):
    """x: [B, 1, D]; cache: {'h': [B,H,P,N] fp32, 'conv': [B, W-1, Cd]}."""
    B = x.shape[0]
    di, H, Pd, N, W = d_inner(cfg), n_ssm_heads(cfg), cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xBC_raw, dt = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([cache["conv"], xBC_raw], axis=1)    # [B, W, Cd]
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype)) \
        + p["conv_b"].astype(x.dtype)
    xBC = jax.nn.silu(conv_out)                                    # [B, Cd]
    xs = xBC[..., :di].reshape(B, H, Pd)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * A)                                       # [B,H]
    xf = xs.astype(jnp.float32)
    h_new = cache["h"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", Bm.astype(jnp.float32), dtv, xf)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h_new)
    y = (y + xf * p["D_skip"].astype(jnp.float32)[None, :, None]).astype(x.dtype)
    y = y.reshape(B, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    new_conv = window[:, 1:, :]
    return out, {"h": h_new, "conv": new_conv}


def mamba2_cache_shape(cfg: ModelConfig, batch: int, dtype):
    H, Pd, N, W = n_ssm_heads(cfg), cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jax.ShapeDtypeStruct((batch, H, Pd, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, W - 1, conv_dim(cfg)), dtype),
    }


def mamba2_cache_axes():
    return {"h": ("batch", "ssm_heads", None, "state"),
            "conv": ("batch", None, "ffn")}
