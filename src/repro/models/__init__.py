from repro.models.api import build_model, input_specs  # noqa: F401
