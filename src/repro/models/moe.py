"""Mixture-of-Experts layer with sort-based (dropping) token dispatch.

Dispatch is gather/scatter based (MegaBlocks/MaxText style) rather than the
one-hot ``einsum`` dispatch: tokens are routed top-k, assignments are sorted
by expert id, positions within each expert are computed from exclusive
cumsum of expert counts, and tokens beyond ``capacity`` are dropped. Expert
GEMMs then run as clean batched matmuls ``[E, C, D] x [E, D, F]`` which (a)
keeps HLO FLOPs ~= useful FLOPs and (b) gives GSPMD an explicit ``experts``
dim to shard over the ``model`` axis (expert parallelism).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory
from repro.sharding import shard_act


def init_moe(pf: ParamFactory, cfg: ModelConfig) -> None:
    d, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    pf.param("router", (d, E), ("d_model", "experts"), scale=0.02)
    pf.param("w_gate", (E, d, F), ("experts", "d_model", "ffn"))
    pf.param("w_up", (E, d, F), ("experts", "d_model", "ffn"))
    pf.param("w_down", (E, F, d), ("experts", "ffn", "d_model"))
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        pf.param("ws_gate", (d, Fs), ("d_model", "ffn"))
        pf.param("ws_up", (d, Fs), ("d_model", "ffn"))
        pf.param("ws_down", (Fs, d), ("ffn", "d_model"))


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: [B, S, D]. Returns (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, D)

    # -- routing (fp32) ------------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                       # [T, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(top_e, E, dtype=jnp.float32)).sum(1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens * frac_probs)

    # -- sort-based dispatch ---------------------------------------------------
    flat_e = top_e.reshape(T * K)
    flat_w = top_w.reshape(T * K)
    order = jnp.argsort(flat_e)                                   # [T*K]
    sorted_e = flat_e[order]
    src_token = order // K                                        # token of each sorted assignment
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=0)   # [E]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)        # E*C == drop bin

    # scatter token ids into [E*C] slots (dropped -> slot E*C, sliced off)
    slot_token = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(src_token)
    slot_valid = jnp.zeros((E * C + 1,), jnp.bool_).at[slot].set(keep)
    slot_token, slot_valid = slot_token[:-1], slot_valid[:-1]

    gathered = xf[slot_token] * slot_valid[:, None].astype(x.dtype)
    ge = gathered.reshape(E, C, D)
    ge = shard_act(ge, ("experts", "expert_cap", "d_model"))

    # -- expert GEMMs ----------------------------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ge, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", ge, p["w_up"].astype(x.dtype))
    h = shard_act(h, ("experts", "expert_cap", "ffn"))
    oe = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    oe = shard_act(oe, ("experts", "expert_cap", "d_model"))

    # -- combine ---------------------------------------------------------------
    out_flat = oe.reshape(E * C, D)
    contrib = out_flat[jnp.clip(slot, 0, E * C - 1)]
    contrib = contrib * (flat_w[order] * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[src_token].add(contrib)

    # -- shared experts (always-on dense path) ---------------------------------
    if cfg.n_shared_experts:
        hs = jax.nn.silu(xf @ p["ws_gate"].astype(x.dtype)) * (xf @ p["ws_up"].astype(x.dtype))
        y = y + hs @ p["ws_down"].astype(x.dtype)

    y = y.reshape(B, S, D)
    return shard_act(y, ("batch", "seq", "d_model")), aux


# Pure-jnp reference (einsum one-hot dispatch) for property tests ------------


def moe_reference(p: dict, x: jax.Array, cfg: ModelConfig):
    """O(E x T) masked-dense reference: every expert sees every token; the
    top-k weights select. No capacity drops -> compare with high capacity."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(B * S, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    w_te = jnp.zeros((xf.shape[0], E), jnp.float32)
    w_te = jax.vmap(lambda w, e, row: row.at[e].add(w))(top_w, top_e, w_te)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("td,edf->tef", xf, p["w_up"].astype(x.dtype))
    oe = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("ted,te->td", oe.astype(jnp.float32), w_te).astype(x.dtype)
    if cfg.n_shared_experts:
        hs = jax.nn.silu(xf @ p["ws_gate"].astype(x.dtype)) * (xf @ p["ws_up"].astype(x.dtype))
        y = y + hs @ p["ws_down"].astype(x.dtype)
    return y.reshape(B, S, D)
