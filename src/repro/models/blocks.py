"""Residual block compositions used by every architecture family."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ParamFactory, rms_norm, swiglu
from repro.sharding import shard_act


# -- dense FFN ----------------------------------------------------------------


def init_ffn(pf: ParamFactory, d_model: int, d_ff: int) -> None:
    pf.param("w_gate", (d_model, d_ff), ("d_model", "ffn"))
    pf.param("w_up", (d_model, d_ff), ("d_model", "ffn"))
    pf.param("w_down", (d_ff, d_model), ("ffn", "d_model"))


def ffn_forward(p: dict, x: jax.Array) -> jax.Array:
    h = swiglu(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype)),
               jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)))
    h = shard_act(h, ("batch", "seq", "ffn"))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return shard_act(y, ("batch", "seq", "d_model"))


# -- standard decoder block (GQA or MLA attention + dense FFN or MoE) ---------


def init_decoder_block(pf: ParamFactory, cfg: ModelConfig, *, kind: str) -> None:
    """kind: 'dense' | 'moe' | 'mla_dense' | 'mla_moe'."""
    d = cfg.d_model
    pf.param("ln_attn", (d,), ("d_model",), init="ones")
    pf.param("ln_mlp", (d,), ("d_model",), init="ones")
    with pf.scope("attn"):
        if kind.startswith("mla"):
            attn.init_mla(pf, cfg)
        else:
            attn.init_gqa(pf, cfg)
    with pf.scope("mlp"):
        if kind.endswith("moe"):
            moe_mod.init_moe(pf, cfg)
            if cfg.dense_residual:
                with pf.scope("dense_res"):
                    init_ffn(pf, d, cfg.d_ff)
        else:
            init_ffn(pf, d, cfg.d_ff)


def decoder_block(p: dict, x: jax.Array, cfg: ModelConfig, positions, *,
                  kind: str, cache: Optional[dict] = None, pos=None,
                  causal: bool = True):
    """Returns (y, new_cache, aux_loss)."""
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    attn_fn = attn.mla_forward if kind.startswith("mla") else attn.gqa_forward
    a, new_cache = attn_fn(p["attn"], h, cfg, positions, cache=cache, pos=pos,
                           causal=causal)
    x = x + a
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind.endswith("moe"):
        m, aux = moe_mod.moe_forward(p["mlp"], h, cfg)
        if cfg.dense_residual:
            m = m + ffn_forward(p["mlp"]["dense_res"], h)
    else:
        m = ffn_forward(p["mlp"], h)
    return x + m, new_cache, aux


# -- mamba2 block --------------------------------------------------------------


def init_mamba_block(pf: ParamFactory, cfg: ModelConfig) -> None:
    pf.param("ln", (cfg.d_model,), ("d_model",), init="ones")
    with pf.scope("mixer"):
        ssm_mod.init_mamba2(pf, cfg)


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[dict] = None, decode: bool = False):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if decode:
        y, new_cache = ssm_mod.mamba2_decode_step(p["mixer"], h, cfg, cache)
    else:
        y, new_cache = ssm_mod.mamba2_forward(p["mixer"], h, cfg, cache=cache)
    return x + y, new_cache


# -- zamba2 shared attention block ---------------------------------------------
# The shared block consumes concat(hidden, initial_embedding) (Zamba trick),
# projects back to d_model, then runs a full transformer block with weights
# shared across all applications.


def init_zamba_shared(pf: ParamFactory, cfg: ModelConfig) -> None:
    d = cfg.d_model
    pf.param("w_concat", (2 * d, d), ("d_model", None))
    pf.param("ln_in", (2 * d,), ("d_model",), init="ones")
    init_decoder_block(pf, cfg, kind="dense")


def zamba_shared_block(p: dict, x: jax.Array, x0: jax.Array, cfg: ModelConfig,
                       positions, *, cache=None, pos=None, causal=True):
    h = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(h, p["ln_in"], cfg.norm_eps)
    h = jnp.einsum("bse,ed->bsd", h, p["w_concat"].astype(x.dtype))
    y, new_cache, _ = decoder_block(p, h, cfg, positions, kind="dense",
                                    cache=cache, pos=pos, causal=causal)
    return x + (y - h), new_cache  # residual on the block's delta


# -- cross-attention block (vision / enc-dec) -----------------------------------


def init_cross_block(pf: ParamFactory, cfg: ModelConfig, *, gated: bool) -> None:
    d = cfg.d_model
    pf.param("ln", (d,), ("d_model",), init="ones")
    with pf.scope("xattn"):
        attn.init_cross(pf, cfg, gated=gated)
    pf.param("ln_mlp", (d,), ("d_model",), init="ones")
    with pf.scope("mlp"):
        init_ffn(pf, d, cfg.d_ff)


def cross_block(p: dict, x: jax.Array, kv: dict, cfg: ModelConfig, *,
                gated: bool) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    x = x + attn.cross_forward(p["xattn"], h, kv, gated=gated)
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    return x + ffn_forward(p["mlp"], h)
