"""Shared model primitives: parameter factory with logical sharding axes,
norms, rotary embeddings, initializers, losses.

Parameters are plain nested dicts of jnp arrays. Alongside the value tree,
:class:`ParamFactory` builds a parallel tree of *logical axis names* (one
tuple per leaf, same structure) which ``repro.sharding.rules`` later maps to
mesh ``PartitionSpec``s. This keeps model code declarative about parallelism
without ever hard-coding a mesh axis.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Pytree = Any

# ----------------------------------------------------------------------------
# Parameter factory
# ----------------------------------------------------------------------------


class ParamFactory:
    """Accumulates (value, logical-axes) parameter trees under nested scopes.

    Usage::

        pf = ParamFactory(rng, dtype=jnp.float32)
        with pf.scope("attn"):
            wq = pf.param("wq", (d, h, hd), ("d_model", "heads", "head_dim"))
        params, axes = pf.build()
    """

    def __init__(self, rng: jax.Array, dtype=jnp.float32, abstract: bool = False):
        self._rng = rng
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict = {}
        self.axes: dict = {}
        self._path: list[str] = []

    # -- scoping -------------------------------------------------------------
    def scope(self, name: str):
        factory = self

        class _Scope:
            def __enter__(self):
                factory._path.append(name)

            def __exit__(self, *exc):
                factory._path.pop()

        return _Scope()

    def _insert(self, tree: dict, name: str, leaf):
        node = tree
        for p in self._path:
            node = node.setdefault(p, {})
        if name in node:
            raise ValueError(f"duplicate param {'/'.join(self._path + [name])}")
        node[name] = leaf

    def _next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    # -- creation ------------------------------------------------------------
    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        logical_axes: tuple[Optional[str], ...],
        init: str = "normal",
        scale: Optional[float] = None,
    ) -> jax.Array:
        assert len(shape) == len(logical_axes), (name, shape, logical_axes)
        if self.abstract:
            value = jax.ShapeDtypeStruct(shape, self.dtype)
        else:
            value = _initialize(self._next_rng(), shape, self.dtype, init, scale)
        self._insert(self.params, name, value)
        self._insert(self.axes, name, tuple(logical_axes))
        return value

    def build(self) -> tuple[dict, dict]:
        return self.params, self.axes


def _initialize(rng, shape, dtype, init: str, scale: Optional[float]):
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    if init == "normal":
        # fan-in scaled truncated normal; fan_in = prod of all but the last
        # dim (correct for conv HWIO and fused [in, heads, hd] projections)
        fan_in = 1
        for d in shape[:-1]:
            fan_in *= d
        if len(shape) < 2:
            fan_in = shape[-1] if shape else 1
        std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)
    if init == "embed":
        std = scale if scale is not None else 0.02
        return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)
    if init == "ssm_dt":
        # dt bias init: softplus^-1 of uniform in [1e-3, 1e-1]
        lo, hi = 1e-3, 1e-1
        u = jax.random.uniform(rng, shape, jnp.float32, lo, hi)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if init == "ssm_a":
        # A_log init: log of uniform in [1, 16]
        u = jax.random.uniform(rng, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    raise ValueError(f"unknown init {init}")


def map_axes(axes_tree: Pytree, fn: Callable[[tuple], tuple]) -> Pytree:
    """tree.map over an axes tree whose leaves are tuples of axis names."""
    return jax.tree.map(fn, axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def init_stacked(init_fn: Callable, rng: jax.Array, n: int, dtype, *args) -> tuple[dict, dict]:
    """Initialize ``n`` stacked copies of a block along a leading 'layers' axis.

    ``init_fn(pf, *args)`` registers a single block's params on a
    :class:`ParamFactory`. Returns (stacked params, axes with 'layers'
    prepended). Stacked layers are consumed with ``lax.scan``.
    """

    def one(r):
        pf = ParamFactory(r, dtype)
        init_fn(pf, *args)
        return pf.params

    params = jax.vmap(one)(jax.random.split(rng, n))
    pf_abs = ParamFactory(rng, dtype, abstract=True)
    init_fn(pf_abs, *args)
    axes = map_axes(pf_abs.axes, lambda a: ("layers",) + tuple(a))
    return params, axes


# ----------------------------------------------------------------------------
# Norms / activations
# ----------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight + bias).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               has_heads: bool = True) -> jax.Array:
    """x: [..., S, H, hd] (has_heads) or [..., S, hd]; positions [S] or [B, S].

    Applies rotary embedding over the final dim (split-half convention).
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    if has_heads:
        angles = angles[..., :, None, :]  # broadcast over the heads axis
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Losses / metrics
# ----------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """Mean next-token cross entropy. logits [..., V] fp32-accumulated."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def count_params(params: Pytree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
