"""Model factory + abstract input specs (ShapeDtypeStructs for the dry-run)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family.startswith("paper"):
        from repro.models.paper_models import build_paper_model
        return build_paper_model(cfg.name)
    from repro.models.lm import DecoderLM
    return DecoderLM(cfg)


def _cdtype(cfg: ModelConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.compute_dtype]


class LMClientAdapter:
    """Adapts a DecoderLM to the FL client interface (loss/accuracy over
    {'x': tokens [B,S], 'y': targets [B,S]}), so the Apodotiko controller can
    federate any assigned architecture (examples/train_fl_lm.py)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.lm = build_model(cfg)

    def init(self, rng):
        return self.lm.init(rng)

    def loss(self, params, batch):
        return self.lm.loss(params, {"tokens": batch["x"],
                                     "targets": batch["y"]})

    def accuracy(self, params, batch):
        logits, _, _ = self.lm.apply(params, {"tokens": batch["x"]})
        pred = jnp.argmax(logits, axis=-1)
        mask = batch["y"] >= 0
        return (jnp.sum((pred == batch["y"]) * mask)
                / jnp.maximum(jnp.sum(mask), 1)).astype(jnp.float32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """Returns (batch ShapeDtypeStruct tree, logical-axes tree) for the
    full-sequence entry points (train/prefill). Decode inputs come from the
    model's ``cache_struct`` (see launch/steps.py)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    batch: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    if cfg.family == "encdec":
        batch["frames"] = sd((B, S, cfg.d_model), _cdtype(cfg))
        axes["frames"] = ("batch", "seq", "d_model")
        batch["tokens"] = sd((B, S), i32)
        axes["tokens"] = ("batch", "seq")
    else:
        batch["tokens"] = sd((B, S), i32)
        axes["tokens"] = ("batch", "seq")
        if cfg.family == "vlm":
            batch["patches"] = sd((B, cfg.n_patches, cfg.d_model), _cdtype(cfg))
            axes["patches"] = ("batch", "patches", "d_model")
    if shape.kind == "train":
        batch["targets"] = sd(batch["tokens"].shape, i32)
        axes["targets"] = ("batch", "seq")
    return batch, axes
