"""Bench-scale proxy client models.

The paper's exact models (repro.models.paper_models) cost ~150 s per
simulated round on this 1-core CPU container — fine for unit tests, far too
slow for the 6-strategy x 4-dataset benchmark grid. These proxies keep the
same API/loss surface and non-IID learning dynamics at ~100x less compute
(benchmarks pass ``--fidelity paper`` to use the exact models instead).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory, softmax_cross_entropy
from repro.models.paper_models import _ClassifierBase, _apply_conv, _conv, _maxpool


class ProxyCNN(_ClassifierBase):
    """Small 2-conv CNN on 8x8x1 inputs."""

    def __init__(self, n_classes: int, c1: int = 8, c2: int = 16, fc: int = 32):
        self.n_classes = n_classes
        self.input_shape = (8, 8, 1)
        self.c1, self.c2, self.fc = c1, c2, fc

    def init(self, rng):
        pf = ParamFactory(rng, jnp.float32)
        _conv(pf, "c1", 3, 1, self.c1)
        _conv(pf, "c2", 3, self.c1, self.c2)
        pf.param("fc1_w", (2 * 2 * self.c2, self.fc), ("d_model", "ffn"))
        pf.param("fc1_b", (self.fc,), ("ffn",), init="zeros")
        pf.param("fc2_w", (self.fc, self.n_classes), ("ffn", "vocab"))
        pf.param("fc2_b", (self.n_classes,), ("vocab",), init="zeros")
        return pf.params, pf.axes

    def predict(self, p, x):
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c1", x, "SAME")))   # 8->4
        x = _maxpool(jax.nn.relu(_apply_conv(p, "c2", x, "SAME")))   # 4->2
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["fc1_w"] + p["fc1_b"])
        return x @ p["fc2_w"] + p["fc2_b"]


class ProxyLSTM:
    """Next-char model on short sequences: embed -> LSTM(h) -> dense(vocab)."""

    def __init__(self, vocab: int = 82, seq_len: int = 20, emb: int = 8,
                 hidden: int = 64):
        self.vocab = vocab
        self.n_classes = vocab
        self.seq_len = seq_len
        self.emb = emb
        self.hidden = hidden

    def init(self, rng):
        pf = ParamFactory(rng, jnp.float32)
        pf.param("embed", (self.vocab, self.emb), ("vocab", "d_model"), init="embed")
        pf.param("wx", (self.emb, 4 * self.hidden), ("d_model", "ffn"))
        pf.param("wh", (self.hidden, 4 * self.hidden), ("d_model", "ffn"))
        pf.param("b", (4 * self.hidden,), ("ffn",), init="zeros")
        pf.param("out_w", (self.hidden, self.vocab), ("d_model", "vocab"))
        pf.param("out_b", (self.vocab,), ("vocab",), init="zeros")
        return pf.params, pf.axes

    def predict(self, p, x):
        e = jnp.take(p["embed"], x, axis=0).swapaxes(0, 1)  # [S, B, emb]
        B = e.shape[1]
        h0 = jnp.zeros((B, self.hidden), e.dtype)
        c0 = jnp.zeros((B, self.hidden), e.dtype)

        def step(carry, xt):
            h, c = carry
            gates = xt @ p["wx"] + h @ p["wh"] + p["b"]
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), None

        (h, _), _ = jax.lax.scan(step, (h0, c0), e)
        return h @ p["out_w"] + p["out_b"]

    def loss(self, params, batch):
        logits = self.predict(params, batch["x"])
        ce = softmax_cross_entropy(logits, batch["y"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
        return ce, {"ce": ce, "acc": acc}

    def accuracy(self, params, batch):
        logits = self.predict(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


def build_bench_model(dataset: str, fidelity: str = "proxy"):
    """Model for a (paper) dataset at the requested fidelity."""
    if fidelity == "paper":
        from repro.models.paper_models import build_paper_model
        return build_paper_model(f"paper-{dataset}")
    n_classes = {"mnist": 10, "femnist": 62, "speech": 35}
    if dataset == "shakespeare":
        return ProxyLSTM(vocab=82, seq_len=20)
    return ProxyCNN(n_classes[dataset])
