"""Encoder-decoder LM (seamless-m4t family). The audio frontend is a stub per
the assignment: ``input_specs()`` provides precomputed frame embeddings
[B, S, d_model]; the transformer backbone (encoder self-attn, decoder
self+cross attn) is real.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import blocks as blk
from repro.models.common import (
    ParamFactory,
    init_stacked,
    rms_norm,
    softmax_cross_entropy,
)
from repro.sharding import shard_act

Pytree = Any


def _scan(cfg: ModelConfig, body, carry, xs):
    """Layer scan; fully unrolled when cfg.unroll_layers (see launch/dryrun)."""
    return jax.lax.scan(body, carry, xs,
                        unroll=True if cfg.unroll_layers else 1)


def _init_dec_block(pf: ParamFactory, cfg: ModelConfig) -> None:
    d = cfg.d_model
    pf.param("ln_self", (d,), ("d_model",), init="ones")
    with pf.scope("self"):
        attn.init_gqa(pf, cfg)
    pf.param("ln_cross", (d,), ("d_model",), init="ones")
    with pf.scope("cross"):
        attn.init_cross(pf, cfg, gated=False)
    pf.param("ln_mlp", (d,), ("d_model",), init="ones")
    with pf.scope("mlp"):
        blk.init_ffn(pf, d, cfg.d_ff)


def _dec_block(p: dict, x, enc_kv, cfg: ModelConfig, positions, *,
               cache=None, pos=None):
    h = rms_norm(x, p["ln_self"], cfg.norm_eps)
    a, new_cache = attn.gqa_forward(p["self"], h, cfg, positions, cache=cache,
                                    pos=pos, causal=True)
    x = x + a
    h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
    x = x + attn.cross_forward(p["cross"], h, enc_kv, gated=False)
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    return x + blk.ffn_forward(p["mlp"], h), new_cache


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.param_dtype]
        self.cdtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.compute_dtype]

    # ------------------------------------------------------------------ init
    def init(self, rng: jax.Array):
        cfg = self.cfg
        r_e, r_enc, r_dec, r_h = jax.random.split(rng, 4)
        pf = ParamFactory(r_e, self.pdtype)
        pf.param("tok_embed", (cfg.vocab_size, cfg.d_model), ("vocab", "d_model"),
                 init="embed")
        pf.param("ln_enc", (cfg.d_model,), ("d_model",), init="ones")
        pf.param("ln_f", (cfg.d_model,), ("d_model",), init="ones")
        pf.param("head", (cfg.d_model, cfg.vocab_size), ("d_model", "vocab"))
        params, axes = pf.params, pf.axes
        enc, enc_axes = init_stacked(
            lambda pf_: blk.init_decoder_block(pf_, cfg, kind="dense"),
            r_enc, cfg.enc_layers, self.pdtype)
        dec, dec_axes = init_stacked(
            lambda pf_: _init_dec_block(pf_, cfg), r_dec, cfg.dec_layers,
            self.pdtype)
        params["encoder"], axes["encoder"] = enc, enc_axes
        params["decoder"], axes["decoder"] = dec, dec_axes
        return params, axes

    # ---------------------------------------------------------------- encode
    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(self.cdtype)
        x = shard_act(x, ("batch", "seq", "d_model"))
        positions = jnp.arange(x.shape[1])

        def body(x, inp):
            p_i, = inp
            y, _, _ = blk.decoder_block(p_i, x, cfg, positions, kind="dense",
                                        causal=False)
            return y, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = _scan(cfg, body, x, (params["encoder"],))
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    # ------------------------------------------------------------- full pass
    def apply(self, params, batch: dict, *, make_cache: bool = False,
              cache_len: Optional[int] = None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["tok_embed"], tokens, axis=0).astype(self.cdtype)
        x = shard_act(x, ("batch", "seq", "d_model"))
        positions = jnp.arange(S)
        cache_len = cache_len or S

        def body(x, inp):
            p_i, c_i = inp
            kv = attn.cross_kv(p_i["cross"], enc_out)
            y, nc = _dec_block(p_i, x, kv, cfg, positions, cache=c_i,
                               pos=0 if make_cache else None)
            return y, nc

        if cfg.remat:
            body = jax.checkpoint(body)
        caches_in = None
        if make_cache:
            one = attn.gqa_cache_shape(cfg, B, cache_len, self.cdtype)
            caches_in = jax.tree.map(
                lambda s: jnp.zeros((cfg.dec_layers,) + s.shape, s.dtype), one)
        x, new_caches = _scan(cfg, body, x, (params["decoder"], caches_in))
        logits = self._head(params, x)
        caches = None
        if make_cache:
            cross = jax.vmap(lambda p: attn.cross_kv(p["cross"], enc_out))(
                params["decoder"])
            caches = {"self": new_caches, "cross": cross}
        return logits, caches, jnp.zeros((), jnp.float32)

    def _head(self, params, x):
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
        return shard_act(logits, ("batch", "seq", "vocab"))

    def loss(self, params, batch: dict):
        logits, _, aux = self.apply(params, batch)
        targets = batch["targets"]
        mask = targets >= 0
        ce = softmax_cross_entropy(logits, jnp.maximum(targets, 0), mask)
        return ce + aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving
    def cache_struct(self, batch: int, cache_len: int, enc_len: int):
        cfg = self.cfg
        cdt = self.cdtype
        one = attn.gqa_cache_shape(cfg, batch, cache_len, cdt)
        self_struct = {k: jax.ShapeDtypeStruct((cfg.dec_layers,) + v.shape, v.dtype)
                       for k, v in one.items()}
        self_axes = {k: ("layers",) + tuple(v)
                     for k, v in attn.gqa_cache_axes().items()}
        kv = {
            "k": jax.ShapeDtypeStruct((cfg.dec_layers, batch, enc_len,
                                       cfg.n_kv_heads, cfg.hd()), cdt),
            "v": jax.ShapeDtypeStruct((cfg.dec_layers, batch, enc_len,
                                       cfg.n_kv_heads, cfg.hd()), cdt),
        }
        kv_axes = {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
                   "v": ("layers", "batch", "kv_seq", "kv_heads", None)}
        return ({"self": self_struct, "cross": kv},
                {"self": self_axes, "cross": kv_axes})

    def decode_step(self, params, caches, tokens: jax.Array, pos: jax.Array):
        cfg = self.cfg
        x = jnp.take(params["tok_embed"], tokens, axis=0).astype(self.cdtype)
        positions = pos + jnp.arange(1)

        def body(x, inp):
            p_i, c_i, kv_i = inp
            y, nc = _dec_block(p_i, x, kv_i, cfg, positions, cache=c_i, pos=pos)
            return y, (nc, kv_i)

        x, (new_self, kvs) = _scan(cfg, 
            body, x, (params["decoder"], caches["self"], caches["cross"]))
        logits = self._head(params, x)
        return logits, {"self": new_self, "cross": kvs}
