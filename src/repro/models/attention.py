"""Attention variants: GQA (with optional qk-norm), DeepSeek MLA, cross-attn.

All attention functions are functional: ``forward(params, x, ...)`` and
optionally take/return a KV cache dict for decode. Caches use a fixed-size
sequence buffer with a scalar write position ``pos`` (the assigned decode
shapes model "one new token against a cache of seq_len", so the buffer is
allocated at seq_len and attention masks to ``index <= pos``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory, apply_rope, rms_norm
from repro.sharding import shard_act

NEG_INF = -2.0**30


# ----------------------------------------------------------------------------
# GQA
# ----------------------------------------------------------------------------


def init_gqa(pf: ParamFactory, cfg: ModelConfig, *, rope: bool = True) -> None:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    pf.param("wq", (d, h, hd), ("d_model", "heads", "head_dim"))
    pf.param("wk", (d, k, hd), ("d_model", "kv_heads", "head_dim"))
    pf.param("wv", (d, k, hd), ("d_model", "kv_heads", "head_dim"))
    pf.param("wo", (h, hd, d), ("heads", "head_dim", "d_model"))
    if cfg.qk_norm:
        pf.param("q_norm", (hd,), ("head_dim",), init="ones")
        pf.param("k_norm", (hd,), ("head_dim",), init="ones")


def _gqa_core(q, k, v, *, causal: bool, q_pos=None, kv_valid=None,
              seq_parallel: bool = False):
    """q [B,S,H,hd], k/v [B,T,K,hd]; GQA grouping H = K*g. Returns [B,S,H,hd].

    K/V are expanded to per-query-head layout (repeat by g) so tensor
    parallelism shards attention over the H query heads even when K does not
    divide the model axis (e.g. kv=8 on a 16-way mesh).

    ``seq_parallel`` (decode): the KV cache is kv_seq-sharded over the model
    axis; replicate the (tiny) q instead of gathering the (huge) cache —
    logits stay T-sharded, softmax reduces with small cross-shard max/sum
    collectives, and the value contraction psums a [B,H,S,hd] vector. This
    removed the per-step full-cache all-gather (perf iteration #2,
    EXPERIMENTS.md §Perf).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if seq_parallel:
        q = shard_act(q, ("batch", None, None, None))       # replicate heads
        k = shard_act(k, ("batch", "kv_seq", None, None))
        v = shard_act(v, ("batch", "kv_seq", None, None))
    scale = hd ** -0.5
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if seq_parallel:
        logits = shard_act(logits, ("batch", None, None, "kv_seq"))
    mask = None
    if causal:
        qp = q_pos if q_pos is not None else jnp.arange(S)
        mask = qp[:, None] >= jnp.arange(T)[None, :]  # [S, T]
    if kv_valid is not None:
        valid = kv_valid[None, :]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        logits = jnp.where(mask[None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out


def gqa_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    causal: bool = True,
):
    """Self attention. With ``cache`` (decode): writes this step's K/V at
    ``pos`` and attends over slots <= pos. Returns (out, new_cache)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        ck = shard_act(ck, ("batch", "kv_seq", "kv_heads", None))
        cv = shard_act(cv, ("batch", "kv_seq", "kv_heads", None))
        new_cache = {"k": ck, "v": cv}
        # absolute positions of the S query tokens; causal mask over the buffer
        q_pos = pos + jnp.arange(S)
        # seq-parallel attention only for single-token decode; multi-token
        # prefill into a cache keeps the heads-sharded compute layout
        out = _gqa_core(q, ck.astype(x.dtype), cv.astype(x.dtype), causal=True,
                        q_pos=q_pos, seq_parallel=(S == 1))
    else:
        out = _gqa_core(q, k, v, causal=causal)
    out = shard_act(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard_act(y, ("batch", "seq", "d_model")), new_cache


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    k, hd = cfg.n_kv_heads, cfg.hd()
    return {
        "k": jax.ShapeDtypeStruct((batch, seq_len, k, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, seq_len, k, hd), dtype),
    }


def gqa_cache_axes():
    return {
        "k": ("batch", "kv_seq", "kv_heads", None),
        "v": ("batch", "kv_seq", "kv_heads", None),
    }


# ----------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache, decoupled RoPE key, absorbed decode
# ----------------------------------------------------------------------------


def init_mla(pf: ParamFactory, cfg: ModelConfig) -> None:
    d, h = cfg.d_model, cfg.n_heads
    L, nope, rope_d, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pf.param("wq", (d, h, nope + rope_d), ("d_model", "heads", "head_dim"))
    pf.param("w_dkv", (d, L), ("d_model", "lora"))
    pf.param("kv_norm", (L,), ("lora",), init="ones")
    pf.param("w_uk", (L, h, nope), ("lora", "heads", "head_dim"))
    pf.param("w_uv", (L, h, vd), ("lora", "heads", "head_dim"))
    pf.param("w_kpe", (d, rope_d), ("d_model", "head_dim"))
    pf.param("wo", (h, vd, d), ("heads", "head_dim", "d_model"))


def mla_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    causal: bool = True,
):
    B, S, _ = x.shape
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    scale = (nope + rope_d) ** -0.5
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    c = rms_norm(jnp.einsum("bsd,dl->bsl", x, p["w_dkv"].astype(x.dtype)),
                 p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(jnp.einsum("bsd,dr->bsr", x, p["w_kpe"].astype(x.dtype)),
                      positions, cfg.rope_theta, has_heads=False)

    if cache is not None:
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c"], c.astype(cache["c"].dtype), pos, axis=1)
        cpe = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), pos, axis=1)
        cc = shard_act(cc, ("batch", "kv_seq", "lora"))
        new_cache = {"c": cc, "k_pe": cpe}
        T = cc.shape[1]
        q_pos = pos + jnp.arange(S)
        mask = (q_pos[:, None] >= jnp.arange(T)[None, :])[None, None, :, :]
        # Absorbed attention: never materialize per-head K/V at full length.
        q_abs = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"].astype(x.dtype))
        if S == 1:
            # seq-parallel decode: replicate the tiny absorbed q, keep the
            # compressed cache kv_seq-sharded (perf iteration #2)
            q_abs = shard_act(q_abs, ("batch", None, None, None))
            q_pe = shard_act(q_pe, ("batch", None, None, None))
        logits = (jnp.einsum("bshl,btl->bhst", q_abs.astype(jnp.float32), cc.astype(jnp.float32))
                  + jnp.einsum("bshr,btr->bhst", q_pe.astype(jnp.float32), cpe.astype(jnp.float32))) * scale
        if S == 1:
            logits = shard_act(logits, ("batch", None, None, "kv_seq"))
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhst,btl->bshl", probs, cc.astype(x.dtype))
        out = jnp.einsum("bshl,lhv->bshv", ctx, p["w_uv"].astype(x.dtype))
    else:
        new_cache = None
        k_nope = jnp.einsum("bsl,lhn->bshn", c, p["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsl,lhv->bshv", c, p["w_uv"].astype(x.dtype))
        k_nope = shard_act(k_nope, ("batch", "seq", "heads", None))
        v = shard_act(v, ("batch", "seq", "heads", None))
        logits = (jnp.einsum("bshn,bthn->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
                  + jnp.einsum("bshr,btr->bhst", q_pe.astype(jnp.float32), k_pe.astype(jnp.float32))) * scale
        if causal:
            mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
            logits = jnp.where(mask[None, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthv->bshv", probs, v)
    out = shard_act(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))
    return shard_act(y, ("batch", "seq", "d_model")), new_cache


def mla_cache_shape(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    return {
        "c": jax.ShapeDtypeStruct((batch, seq_len, cfg.kv_lora_rank), dtype),
        "k_pe": jax.ShapeDtypeStruct((batch, seq_len, cfg.qk_rope_dim), dtype),
    }


def mla_cache_axes():
    return {"c": ("batch", "kv_seq", "lora"), "k_pe": ("batch", "kv_seq", None)}


# ----------------------------------------------------------------------------
# Cross-attention (vision / encoder-decoder)
# ----------------------------------------------------------------------------


def init_cross(pf: ParamFactory, cfg: ModelConfig, *, gated: bool = False) -> None:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    pf.param("wq", (d, h, hd), ("d_model", "heads", "head_dim"))
    pf.param("wk", (d, k, hd), ("d_model", "kv_heads", "head_dim"))
    pf.param("wv", (d, k, hd), ("d_model", "kv_heads", "head_dim"))
    pf.param("wo", (h, hd, d), ("heads", "head_dim", "d_model"))
    if gated:
        pf.param("gate", (), (), init="zeros")


def cross_kv(p: dict, memory: jax.Array):
    """Precompute K/V over the memory (image patches / encoder states)."""
    k = jnp.einsum("bmd,dhk->bmhk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bmd,dhk->bmhk", memory, p["wv"].astype(memory.dtype))
    return {"k": k, "v": v}


def cross_forward(p: dict, x: jax.Array, kv: dict, *, gated: bool = False):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    out = _gqa_core(q, kv["k"].astype(x.dtype), kv["v"].astype(x.dtype), causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if gated:
        y = y * jnp.tanh(p["gate"].astype(y.dtype))
    return shard_act(y, ("batch", "seq", "d_model"))
