"""Coordinated multi-plane snapshots (DESIGN.md §14).

One snapshot captures *every* RNG stream and every piece of mutable
engine state at a round-close boundary, so ``build_engine`` +
``install_snapshot`` reconstructs a runtime whose subsequent execution
is bit-identical to the uncrashed run:

  * the database (``Database.save``: fleet columns / client records,
    results, blobs, quarantine state, round counter, global-model keys);
  * the global model parameters (``save_pytree`` — *not*
    ``put_global_model``, which would mutate the database);
  * the update store: capacity, the exact LIFO free-list order (future
    ``alloc`` calls must pop the same ids), and the live rows — both
    pending-result rows and rows still owned by in-flight payloads
    (which ``FLRuntime.checkpoint`` does not persist);
  * platform state (warm/busy instance clocks, the legacy-noise PCG64
    position, the fault model's RNG, the full invocation log);
  * the in-flight registry in dict-insertion order with each
    invocation's loop-event sequence number (completion events are
    re-scheduled in that order on restore so heap tie-breaks are
    preserved), plus refcounted payloads and un-landed blob payloads;
  * the scheduler extras: the timer heap (tags re-bound to restored
    ``Inflight`` objects; retry tags reconstructed), the timer sequence
    cursor, per-round flags, and event counters;
  * every policy/strategy RNG and adaptation state via their
    ``state_dict``/``load_state`` protocol (selection RNG, adapter
    phase, adaptive CR history, recovery attempts/budget/jitter RNG);
  * trainer PRNG key, SCAFFOLD variates, traffic cursor, accumulated
    metrics counters, history, and the simulated clock.

Atomicity: files land in the final ``snap_<seq>`` directory, but the
manifest — with per-file size + CRC32 — is written last (tmp +
``os.replace``). A directory without a valid manifest, or whose files
fail their CRCs, is ignored by ``find_latest_snapshot``; resume then
falls back to the next older snapshot or to genesis.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_pytree, save_pytree
from repro.core.database import Database, _flatten, _treedef, _unflatten
from repro.core.services import Inflight, _Payload
from repro.core.update_store import UpdateStore
from repro.faas.hardware import HardwareProfile

SNAP_PREFIX = "snap_"
MANIFEST = "MANIFEST.json"
SNAPSHOT_VERSION = 1


def _rng_state(rng: np.random.Generator) -> dict:
    return rng.bit_generator.state


def _set_rng_state(rng: np.random.Generator, state: dict) -> None:
    rng.bit_generator.state = state


def _profile_tuple(p: HardwareProfile) -> list:
    return [p.name, p.speed, p.vcpus, p.mem_gib, p.is_gpu, p.gpu_fraction,
            p.variability]


def _profile_from(t) -> HardwareProfile:
    name, speed, vcpus, mem, is_gpu, gfrac, var = t
    return HardwareProfile(name, speed=speed, vcpus=vcpus, mem_gib=mem,
                           is_gpu=bool(is_gpu), gpu_fraction=gfrac,
                           variability=var)


# ----------------------------------------------------------------- capture

def capture_state(rt) -> Tuple[dict, dict]:
    """The JSON-serializable runtime state plus a dict of numpy arrays
    (blob-plane in-flight payloads) destined for ``inflight_blobs.npz``."""
    state: dict = {"version": SNAPSHOT_VERSION, "engine": rt.engine_name}
    state["now"] = rt.loop.now
    state["t0"] = getattr(rt, "_t0", 0.0)
    state["acc"] = getattr(rt, "_acc", 0.0)
    state["history"] = [dataclasses.asdict(l) for l in rt.history]
    state["completed"] = sorted(rt._completed_this_round)
    state["counters"] = {
        "n_hedges": rt.n_hedges, "n_hedge_wins": rt.n_hedge_wins,
        "n_cancelled": rt.n_cancelled, "n_retries": rt.n_retries,
        "n_timeouts": rt.n_timeouts, "n_quarantined": rt.n_quarantined,
        "retry_latency_s": rt.retry_latency_s,
        "update_host_bytes": rt.update_host_bytes,
        "data_h2d_bytes": rt.trainer.data_h2d_bytes,
        "n_traffic_joins": rt.n_traffic_joins,
        "n_traffic_leaves": rt.n_traffic_leaves,
    }
    state["traffic_pos"] = rt._traffic_pos
    state["platform"] = rt.platform.state_dict()
    state["trainer_key"] = np.asarray(rt.trainer._key).tolist()
    state["c_cap"] = rt._c_cap

    # hardware universe: fleet order + id->position map; profiles of
    # removed clients survive only in _hw_history (metrics need them)
    state["fleet"] = [_profile_tuple(p) for p in rt.fleet]
    state["fleet_pos"] = [[cid, pos] for cid, pos in rt._fleet_pos.items()]
    state["hw_extra"] = [[cid, _profile_tuple(p)]
                         for cid, p in rt._hw_history.items()
                         if cid not in rt._fleet_pos]

    # in-flight registry: dict/list order is behavioural (DatabaseView
    # iteration, hedge-sort stability), so serialize it verbatim; the
    # loop-event seq per invocation orders the re-scheduled completions
    rec_index = {id(r): i for i, r in enumerate(rt.platform.invocations)}
    payload_ids: dict = {}
    payloads: List[dict] = []
    blob_arrays: dict = {}
    inflight_ser: List[list] = []
    inv_gidx: dict = {}
    for cid, invs in rt.inflight.items():
        entries = []
        for inv in invs:
            pid = payload_ids.get(id(inv.payload))
            if pid is None:
                pid = len(payloads)
                payload_ids[id(inv.payload)] = pid
                pay = inv.payload
                payloads.append({"row": pay.row, "refs": pay.refs,
                                 "landed": pay.landed,
                                 "has_blob": pay.blob is not None})
                if pay.blob is not None:
                    leaves, _ = _flatten(pay.blob)
                    for i, leaf in enumerate(leaves):
                        blob_arrays[f"p{pid}|{i}"] = np.asarray(leaf)
                    blob_arrays[f"p{pid}|treedef"] = np.array(
                        json.dumps(_treedef(pay.blob)))
            inv_gidx[id(inv)] = len(inv_gidx)
            entries.append({
                "client_id": inv.client_id, "round": inv.round,
                "steps": inv.steps, "t_invoked": inv.t_invoked,
                "rec": rec_index[id(inv.rec)], "payload": pid,
                "n_samples": inv.n_samples, "loss": inv.loss,
                "is_hedge": inv.is_hedge, "eseq": inv.event.seq})
        inflight_ser.append([cid, entries])
    state["payloads"] = payloads
    state["inflight"] = inflight_ser

    # update store: live rows = pending-result rows + in-flight payload
    # rows (the latter are invisible to the database)
    if rt.store is not None:
        ids: List[int] = []
        seen = set()
        for r in rt.db.results:
            if not r.aggregated and r.update_row >= 0:
                if r.update_row not in seen:
                    seen.add(r.update_row)
                    ids.append(int(r.update_row))
        for p in payloads:
            if p["row"] >= 0 and not p["landed"] and p["row"] not in seen:
                seen.add(p["row"])
                ids.append(int(p["row"]))
        state["store"] = {"capacity": rt.store.capacity,
                          "free": [int(i) for i in rt.store._free],
                          "ids": ids}
    else:
        state["store"] = None

    # policy / strategy state (RNG positions, adapter phase, CR history,
    # recovery attempts) via the state_dict protocol
    if hasattr(rt, "policy"):
        state["policy"] = rt.policy.state_dict()
    else:
        state["policy"] = {"strategy": rt.strategy.state_dict()}

    # scheduler extras: timer heap + cursors. Stale timers (closed round
    # or settled invocation) are dropped here — identical to the lazy
    # purge ``_peek_timer`` would apply before ever firing them.
    if hasattr(rt, "_timers"):
        timers = []
        max_seq = -1
        for (t, seq, round_, tag) in rt._timers:
            max_seq = max(max_seq, seq)
            if round_ < rt.db.round and not _runtime_round(round_):
                continue
            if isinstance(tag, Inflight):
                if tag.done:
                    continue
                ser_tag = {"kind": "inflight", "v": inv_gidx[id(tag)]}
            elif isinstance(tag, str):
                ser_tag = {"kind": "str", "v": tag}
            else:   # _RetryTag
                ser_tag = {"kind": "retry", "client_id": tag.client_id,
                           "t_failed": tag.t_failed}
            timers.append({"t": t, "seq": seq, "round": round_,
                           "tag": ser_tag})
        state["scheduler"] = {
            "timers": timers, "next_timer_seq": max_seq + 1,
            "invoked_this_round": rt._invoked_this_round,
            "n_events": rt.n_events, "n_coalesced": rt.n_coalesced,
            "megastep_rounds": rt.megastep_rounds,
            "megastep_scans": rt.megastep_scans,
            "megastep_fallback_reason": rt.megastep_fallback_reason}
    else:
        state["scheduler"] = None
    return state, blob_arrays


def _runtime_round(round_: int) -> bool:
    return round_ >= (1 << 62)


# ------------------------------------------------------------------ write

def snapshot_dir(root: str, seq: int) -> str:
    return os.path.join(root, f"{SNAP_PREFIX}{seq:010d}")


def write_snapshot(rt, root: str, seq: int, *, keep: int = 2) -> bool:
    """Write the coordinated snapshot for journal seq ``seq``. Returns
    False (untouched) if a manifest already exists for it — a resumed
    run re-reaches the same boundary idempotently."""
    d = snapshot_dir(root, seq)
    if os.path.exists(os.path.join(d, MANIFEST)):
        return False
    os.makedirs(d, exist_ok=True)

    rt.db.meta["update_plane"] = rt.update_plane
    rt.db.save(os.path.join(d, "db"))
    save_pytree(jax.tree.map(np.asarray, rt.params),
                os.path.join(d, "params"))
    state, blob_arrays = capture_state(rt)
    if rt.c_global is not None:
        save_pytree(jax.tree.map(np.asarray,
                                 {"c_global": rt.c_global, "c_buf": rt.c_buf}),
                    os.path.join(d, "scaffold"))
        state["has_scaffold"] = True
    else:
        state["has_scaffold"] = False
    if blob_arrays:
        with open(os.path.join(d, "inflight_blobs.npz"), "wb") as f:
            np.savez(f, **blob_arrays)
    if state["store"] is not None and state["store"]["ids"]:
        rows = np.asarray(rt.store.gather(state["store"]["ids"]))
        with open(os.path.join(d, "rows.npz"), "wb") as f:
            np.savez(f, rows=rows, n_params=np.int64(rt.spec.n_params))
    with open(os.path.join(d, "runtime.json"), "w") as f:
        json.dump(state, f)

    # manifest last: its presence is the commit point
    files = {}
    for dirpath, _, names in os.walk(d):
        for name in names:
            if name == MANIFEST:
                continue
            full = os.path.join(dirpath, name)
            rel = os.path.relpath(full, d)
            with open(full, "rb") as f:
                data = f.read()
            files[rel] = {"crc": zlib.crc32(data), "size": len(data)}
    manifest = {"version": SNAPSHOT_VERSION, "seq": seq,
                "round": rt.db.round, "engine": rt.engine_name,
                "files": files}
    tmp = os.path.join(d, ".manifest.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(d, MANIFEST))
    _gc_snapshots(root, keep)
    return True


def _gc_snapshots(root: str, keep: int) -> None:
    seqs = list_snapshots(root)
    for seq in seqs[:-keep] if keep else seqs:
        shutil.rmtree(snapshot_dir(root, seq), ignore_errors=True)


def list_snapshots(root: str) -> List[int]:
    out = []
    for name in os.listdir(root):
        if name.startswith(SNAP_PREFIX):
            try:
                out.append(int(name[len(SNAP_PREFIX):]))
            except ValueError:
                continue
    return sorted(out)


# ------------------------------------------------------------------- read

@dataclass
class SnapshotRef:
    seq: int
    path: str


def validate_snapshot(path: str) -> bool:
    """Manifest present and every file matches its recorded size+CRC."""
    mpath = os.path.join(path, MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for rel, info in manifest["files"].items():
            full = os.path.join(path, rel)
            with open(full, "rb") as f:
                data = f.read()
            if len(data) != info["size"] or zlib.crc32(data) != info["crc"]:
                return False
        return True
    except (OSError, ValueError, KeyError):
        return False


def find_latest_snapshot(root: str, *, max_seq: Optional[int] = None
                         ) -> Optional[SnapshotRef]:
    """Newest *valid* snapshot with seq <= max_seq. A snapshot whose
    journal record is itself past the consistent prefix is unusable:
    the journal is written first, so such a snapshot implies the prefix
    was torn — fall back past it."""
    for seq in reversed(list_snapshots(root)):
        if max_seq is not None and seq > max_seq:
            continue
        d = snapshot_dir(root, seq)
        if validate_snapshot(d):
            return SnapshotRef(seq=seq, path=d)
    return None


def load_snapshot(path: str) -> Tuple[dict, Database, Any]:
    """(runtime state, database, global params) from a validated
    snapshot directory."""
    with open(os.path.join(path, "runtime.json")) as f:
        state = json.load(f)
    db = Database.load(os.path.join(path, "db"))
    params = jax.tree.map(jnp.asarray, restore_pytree(os.path.join(path, "params")))
    return state, db, params


# ---------------------------------------------------------------- install

def install_snapshot(rt, state: dict, path: str) -> None:
    """Overwrite a freshly built engine's live state with the snapshot.
    The engine was constructed with the snapshot's database and params
    already (``build_engine(..., db=..., init_params=...)``); this
    restores everything the constructor derives freshly."""
    if state["engine"] != rt.engine_name:
        raise ValueError(
            f"snapshot was written by engine {state['engine']!r} but the "
            f"resume is configured for {rt.engine_name!r}")
    rt.loop.now = state["now"]
    rt._t0 = state["t0"]
    rt._acc = state["acc"]
    from repro.core.services import RoundLog
    rt.history = [RoundLog(**d) for d in state["history"]]
    rt._completed_this_round = set(int(c) for c in state["completed"])
    c = state["counters"]
    rt.n_hedges = c["n_hedges"]
    rt.n_hedge_wins = c["n_hedge_wins"]
    rt.n_cancelled = c["n_cancelled"]
    rt.n_retries = c["n_retries"]
    rt.n_timeouts = c["n_timeouts"]
    rt.n_quarantined = c["n_quarantined"]
    rt.retry_latency_s = c["retry_latency_s"]
    rt.update_host_bytes = c["update_host_bytes"]
    rt.trainer.data_h2d_bytes = c["data_h2d_bytes"]
    rt.n_traffic_joins = c["n_traffic_joins"]
    rt.n_traffic_leaves = c["n_traffic_leaves"]
    rt._traffic_pos = int(state["traffic_pos"])
    rt.platform.load_state(state["platform"])
    rt.trainer._key = jnp.asarray(np.asarray(state["trainer_key"], np.uint32))

    fleet = [_profile_from(t) for t in state["fleet"]]
    rt.fleet = fleet
    rt._fleet_pos = {int(cid): int(pos) for cid, pos in state["fleet_pos"]}
    rt.hw = {cid: fleet[pos] for cid, pos in rt._fleet_pos.items()}
    rt._hw_history = dict(rt.hw)
    for cid, t in state["hw_extra"]:
        rt._hw_history[int(cid)] = _profile_from(t)

    if state["has_scaffold"]:
        sc = restore_pytree(os.path.join(path, "scaffold"))
        rt.c_global = jax.tree.map(jnp.asarray, sc["c_global"])
        rt.c_buf = jax.tree.map(jnp.asarray, sc["c_buf"])
        rt._c_cap = int(state["c_cap"])

    # update store: exact capacity and free-list order so future allocs
    # pop the same ids the uncrashed run would
    st = state["store"]
    if st is not None:
        store = UpdateStore(rt.spec.n_params, capacity=st["capacity"])
        if store.capacity != st["capacity"]:
            raise ValueError("update-store capacity mismatch on restore")
        ids = [int(i) for i in st["ids"]]
        if ids:
            with np.load(os.path.join(path, "rows.npz")) as data:
                rows = data["rows"]
            store.write_at(ids, rows)
        store._free = [int(i) for i in st["free"]]
        store._live = set(ids)
        rt.store = store

    # in-flight registry + payloads; completions re-scheduled in saved
    # event-seq order so loop tie-breaks replay identically
    blob_payloads: dict = {}
    bpath = os.path.join(path, "inflight_blobs.npz")
    if os.path.exists(bpath):
        data = np.load(bpath, allow_pickle=False)
        groups: dict = {}
        for name in data.files:
            key, idx = name.rsplit("|", 1)
            groups.setdefault(key, {})[idx] = data[name]
        for key, parts in groups.items():
            tdef = json.loads(str(parts.pop("treedef")))
            leaves = [parts[str(i)] for i in range(len(parts))]
            blob_payloads[int(key[1:])] = _unflatten(tdef, leaves)
    payload_objs = []
    for pid, p in enumerate(state["payloads"]):
        payload_objs.append(_Payload(row=int(p["row"]), refs=int(p["refs"]),
                                     landed=bool(p["landed"]),
                                     blob=blob_payloads.get(pid)))
    rt.inflight = {}
    ordered: List[Tuple[int, Inflight]] = []
    flat_invs: List[Inflight] = []
    for cid, entries in state["inflight"]:
        lst = []
        for e in entries:
            inv = Inflight(
                client_id=int(e["client_id"]), round=int(e["round"]),
                steps=e["steps"], t_invoked=e["t_invoked"],
                rec=rt.platform.invocations[int(e["rec"])],
                payload=payload_objs[int(e["payload"])],
                n_samples=int(e["n_samples"]), loss=e["loss"],
                is_hedge=bool(e["is_hedge"]))
            lst.append(inv)
            ordered.append((int(e["eseq"]), inv))
            flat_invs.append(inv)
        rt.inflight[int(cid)] = lst
    for _, inv in sorted(ordered, key=lambda p: p[0]):
        inv.event = rt.loop.schedule(
            inv.rec.t_completed - rt.loop.now,
            (lambda inv=inv: rt._complete(inv)))

    # policy / strategy
    if hasattr(rt, "policy"):
        rt.policy.load_state(state["policy"])
    else:
        rt.strategy.load_state(state["policy"]["strategy"])

    # scheduler timer heap + cursors
    sch = state["scheduler"]
    if sch is not None:
        import heapq
        import itertools
        from repro.core.scheduler import _RetryTag
        timers = []
        for tm in sch["timers"]:
            tag = tm["tag"]
            if tag["kind"] == "inflight":
                obj = flat_invs[int(tag["v"])]
            elif tag["kind"] == "str":
                obj = tag["v"]
            else:
                obj = _RetryTag(int(tag["client_id"]), tag["t_failed"])
            timers.append((tm["t"], int(tm["seq"]), int(tm["round"]), obj))
        heapq.heapify(timers)
        rt._timers = timers
        rt._timer_seq = itertools.count(int(sch["next_timer_seq"]))
        rt._invoked_this_round = bool(sch["invoked_this_round"])
        rt.n_events = int(sch["n_events"])
        rt.n_coalesced = int(sch["n_coalesced"])
        rt.megastep_rounds = int(sch["megastep_rounds"])
        rt.megastep_scans = int(sch["megastep_scans"])
        rt.megastep_fallback_reason = sch["megastep_fallback_reason"]
